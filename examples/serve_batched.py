"""Batched serving example: greedy generation with KV caches on a reduced
gemma-2b (MQA) config.

  PYTHONPATH=src python examples/serve_batched.py
"""

import sys
import time
sys.path.insert(0, "src")

import jax

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import ServeEngine


def main() -> None:
    cfg = get_config("gemma-2b").reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_seq=128, batch=4)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0,
                                 cfg.vocab_size)
    t0 = time.perf_counter()
    out = engine.generate(prompts, 24)
    dt = time.perf_counter() - t0
    toks = engine.stats.prefill_tokens + engine.stats.decode_tokens
    print(f"batch=4 prompt=12 new=24 -> {out.shape} in {dt:.2f}s "
          f"({toks / dt:.0f} tok/s)")
    for row in out[:2]:
        print(" ", row.tolist()[:20], "...")


if __name__ == "__main__":
    main()
