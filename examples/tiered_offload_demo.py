"""Real tier moves: the Unimem mover relocating actual JAX arrays between
memory kinds (``device`` <-> ``pinned_host``) with async device_put — the
production HBM/host path, exercised on the CPU backend (which exposes the
same memory-kind API).

v2 session API: arrays are registered pytree-natively (leaf byte spans
recorded), the loop is the ``iteration()``/``phase()`` context managers,
and the copy engine comes from the string-keyed backend registry —
``backend="jax_async"`` selects asynchronous device_put with per-leaf
fencing (tier flips when a copy *lands*, settled without blocking at phase
boundaries).

  PYTHONPATH=src python examples/tiered_offload_demo.py
"""

import sys
import time
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core import PAPER_DRAM_NVM, RuntimeConfig, UnimemRuntime

MB = 1024 ** 2


def main() -> None:
    dev = jax.devices()[0]
    kinds = [m.kind for m in dev.addressable_memories()]
    print("device:", dev, "memories:", kinds)
    # host tier = pinned_host where the backend offers it (TPU/GPU); on a
    # backend without it the moves are logical (tier bookkeeping only)
    host_kind = "pinned_host" if "pinned_host" in kinds else kinds[0]

    machine = PAPER_DRAM_NVM
    rt = UnimemRuntime(machine,
                       RuntimeConfig(fast_capacity_bytes=64 * MB,
                                     enable_partitioning=False,
                                     backend="jax_async"))

    # register real arrays as target data objects (all start on host tier)
    sharding = jax.sharding.SingleDeviceSharding(
        dev, memory_kind=host_kind)
    objs = {}
    for name, mbs in (("weights_hot", 24), ("kv_block", 24),
                      ("opt_state_cold", 48)):
        arr = jax.device_put(
            jnp.ones((mbs * MB // 4,), jnp.float32), sharding)
        objs[name] = rt.register(name, arr)

    # iteration 1 profiles; accesses favor the hot objects
    for it in range(4):
        with rt.iteration():
            with rt.phase("compute", elapsed=0.05,
                          accesses={"weights_hot": 4e5, "kv_block": 3e5}):
                time.sleep(0.01)
            with rt.phase("update", elapsed=0.02,
                          accesses={"opt_state_cold": 5e4}):
                pass
        for name, obj in objs.items():
            kind = (jax.tree_util.tree_leaves(obj.payload)[0]
                    .sharding.memory_kind)
            print(f"  iter {it}: {name:16s} tier={obj.tier:5s} "
                  f"memory_kind={kind}")
    print("stats:", rt.stats())


if __name__ == "__main__":
    main()
