"""Real tier moves: the Unimem mover relocating actual JAX arrays between
memory kinds (``device`` <-> ``pinned_host``) with async device_put — the
production HBM/host path, exercised on the CPU backend (which exposes the
same memory-kind API).

  PYTHONPATH=src python examples/tiered_offload_demo.py
"""

import sys
import time
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core import (JaxTierBackend, PAPER_DRAM_NVM, RuntimeConfig,
                        UnimemRuntime)

MB = 1024 ** 2


def main() -> None:
    dev = jax.devices()[0]
    print("device:", dev, "memories:",
          [m.kind for m in dev.addressable_memories()])

    machine = PAPER_DRAM_NVM
    rt = UnimemRuntime(machine,
                       RuntimeConfig(fast_capacity_bytes=64 * MB,
                                     enable_partitioning=False),
                       backend=JaxTierBackend(machine))

    # register real arrays as target data objects (all start on host tier)
    sharding = jax.sharding.SingleDeviceSharding(
        dev, memory_kind="pinned_host")
    objs = {}
    for name, mbs in (("weights_hot", 24), ("kv_block", 24),
                      ("opt_state_cold", 48)):
        arr = jax.device_put(
            jnp.ones((mbs * MB // 4,), jnp.float32), sharding)
        objs[name] = rt.alloc(name, payload=arr)
    rt.start_loop(["compute", "update"])

    # iteration 1 profiles; accesses favor the hot objects
    for it in range(4):
        rt.begin_iteration()
        rt.phase_begin(0)
        time.sleep(0.01)
        rt.phase_end(0, elapsed=0.05,
                     accesses={"weights_hot": 4e5, "kv_block": 3e5})
        rt.phase_begin(1)
        rt.phase_end(1, elapsed=0.02, accesses={"opt_state_cold": 5e4})
        rt.end_iteration()
        for name, obj in objs.items():
            kind = (jax.tree_util.tree_leaves(obj.payload)[0]
                    .sharding.memory_kind)
            print(f"  iter {it}: {name:16s} tier={obj.tier:5s} "
                  f"memory_kind={kind}")
    print("stats:", rt.stats())


if __name__ == "__main__":
    main()
