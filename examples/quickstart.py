"""Quickstart: the Unimem runtime managing a CG-like workload on simulated
DRAM+NVM, reproducing the paper's headline result in ~5 seconds — written
against the v2 session API: pytree-native ``register`` (here size-only
objects), no upfront phase list (phases auto-register as the simulator's
driver enters them), and the simulator supplying instrumentation through
its ``SimSource``.

  PYTHONPATH=src python examples/quickstart.py
"""

import sys
sys.path.insert(0, "src")

from repro.core import (PAPER_DRAM_NVM, RuntimeConfig, UnimemRuntime,
                        calibrate)
from repro.core.data_objects import ObjectRegistry
from repro.sim import NPB_WORKLOADS, SimulationEngine

MB = 1024 ** 2


def main() -> None:
    machine = PAPER_DRAM_NVM.scaled(bw_scale=0.5)    # NVM = 1/2 DRAM bw
    wl = NPB_WORKLOADS["cg"]()

    def static(tier):
        reg = ObjectRegistry()
        for n, s in wl.objects.items():
            reg.alloc(n, s, tier=tier)
        return SimulationEngine(machine, wl, registry=reg).run(10)

    dram = static("fast")
    nvm = static("slow")

    # unimem_init + unimem_malloc: register each target object (size or
    # pytree); static_refs feed the initial-placement compiler analysis
    rt = UnimemRuntime(machine, RuntimeConfig(fast_capacity_bytes=256 * MB),
                       cf=calibrate(machine))
    statics = wl.static_ref_counts()
    for n, s in wl.objects.items():
        rt.register(n, s, static_refs=statics.get(n))
    # the engine drives `with rt.iteration(): with rt.phase(name): ...`
    # itself; its SimSource supplies accesses/time_shares/access_bins
    uni = SimulationEngine(machine, wl, runtime=rt).run(12)

    d = dram.steady_iteration_time
    print(f"DRAM-only        : {d * 1e3:8.2f} ms/iter (1.00x)")
    print(f"NVM-only         : {nvm.steady_iteration_time * 1e3:8.2f} ms/iter"
          f" ({nvm.steady_iteration_time / d:.2f}x)")
    print(f"Unimem (256MB)   : {uni.steady_iteration_time * 1e3:8.2f} ms/iter"
          f" ({uni.steady_iteration_time / d:.2f}x)")
    print("runtime:", rt.stats())


if __name__ == "__main__":
    main()
