"""End-to-end training driver: a ~110M-parameter dense LM trained on the
synthetic pipeline with checkpointing and the Unimem runtime enabled.

Default profile is CPU-friendly (~25M params, 100 steps).  ``--full`` trains
the 110M model for 300 steps (the deliverable profile; takes a while on one
CPU core, runs unchanged on a TPU host).

  PYTHONPATH=src python examples/train_e2e.py
  PYTHONPATH=src python examples/train_e2e.py --full
"""

import argparse
import dataclasses
import sys
sys.path.insert(0, "src")

from repro.configs.base import ArchConfig
from repro.optim import AdamWConfig
from repro.train.loop import TrainConfig, train


def lm_config(full: bool) -> ArchConfig:
    if full:   # ~110M params
        return ArchConfig(name="lm-110m", family="dense", n_layers=12,
                          d_model=768, n_heads=12, n_kv_heads=4,
                          d_ff=2048, vocab_size=32000, tie_embeddings=True)
    return ArchConfig(name="lm-25m", family="dense", n_layers=8,
                      d_model=512, n_heads=8, n_kv_heads=4,
                      d_ff=1408, vocab_size=8192, tie_embeddings=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    cfg = lm_config(args.full)
    steps = args.steps or (300 if args.full else 100)
    tcfg = TrainConfig(steps=steps, global_batch=8, seq_len=128, lr=6e-4,
                       checkpoint_dir=args.ckpt, checkpoint_every=50,
                       log_every=10)
    print(f"training {cfg.name}: {cfg.n_params() / 1e6:.1f}M params, "
          f"{steps} steps")
    res = train(cfg, tcfg, AdamWConfig(lr=6e-4))
    print(f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f}; "
          f"checkpoints in {args.ckpt}")
    print("unimem:", res.runtime_stats)


if __name__ == "__main__":
    main()
