"""Multi-host tier management end to end: per-host shard managers, the
cluster coordinator's rebalance, and cross-host migration over modeled
interconnect links, on the ``moe_churn_multihost`` scenario.

Four virtual hosts each own four MoE expert shards plus a replicated
dense trunk and router.  After router churn collapses all traffic onto
host h0's experts, its hot shard exceeds DRAM capacity while the peers'
experts sit idle — host-local management can only shuffle h0's own
DRAM/NVM pair, so two surplus hot experts serve from NVM every
iteration.  The :class:`~repro.distributed.ClusterCoordinator` compares
local NVM->DRAM promotion against pulling each surplus shard to a peer
with spare capacity (priced per link via ``cross_host_cost``), executes
the pulls on the registered ``"cross_host"`` backend (send/recv channel
pairs, link shares apportioned by bytes demand), and re-homes the shards
— the steady cluster iteration time is the slowest host's, and the
rebalance flattens it.

  PYTHONPATH=src python examples/multihost_demo.py
"""

import sys
sys.path.insert(0, "src")

from repro.sim import ClusterSimulation, moe_churn_multihost

ITERS = 12


def main() -> None:
    machine, wl, links, knobs = moe_churn_multihost()
    sim = ClusterSimulation(machine, wl, links=links, **knobs)

    local = sim.run_local_only(ITERS)
    coord = sim.run_coordinated(ITERS)

    print(f"scenario: {wl.name} ({len(wl.hosts())} hosts, "
          f"{len(wl.objects)} expert shards + {len(wl.shared)} replicated)")
    print(f"link: {links.link('h0', 'h1').name} "
          f"{links.link('h0', 'h1').bandwidth / 1e9:.0f} GB/s x "
          f"{links.link('h0', 'h1').channel_pairs} send/recv pairs\n")

    print("coordinator rebalance:")
    for m in coord.migrations:
        print(f"  {m.obj:12s} {m.mode:13s} {m.src_host} -> {m.dst_host}  "
              f"cost {m.est_cost_s * 1e3:6.2f} ms   "
              f"benefit {m.est_benefit_s * 1e3:6.2f} ms/iter  "
              f"link {m.link or '-'}")
    print(f"  one-time migration wall time: {coord.migration_s * 1e3:.2f} ms\n")

    print(f"{'host':6s} {'local-only':>12s} {'coordinated':>12s} {'gain':>7s}")
    for h in wl.hosts():
        lo, co = local.steady_time(h), coord.steady_time(h)
        print(f"{h:6s} {lo * 1e3:10.2f}ms {co * 1e3:10.2f}ms {lo / co:6.2f}x")
    print(f"{'max':6s} {local.cluster_steady_time * 1e3:10.2f}ms "
          f"{coord.cluster_steady_time * 1e3:10.2f}ms "
          f"{local.cluster_steady_time / coord.cluster_steady_time:6.2f}x")

    prog = coord.program
    print(f"\nglobal plan: strategy={prog.strategy} "
          f"predicted={prog.predicted_iteration_time * 1e3:.2f}ms "
          f"(max over hosts), {len(prog.migrations)} migrations, "
          f"host sections: {', '.join(sorted(prog.host_sections))}")
    hot = local.cluster_steady_time / coord.cluster_steady_time
    assert hot >= 1.10, f"coordinator gain collapsed: {hot:.2f}x"


if __name__ == "__main__":
    main()
