"""Multi-tenant serving end to end: tenant namespaces, QoS-weighted
bandwidth partitioning, and admission control on the ``tenant_serving``
scenario (one whale, three mid tenants, one cold archive).

Each tenant is declared with its (priority, slo) contract via
``rt.tenant(name, ...)`` and registers its objects through the returned
handle — names land in the registry as ``tenant/object``, so attribution,
fault provenance, and the per-tenant p99 metric all key off the namespace.
The ``bandwidth_partition`` policy splits the fast tier and the copy
channels across tenants by QoS weight (priority/slo), demotes the cold
tenant to serve-from-slow, and solves placement per tenant inside its
share; the demo compares its per-tenant p99 slack against the aggregate
unimem solve.

  PYTHONPATH=src python examples/tenant_serving_demo.py
"""

import sys
sys.path.insert(0, "src")

from repro.core import PAPER_DRAM_NVM, RuntimeConfig, UnimemRuntime, calibrate
from repro.core.tenancy import per_tenant_p99
from repro.sim import SimulationEngine
from repro.sim.workloads import TENANT_SERVING_QOS, tenant_serving

MB = 1024 ** 2
ITERS = 16


def run(policy: str):
    machine = PAPER_DRAM_NVM.scaled(bw_scale=0.5, lat_scale=2.0)
    wl = tenant_serving()
    rt = UnimemRuntime(
        machine,
        RuntimeConfig(fast_capacity_bytes=192 * MB, copy_channels=7,
                      drift_threshold=10.0, policy=policy),
        cf=calibrate(machine))
    handles = {t: rt.tenant(t, priority=p, slo=s)
               for t, (p, s) in TENANT_SERVING_QOS.items()}
    statics = wl.static_ref_counts()
    for name, size in wl.objects.items():
        tenant, _, rest = name.partition("/")
        handles[tenant].register(rest, size, static_refs=statics.get(name))
    res = SimulationEngine(machine, wl, runtime=rt).run(ITERS)
    return res, rt, wl


def main() -> None:
    uni, _, wl = run("unimem")
    part, rt, _ = run("bandwidth_partition")
    names = [ph.name for ph in wl.phases]
    p_uni = per_tenant_p99(uni.phase_trace, names, TENANT_SERVING_QOS)
    p_bp = per_tenant_p99(part.phase_trace, names, TENANT_SERVING_QOS)

    shares = dict(getattr(rt.plan, "tenant_shares", {}) or {})
    channels = dict(getattr(rt.plan, "tenant_channels", {}) or {})
    admission = dict(getattr(rt.plan, "tenant_admission", {}) or {})
    print(f"{'tenant':8s} {'weight':>6s} {'share':>8s} {'chans':>6s} "
          f"{'p99 unimem':>11s} {'p99 part':>9s} {'gain':>6s}")
    for t, (prio, slo) in TENANT_SERVING_QOS.items():
        gain = p_uni[t] / p_bp[t]
        print(f"{t:8s} {prio / slo:6.2f} {shares.get(t, 0) / MB:6.0f}MB "
              f"{len(channels.get(t, [])):6d} {p_uni[t] * 1e3:9.1f}ms "
              f"{p_bp[t] * 1e3:7.1f}ms {gain:5.2f}x")
    for t, why in sorted(admission.items()):
        print(f"admission: {t!r} demoted to serve-from-slow ({why})")
    s = rt.stats()
    print(f"stats: n_tenants={s['n_tenants']} "
          f"n_admission_demotions={s['n_admission_demotions']} "
          f"strategy={s['strategy']}")


if __name__ == "__main__":
    main()
