from .engine import ServeEngine, build_decode_step

__all__ = ["ServeEngine", "build_decode_step"]
