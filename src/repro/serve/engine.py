"""Serving: batched greedy decoding with tiered KV caches.

``build_decode_step`` produces the jit-able one-token step the dry-run
lowers for ``decode_32k`` / ``long_500k``.  The engine below drives it for
real batches (prefill = scanned decode, which works uniformly across the
attention / hybrid / xlstm cache families) and integrates the Unimem
runtime: KV blocks are registered as target data objects so cold cache
blocks can live on the host tier.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import lm


def build_decode_step(cfg: ArchConfig, sample: str = "greedy") -> Callable:
    """Returns decode_step(params, cache, token, pos) ->
    (next_token, logits, cache)."""

    def decode_step(params, cache, token, pos):
        logits, cache = lm.decode_step(params, cfg, cache, token, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, logits, cache

    return decode_step


@dataclasses.dataclass
class ServeStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0


class ServeEngine:
    """Minimal batched serving loop (greedy) on the Runtime API v2.

    With a ``runtime`` (a v2 :class:`~repro.core.session.Session` /
    ``UnimemRuntime``), the engine is a serving *front-end*: params and the
    KV cache are registered as runtime data objects (sizes only — jit owns
    the buffers), every ``generate`` call is one runtime iteration, and
    prefill/decode run as instrumented phases, so the runtime profiles the
    cache traffic and plans tier placement across calls.  ``tenant`` scopes
    all of it to a tenant namespace (``rt.tenant(tenant, ...)``): object
    and phase names carry the ``tenant/`` prefix, so one runtime can host
    many engines — one per request stream — and the bandwidth-partition
    policy splits the fast tier between them by the (priority, slo)
    contract.  ``runtime=None`` keeps the plain jit loop, untouched."""

    def __init__(self, cfg: ArchConfig, params: Any, *, max_seq: int,
                 batch: int, runtime=None, tenant: Optional[str] = None,
                 priority: float = 1.0, slo: float = 1.0):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.batch = batch
        self.runtime = runtime
        self._ns = None       # registration namespace: tenant handle or rt
        self._registered = False
        if runtime is not None:
            self._ns = (runtime.tenant(tenant, priority=priority, slo=slo)
                        if tenant else runtime)
        self.step = jax.jit(build_decode_step(cfg))
        self.stats = ServeStats()

    # ------------------------------------------------------------------
    def _register(self, cache: Any) -> None:
        if self._ns is None or self._registered:
            return
        self._ns.register("params", self.params, manage_payload=False,
                          pinned=True)
        self._ns.register("kv_cache", cache, manage_payload=False,
                          chunkable=True)
        self._registered = True

    def _phase(self, name: str):
        return (contextlib.nullcontext() if self._ns is None
                else self._ns.phase(name))

    def generate(self, prompts: jax.Array, n_new: int) -> jax.Array:
        """prompts: (B, P) int32.  Returns (B, P + n_new)."""
        B, P = prompts.shape
        assert B == self.batch
        cache = lm.init_cache(self.cfg, B, self.max_seq)
        self._register(cache)
        with (self.runtime.iteration() if self.runtime is not None
              else contextlib.nullcontext()):
            tok = prompts[:, 0]
            out = [prompts]
            # prefill by scanned decode (uniform across cache families)
            with self._phase("prefill"):
                for i in range(P):
                    nxt, _, cache = self.step(self.params, cache,
                                              prompts[:, i], jnp.int32(i))
                    self.stats.prefill_tokens += B
            tok = nxt
            gen = []
            with self._phase("decode"):
                for j in range(n_new):
                    gen.append(tok[:, None])
                    nxt, _, cache = self.step(self.params, cache, tok,
                                              jnp.int32(P + j))
                    tok = nxt
                    self.stats.decode_tokens += B
            return jnp.concatenate(out + gen, axis=1)
