"""Serving: batched greedy decoding with tiered KV caches.

``build_decode_step`` produces the jit-able one-token step the dry-run
lowers for ``decode_32k`` / ``long_500k``.  The engine below drives it for
real batches (prefill = scanned decode, which works uniformly across the
attention / hybrid / xlstm cache families) and integrates the Unimem
runtime: KV blocks are registered as target data objects so cold cache
blocks can live on the host tier.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import lm


def build_decode_step(cfg: ArchConfig, sample: str = "greedy") -> Callable:
    """Returns decode_step(params, cache, token, pos) ->
    (next_token, logits, cache)."""

    def decode_step(params, cache, token, pos):
        logits, cache = lm.decode_step(params, cfg, cache, token, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, logits, cache

    return decode_step


@dataclasses.dataclass
class ServeStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0


class ServeEngine:
    """Minimal batched serving loop (greedy)."""

    def __init__(self, cfg: ArchConfig, params: Any, *, max_seq: int,
                 batch: int, runtime=None):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.batch = batch
        self.runtime = runtime
        self.step = jax.jit(build_decode_step(cfg))
        self.stats = ServeStats()

    def generate(self, prompts: jax.Array, n_new: int) -> jax.Array:
        """prompts: (B, P) int32.  Returns (B, P + n_new)."""
        B, P = prompts.shape
        assert B == self.batch
        cache = lm.init_cache(self.cfg, B, self.max_seq)
        tok = prompts[:, 0]
        out = [prompts]
        # prefill by scanned decode (uniform across cache families)
        for i in range(P):
            nxt, _, cache = self.step(self.params, cache, prompts[:, i],
                                      jnp.int32(i))
            self.stats.prefill_tokens += B
        tok = nxt
        gen = []
        for j in range(n_new):
            gen.append(tok[:, None])
            nxt, _, cache = self.step(self.params, cache, tok,
                                      jnp.int32(P + j))
            tok = nxt
            self.stats.decode_tokens += B
        return jnp.concatenate(out + gen, axis=1)
