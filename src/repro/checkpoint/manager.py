"""Checkpointing: atomic, async, elastic.

* **atomic** — writes go to ``step_N.tmp/`` and are renamed only after fsync;
  a crash mid-write never corrupts the latest checkpoint.
* **async** — a background thread serializes and writes device-fetched
  arrays; the training loop only blocks on the *previous* save (double
  buffering, the same proactive-overlap discipline as the Unimem mover).
* **elastic** — arrays are saved as full logical tensors with their
  PartitionSpec recorded; restore re-shards onto *any* mesh (different DP/TP
  extent), which is what lets a job resume after losing a slice of the
  fleet.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]) -> Any:
    root: Dict = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if not isinstance(node, dict):
            return node
        if node and all(k.isdigit() for k in node):
            return tuple(fix(node[str(i)]) for i in range(len(node)))
        return {k: fix(v) for k, v in node.items()}

    return fix(root)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, *, blocking: bool = False) -> None:
        """Snapshot ``state`` (pytree of arrays) at ``step``."""
        self.wait()                       # at most one save in flight
        flat = _flatten(state)
        # fetch to host now (cheap np views for CPU; device->host for TPU);
        # stored as raw bytes so ml_dtypes (bfloat16/fp8) round-trip
        host = {k: np.ascontiguousarray(np.asarray(v)).reshape(-1)
                .view(np.uint8)
                for k, v in flat.items() if hasattr(v, "shape")}
        meta = {"step": step,
                "leaves": {k: {"shape": list(np.asarray(v).shape),
                               "dtype": str(np.asarray(v).dtype)}
                           for k, v in flat.items() if hasattr(v, "shape")}}

        def work():
            try:
                tmp = os.path.join(self.directory, f"step_{step}.tmp")
                final = os.path.join(self.directory, f"step_{step}")
                os.makedirs(tmp, exist_ok=True)
                np.savez(os.path.join(tmp, "arrays.npz"),
                         **{k.replace("/", "__"): v for k, v in host.items()})
                with open(os.path.join(tmp, "meta.json"), "w") as f:
                    json.dump(meta, f)
                if os.path.isdir(final):          # re-save of same step
                    shutil.rmtree(final)
                os.replace(tmp, final)            # atomic publish
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(self.list_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def list_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, *,
                shardings: Any = None) -> Tuple[int, Any]:
        """Load a checkpoint; ``shardings`` (optional pytree of NamedSharding
        mirroring the state) re-shards onto the current mesh — elastic
        restore onto a different topology."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step}")
        data = np.load(os.path.join(path, "arrays.npz"))
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        flat_sh = _flatten(shardings) if shardings is not None else {}
        placed = {}
        for raw_key in data.files:
            k = raw_key.replace("__", "/")
            info = meta["leaves"][k]
            v = data[raw_key].view(np.dtype(info["dtype"])).reshape(
                info["shape"])
            sh = flat_sh.get(k)
            placed[k] = (jax.device_put(v, sh) if sh is not None
                         else jax.device_put(v))
        return step, _unflatten(placed)
