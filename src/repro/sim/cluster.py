"""N-virtual-host cluster simulation over the two-tier engine.

Extends the single-machine simulator (``sim/engine.py``) to a cluster of
N virtual hosts, each with its own DRAM/NVM pair, virtual clock, session
and registry — tier-1 speed, no hardware.  A :class:`ShardedWorkload`
describes the global job (movable shard objects with a home assignment,
plus per-host replicated ``shared`` objects like the dense trunk and the
router) and materializes each host's :class:`~.engine.SimWorkload` by
filtering phase touches to the objects the host holds; per-object
compute follows the object, so re-homing a hot expert moves both its
memory traffic and its FLOPs to the new host.

:class:`ClusterSimulation` then runs the cluster two ways:

* ``run_local_only`` — every host manages its own shard with the full
  PR 3-8 session pipeline, no coordination (the baseline the nightly
  gate measures against);
* ``run_coordinated`` — a short probe stage profiles each host, the
  :class:`~repro.distributed.ClusterCoordinator` plans a rebalance
  (local NVM->DRAM promotion vs. peer pull per surplus hot shard),
  migrations execute in virtual time on the registered ``"cross_host"``
  backend over the modeled interconnect links, and a steady stage re-runs
  the cluster under the new shard assignment.

Hosts run with *independent* virtual clocks, so the engine may execute
them in any order (sequentially, or interleaved iteration-by-iteration)
without changing any host's trace — per-host chaos RNG sub-streams
(:func:`~repro.core.faults.host_sub_seed`) keep fault injection
deterministic per host regardless of scheduling order (regression-tested
in ``tests/test_multihost.py``).

``moe_churn_multihost`` is the gated scenario: one host's expert shard
goes hot past its DRAM capacity after router churn while peers sit on
spare capacity; coordinator rebalance must beat host-local-only
management by >= 1.10x steady time on the hot host (nightly floor).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from ..core.perfmodel import (CalibrationConstants, InterconnectModel,
                              LinkSpec, calibrate)
from ..core.policy import PlanProgram
from ..core.runtime import UnimemRuntime
from ..core.session import RuntimeConfig
from ..core.tiers import PAPER_DRAM_NVM, MachineProfile
from ..distributed.coordinator import (ClusterCoordinator, HostTierManager,
                                       ShardMigration)
from .engine import (SimObjectAccess, SimPhaseSpec, SimResult, SimWorkload,
                     SimulationEngine)

MB = 1024 ** 2
LINE = 64


# ---------------------------------------------------------------------------
# sharded workload description
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ShardPhaseSpec:
    """A global phase template: base compute plus per-object touches whose
    compute contribution travels with the object when it is re-homed."""

    name: str
    base_compute_s: float
    touches: Dict[str, SimObjectAccess]
    obj_compute_s: Dict[str, float] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class ShardedWorkload:
    """The global job: movable shards with a home assignment plus per-host
    replicated objects (every host holds its own copy of each ``shared``
    object — they are never migration candidates)."""

    name: str
    phases: List[ShardPhaseSpec]
    objects: Dict[str, int]            # movable shard -> size bytes
    shared: Dict[str, int]             # replicated per host -> size bytes
    assignment: Dict[str, str]         # shard -> home host
    chunkable: Dict[str, bool] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        missing = sorted(set(self.objects) - set(self.assignment))
        if missing:
            raise ValueError(f"shards with no home host: {missing}")
        overlap = sorted(set(self.objects) & set(self.shared))
        if overlap:
            raise ValueError(f"objects both movable and shared: {overlap}")

    def hosts(self) -> List[str]:
        return sorted(set(self.assignment.values()))

    def host_workload(self, host: str,
                      assignment: Optional[Dict[str, str]] = None
                      ) -> SimWorkload:
        """This host's SimWorkload under ``assignment`` (default: the home
        assignment): its shards plus its replicas of the shared objects,
        phases filtered to present objects, per-object compute included
        for the objects the host actually holds."""
        asg = assignment if assignment is not None else self.assignment
        objs = {o: s for o, s in self.objects.items() if asg.get(o) == host}
        objs.update(self.shared)
        phases = []
        for ph in self.phases:
            touches = {o: a for o, a in ph.touches.items() if o in objs}
            compute = ph.base_compute_s + sum(
                c for o, c in ph.obj_compute_s.items() if o in objs)
            phases.append(SimPhaseSpec(ph.name, compute, touches))
        return SimWorkload(f"{self.name}@{host}", phases, objs,
                           {o: self.chunkable.get(o, False) for o in objs})


# ---------------------------------------------------------------------------
# cluster runner
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ClusterResult:
    """One cluster run: per-host simulation results plus (for coordinated
    runs) the migration record and the aggregated global plan."""

    host_results: Dict[str, SimResult]
    assignment: Dict[str, str]
    migrations: List[ShardMigration] = dataclasses.field(default_factory=list)
    migration_s: float = 0.0
    program: Optional[PlanProgram] = None
    probe_results: Dict[str, SimResult] = dataclasses.field(
        default_factory=dict)

    def steady_time(self, host: str) -> float:
        return self.host_results[host].steady_iteration_time

    @property
    def cluster_steady_time(self) -> float:
        """Cluster iteration time = the slowest host (hosts run in
        parallel on independent clocks)."""
        return max(r.steady_iteration_time
                   for r in self.host_results.values())


class ClusterSimulation:
    """Two-stage cluster runner over per-host sessions (module docstring).

    Each host's session is constructed exactly as the single-machine
    harness builds one (same ``RuntimeConfig`` knobs, same registration
    order) plus the ``host=`` provenance tag — a one-host cluster is
    therefore bit-identical to the unclustered path (golden-pinned)."""

    def __init__(self, machine: MachineProfile, workload: ShardedWorkload,
                 links: Optional[InterconnectModel] = None,
                 fast_capacity_bytes: Optional[int] = None,
                 config: Optional[RuntimeConfig] = None,
                 cf: Optional[CalibrationConstants] = None,
                 mover: str = "slack", amortize_iters: float = 5.0,
                 min_heat_s: float = 0.0, **config_kw):
        self.machine = machine
        self.workload = workload
        self.links = links or InterconnectModel()
        self.cf = cf or calibrate(machine)
        self.amortize_iters = amortize_iters
        self.min_heat_s = min_heat_s
        if config is not None:
            if mover != "slack" or config_kw or fast_capacity_bytes is not None:
                raise ValueError("pass knobs either via config= or as "
                                 "keyword arguments, not both")
            self._config = config
        else:
            self._config = RuntimeConfig(
                fast_capacity_bytes=fast_capacity_bytes, mover=mover,
                **config_kw)

    # ------------------------------------------------------------------
    def _build(self, assignment: Dict[str, str]
               ) -> Tuple[ClusterCoordinator, Dict[str, SimulationEngine]]:
        """One manager + engine per host, mirroring the single-machine
        harness construction object-for-object."""
        managers: List[HostTierManager] = []
        engines: Dict[str, SimulationEngine] = {}
        for host in self.workload.hosts():
            cfg = dataclasses.replace(self._config, host=host)
            rt = UnimemRuntime(self.machine, cfg, cf=self.cf)
            wl = self.workload.host_workload(host, assignment)
            statics = wl.static_ref_counts()
            for n, s in wl.objects.items():
                rt.register(n, s, chunkable=wl.chunkable.get(n, False),
                            static_refs=statics.get(n))
            managers.append(HostTierManager(host, self.machine, session=rt))
            engines[host] = SimulationEngine(self.machine, wl, runtime=rt)
        coord = ClusterCoordinator(managers, self.links,
                                   amortize_iters=self.amortize_iters,
                                   min_heat_s=self.min_heat_s)
        return coord, engines

    @staticmethod
    def run_hosts(engines: Dict[str, SimulationEngine], n: int,
                  interleave: bool = False) -> Dict[str, SimResult]:
        """Run every host for ``n`` iterations.  Hosts have independent
        virtual clocks, so host-major and iteration-major (interleaved)
        scheduling must produce identical per-host results — the
        determinism property the chaos sub-seed test pins."""
        if not interleave:
            return {h: engines[h].run(n) for h in sorted(engines)}
        partial: Dict[str, List[SimResult]] = {h: [] for h in engines}
        for _ in range(n):
            for h in sorted(engines):
                partial[h].append(engines[h].run(1))
        out: Dict[str, SimResult] = {}
        for h, parts in partial.items():
            iter_times = [t for p in parts for t in p.iteration_times]
            # each run(1) restarts its local iteration counter; renumber
            # so the stitched trace matches a host-major run exactly
            trace = [dataclasses.replace(e, iteration=j)
                     for j, p in enumerate(parts) for e in p.phase_trace]
            out[h] = SimResult(iter_times, sum(iter_times),
                               parts[-1].stats, trace)
        return out

    # ------------------------------------------------------------------
    def run_local_only(self, n_iterations: int,
                       interleave: bool = False) -> ClusterResult:
        """Baseline: every host manages its shard alone, no coordinator."""
        _, engines = self._build(self.workload.assignment)
        results = self.run_hosts(engines, n_iterations, interleave)
        return ClusterResult(results, dict(self.workload.assignment))

    def run_coordinated(self, n_iterations: int, profile_iters: int = 4,
                        interleave: bool = False) -> ClusterResult:
        """Probe -> rebalance -> migrate (virtual time) -> steady stage
        under the new assignment."""
        coord, engines = self._build(self.workload.assignment)
        probe = self.run_hosts(engines, profile_iters, interleave)
        migrations = coord.plan_rebalance()
        clock = [max(e.clock for e in engines.values())]
        backend = coord.make_backend(now_fn=lambda: clock[0])
        migration_s, _ = coord.execute_migrations(
            migrations, backend, now=clock[0])
        assignment = dict(self.workload.assignment)
        for mig in migrations:
            if mig.mode == "cross_host":
                assignment[mig.obj] = mig.dst_host
        coord2, engines2 = self._build(assignment)
        results = self.run_hosts(engines2, n_iterations, interleave)
        return ClusterResult(results, assignment, migrations, migration_s,
                             coord2.aggregate_program(migrations), probe)


# ---------------------------------------------------------------------------
# gated scenario: MoE expert churn across hosts
# ---------------------------------------------------------------------------
def _acc(size_bytes: int, passes: float, stream: float) -> SimObjectAccess:
    return SimObjectAccess(accesses=passes * size_bytes / LINE,
                           stream_fraction=stream)


def moe_churn_multihost(n_hosts: int = 4, experts_per_host: int = 4,
                        expert_mb: int = 40, trunk_mb: int = 64,
                        router_mb: int = 4, hot_host: str = "h0",
                        hot_passes: float = 3.0):
    """MoE serving after router churn: every host owns ``experts_per_host``
    expert shards plus a replicated dense trunk and router, and the
    router's traffic has collapsed onto ``hot_host``'s experts — its whole
    shard is hot past DRAM capacity while peers' experts go idle, leaving
    them spare capacity.  The hot host can keep only part of its shard
    fast; the coordinator should pull the surplus hot experts to peers.

    Returns ``(machine, workload, links, knobs)`` where ``knobs`` are the
    :class:`ClusterSimulation` keyword arguments the scenario was tuned
    for (fast capacity below the hot shard's demand, >= one expert of
    spare per peer; link pricing that amortizes within a few iterations).
    """
    machine = PAPER_DRAM_NVM
    hosts = [f"h{i}" for i in range(n_hosts)]
    expert_b, trunk_b, router_b = (expert_mb * MB, trunk_mb * MB,
                                   router_mb * MB)
    objects: Dict[str, int] = {}
    assignment: Dict[str, str] = {}
    expert_touch: Dict[str, SimObjectAccess] = {}
    expert_compute: Dict[str, float] = {}
    for h in hosts:
        for k in range(experts_per_host):
            name = f"{h}/expert{k}"
            objects[name] = expert_b
            assignment[name] = h
            if h == hot_host:
                # all router traffic lands here after the churn
                expert_touch[name] = _acc(expert_b, hot_passes, 0.9)
                expert_compute[name] = 0.004
    shared = {"trunk": trunk_b, "router": router_b}
    phases = [
        ShardPhaseSpec("route", 0.002,
                       {"router": _acc(router_b, 2.0, 0.1),
                        "trunk": _acc(trunk_b, 1.5, 0.9)}),
        ShardPhaseSpec("experts", 0.002, dict(expert_touch),
                       obj_compute_s=dict(expert_compute)),
    ]
    wl = ShardedWorkload("moe_churn_multihost", phases, objects, shared,
                         assignment)
    links = InterconnectModel(
        default=LinkSpec("icl", bandwidth=3e9, latency=10e-6,
                         channel_pairs=2))
    knobs = dict(fast_capacity_bytes=120 * MB, amortize_iters=5.0,
                 min_heat_s=2e-3)
    return machine, wl, links, knobs
