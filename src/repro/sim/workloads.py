"""NPB-inspired phase workloads (paper §4, Table 3) and LM training traces.

These are phase/data-object traces whose structure mirrors the paper's
benchmarks: same target data objects (Table 3), same phase anatomy (compute
phases delimited by communication), CLASS-C-per-rank object sizes (4 ranks),
and the access-pattern mix that produced the paper's Observation 3 (e.g.
SP's ``in_buffer/out_buffer`` bandwidth-sensitive, ``lhs`` latency-sensitive,
``rhs`` both).  ``passes`` encodes cache filtering: only traffic that reaches
main memory counts (the paper's LLC-miss counters measure the same thing).

``lm_train_workload`` derives the same kind of trace from a transformer
training step (per-layer phases; weight/optimizer/activation objects) — the
production use of the runtime on TPU tiers.
"""

from __future__ import annotations

from typing import Dict, List

from ..core.faults import FaultSpec
from .engine import SimObjectAccess, SimPhaseSpec, SimWorkload

MB = 1024 ** 2
LINE = 64


def _acc(size_bytes: float, passes: float = 1.0, stream: float = 1.0,
         density: List[float] = None) -> SimObjectAccess:
    """Touch ``passes`` full main-memory sweeps over an object."""
    return SimObjectAccess(accesses=passes * size_bytes / LINE,
                           stream_fraction=stream, density=density)


def power_law_density(n_bins: int = 64, alpha: float = 1.2,
                      seed: int = None) -> List[float]:
    """Zipf-like access density over an object's byte range: bin ``i`` gets
    weight ``(i+1)^-alpha`` — the shape of power-law degree distributions
    (a few high-degree vertices absorb most gather traffic).

    ``seed`` permutes the bins: without an offline degree-sort of the vertex
    array (which a runtime system does not get to assume), the hot vertices
    are scattered across the address range — the case where only *measured*
    per-chunk attribution can find them."""
    import numpy as np
    w = np.array([(i + 1.0) ** -alpha for i in range(n_bins)])
    if seed is not None:
        w = w[np.random.default_rng(seed).permutation(n_bins)]
    return list(w)


# ---------------------------------------------------------------------------
def cg_like(scale: float = 1.0) -> SimWorkload:
    """Conjugate-gradient (paper Fig 1): SpMV + dot/axpy phases.

    CLASS-C/4-rank sizes: the whole target set (~170 MB) fits the 256 MB
    fast tier -> cross-phase global search recovers nearly all of the gap
    (paper Fig 11: >90% of CG's win comes from global search)."""
    s = scale
    objects = {
        "a": int(110 * MB * s), "colidx": int(55 * MB * s),
        "rowstr": int(1 * MB * s), "p": int(2 * MB * s),
        "q": int(2 * MB * s), "r": int(2 * MB * s),
        "z": int(2 * MB * s), "w": int(2 * MB * s), "x": int(2 * MB * s),
    }
    o = objects
    phases = [
        SimPhaseSpec("spmv_q=Ap", 0.020, {
            "a": _acc(o["a"], 1.0, 1.0),            # streamed matrix values
            "colidx": _acc(o["colidx"], 1.0, 1.0),
            "rowstr": _acc(o["rowstr"], 1.0, 1.0),
            # indirect x[colidx[j]] gathers: mostly LLC-resident at CLASS C,
            # the misses that escape are dependent loads (chase)
            "p": _acc(o["p"], 6.0, 0.0),
            "q": _acc(o["q"], 1.0, 1.0),
        }),
        SimPhaseSpec("comm_reduce_q", 0.004, {"q": _acc(o["q"], 1.0, 1.0)}),
        SimPhaseSpec("dot_pq", 0.002, {
            "p": _acc(o["p"], 1.0, 1.0), "q": _acc(o["q"], 1.0, 1.0)}),
        SimPhaseSpec("axpy_zr", 0.002, {
            "z": _acc(o["z"], 2.0, 1.0), "r": _acc(o["r"], 2.0, 1.0),
            "p": _acc(o["p"], 1.0, 1.0), "q": _acc(o["q"], 1.0, 1.0)}),
        SimPhaseSpec("norm_comm", 0.003, {"r": _acc(o["r"], 1.0, 1.0)}),
        SimPhaseSpec("update_px", 0.002, {
            "p": _acc(o["p"], 2.0, 1.0), "r": _acc(o["r"], 1.0, 1.0),
            "x": _acc(o["x"], 2.0, 1.0)}),
    ]
    return SimWorkload("cg", phases, objects)


def ft_like(scale: float = 1.0) -> SimWorkload:
    """3-D FFT: few huge streamed arrays (512 MB each per rank at CLASS
    C/4); none fits the fast tier whole -> the one workload where 1-D
    chunk partitioning pays off (paper Fig 11: 58% of FT's win)."""
    s = scale
    objects = {
        "u": int(8 * MB * s), "u0": int(512 * MB * s),
        "u1": int(512 * MB * s), "u2": int(512 * MB * s),
        "twiddle": int(64 * MB * s),
    }
    o = objects
    phases = [
        SimPhaseSpec("evolve", 0.090, {
            "u0": _acc(o["u0"], 0.5, 1.0), "u1": _acc(o["u1"], 0.5, 1.0),
            "twiddle": _acc(o["twiddle"], 1.0, 1.0)}),
        SimPhaseSpec("fft_z", 0.130, {
            # grid arrays are streamed, cache-blocked (0.5 main-memory
            # passes); the roots-of-unity table u is accessed dependently
            # -> latency-sensitive
            "u1": _acc(o["u1"], 0.5, 1.0), "u": _acc(o["u"], 4.0, 0.0)}),
        SimPhaseSpec("transpose_comm", 0.020, {
            "u1": _acc(o["u1"], 0.5, 1.0), "u2": _acc(o["u2"], 0.5, 1.0)}),
        SimPhaseSpec("fft_xy", 0.130, {
            "u2": _acc(o["u2"], 0.5, 1.0), "u": _acc(o["u"], 4.0, 0.0)}),
        SimPhaseSpec("checksum_comm", 0.005, {"u2": _acc(o["u2"], 0.1, 1.0)}),
    ]
    return SimWorkload("ft", phases, objects,
                       chunkable={"u0": True, "u1": True, "u2": True})


def _sweep_workload(name: str, scale: float, lhs_stream: float,
                    lhs_objects: Dict[str, float], buf_mb: float,
                    per_sweep_objects: Dict[str, tuple] = None
                    ) -> SimWorkload:
    """Shared structure for BT/SP: rhs + x/y/z sweeps with per-sweep hot
    sets (the per-phase variation that makes local search pay off)."""
    s = scale
    per_sweep_objects = per_sweep_objects or {}
    objects = {
        "u": int(42 * MB * s), "rhs": int(42 * MB * s),
        "forcing": int(42 * MB * s), "us": int(9 * MB * s),
        "vs": int(9 * MB * s), "ws": int(9 * MB * s),
        "qs": int(9 * MB * s), "rho_i": int(9 * MB * s),
        "square": int(9 * MB * s),
        "in_buffer": int(buf_mb * MB * s), "out_buffer": int(buf_mb * MB * s),
    }
    for lname, lmb in lhs_objects.items():
        objects[lname] = int(lmb * MB * s)
    for axis, (jname, jmb) in per_sweep_objects.items():
        objects[jname] = int(jmb * MB * s)
    o = objects
    def sweep(axis: str, extra: Dict[str, SimObjectAccess]) -> SimPhaseSpec:
        base = {
            "rhs": _acc(o["rhs"], 3.0, 0.5),          # both bw and lat
            "u": _acc(o["u"], 1.0, 1.0),
        }
        for lname in lhs_objects:                      # factorization arrays
            base[lname] = _acc(o[lname], 1.0, lhs_stream)
        if axis in per_sweep_objects:                  # this sweep's jacobian
            jname = per_sweep_objects[axis][0]
            base[jname] = _acc(o[jname], 1.0, lhs_stream)
        base.update(extra)
        return SimPhaseSpec(f"{axis}_solve", 0.030, base)
    phases = [
        SimPhaseSpec("compute_rhs", 0.030, {
            "u": _acc(o["u"], 2.0, 1.0), "rhs": _acc(o["rhs"], 2.0, 1.0),
            "forcing": _acc(o["forcing"], 1.0, 1.0),
            "us": _acc(o["us"], 1.0, 1.0), "vs": _acc(o["vs"], 1.0, 1.0),
            "ws": _acc(o["ws"], 1.0, 1.0), "qs": _acc(o["qs"], 1.0, 1.0),
            "rho_i": _acc(o["rho_i"], 1.0, 1.0),
            "square": _acc(o["square"], 1.0, 1.0)}),
        sweep("x", {"us": _acc(o["us"], 4.0, 1.0)}),
        SimPhaseSpec("x_comm", 0.008, {
            "in_buffer": _acc(o["in_buffer"], 4.0, 1.0),
            "out_buffer": _acc(o["out_buffer"], 4.0, 1.0)}),
        sweep("y", {"vs": _acc(o["vs"], 4.0, 1.0)}),
        SimPhaseSpec("y_comm", 0.008, {
            "in_buffer": _acc(o["in_buffer"], 4.0, 1.0),
            "out_buffer": _acc(o["out_buffer"], 4.0, 1.0)}),
        sweep("z", {"ws": _acc(o["ws"], 4.0, 1.0)}),
        SimPhaseSpec("add_update", 0.010, {
            "u": _acc(o["u"], 2.0, 1.0), "rhs": _acc(o["rhs"], 1.0, 1.0)}),
    ]
    return SimWorkload(name, phases, objects)


def bt_like(scale: float = 1.0) -> SimWorkload:
    # block-tridiagonal: per-sweep jacobian/factor workspaces (Table 3:
    # fjac/njac/lhsa/lhsb/lhsc) are hot only in their own sweep -> the
    # rotating hot set that phase-local search exploits (paper Fig 11:
    # BT +19% from local search).
    return _sweep_workload(
        "bt", scale, lhs_stream=0.6,
        lhs_objects={}, buf_mb=12,
        per_sweep_objects={"x": ("fjac_x", 70), "y": ("njac_y", 70),
                           "z": ("lhs_z", 70)})


def sp_like(scale: float = 1.0) -> SimWorkload:
    # scalar-pentadiagonal: lhs latency-sensitive (paper Fig 4), buffers hot
    return _sweep_workload("sp", scale, lhs_stream=0.0,
                           lhs_objects={"lhs": 120}, buf_mb=24)


def lu_like(scale: float = 1.0) -> SimWorkload:
    """SSOR: lower/upper sweeps touch the same hot arrays every phase ->
    cross-phase global placement wins (paper Fig 11: >90% for LU)."""
    s = scale
    objects = {
        "u": int(42 * MB * s), "rsd": int(42 * MB * s),
        "frct": int(42 * MB * s), "flux": int(9 * MB * s),
        "abcd": int(680 * MB * s), "buf": int(6 * MB * s),
    }
    o = objects
    phases = [
        SimPhaseSpec("rhs", 0.030, {
            "rsd": _acc(o["rsd"], 3.0, 1.0), "frct": _acc(o["frct"], 1.0, 1.0),
            "flux": _acc(o["flux"], 4.0, 1.0), "u": _acc(o["u"], 2.0, 1.0)}),
        SimPhaseSpec("lower_sweep", 0.040, {
            "rsd": _acc(o["rsd"], 3.0, 0.3), "abcd": _acc(o["abcd"], 0.15, 1.0),
            "u": _acc(o["u"], 1.0, 1.0)}),
        SimPhaseSpec("lower_comm", 0.005, {"buf": _acc(o["buf"], 2.0, 1.0)}),
        SimPhaseSpec("upper_sweep", 0.040, {
            "rsd": _acc(o["rsd"], 3.0, 0.3), "abcd": _acc(o["abcd"], 0.15, 1.0),
            "u": _acc(o["u"], 1.0, 1.0)}),
        SimPhaseSpec("upper_comm", 0.005, {"buf": _acc(o["buf"], 2.0, 1.0)}),
        SimPhaseSpec("update_u", 0.010, {
            "u": _acc(o["u"], 2.0, 1.0), "rsd": _acc(o["rsd"], 1.0, 1.0)}),
    ]
    return SimWorkload("lu", phases, objects)


def mg_like(scale: float = 1.0) -> SimWorkload:
    """Multigrid V-cycle: 256 MB grids per rank that cannot fit the fast
    tier; stencil locality keeps main-memory traffic low -> small inherent
    gap, one small migration (paper Table 4: MG moved 17 MB once)."""
    s = scale
    objects = {"buff": int(20 * MB * s), "u": int(120 * MB * s),
               "v": int(120 * MB * s), "r": int(120 * MB * s)}
    o = objects
    phases = [
        SimPhaseSpec("resid", 0.050, {
            "u": _acc(o["u"], 0.3, 0.85), "v": _acc(o["v"], 0.3, 1.0),
            "r": _acc(o["r"], 0.3, 0.85)}),
        SimPhaseSpec("rprj_down", 0.030, {"r": _acc(o["r"], 0.4, 0.85)}),
        SimPhaseSpec("comm_halo", 0.008, {"buff": _acc(o["buff"], 3.0, 1.0)}),
        SimPhaseSpec("psinv_up", 0.050, {
            "r": _acc(o["r"], 0.3, 0.85), "u": _acc(o["u"], 0.4, 0.85)}),
        SimPhaseSpec("interp", 0.030, {
            "u": _acc(o["u"], 0.3, 1.0), "v": _acc(o["v"], 0.2, 1.0)}),
    ]
    return SimWorkload("mg", phases, objects, chunkable={"u": True, "r": True})


def nek_like(scale: float = 1.0, n_vars: int = 48) -> SimWorkload:
    """Nek5000-eddy-like: many simulation variables + geometry arrays with
    phase-varying hot sets (the workload where adaptivity matters; paper
    Table 4: 102 migrations, 1.1 GB moved, 70.6% overlapped)."""
    s = scale
    objects: Dict[str, int] = {}
    for i in range(n_vars):
        objects[f"v{i:02d}"] = int((4 + (i * 5) % 28) * MB * s)
    objects["geom"] = int(200 * MB * s)
    phases: List[SimPhaseSpec] = []
    for p in range(8):
        touches: Dict[str, SimObjectAccess] = {
            "geom": _acc(objects["geom"], 0.2, 1.0)}
        for i in range(n_vars):
            if (i + p) % 4 == 0:    # rotating hot set across phases
                stream = 1.0 if i % 3 else 0.3
                touches[f"v{i:02d}"] = _acc(objects[f"v{i:02d}"], 4.0, stream)
        phases.append(SimPhaseSpec(f"nek_phase{p}", 0.020, touches))
        if p % 3 == 2:
            phases.append(SimPhaseSpec(
                f"nek_comm{p}", 0.005,
                {"v00": _acc(objects["v00"], 0.5, 1.0)}))
    return SimWorkload("nek5000", phases, objects)


NPB_WORKLOADS = {
    "cg": cg_like, "ft": ft_like, "bt": bt_like,
    "lu": lu_like, "sp": sp_like, "mg": mg_like, "nek5000": nek_like,
}


# ---------------------------------------------------------------------------
# scenario matrix — steady-state migration-churn workloads for the
# slack-aware async scheduler (beyond the paper's one-shot NPB placements).
# Each scenario's per-phase hot set exceeds the fast tier, so movement
# recurs every iteration and the mover's overlap quality shows up directly
# in steady-state iteration time.
# ---------------------------------------------------------------------------
def kv_serving(scale: float = 1.0, n_blocks: int = 12, n_phases: int = 12,
               window: int = 3) -> SimWorkload:
    """Serving-style KV-cache growth: decode phases over a growing context.

    One weights object is hot in every phase; the KV cache is two rings of
    fixed-size blocks (keys and values) whose hot *window* — the blocks
    holding the most recent tokens — slides one block per decode phase,
    while long-context attention keeps touching the deep history lightly
    (blocks three-to-five positions behind the window; the pair that just
    left the window goes briefly cold, so it is evictable).  The window
    plus weights exceed the fast tier, so every phase boundary pairs two
    fetches (one K, one V block) with two evictions — the FIFO mover
    serializes all four copies on the critical path; the slack scheduler
    keeps evictions off the fence and runs the fetches on concurrent
    channels."""
    s = scale
    blk = int(24 * MB * s)
    objects: Dict[str, int] = {"w": int(96 * MB * s)}
    for b in range(n_blocks):
        objects[f"k{b:02d}"] = blk
        objects[f"v{b:02d}"] = blk
    phases: List[SimPhaseSpec] = []
    for p in range(n_phases):
        touches: Dict[str, SimObjectAccess] = {
            "w": _acc(objects["w"], 1.0, 1.0)}
        hot = [(p + k) % n_blocks for k in range(window)]
        for b in hot:           # recent-token attention: bandwidth-bound
            touches[f"k{b:02d}"] = _acc(blk, 4.0, 1.0)
            touches[f"v{b:02d}"] = _acc(blk, 4.0, 1.0)
        for back in range(3, 6):
            b = (p - back) % n_blocks
            if b not in hot:    # deep-history attention, cache-filtered
                touches[f"k{b:02d}"] = _acc(blk, 0.1, 1.0)
                touches[f"v{b:02d}"] = _acc(blk, 0.1, 1.0)
        phases.append(SimPhaseSpec(f"decode{p}", 0.008, touches))
    return SimWorkload("kv_serving", phases, objects)


def moe_expert_churn(scale: float = 1.0, n_experts: int = 16,
                     n_phases: int = 8) -> SimWorkload:
    """MoE expert working-set churn: routed token groups activate a rotating
    expert pair each phase.

    Experts are only referenced in the phase that routes to them, so their
    copy window spans nearly the whole iteration — but the fast tier only
    holds four experts beside the shared trunk, so each boundary still
    pairs two fetches with two evictions.  Expert GEMMs are mixed-
    sensitivity (irregular token gather/scatter), the router table is pure
    pointer chasing."""
    s = scale
    ex = int(40 * MB * s)
    objects: Dict[str, int] = {"shared": int(64 * MB * s),
                               "router": int(4 * MB * s)}
    for e in range(n_experts):
        objects[f"exp{e:02d}"] = ex
    phases: List[SimPhaseSpec] = []
    for p in range(n_phases):
        touches: Dict[str, SimObjectAccess] = {
            "shared": _acc(objects["shared"], 1.5, 1.0),
            "router": _acc(objects["router"], 2.0, 0.0),
        }
        for e in ((2 * p) % n_experts, (2 * p + 1) % n_experts):
            touches[f"exp{e:02d}"] = _acc(ex, 4.0, 0.35)
        phases.append(SimPhaseSpec(f"route{p}", 0.012, touches))
    return SimWorkload("moe_churn", phases, objects)


def graph_chase(scale: float = 1.0) -> SimWorkload:
    """Pointer-chasing graph analytics with two adjacency shards.

    The frontier is dependent-load bound (pure chasing); the two adjacency
    shards are large, chunkable, and each hot in its own gather phase — the
    shard swap each iteration moves ~6 chunks through the copy engine, and
    chunk-granular double buffering lets the gather consume early chunks
    while later ones are still in flight."""
    s = scale
    objects = {
        "frontier": int(16 * MB * s),
        "visited": int(32 * MB * s),
        "adjA": int(320 * MB * s),
        "adjB": int(320 * MB * s),
    }
    o = objects
    phases = [
        SimPhaseSpec("gatherA", 0.020, {
            "adjA": _acc(o["adjA"], 3.0, 0.85),
            "frontier": _acc(o["frontier"], 0.5, 0.0),
        }),
        SimPhaseSpec("gatherB", 0.020, {
            "adjB": _acc(o["adjB"], 3.0, 0.85),
            "frontier": _acc(o["frontier"], 0.5, 0.0),
        }),
        SimPhaseSpec("apply", 0.008, {
            "visited": _acc(o["visited"], 4.0, 0.6),
            "frontier": _acc(o["frontier"], 1.0, 0.0),
        }),
    ]
    return SimWorkload("graph_chase", phases, objects,
                       chunkable={"adjA": True, "adjB": True})


def graph_chase_skewed(scale: float = 1.0, alpha: float = 1.3,
                       seed: int = 7, density_bins: int = 64) -> SimWorkload:
    """Power-law graph analytics over two oversized adjacency shards.

    Each 640 MB shard's gather traffic follows a permuted power-law density
    (exponent ``alpha``): a few scattered hot regions — high-degree vertex
    neighborhoods, *not* sorted to the array head — absorb most accesses.
    With uniform attribution every equal chunk looks identically warm, so
    the planner cycles whole shards through the fast tier; with measured
    per-chunk attribution, skew-aware bisection isolates the hot regions
    and the knapsack keeps exactly them resident, cutting migration traffic
    and steady-state time.

    ``density_bins`` sets the *true* density's native resolution.  Above
    the profiler's bin budget (64 by default) the truth carries structure
    a fixed-width measured histogram cannot resolve — the regime where
    adaptive multi-resolution refinement (``RuntimeConfig.
    histogram_refine``) pays: hot-head bins refine below one legacy bin
    while the cold tail coarsens."""
    s = scale
    objects = {
        "frontier": int(16 * MB * s),
        "visited": int(32 * MB * s),
        "adjA": int(640 * MB * s),
        "adjB": int(640 * MB * s),
    }
    o = objects
    dens_a = power_law_density(density_bins, alpha, seed=seed)
    dens_b = power_law_density(density_bins, alpha, seed=seed + 1)
    phases = [
        SimPhaseSpec("gatherA", 0.020, {
            "adjA": _acc(o["adjA"], 3.0, 0.85, density=dens_a),
            "frontier": _acc(o["frontier"], 0.5, 0.0),
        }),
        SimPhaseSpec("gatherB", 0.020, {
            "adjB": _acc(o["adjB"], 3.0, 0.85, density=dens_b),
            "frontier": _acc(o["frontier"], 0.5, 0.0),
        }),
        SimPhaseSpec("apply", 0.008, {
            "visited": _acc(o["visited"], 4.0, 0.6),
            "frontier": _acc(o["frontier"], 1.0, 0.0),
        }),
    ]
    return SimWorkload("graph_chase_skew", phases, objects,
                       chunkable={"adjA": True, "adjB": True})


def kv_serving_skewed(scale: float = 1.0, n_blocks: int = 12,
                      n_phases: int = 12, window: int = 3,
                      sub: int = 1, taper: float = 0.62) -> SimWorkload:
    """KV-cache serving with the cache as two monolithic chunkable rings.

    Same access anatomy as :func:`kv_serving`, but the keys and values are
    single large registered objects (``kcache``/``vcache``) — the realistic
    allocation for a paged cache arena — so the *runtime* must discover the
    block structure: each decode phase's access density over the ring has a
    sharp sliding hot window (recent tokens, 4 passes) and a light
    deep-history band (0.1 passes).  Without per-chunk attribution every
    equal chunk looks identically warm and the planner cannot place the
    window; with it, skew-aware bisection cuts the ring along the measured
    per-phase density edges and the local search prefetches exactly the
    window chunks.

    ``sub > 1`` resolves the true density *within* each block at ``sub``
    sub-bins: a hot block's mass tapers geometrically (``taper``) from its
    head — the recent-token gradient inside a block — so the truth carries
    structure finer than one block.  A fixed-width measured histogram at
    block granularity smears it; adaptive multi-resolution refinement
    resolves the intra-block head and lets hot chunks shrink below one
    legacy bin."""
    s = scale
    blk = int(24 * MB * s)
    cache = blk * n_blocks
    objects: Dict[str, int] = {"w": int(96 * MB * s),
                               "kcache": cache, "vcache": cache}

    def expand(weights: List[float]) -> List[float]:
        if sub <= 1:
            return list(weights)
        g = [taper ** k for k in range(sub)]
        gs = sum(g)
        out: List[float] = []
        for w in weights:
            if w >= 1.0:        # hot block: recent-token head gradient
                out.extend(w * sub * gk / gs for gk in g)
            else:               # deep history / cold: flat within the block
                out.extend(w for _ in range(sub))
        return out

    phases: List[SimPhaseSpec] = []
    for p in range(n_phases):
        weights = [0.0] * n_blocks
        hot = [(p + k) % n_blocks for k in range(window)]
        for b in hot:
            weights[b] = 4.0
        for back in range(3, 6):
            b = (p - back) % n_blocks
            if b not in hot:
                weights[b] = 0.1
        total_passes = sum(weights)
        acc = total_passes * blk / LINE
        dens = expand(weights)
        touches: Dict[str, SimObjectAccess] = {
            "w": _acc(objects["w"], 1.0, 1.0),
            "kcache": SimObjectAccess(accesses=acc, stream_fraction=1.0,
                                      density=dens),
            "vcache": SimObjectAccess(accesses=acc, stream_fraction=1.0,
                                      density=list(dens)),
        }
        phases.append(SimPhaseSpec(f"decode{p}", 0.008, touches))
    return SimWorkload("kv_serving_skew", phases, objects,
                       chunkable={"kcache": True, "vcache": True})


def paged_attention(scale: float = 1.0, n_pages: int = 28,
                    page_mb: float = 12.0, n_requests: int = 8,
                    n_phases: int = 12, active: int = 3,
                    seed: int = 11) -> SimWorkload:
    """Paged-attention serving: variable-length requests over a paged KV
    arena (the ROADMAP's serving trace).

    The KV cache is one monolithic chunkable arena of ``n_pages``
    fixed-size pages.  Requests have *variable lengths* (2–6 pages) and a
    paged allocator hands them whatever pages are free: page assignment is
    a seeded permutation of the arena, so a request's pages are scattered —
    no spatial locality, exactly like a production paged-KV allocator
    after churn.  Each decode phase serves a rotating window of ``active``
    requests; a request's two most recent pages absorb the dense
    recent-token attention (4 main-memory passes) while its older pages see
    only the light deep-history band (0.15 passes).  The page table is
    dependent-load indirection (pure chasing) and the weights are hot
    every phase.

    Uniform chunk attribution sees a uniformly-warm 336 MB arena that
    cannot fit the fast tier; only measured per-chunk attribution can find
    the scattered active pages, so this workload exercises the full
    hot-chunk pipeline under paging-induced fragmentation."""
    import numpy as np
    s = scale
    page = int(page_mb * MB * s)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n_pages)
    lengths = 2 + rng.integers(0, 5, size=n_requests)      # 2..6 pages
    pages: Dict[int, List[int]] = {}
    cur = 0
    for r in range(n_requests):
        pages[r] = [int(perm[(cur + k) % n_pages])
                    for k in range(int(lengths[r]))]
        cur += int(lengths[r])
    objects = {"w": int(96 * MB * s), "page_table": int(4 * MB * s),
               "kv_arena": page * n_pages}
    phases: List[SimPhaseSpec] = []
    for p in range(n_phases):
        weights = [0.0] * n_pages
        for j in range(active):
            r = (p + j) % n_requests
            own = pages[r]
            for k, pg in enumerate(own):
                weights[pg] += 4.0 if k >= len(own) - 2 else 0.15
        acc = sum(weights) * page / LINE
        touches: Dict[str, SimObjectAccess] = {
            "w": _acc(objects["w"], 1.0, 1.0),
            "page_table": _acc(objects["page_table"], 2.0, 0.0),
            "kv_arena": SimObjectAccess(accesses=acc, stream_fraction=0.9,
                                        density=list(weights)),
        }
        phases.append(SimPhaseSpec(f"decode{p}", 0.008, touches))
    return SimWorkload("paged_serving", phases, objects,
                       chunkable={"kv_arena": True})


def fsdp_grad_buckets(scale: float = 1.0, n_layers: int = 6) -> SimWorkload:
    """FSDP-style gradient-bucket churn (the ROADMAP's training trace).

    Fully-sharded training materializes per-layer state transiently: the
    forward pass all-gathers each layer's weights just in time; the
    backward pass revisits them in reverse and fills a per-layer *gradient
    bucket* that is reduce-scattered right after the layer's backward and
    then goes cold until the next iteration.  Optimizer shards are touched
    only in the trailing update phase.  The per-phase hot set is small
    (one layer's weights + one bucket) but rotates through every layer
    each iteration while the total state is ~3x the fast tier — the
    highest-churn scenario in the matrix: every phase boundary retires one
    bucket and prefetches the next layer's state, so the mover's
    eviction-off-the-fence and overlap quality dominate steady-state time.
    Weight gathers are bandwidth-bound; bucket reduction mixes in the
    irregular index traffic of the sharded reduce; optimizer math streams
    both its shard and the weights."""
    s = scale
    wb = int(44 * MB * s)           # one layer's gathered weights
    gb = int(44 * MB * s)           # its gradient bucket
    ob = int(26 * MB * s)           # its optimizer shard
    objects: Dict[str, int] = {"act_stash": int(48 * MB * s)}
    for i in range(n_layers):
        objects[f"w{i}"] = wb
        objects[f"g{i}"] = gb
        objects[f"opt{i}"] = ob
    phases: List[SimPhaseSpec] = []
    for i in range(n_layers):
        phases.append(SimPhaseSpec(f"fwd{i}", 0.010, {
            f"w{i}": _acc(wb, 2.0, 1.0),
            "act_stash": _acc(objects["act_stash"], 0.5, 1.0)}))
    for i in reversed(range(n_layers)):
        phases.append(SimPhaseSpec(f"bwd{i}", 0.014, {
            f"w{i}": _acc(wb, 2.0, 1.0),
            f"g{i}": _acc(gb, 3.0, 0.8),
            "act_stash": _acc(objects["act_stash"], 0.5, 1.0)}))
        phases.append(SimPhaseSpec(f"rs{i}", 0.004, {
            f"g{i}": _acc(gb, 2.0, 0.6)}))
    opt_touches: Dict[str, SimObjectAccess] = {}
    for i in range(n_layers):
        opt_touches[f"opt{i}"] = _acc(ob, 2.0, 1.0)
        opt_touches[f"w{i}"] = _acc(wb, 1.0, 1.0)
    phases.append(SimPhaseSpec("opt_update", 0.012, opt_touches))
    return SimWorkload("fsdp_buckets", phases, objects)


SCENARIO_WORKLOADS = {
    "kv_serving": kv_serving,
    "moe_churn": moe_expert_churn,
    "graph_chase": graph_chase,
    "fsdp_buckets": fsdp_grad_buckets,
}


# ---------------------------------------------------------------------------
# multi-tenant serving — the tenancy layer's target workload.
# Driven directly by ``bench_tenants`` (not part of SCENARIO_WORKLOADS: it
# needs per-tenant registration through ``rt.tenant()`` handles, which the
# generic scenario runner does not do).
# ---------------------------------------------------------------------------

#: tenant -> (priority, slo) for ``tenant_serving``.  Popularity across
#: tenants is Zipf-like: one whale absorbs most of the traffic, three mid
#: tenants split a thin tail, and one cold archive tenant barely shows up.
#: The whale's priority and the mids' tight SLO (0.75 = stricter latency
#: budget => more weight per unit priority) give fast-tier weights 8 : 4/3
#: : 1/2 — whale share 2/3 of capacity, mids 1/9 each.
TENANT_SERVING_QOS = {
    "whale": (8.0, 1.0),
    "m0": (1.0, 0.75),
    "m1": (1.0, 0.75),
    "m2": (1.0, 0.75),
    "cold": (0.5, 1.0),
}


def tenant_serving(scale: float = 1.0, n_rounds: int = 8,
                   whale_compute_s: float = 0.060) -> SimWorkload:
    """Multi-tenant KV-serving: one whale, three mid tenants, one cold.

    Each round interleaves one whale decode phase with one decode phase per
    mid tenant; a trailing archive scan touches the cold tenant's state.
    All object and phase names carry ``tenant/`` prefixes — the runtime's
    tenant namespaces — so per-tenant latency can be read straight off the
    phase trace.

    The QoS tension the bandwidth-partition policy has to resolve:

    * The *whale* is a long-context stream — big weights, a 12-position
      KV-block ring with a 2-wide hot window sliding one position per
      round, and deep-history attention over positions 2-3 behind it.
      Its per-phase working set (weights + 4 block pairs = 128 MB) just
      fits the whale's QoS share, so the partitioned solve can rotate
      the ring under the whale's compute-rich phases — but the ring's
      per-iteration sweep (~256 MB) dwarfs any share, and the deep
      history's per-byte traffic is *higher* than the mid tenants' hot
      windows, so an aggregate optimizer spends the last of the fast
      tier on whale ring blocks instead of mid windows.
    * The *mids* are short-context decoders whose phases are memory-bound:
      every byte of their hot window served from slow lands directly on
      their (small) phase time.  Starving them is cheap in aggregate time
      and catastrophic in per-tenant p99.
    * The *cold* tenant's archive sees ~0.05 sweeps/iteration — below any
      sensible admission heat floor; it should be demoted to
      serve-from-slow, not squat in fast capacity.
    """
    s = scale
    objects: Dict[str, int] = {}
    # whale: 64 MB weights + 12 K/V block pairs of 8 MB
    objects["whale/w"] = int(64 * MB * s)
    n_blk, blk = 12, int(8 * MB * s)
    for b in range(n_blk):
        objects[f"whale/k{b:02d}"] = blk
        objects[f"whale/v{b:02d}"] = blk
    # mids: 8 MB weights + 8 K/V block pairs of 3 MB each — hot set
    # (weights + 2-position window = 20 MB) sized to fit a mid tenant's
    # fast-tier share, so the partitioned solve can serve a mid fully
    m_blk_n, m_blk = 8, int(3 * MB * s)
    for m in range(3):
        objects[f"m{m}/w"] = int(8 * MB * s)
        for b in range(m_blk_n):
            objects[f"m{m}/k{b:02d}"] = m_blk
            objects[f"m{m}/v{b:02d}"] = m_blk
    objects["cold/archive"] = int(96 * MB * s)

    phases: List[SimPhaseSpec] = []
    for p in range(n_rounds):
        # whale decode: hot window @3.0 sweeps, deep history (2-3 positions
        # back) @2.5 — per-byte deep traffic ~5 sweeps/iter, above the mid
        # windows' ~4, so the aggregate knapsack prefers whale ring blocks
        # over mid hot windows once weights + windows are placed.
        touches: Dict[str, SimObjectAccess] = {
            "whale/w": _acc(objects["whale/w"], 1.0, 1.0)}
        hot = [(p + k) % n_blk for k in range(2)]
        for b in hot:
            touches[f"whale/k{b:02d}"] = _acc(blk, 3.0, 1.0)
            touches[f"whale/v{b:02d}"] = _acc(blk, 3.0, 1.0)
        for back in range(2, 4):
            b = (p - back) % n_blk
            if b not in hot:
                touches[f"whale/k{b:02d}"] = _acc(blk, 2.5, 1.0)
                touches[f"whale/v{b:02d}"] = _acc(blk, 2.5, 1.0)
        phases.append(SimPhaseSpec(f"whale/decode{p}", whale_compute_s,
                                   touches))
        # mid decodes: memory-bound (compute ~ fast-tier mem time)
        for m in range(3):
            mt: Dict[str, SimObjectAccess] = {
                f"m{m}/w": _acc(objects[f"m{m}/w"], 1.0, 1.0)}
            mhot = [(p + k) % m_blk_n for k in range(2)]
            for b in mhot:
                mt[f"m{m}/k{b:02d}"] = _acc(m_blk, 2.0, 1.0)
                mt[f"m{m}/v{b:02d}"] = _acc(m_blk, 2.0, 1.0)
            phases.append(SimPhaseSpec(f"m{m}/decode{p}", 0.004, mt))
    phases.append(SimPhaseSpec("cold/scan", 0.004, {
        "cold/archive": _acc(objects["cold/archive"], 0.05, 1.0)}))
    return SimWorkload("tenant_serving", phases, objects)

# Skewed variants: the hot-chunk placement pipeline's target workloads.
# Separate registry so the golden virtual-time traces of the base matrix
# stay pinned; benchmarked in ``bench_scenarios`` against the uniform
# (chunk_aware=False) pipeline.
SKEWED_SCENARIO_WORKLOADS = {
    "graph_chase_skew": graph_chase_skewed,
    "kv_serving_skew": kv_serving_skewed,
    "paged_serving": paged_attention,
}


# ---------------------------------------------------------------------------
# chaos fault profiles — fixed-seed FaultSpecs for the scenario matrix.
# The chaos scenario family is the full matrix above re-run under one of
# these profiles (benchmarks/run.py ``bench_chaos``); fixed seeds against
# the deterministic virtual-time issue sequence make every chaos row as
# reproducible as the fault-free golden traces.
# ---------------------------------------------------------------------------
def chaos_gated_spec(seed: int = 0) -> FaultSpec:
    """The nightly-gated profile: 5% transient ``start_move`` failures
    plus one permanently collapsed channel (channel 1 at 8x slowdown).
    The regression gate requires every ``scenario_*_chaos`` row under this
    profile to hold >= 0.85x its fault-free slack with zero audit
    violations."""
    return FaultSpec(seed=seed, transient_rate=0.05,
                     straggler_channel=1, straggler_channel_factor=8.0)


def chaos_heavy_spec(seed: int = 0) -> FaultSpec:
    """Kitchen-sink profile for robustness tests: every fault class on at
    once (transients, stuck handles, late failures, straggler windows) —
    the survival test, not the performance gate."""
    return FaultSpec(seed=seed, transient_rate=0.08, stuck_rate=0.02,
                     late_fail_rate=0.04, straggler_rate=0.05)


CHAOS_FAULT_PROFILES = {
    "gated": chaos_gated_spec,
    "heavy": chaos_heavy_spec,
}


# ---------------------------------------------------------------------------
def lm_train_workload(*, n_layers: int, layer_bytes: int, opt_bytes: int,
                      act_bytes: int, name: str = "lm",
                      layer_group: int = 4,
                      compute_per_group_s: float = 0.002) -> SimWorkload:
    """Transformer training step as a Unimem phase trace on TPU tiers.

    Objects: per-layer-group weights, optimizer shards, activation
    checkpoints.  Phases: forward groups, backward groups (reverse order),
    optimizer update.  Weights are read in fwd+bwd; activations written in
    fwd and read in bwd; optimizer state touched only in the update phase —
    the access pattern that makes optimizer state the prime offload victim.
    """
    groups = max(1, n_layers // layer_group)
    objects: Dict[str, int] = {}
    for g in range(groups):
        objects[f"w{g}"] = layer_bytes * layer_group
        objects[f"opt{g}"] = opt_bytes * layer_group
        objects[f"act{g}"] = act_bytes * layer_group
    phases: List[SimPhaseSpec] = []
    for g in range(groups):
        phases.append(SimPhaseSpec(f"fwd{g}", compute_per_group_s, {
            f"w{g}": _acc(objects[f"w{g}"], 1.0, 1.0),
            f"act{g}": _acc(objects[f"act{g}"], 1.0, 1.0)}))
    for g in reversed(range(groups)):
        phases.append(SimPhaseSpec(f"bwd{g}", 2 * compute_per_group_s, {
            f"w{g}": _acc(objects[f"w{g}"], 2.0, 1.0),
            f"act{g}": _acc(objects[f"act{g}"], 1.0, 1.0)}))
    for g in range(groups):
        phases.append(SimPhaseSpec(f"opt{g}", compute_per_group_s / 2, {
            f"opt{g}": _acc(objects[f"opt{g}"], 2.0, 1.0),
            f"w{g}": _acc(objects[f"w{g}"], 1.0, 1.0)}))
    return SimWorkload(name, phases, objects)
