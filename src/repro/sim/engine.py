"""Discrete-event simulation of phase execution on a two-tier memory.

Stands in for the Quartz emulator (paper §4).  The physics live in
:class:`SimSource` — an :class:`~repro.core.instrumentation.
InstrumentationSource` that derives each phase's execution time and its
instrumentation (true access counts, per-object time shares, per-chunk
access densities) from the workload spec and the *current* registry tier
state:

* ``stream``-type accesses are bandwidth-bound: ``bytes / tier.bw`` (memory
  level parallelism hides latency);
* ``chase``-type accesses are latency-bound: ``accesses x tier.lat``
  (dependent pointer chasing exposes full latency, bandwidth irrelevant).

An object's pattern mixes the two with ``stream_fraction`` — this reproduces
the paper's Observation 3 (objects can be bandwidth-sensitive,
latency-sensitive, or both).  Phase time = scalar compute + the serialized
memory time of its objects.

:class:`SimulationEngine` is then just a virtual clock around the v2
session API: each iteration is ``with rt.iteration():``, each phase a
``with rt.phase(name):`` whose instrumentation the attached
:class:`SimSource` supplies — the exact pipeline a hardware driver feeds
through :class:`~repro.core.instrumentation.XlaCostAnalysisSource`.
Migration copies run on the simulated copy engine from the backend
registry (``make_backend("sim", ...)``) matched to the runtime's
configured mover — the FIFO baseline (``SimTierBackend``, one serial
queue) or the slack-aware scheduler's multi-channel engine
(``ChannelSimBackend``, concurrent copies with bandwidth contention, tier
flips only on landing).  Fence stalls land on the critical path only when
slack is exhausted; every phase execution is recorded in a virtual-time
trace (``PhaseExec``) for invariant checks.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.backends import make_backend
from ..core.data_objects import ObjectRegistry
from ..core.instrumentation import PhaseSample
from ..core.partition import bin_mass, chunk_spans
from ..core.session import Session
from ..core.tiers import MachineProfile


@dataclasses.dataclass
class SimObjectAccess:
    """How one phase touches one object."""

    accesses: float              # main-memory accesses (cachelines)
    stream_fraction: float = 1.0  # 1.0 = pure streaming, 0.0 = pure chasing
    # Optional access distribution over the object's byte range: relative
    # weights over equal-width bins (skewed workloads — power-law adjacency,
    # sliding KV hot windows).  None = uniform.  Drives both the simulated
    # physics (per-chunk service times) and, via ``PhaseTraceEvent.
    # access_bins``, the runtime's per-chunk attribution.
    density: Optional[Sequence[float]] = None


@dataclasses.dataclass
class SimPhaseSpec:
    name: str
    compute_s: float                       # non-memory compute time
    touches: Dict[str, SimObjectAccess]    # obj -> access descriptor

    def true_accesses(self) -> Dict[str, float]:
        return {o: a.accesses for o, a in self.touches.items()}


@dataclasses.dataclass
class SimWorkload:
    name: str
    phases: List[SimPhaseSpec]
    objects: Dict[str, int]                # obj -> size bytes
    chunkable: Dict[str, bool] = dataclasses.field(default_factory=dict)

    def static_ref_counts(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for ph in self.phases:
            for o, a in ph.touches.items():
                out[o] = out.get(o, 0.0) + a.accesses
        return out


@dataclasses.dataclass
class PhaseExec:
    """One dynamic phase execution in virtual time (trace for tests)."""

    iteration: int
    phase_index: int
    start: float                 # virtual time phase_begin was entered
    stall_s: float               # fence stall absorbed before compute
    duration_s: float            # phase execution time (post-stall)

    @property
    def compute_start(self) -> float:
        return self.start + self.stall_s

    @property
    def end(self) -> float:
        return self.start + self.stall_s + self.duration_s


@dataclasses.dataclass
class SimResult:
    iteration_times: List[float]
    total_time: float
    stats: Dict[str, object]
    phase_trace: List[PhaseExec] = dataclasses.field(default_factory=list)

    @property
    def steady_iteration_time(self) -> float:
        tail = self.iteration_times[len(self.iteration_times) // 2:]
        return sum(tail) / len(tail)

    @property
    def total_stall_s(self) -> float:
        return sum(p.stall_s for p in self.phase_trace)


class SimSource:
    """Density-driven simulated instrumentation (the physics, migrated out
    of the engine so any driver — or the parity tests — can consume the
    exact event stream the simulator produces).

    ``collect`` returns the phase's true access counts, PEBS-like per-object
    time shares, each skewed object's true address histogram, and the
    simulated phase duration as ``elapsed`` (virtual time)."""

    #: fraction of the smaller of (compute, memory) that cannot be hidden —
    #: out-of-order cores overlap most memory stalls with compute (MLP); 1.0
    #: would be fully serialized, 0.0 perfectly overlapped.
    serialization = 0.25

    def __init__(self, machine: MachineProfile, workload: SimWorkload,
                 registry: ObjectRegistry):
        self.machine = machine
        self.workload = workload
        self.registry = registry
        self._specs = {ph.name: ph for ph in workload.phases}
        if len(self._specs) != len(workload.phases):
            # phases are name-keyed through the session API; a duplicate
            # would silently collapse onto the last spec's physics
            dupes = sorted({ph.name for i, ph in enumerate(workload.phases)
                            if any(q.name == ph.name
                                   for q in workload.phases[:i])})
            raise ValueError(
                f"workload {workload.name!r} has duplicate phase names "
                f"{dupes}; phase names must be unique")

    def phase_time(self, ph: SimPhaseSpec) -> Tuple[float, Dict[str, float]]:
        """Returns (total_time, {logical_obj_name: memory_time})."""
        mem = 0.0
        obj_times: Dict[str, float] = {}
        line = self.machine.cacheline_bytes
        for name, acc in ph.touches.items():
            parts: List[tuple] = []
            if name in self.registry:
                parts.append((self.registry[name], acc.accesses))
            else:
                # partitioned: distribute accesses over chunks by the true
                # access density (uniform = by size) — the simulated ground
                # truth the profiler's sampled attribution approximates
                spans = chunk_spans(self.registry, name)
                total = sum(c.size_bytes for c, _, _ in spans) or 1
                if acc.density is None:
                    for c, _, _ in spans:
                        parts.append((c, acc.accesses * c.size_bytes / total))
                else:
                    masses = [bin_mass(acc.density, lo / total, hi / total)
                              for _, lo, hi in spans]
                    norm = sum(masses) or 1.0
                    for (c, _, _), m in zip(spans, masses):
                        parts.append((c, acc.accesses * m / norm))
            for obj, n_acc in parts:
                tier = (self.machine.fast if obj.tier == "fast"
                        else self.machine.slow)
                stream_t = (n_acc * acc.stream_fraction * line) / tier.bw
                chase_t = n_acc * (1.0 - acc.stream_fraction) * tier.lat
                obj_times[obj.name] = obj_times.get(obj.name, 0.0) \
                    + stream_t + chase_t
                mem += stream_t + chase_t
        t = max(ph.compute_s, mem) \
            + self.serialization * min(ph.compute_s, mem)
        return t, obj_times

    def collect(self, phase_name: str) -> PhaseSample:
        ph = self._specs[phase_name]
        t_phase, obj_times = self.phase_time(ph)
        # PEBS-like attribution: per-object share of phase time, plus each
        # skewed object's true address histogram (the profiler resamples it
        # with multinomial noise).
        shares: Dict[str, float] = {}
        for name in ph.touches:
            tt = sum(v for k, v in obj_times.items()
                     if k == name or k.startswith(name + "#"))
            shares[name] = tt / t_phase if t_phase > 0 else 0.0
        bins = {name: acc.density for name, acc in ph.touches.items()
                if acc.density is not None}
        return PhaseSample(accesses=ph.true_accesses(), time_shares=shares,
                           access_bins=bins or None, elapsed=t_phase)


class SimulationEngine:
    """Runs a SimWorkload for N iterations under a placement policy.

    ``runtime=None`` simulates a *static* placement (whatever tiers the
    registry currently holds) — used for DRAM-only / NVM-only / offline-
    profiling baselines.  With a runtime (a v2 :class:`Session` or the
    ``UnimemRuntime`` facade), iteration 1 profiles and later iterations
    follow the Unimem plan with proactive movement.
    """

    def __init__(self, machine: MachineProfile, workload: SimWorkload,
                 runtime: Optional[Session] = None,
                 registry: Optional[ObjectRegistry] = None):
        self.machine = machine
        self.workload = workload
        self.clock = 0.0
        if runtime is not None:
            self.runtime = runtime
            self.registry = runtime.registry
            # swap in a simulated copy engine wired to our clock, resolved
            # from the backend registry and matched to the runtime's
            # configured migration engine
            backend = make_backend(
                "sim", machine, now_fn=lambda: self.clock,
                mover=runtime.config.mover,
                channels=runtime.config.copy_channels,
                priorities=getattr(runtime.config,
                                   "copy_channel_priorities", None))
            fault_spec = getattr(runtime.config, "fault_spec", None)
            if fault_spec is not None:
                # chaos rides the clock-wired sim engine: the configured
                # fault profile is re-applied to the swapped-in backend
                from ..core.faults import ChaosBackend
                backend = ChaosBackend(backend, fault_spec,
                                       host=getattr(runtime.config, "host",
                                                    None))
            self.runtime.backend = backend
            if self.runtime.mover is not None:
                self.runtime.mover.backend = backend
        else:
            self.runtime = None
            self.registry = registry if registry is not None else ObjectRegistry()
            if registry is None:
                for name, size in workload.objects.items():
                    self.registry.alloc(name, size)
        self.source = SimSource(machine, workload, self.registry)
        if self.runtime is not None:
            self.runtime.attach_source(self.source)

    # ------------------------------------------------------------------
    def object_tier(self, name: str):
        # chunked objects: registry holds name#k chunks
        if name in self.registry:
            return self.registry[name].tier
        return None

    def phase_time(self, ph: SimPhaseSpec) -> tuple:
        return self.source.phase_time(ph)

    # ------------------------------------------------------------------
    def run(self, n_iterations: int) -> SimResult:
        iter_times: List[float] = []
        trace: List[PhaseExec] = []
        for it in range(n_iterations):
            t_iter = 0.0
            if self.runtime is not None:
                with self.runtime.iteration():
                    for i, ph in enumerate(self.workload.phases):
                        t_enter = self.clock
                        with self.runtime.phase(ph.name) as pc:
                            pass        # the SimSource supplies the physics
                        trace.append(PhaseExec(it, i, t_enter, pc.stall_s,
                                               pc.elapsed))
                        self.clock += pc.stall_s + pc.elapsed
                        t_iter += pc.stall_s + pc.elapsed
            else:
                for i, ph in enumerate(self.workload.phases):
                    t_enter = self.clock
                    t_phase, _ = self.source.phase_time(ph)
                    trace.append(PhaseExec(it, i, t_enter, 0.0, t_phase))
                    self.clock += t_phase
                    t_iter += t_phase
            iter_times.append(t_iter)
        stats = self.runtime.stats() if self.runtime is not None else {}
        return SimResult(iter_times, sum(iter_times), stats, trace)


# ---------------------------------------------------------------------------
# calibration micro-workloads (STREAM / pointer-chasing analogues, §3.1.2)
# ---------------------------------------------------------------------------
def simulate_stream_time(machine: MachineProfile, n_bytes: int,
                         tier: str = "fast") -> float:
    t = machine.fast if tier == "fast" else machine.slow
    return n_bytes / t.bw


def simulate_chase_time(machine: MachineProfile, n_accesses: int,
                        tier: str = "fast") -> float:
    t = machine.fast if tier == "fast" else machine.slow
    return n_accesses * t.lat
