"""Discrete-event tier simulator (Quartz-emulator analogue, paper §4)."""

from .cluster import (ClusterResult, ClusterSimulation, ShardPhaseSpec,
                      ShardedWorkload, moe_churn_multihost)
from .engine import (PhaseExec, SimObjectAccess, SimPhaseSpec, SimSource,
                     SimWorkload, SimulationEngine, SimResult,
                     simulate_stream_time, simulate_chase_time)
from .workloads import (cg_like, ft_like, bt_like, lu_like, sp_like, mg_like,
                        nek_like, NPB_WORKLOADS, lm_train_workload,
                        kv_serving, kv_serving_skewed, moe_expert_churn,
                        graph_chase, graph_chase_skewed, paged_attention,
                        power_law_density,
                        SCENARIO_WORKLOADS, SKEWED_SCENARIO_WORKLOADS,
                        tenant_serving, TENANT_SERVING_QOS,
                        chaos_gated_spec, chaos_heavy_spec,
                        CHAOS_FAULT_PROFILES)

__all__ = [
    "PhaseExec", "SimObjectAccess", "SimPhaseSpec", "SimSource",
    "SimWorkload", "SimulationEngine", "SimResult", "simulate_stream_time",
    "simulate_chase_time",
    "cg_like", "ft_like", "bt_like", "lu_like", "sp_like", "mg_like",
    "nek_like", "NPB_WORKLOADS", "lm_train_workload",
    "kv_serving", "kv_serving_skewed", "moe_expert_churn", "graph_chase",
    "graph_chase_skewed", "paged_attention", "power_law_density",
    "SCENARIO_WORKLOADS", "SKEWED_SCENARIO_WORKLOADS",
    "tenant_serving", "TENANT_SERVING_QOS",
    "chaos_gated_spec", "chaos_heavy_spec", "CHAOS_FAULT_PROFILES",
    "ClusterResult", "ClusterSimulation", "ShardPhaseSpec",
    "ShardedWorkload", "moe_churn_multihost",
]
