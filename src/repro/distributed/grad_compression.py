"""Gradient compression for cross-pod reduction: int8 quantization with
error feedback.

At 2+ pods the ``pod`` axis crosses the slower inter-pod links; compressing
gradients 4x (fp32->int8 with per-block scales) before the cross-pod
all-reduce cuts that traffic proportionally.  Error feedback (residual
carried to the next step) keeps convergence (1-bit Adam / EF-SGD lineage).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array, block: int = 256
                  ) -> Tuple[jax.Array, jax.Array]:
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compressed_psum(x: jax.Array, axis_name: str, *, block: int = 256,
                    error: jax.Array = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """int8 error-feedback psum over ``axis_name`` (use inside shard_map).

    Returns (reduced value, new error residual)."""
    if error is not None:
        x = x + error
    q, scale = quantize_int8(x, block)
    sent = dequantize_int8(q, scale, x.shape)
    new_error = x - sent
    reduced = jax.lax.psum(sent, axis_name)
    return reduced, new_error


def tree_compressed_psum(tree: Any, axis_name: str, errors: Any = None
                         ) -> Tuple[Any, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    errs = (treedef.flatten_up_to(errors) if errors is not None
            else [None] * len(leaves))
    out, new_errs = [], []
    for leaf, err in zip(leaves, errs):
        r, e = compressed_psum(leaf, axis_name, error=err)
        out.append(r)
        new_errs.append(e)
    return treedef.unflatten(out), treedef.unflatten(new_errs)
