"""Multi-host tier management: per-shard tier managers + cluster coordinator.

Unimem runs one runtime instance per MPI rank and keeps data-management
decisions coordinated so migration never introduces load imbalance
(paper §3.3); production jax_pallas models are sharded across hosts, so
the reproduction's single DRAM/NVM session becomes the *per-host shard
manager* and this module adds the layer above it:

* :class:`HostTierManager` — one existing :class:`~repro.core.Session`
  (the full PR 3-8 pipeline: profile -> plan -> slack-aware movement ->
  monitor) managing one host's shard over its own DRAM/NVM pair, with
  host provenance threaded through its plan stage records, fault log and
  ``stats()`` (``RuntimeConfig.host``).
* :class:`ClusterCoordinator` — aggregates the per-shard profiles into a
  global :class:`~repro.core.PlanProgram` with per-host residency
  sections, and decides *shard re-homing*: when one host's shard goes
  hot past its fast-tier capacity, the coordinator compares **local
  NVM->DRAM promotion** (Eq. (4) against the host's copy engine, only
  feasible while local fast capacity remains) against **pulling the hot
  shard to a peer host** (priced per interconnect link by
  :func:`~repro.core.perfmodel.cross_host_cost`), and emits the chosen
  :class:`ShardMigration` list.  Cross-host pulls execute on the
  registered ``"cross_host"`` backend (send/recv channel pairs per
  link); when several destinations contend for one source host's egress
  the link's channel pairs are split by bytes-demand with the shared
  largest-remainder :func:`~repro.core.tenancy.apportion` helper.

A one-host cluster degenerates exactly to the unclustered session: no
peers means no migration candidates, and the per-host manager *is* the
PR 8 runtime — plans and virtual-time traces are bit-identical (golden-
pinned in ``tests/test_multihost.py``).
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any, Dict, List, Optional, Tuple

from ..core import backends as backends_mod
from ..core.perfmodel import (CalibrationConstants, InterconnectModel,
                              benefit, cross_host_cost, movement_cost)
from ..core.policy import PlanProgram, StageProvenance
from ..core.session import RuntimeConfig, Session
from ..core.tenancy import apportion
from ..core.tiers import MachineProfile


@dataclasses.dataclass(frozen=True)
class ShardMigration:
    """One coordinator decision for a surplus hot shard.

    ``mode`` records which side of the promotion-vs-pull choice won:
    ``"cross_host"`` re-homes the shard to ``dst_host`` over ``link``
    (``est_cost_s`` = the Eq. (4)-style unhidden link cost),
    ``"local_promote"`` keeps it on ``src_host`` and defers to the local
    planner's NVM->DRAM promotion (recorded so the global program shows
    the choice was *made*, not skipped)."""

    obj: str
    src_host: str
    dst_host: str
    size_bytes: int
    mode: str                   # "cross_host" | "local_promote"
    est_cost_s: float           # one-time migration cost (unhidden)
    est_benefit_s: float        # per-iteration benefit once re-homed
    link: str = ""              # pricing link name ("" for local)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


class HostTierManager:
    """One host's shard manager: an ordinary session over the host's own
    DRAM/NVM pair, tagged with the host id so every plan stage record,
    fault event and stats() row carries host provenance."""

    def __init__(self, host: str, machine: MachineProfile,
                 config: Optional[RuntimeConfig] = None,
                 cf: Optional[CalibrationConstants] = None,
                 session: Optional[Session] = None):
        self.host = host
        self.machine = machine
        if session is not None:
            if session.config.host != host:
                raise ValueError(
                    f"manager for {host!r} got a session tagged "
                    f"{session.config.host!r}; set RuntimeConfig.host so "
                    "provenance matches")
            self.session = session
        else:
            cfg = (dataclasses.replace(config, host=host)
                   if config is not None else RuntimeConfig(host=host))
            self.session = Session(machine, cfg, cf=cf)

    # ------------------------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.session.capacity

    def fast_demand_bytes(self) -> int:
        """Bytes the host's trafficked shards want resident."""
        return sum(self.session.registry[o].size_bytes
                   for o in self.shard_heat()
                   if o in self.session.registry)

    def shard_heat(self) -> Dict[str, float]:
        """Per-shard Eq. (1)-(3) benefit (seconds/iteration if served
        from fast instead of slow), summed over the profiled phases —
        the coordinator's common currency for cross-host comparison."""
        s = self.session
        heat: Dict[str, float] = {}
        if s.graph is None:
            return heat
        for ph in s.graph:
            for o, v in ph.refs.items():
                if v <= 0.0 or o not in s.registry:
                    continue
                p = s.profiler.profile(ph.index, o)
                if p is None:
                    continue
                heat[o] = heat.get(o, 0.0) + max(
                    0.0, benefit(p, s.machine, s.cf))
        return heat

    def stats(self) -> Dict[str, Any]:
        return self.session.stats()

    def __repr__(self) -> str:
        return f"HostTierManager({self.host!r}, {len(self.session.registry)} objects)"


class ClusterCoordinator:
    """Aggregates per-host tier managers into one global plan and decides
    cross-host shard migration (see module docstring).

    ``amortize_iters`` is the pull threshold: a cross-host migration is
    worth it when its one-time link cost is recovered within that many
    iterations of per-iteration benefit (the coordinator analogue of the
    planner's Eq. (5) weight staying positive over a plan epoch)."""

    def __init__(self, hosts: List[HostTierManager],
                 links: Optional[InterconnectModel] = None,
                 amortize_iters: float = 5.0, min_heat_s: float = 0.0):
        if not hosts:
            raise ValueError("a cluster needs at least one host manager")
        names = [m.host for m in hosts]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate host ids in cluster: {names}")
        self.hosts = list(hosts)
        self.links = links or InterconnectModel()
        self.amortize_iters = amortize_iters
        # shards below this per-iteration benefit are background noise:
        # they neither count as fast-tier demand nor become migration
        # candidates (Unimem's negligible-benefit cutoff, cluster level)
        self.min_heat_s = min_heat_s

    def _replicated(self) -> set:
        """Object names present in more than one host's registry — per-host
        replicas (trunk/router); they occupy capacity everywhere but are
        never migration candidates."""
        seen: Dict[str, int] = {}
        for m in self.hosts:
            for name in m.session.registry.names():
                seen[name] = seen.get(name, 0) + 1
        return {n for n, c in seen.items() if c > 1}

    # ------------------------------------------------------------------
    def manager(self, host: str) -> HostTierManager:
        for m in self.hosts:
            if m.host == host:
                return m
        raise KeyError(f"unknown host {host!r}")

    # ----------------------------------------------------- rebalance decision
    def plan_rebalance(self, *, overlap_window: float = 0.0
                       ) -> List[ShardMigration]:
        """The promotion-vs-pull chooser.

        Per overloaded host (hot-shard demand above fast capacity), keep
        the locally densest shards (benefit per byte) up to capacity;
        for each surplus shard compare the two feasible options —
        promote locally into remaining spare fast bytes (Eq. (4) against
        the host's copy engine) vs. pull to the peer with the most spare
        capacity (per-link :func:`cross_host_cost`) — and take the
        cheaper feasible one.  A pull must also amortize: one-time link
        cost below ``amortize_iters x`` the shard's per-iteration
        benefit.  One host (no peers) trivially yields no migrations."""
        replicated = self._replicated()
        # demand = non-pinned shards worth managing (above the heat cutoff);
        # pinned bytes are pre-paid capacity, handled separately
        heat = {m.host: {o: g for o, g in m.shard_heat().items()
                         if g > self.min_heat_s
                         and not m.session.registry[o].pinned}
                for m in self.hosts}
        sizes = {m.host: {o: m.session.registry[o].size_bytes
                          for o in heat[m.host]}
                 for m in self.hosts}
        pinned = {m.host: sum(
            obj.size_bytes for obj in m.session.registry if obj.pinned)
            for m in self.hosts}
        # spare fast bytes a peer can lend = capacity - its own hot demand
        spare = {m.host: m.capacity - pinned[m.host]
                 - sum(sizes[m.host].values()) for m in self.hosts}
        migrations: List[ShardMigration] = []
        for m in sorted(self.hosts, key=lambda m: spare[m.host]):
            host = m.host
            if spare[host] >= 0:
                continue                    # everything hot fits locally
            # keep the densest shards up to capacity; the rest is surplus
            budget = m.capacity - pinned[host]
            ranked = sorted(heat[host],
                            key=lambda o: (-heat[host][o]
                                           / max(1, sizes[host][o]), o))
            surplus: List[str] = []
            for o in ranked:
                if sizes[host][o] <= budget:
                    budget -= sizes[host][o]
                else:
                    surplus.append(o)
            local_spare = max(0, budget)
            for o in sorted(surplus, key=lambda o: (-heat[host][o], o)):
                size, gain = sizes[host][o], heat[host][o]
                if gain <= 0.0 or o in replicated:
                    continue    # replicas live on every host; never re-homed
                # option A: local NVM->DRAM promotion (needs spare bytes)
                local_cost = (movement_cost(size, m.machine, overlap_window)
                              if size <= local_spare else None)
                # option B: pull to the peer with the most spare capacity
                peers = [p for p in self.hosts
                         if p.host != host and spare[p.host] >= size]
                pull_cost = pull_to = link_name = None
                if peers:
                    peer = max(peers, key=lambda p: (spare[p.host], p.host))
                    link = self.links.link(host, peer.host)
                    pull_cost = cross_host_cost(size, link, overlap_window)
                    pull_to, link_name = peer.host, link.name
                if local_cost is not None and (pull_cost is None
                                               or local_cost <= pull_cost):
                    migrations.append(ShardMigration(
                        o, host, host, size, "local_promote",
                        local_cost, gain))
                    local_spare -= size
                elif (pull_cost is not None
                      and pull_cost <= self.amortize_iters * gain):
                    migrations.append(ShardMigration(
                        o, host, pull_to, size, "cross_host",
                        pull_cost, gain, link=link_name))
                    spare[pull_to] -= size
        return migrations

    # ------------------------------------------------------------- execution
    def make_backend(self, now_fn=None, on_land=None):
        """The registered ``"cross_host"`` engine wired to this cluster's
        link table (``on_land`` defaults to the registry re-homing hook)."""
        machine = self.hosts[0].machine
        return backends_mod.make_backend(
            "cross_host", machine, links=self.links, now_fn=now_fn,
            on_land=on_land if on_land is not None else self.rehome)

    def rehome(self, copy: Any) -> None:
        """Land-time handoff for a cross-host copy: the shard leaves the
        source host's registry and joins the destination's in the copy's
        destination tier."""
        src = self.manager(copy.src_host).session.registry
        dst = self.manager(copy.dst_host).session.registry
        name = copy.obj.name
        if name in src:
            src.remove(name)
        if name not in dst:
            dst.alloc(name, copy.obj.size_bytes, tier=copy.dst)
        else:
            dst[name].tier = copy.dst

    def execute_migrations(self, migrations: List[ShardMigration],
                           backend: Any, now: float = 0.0
                           ) -> Tuple[float, List[Any]]:
        """Issue the cross-host pulls on the send/recv engine and settle.

        Each source host's egress link pairs are **apportioned across the
        destination hosts by bytes demand** (the shared largest-remainder
        helper's third call site): a destination granted ``k`` pairs runs
        at most ``k`` of its transfers concurrently, later ones chain
        behind earlier handles — several pulls to one peer cannot starve
        the others.  Returns (wall seconds until the last landing,
        handles)."""
        by_src: Dict[str, List[ShardMigration]] = defaultdict(list)
        for mig in migrations:
            if mig.mode == "cross_host":
                by_src[mig.src_host].append(mig)
        handles: List[Any] = []
        for src in sorted(by_src):
            migs = by_src[src]
            pairs = min(self.links.link(src, mig.dst_host).channel_pairs
                        for mig in migs)
            demand = defaultdict(int)
            for mig in migs:
                demand[mig.dst_host] += mig.size_bytes
            total = sum(demand.values()) or 1
            quota = {d: pairs * b / total for d, b in demand.items()}
            shares = apportion(pairs, quota)
            tails: Dict[Tuple[str, int], Any] = {}
            slot_rr: Dict[str, int] = defaultdict(int)
            for mig in sorted(migs, key=lambda g: (g.dst_host, g.obj)):
                slots = max(1, shares.get(mig.dst_host, 0))
                slot = slot_rr[mig.dst_host] % slots
                slot_rr[mig.dst_host] += 1
                obj = self.manager(src).session.registry[mig.obj]
                h = backend.start_move(
                    obj, "fast", src_host=src, dst_host=mig.dst_host,
                    after=tails.get((mig.dst_host, slot)))
                tails[(mig.dst_host, slot)] = h
                handles.append(h)
        if not handles:
            return 0.0, []
        done = max(h.done for h in handles)
        backend.settle(done)
        return max(0.0, done - now), handles

    # ------------------------------------------------------------ aggregation
    def aggregate_program(self, migrations: Optional[List[ShardMigration]]
                          = None) -> PlanProgram:
        """The global plan: per-host residency sections + the migration
        list, with every host's stage provenance (already host-stamped by
        the per-host pipelines) concatenated.  Cluster iteration time is
        the slowest host's (hosts run in parallel), so predicted/baseline
        are maxes, not sums."""
        sections: Dict[str, Any] = {}
        provenance: List[StageProvenance] = []
        predicted = baseline = 0.0
        capacity = 0
        for m in self.hosts:
            plan, s = m.session.plan, m.session
            sec: Dict[str, Any] = dict(
                capacity_bytes=s.capacity,
                n_objects=len(s.registry),
                fast_resident_bytes=s.registry.bytes_in_tier("fast"))
            if plan is not None:
                sec.update(
                    strategy=plan.strategy,
                    predicted_iteration_time=plan.predicted_iteration_time,
                    baseline_iteration_time=plan.baseline_iteration_time,
                    residents=[sorted(r) for r in plan.residents],
                    n_moves=len(plan.moves))
                predicted = max(predicted, plan.predicted_iteration_time)
                baseline = max(baseline, plan.baseline_iteration_time)
                if isinstance(plan, PlanProgram):
                    provenance.extend(plan.provenance)
            sections[m.host] = sec
            capacity += s.capacity
        return PlanProgram(
            strategy="cluster", residents=[], moves=[],
            predicted_iteration_time=predicted,
            baseline_iteration_time=baseline,
            policy="cluster", provenance=provenance,
            capacity_bytes=capacity, host_sections=sections,
            migrations=[mig.to_dict() for mig in (migrations or [])])

    def stats(self) -> Dict[str, Any]:
        """Cluster rollup: per-host sections plus cross-host counters."""
        per_host = {m.host: m.stats() for m in self.hosts}
        return dict(
            n_hosts=len(self.hosts),
            hosts=per_host,
            n_moves=sum(s["n_moves"] for s in per_host.values()),
            moved_bytes=sum(s["moved_bytes"] for s in per_host.values()),
            n_degraded_serves=sum(s["n_degraded_serves"]
                                  for s in per_host.values()),
            n_replans=sum(s["n_replans"] for s in per_host.values()),
        )
