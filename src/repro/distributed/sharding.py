"""Sharding rules: DP/FSDP x TP x EP x SP over the production mesh.

Axis roles
----------
``("pod", "data")``  — data parallel + FSDP (ZeRO-3 parameter/optimizer
                       sharding over the *full* DP extent)
``"model"``          — tensor parallel (Megatron splits), expert parallel
                       (MoE expert dim), and head-parallel KV caches
sequence (SP)        — long-context caches shard their sequence dim over
                       ``"data"`` when batch < DP extent (long_500k).

Every rule passes through :func:`fit` which drops mesh axes that do not
divide the corresponding dimension (e.g. gemma's 8 q-heads on a 16-way
model axis shard the fused head*dim instead) — this is what makes all
(arch x shape x mesh) cells compile without per-cell hand tuning.
"""

from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeConfig

AxisName = Union[str, Tuple[str, ...], None]

# Flat-DP mode: small models waste the "model" axis on tensor parallelism
# (every TP collective is pure overhead when a layer fits one chip).  When
# enabled, the "model" axis joins the DP group and TP placements are
# dropped — a perf-profile knob, not a default.
_FLAT_DP = False


def set_flat_dp(value: bool) -> None:
    global _FLAT_DP
    _FLAT_DP = value


def flat_dp() -> bool:
    return _FLAT_DP


def mesh_axis_size(mesh: Mesh, axis: AxisName) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def dp_axes(mesh: Mesh) -> AxisName:
    base = ("pod", "data") if "pod" in mesh.shape else ("data",)
    return base + ("model",) if _FLAT_DP else base


def fit(mesh: Mesh, shape: Tuple[int, ...], *axes: AxisName) -> P:
    """Build a PartitionSpec, dropping axes that don't divide the dim."""
    assert len(axes) == len(shape), (shape, axes)
    if _FLAT_DP:
        axes = tuple(None if ax == "model" else ax for ax in axes)
    out = []
    for dim, ax in zip(shape, axes):
        if ax is None:
            out.append(None)
            continue
        cand = ax if isinstance(ax, tuple) else (ax,)
        # keep the longest prefix of axes whose product divides dim
        kept = []
        prod = 1
        for a in cand:
            if a not in mesh.shape:
                continue
            if dim % (prod * mesh.shape[a]) == 0:
                kept.append(a)
                prod *= mesh.shape[a]
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


# ---------------------------------------------------------------------------
# parameter rules (path-regex -> axis roles per dimension, minus leading L)
# ---------------------------------------------------------------------------
def _param_axes(path: str, ndim: int, dp: AxisName, tied: bool = False):
    """Returns per-dim axis roles for a (possibly L-stacked) parameter."""
    # Embedding: d-sharded for untied archs (gather/scatter fully local per
    # d-slice); vocab-sharded when the table doubles as the LM head (tied)
    # so logits stay vocab-parallel.
    embed_axes = ("model", None) if tied else (None, "model")
    rules = [
        # attention
        (r"attn/w[qkv]$", (dp, "model")),
        (r"attn/wo$", ("model", dp)),
        (r"attn/b[qkv]$", ("model",)),
        # dense mlp
        (r"mlp/w_(gate|up)$", (dp, "model")),
        (r"mlp/w_down$", ("model", dp)),
        # shared experts
        (r"moe/shared_(gate|up)$", (dp, "model")),
        (r"moe/shared_down$", ("model", dp)),
        # moe experts: EP on expert dim + FSDP inside
        (r"moe/router$", (dp, None)),
        (r"moe/w_(gate|up)$", ("model", dp, None)),
        (r"moe/w_down$", ("model", None, dp)),
        # mamba2
        (r"in_proj$", (dp, "model")),
        (r"out_proj$", ("model", dp)),
        (r"conv_w$", (None, "model")),
        (r"conv_b$", ("model",)),
        (r"(a_log|dt_bias|d_skip)$", (None,)),
        # xlstm
        (r"o_gate$", (dp, "model")),
        (r"w_gates$", (dp, "model")),
        (r"r_gates$", (None, None, "model")),
        # embeddings / head (see embed_axes above)
        (r"embed$", embed_axes),
        (r"head$", (None, "model")),
        (r"frontend_proj$", (dp, "model")),
        # norms and everything else small: replicated
        (r".*", tuple([None] * ndim)),
    ]
    for pat, axes in rules:
        if re.search(pat, path):
            axes = tuple(axes)
            if len(axes) < ndim:      # L-stacked: leading layer dim(s)
                axes = tuple([None] * (ndim - len(axes))) + axes
            return axes[:ndim]
    raise AssertionError("unreachable")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(mesh: Mesh, params_shapes: Any, *,
                tied: Optional[bool] = None) -> Any:
    """PartitionSpecs for a params pytree (of ShapeDtypeStruct or arrays)."""
    dp = dp_axes(mesh)
    if tied is None:
        tied = not any("head" in _path_str(p) for p, _ in
                       jax.tree_util.tree_flatten_with_path(params_shapes)[0])

    def spec(path, leaf):
        shape = leaf.shape
        axes = _param_axes(_path_str(path), len(shape), dp, tied)
        return fit(mesh, shape, *axes)

    return jax.tree_util.tree_map_with_path(spec, params_shapes)


def opt_specs(mesh: Mesh, opt_shapes: Any, params_shapes: Any,
              pspecs: Any) -> Any:
    """Optimizer state mirrors parameter sharding (same-shape leaves)."""
    flat_params = {l.shape: s for l, s in zip(
        jax.tree_util.tree_leaves(params_shapes),
        jax.tree_util.tree_leaves(pspecs))}
    dp = dp_axes(mesh)

    def spec(path, leaf):
        if leaf.ndim == 0:
            return P()
        if leaf.shape in flat_params:
            return flat_params[leaf.shape]
        # fallback (quantized moments etc.): FSDP on the largest dim
        axes = [None] * leaf.ndim
        axes[int(np.argmax(leaf.shape))] = dp
        return fit(mesh, leaf.shape, *axes)

    return jax.tree_util.tree_map_with_path(spec, opt_shapes)


# ---------------------------------------------------------------------------
def batch_specs(mesh: Mesh, cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, P]:
    dp = dp_axes(mesh)
    out = {"tokens": fit(mesh, (shape.global_batch, shape.seq_len), dp, None),
           "labels": fit(mesh, (shape.global_batch, shape.seq_len), dp, None)}
    if cfg.frontend:
        out["frontend"] = fit(
            mesh, (shape.global_batch, cfg.frontend_tokens, cfg.d_model),
            dp, None, "model")
    return out


def cache_specs(mesh: Mesh, cfg: ArchConfig, cache_shapes: Any,
                batch: int) -> Any:
    """KV/state cache sharding.  Batch over DP when divisible; otherwise SP:
    shard the sequence dim over "data" (long_500k, batch=1)."""
    dp = dp_axes(mesh)
    batch_ok = batch % mesh_axis_size(mesh, dp) == 0

    def spec(path, leaf):
        p = _path_str(path)
        shape = leaf.shape
        if re.search(r"(^|/)(k|v)$", p):        # (L_or_apps, B, S, K, Dh)
            if batch_ok:
                s = fit(mesh, shape, None, dp, None, "model", None)
                if s[3] is None:
                    # few KV heads (MQA/GQA) cannot split 16-way: shard the
                    # sequence instead (SP cache, flash-decoding style)
                    s = fit(mesh, shape, None, dp, "model", None, None)
                return s
            return fit(mesh, shape, None, None, "data", "model", None)
        if "conv" in p:                          # (L, B, W, C)
            return fit(mesh, shape, None, dp if batch_ok else None,
                       None, "model")
        if "ssm" in p or "state" in p:           # (L, B, H, N, P)
            return fit(mesh, shape, None, dp if batch_ok else None,
                       "model", None, None)
        if leaf.ndim >= 2:                       # slstm h/c/n/m: (L, B, H, P)
            axes = [None] * leaf.ndim
            if batch_ok and leaf.ndim >= 2:
                axes[1] = dp
            return fit(mesh, shape, *axes)
        return P()

    return jax.tree_util.tree_map_with_path(spec, cache_shapes)


def shardings(mesh: Mesh, specs: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
