"""Pipeline parallelism: GPipe-style microbatch schedule over a ``stage``
axis with ``shard_map`` + ``lax.ppermute``.

Off by default (the assigned shapes fit DP x TP), provided as the PP
building block for >2-pod scale-out: stages hold disjoint layer ranges;
microbatches stream through with boundary activations handed to the next
stage by ``ppermute``.  The bubble fraction is (S-1)/(M+S-1) for S stages
and M microbatches.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_forward(layer_fn: Callable, n_stages: int, n_microbatches: int,
                     mesh: Mesh, stage_axis: str = "stage"):
    """Returns fn(stage_params, x_microbatches) -> y_microbatches.

    ``stage_params``: pytree with leading stage dim (sharded over
    ``stage_axis``); ``x_microbatches``: (M, mb, ...) inputs.
    ``layer_fn(params_for_stage, x) -> x``.
    """

    def stage_body(params_local, xs_local):
        # params_local: this stage's params (leading dim 1); xs: (M, mb, ...)
        params = jax.tree_util.tree_map(lambda p: p[0], params_local)
        sid = jax.lax.axis_index(stage_axis)
        M = xs_local.shape[0]
        S = n_stages
        n_ticks = M + S - 1
        perm = [(i, (i + 1) % S) for i in range(S)]

        def tick(carry, t):
            buf, outs = carry          # buf: (mb, ...) current stage input
            mb_idx = t - sid
            take = jnp.logical_and(mb_idx >= 0, mb_idx < M)
            x_in = jnp.where(
                sid == 0,
                xs_local[jnp.clip(mb_idx, 0, M - 1)],
                buf)
            y = layer_fn(params, x_in)
            y = jnp.where(take[..., None, None] if y.ndim > 2 else take, y,
                          jnp.zeros_like(y))
            # hand off to next stage
            nxt = jax.lax.ppermute(y, stage_axis, perm)
            out_idx = t - (S - 1)
            is_out = jnp.logical_and(sid == S - 1,
                                     jnp.logical_and(out_idx >= 0,
                                                     out_idx < M))
            outs = jax.lax.cond(
                is_out,
                lambda o: o.at[jnp.clip(out_idx, 0, M - 1)].set(y),
                lambda o: o, outs)
            return (nxt, outs), None

        buf0 = jnp.zeros_like(xs_local[0])
        outs0 = jnp.zeros_like(xs_local)
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0),
                                    jnp.arange(n_ticks))
        return outs

    return shard_map(
        stage_body, mesh=mesh,
        in_specs=(P(stage_axis), P(None)),
        out_specs=P(None),
        check_rep=False)
