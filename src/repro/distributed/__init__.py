from . import sharding
from .coordinator import ClusterCoordinator, HostTierManager, ShardMigration

__all__ = ["sharding", "ClusterCoordinator", "HostTierManager",
           "ShardMigration"]
