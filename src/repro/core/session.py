"""Runtime session — the v2 user-facing surface of the Unimem runtime.

The paper's Table-2 API (``unimem_malloc`` / ``unimem_start`` /
``unimem_end``) is imperative: every driver repeats the same
``alloc -> start_loop -> begin_iteration -> phase_begin/phase_end``
choreography and hand-feeds instrumentation dicts into each ``phase_end``.
The session keeps the paper's workflow (Fig 8: profile -> model -> plan ->
move -> monitor) but makes the instrumented path the zero-effort path:

* :meth:`register` is **pytree-native**: pass a JAX pytree (arrays or
  ``ShapeDtypeStruct``\\ s) and the session records the object's size *and*
  each leaf's byte span, so chunk attribution can align to leaf boundaries
  and :class:`~.instrumentation.XlaCostAnalysisSource` can map compiled
  programs back onto the object.
* the loop is two context managers — ``with rt.iteration():`` around the
  step, ``with rt.phase("fwd"):`` around each phase.  Phases
  **auto-register on first use** (no upfront name list), timing is
  captured by the context, and an exception can never leave a phase open.
* instrumentation comes from a pluggable
  :class:`~.instrumentation.InstrumentationSource` (manual dicts, the
  simulator's physics, XLA cost analysis); explicit keyword overrides on
  ``phase(...)`` always win.
* the copy engine is resolved from the string-keyed backend registry
  (``RuntimeConfig.backend`` -> :mod:`.backends`), not constructor wiring.

``UnimemRuntime`` (:mod:`.runtime`) subclasses this session and keeps the
old imperative methods as deprecated shims, so every pre-v2 driver runs
unchanged — and produces bit-identical plans, since the shims delegate to
the same internals (parity-tested in ``tests/test_api_v2.py``).

Workflow semantics (unchanged from the paper + earlier PRs): iteration 1
profiles each phase; at its end the planner builds a placement plan (best
of phase-local / cross-phase-global); from iteration 2 on the proactive
mover enforces the plan and the variation monitor re-triggers profiling on
>10% drift — incrementally by default (the plan is never dropped once
built; see ``RuntimeConfig.incremental_replan``).
"""

from __future__ import annotations

import contextlib
import copy
import dataclasses
import time as _time
from typing import Any, Dict, List, Optional, Sequence

from . import backends as backends_mod
from . import initial as initial_mod
from . import perfmodel
from . import policy as policy_mod
from .data_objects import DataObject, ObjectRegistry
from .faults import (ChaosBackend, CopyError, DegradedServe, FaultLog,
                     FaultSpec)
from .instrumentation import InstrumentationSource, PhaseSample
from .monitor import VariationMonitor
from .mover import (ProactiveMover, SlackAwareMover, TierBackend,
                    _handle_orphaned)
from .perfmodel import CalibrationConstants
from .phase import Phase, PhaseGraph, PhaseTraceEvent
from .planner import MoveOp, PlacementPlan, Planner, emit_schedule
from .profiler import PhaseProfiler
from .tenancy import TenantHandle, TenantSpec, tenant_of
from .tiers import MachineProfile


@dataclasses.dataclass
class RuntimeConfig:
    fast_capacity_bytes: Optional[int] = None   # default: machine.fast.capacity
    enable_initial_placement: bool = True
    enable_partitioning: bool = True
    enable_local_search: bool = True
    enable_global_search: bool = True
    drift_threshold: float = 0.10
    profile_iterations: int = 1
    seed: int = 0
    # Migration engine: "slack" = slack-aware multi-channel scheduler (the
    # overlap engine), "fifo" = the paper's single-queue phase-boundary mover.
    mover: str = "slack"
    copy_channels: int = 2          # concurrent copy channels ("slack" only)
    # Copy backend, resolved through the string-keyed registry
    # (:mod:`repro.core.backends`): "jax" = blocking device_put, "jax_async"
    # = async device_put with per-leaf fencing, "sim" = the simulated copy
    # engine (the simulator installs its own clock-wired instance).
    backend: str = "jax"
    # Hot-chunk placement pipeline: ingest per-chunk attribution
    # (access_bins), partition along the measured access CDF, attribute
    # chunk references from histogram mass.  False reproduces the paper's
    # object-granularity profiling + equal chunking.
    chunk_aware: bool = True
    # Drift response: keep serving the current plan while re-profiling, then
    # emit only the diff moves.  False restores the paper's full reset
    # (plan dropped, iterations served unplaced until re-profiled).
    incremental_replan: bool = True
    # How much accumulated profile weight survives a drift event (0 = start
    # from scratch, 1 = new observations barely move the running means).
    replan_decay: float = 0.25
    # Placement policy, resolved through the string-keyed policy registry
    # (:mod:`repro.core.policy`): the pipeline of attribute -> partition ->
    # coalesce -> solve -> schedule stages that turns profiles into a
    # PlanProgram.  "unimem" is the paper's planner.
    policy: str = "unimem"
    # Re-merge adjacent chunks whose measured densities converged and whose
    # tiers agree (caps chunk-registry growth across drift sequences).
    coalesce: bool = True
    # Scoped replanning: with a standing program, re-solve only the phases
    # whose solve inputs changed (O(affected phases), provably equal to a
    # full replan).  False always re-solves every phase.
    scoped_replan: bool = True
    # Snap partition cuts to registered pytree leaf boundaries so chunks
    # are moveable as whole arrays on real backends (no sub-leaf copies).
    leaf_aligned: bool = False
    # Multi-resolution profiling histograms (core/histogram.py): total bin
    # budget per measured (phase, object) histogram.  None accumulates at
    # the instrumentation's native uniform resolution — the legacy
    # fixed-width behavior, bit-identical plans included.
    histogram_bins: Optional[int] = None
    # Adaptive refinement: between profiling iterations, hot bins re-bin
    # finer (down to the budget's min width) while cold regions coarsen to
    # pay for it, so the next iteration's samples resolve the hot head —
    # and the partitioner may cut hot-head chunks below the legacy one-bin
    # ceiling (re-splitting previously coalesced chunks when drift
    # re-heats them).  Off by default: plans stay bit-identical to the
    # fixed-width pipeline.
    histogram_refine: bool = False
    # Per-channel priorities for the simulated multi-channel copy engine
    # (e.g. [0, 1] reserves channel 1 for urgent fetches: bulk demotion
    # evictions may only use the minimum-priority channels and can never
    # head-of-line-block a fetch).  None = all channels equal (legacy).
    copy_channel_priorities: Optional[Sequence[int]] = None
    # Online calibration feedback (perfmodel.fold_online): after each
    # (re)plan settles, regress the plan's per-phase predicted gains
    # against the measured phase times, fold per-class correction factors
    # into CalibrationConstants.cf_bw/cf_lat (the two benefit classes can
    # be mis-calibrated in opposite directions) and a movement-price
    # factor from measured fence stalls into cf_move, then rebuild the
    # plan under the corrected model.  Off by default — all folds are
    # multiplicative with neutral 1.0 factors, so every plan is
    # bit-identical to the pre-feedback pipeline.
    calibrate_feedback: bool = False
    # Max correction/rebuild rounds per plan epoch (a profiling-driven
    # build re-arms the budget; each recalibration rebuild re-measures).
    calibration_rounds: int = 3
    # Relative |predicted - measured| / measured below which the model
    # counts as calibrated and no correction fires.
    calibration_tolerance: float = 0.10
    # EMA blend toward the regression target (1.0 jumps straight there).
    calibration_blend: float = 1.0
    # Interval-guidance policy (policy="interval", Olson et al. style):
    # per-interval exponential decay of the access-heat ranking.
    interval_decay: float = 0.6
    # Fault injection (core/faults.py): a seeded FaultSpec wraps the
    # resolved backend in a ChaosBackend.  None (default) injects nothing
    # and leaves every plan/trace bitwise identical to the fault-free
    # pipeline.
    fault_spec: Optional[FaultSpec] = None
    # Max transient start_move failures retried per move (the backoff is
    # additionally bounded by the move's slack deadline).
    copy_retry_limit: int = 3
    # Straggler threshold: an in-flight copy exceeding this factor times
    # its priced full-bandwidth time is cancelled and reissued on another
    # channel; the same factor bounds fence waits (deadline abandonment,
    # the no-deadlock guarantee against stuck handles).  None resolves to
    # 4.0 when a fault_spec is set (channel contention alone legitimately
    # costs up to copy_channels x) and stays off otherwise.
    straggler_factor: Optional[float] = None
    # Ring-buffer bound on session.fault_log: long-running chaos/serving
    # loops keep only the most recent entries while the dropped-entry
    # counter keeps provenance counts exact.  0/None = unbounded.
    fault_log_limit: int = 1024
    # Continuous calibration: with calibrate_feedback on, re-arm a
    # measurement (and fold, if the error warrants one) every Nth
    # iteration instead of only once per (re)plan epoch — the background
    # controller for drift the monitor's threshold never trips.  None
    # (default) keeps the per-epoch cadence and is bitwise identical.
    calibrate_every: Optional[int] = None
    # Admission control (bandwidth_partition policy): a tenant whose
    # access density falls below this fraction of the mean across
    # trafficked tenants is demoted to serve-from-slow.  0 disables.
    tenant_admission_heat: float = 0.1
    # Optional churn guard: a tenant whose per-phase hot set exceeds this
    # factor times its capacity share is demoted (its share could never
    # hold a useful fraction of any working set).  None = off.
    tenant_churn_guard: Optional[float] = None
    # Cluster host id this session manages a shard for (None = the
    # unclustered single-host path, bitwise identical to PR 8).  Threads
    # host provenance through plan stage records, fault_log events and
    # stats(), and gives the chaos backend its per-host RNG sub-stream.
    host: Optional[str] = None


@dataclasses.dataclass
class PhaseContext:
    """Handle yielded by ``with rt.phase(...) as pc`` — carries the fence
    stall absorbed at entry and, after exit, the recorded elapsed time and
    the instrumentation sample that was folded into the profiler."""

    name: str
    index: int
    stall_s: float = 0.0
    elapsed: float = 0.0
    sample: Optional[PhaseSample] = None


@dataclasses.dataclass
class TierAudit:
    """Result of :meth:`Session.audit_tiers`: the invariant violations
    found before healing, whether a corrective heal ran, and whether the
    post-heal re-check came back clean."""

    violations: List[str]
    healed: bool = False
    clean_after_heal: bool = True

    @property
    def ok(self) -> bool:
        return not self.violations


class Session:
    """The v2 runtime session (see module docstring)."""

    def __init__(self, machine: MachineProfile,
                 config: Optional[RuntimeConfig] = None,
                 backend: Optional[TierBackend] = None,
                 cf: Optional[CalibrationConstants] = None):
        self.machine = machine
        self.config = config or RuntimeConfig()
        self.registry = ObjectRegistry()
        self.backend = backend if backend is not None else \
            backends_mod.make_backend(
                self.config.backend, machine,
                mover=self.config.mover, channels=self.config.copy_channels,
                priorities=self.config.copy_channel_priorities,
                fault_spec=self.config.fault_spec, host=self.config.host)
        if (self.config.fault_spec is not None
                and not isinstance(self.backend, ChaosBackend)):
            # any backend (including one passed in) gains the configured
            # fault profile; the "chaos" factory already wrapped its inner
            self.backend = ChaosBackend(self.backend, self.config.fault_spec,
                                        host=self.config.host)
        self.cf = cf or CalibrationConstants()
        self.capacity = (self.config.fast_capacity_bytes
                         if self.config.fast_capacity_bytes is not None
                         else machine.fast.capacity_bytes)
        self.profiler = PhaseProfiler(
            machine, seed=self.config.seed,
            hist_bins=self.config.histogram_bins,
            hist_refine=self.config.histogram_refine)
        self.monitor = VariationMonitor(threshold=self.config.drift_threshold)
        self.planner = Planner(
            machine, self.registry, self.cf, self.capacity,
            enact_consistent=self.config.histogram_refine)
        self.policy = policy_mod.make_policy(self.config.policy)
        self.mover: Optional[ProactiveMover] = None
        self.plan: Optional[PlacementPlan] = None
        self.graph: Optional[PhaseGraph] = None
        self.source: Optional[InstrumentationSource] = None
        self._phase_names: List[str] = []
        self._phase_ids: Dict[str, int] = {}
        self._loop_started = False
        self._iter_open = False
        self._open_phase: Optional[str] = None
        self._iteration = 0
        self._events_this_iter: List[PhaseTraceEvent] = []
        self._profiling = True
        self._profiled_iters = 0
        self._baseline_pending = False
        self._plan_n_phases = 0     # phase count the live plan was built on
        # Scoped drift response: the phase indices being re-profiled (None
        # = every phase).  Set by _reprofile, consumed until the rebuild.
        self._drift_scope: Optional[set] = None
        self._static_refs: Dict[str, float] = {}
        self.n_replans = 0              # drift-triggered replan cycles
        self.n_incremental_replans = 0  # ... served without dropping the plan
        # Calibration feedback state: per-iteration measurement
        # accumulators, the per-plan-epoch correction budget, and the flag
        # that invalidates standing-plan reuse after a CF change (a cf
        # change moves every cached benefit without touching any reuse
        # fingerprint, so scoped reuse must be bypassed wholesale).
        self._iter_stall_s = 0.0
        self._iter_elapsed_s = 0.0
        self._iter_phase_elapsed: Dict[int, float] = {}
        self._measuring_baseline = False
        self._measure_pending = False
        self._cal_rounds_left = 0
        self._cf_dirty = False
        # best measured iteration this plan epoch and the constants that
        # produced it — the feedback's safety net: a fold that makes the
        # *measured* iteration worse is reverted, so calibration can only
        # keep a model whose plan demonstrably improved the workload
        self._cal_best: Optional[tuple] = None
        # profiler state frozen at the first fold of an epoch, so a revert
        # re-solves from the same inputs that produced the epoch's best plan
        self._cal_snapshot: Optional[dict] = None
        self.n_recalibrations = 0       # CF folds applied by the feedback
        self.last_measured_iteration_time: Optional[float] = None
        self.last_pred_err: Optional[float] = None
        # Fault-tolerance bookkeeping: the session-level log of
        # DegradedServe/EvictionRollback events (stamped with iteration),
        # the audit counters, and the per-iteration/per-epoch flags that
        # trigger auto-audits and fault provenance.
        self.fault_log = FaultLog(self.config.fault_log_limit)
        self.n_degraded_serves = 0
        self.n_eviction_rollbacks = 0
        self.n_admission_demotions = 0
        # Tenant namespaces (core/tenancy.py): declared QoS contracts,
        # consumed by the bandwidth_partition policy and fault provenance.
        self.tenants: Dict[str, TenantSpec] = {}
        self.n_audits = 0
        self.n_audit_violations = 0
        self.n_heals = 0
        self._faults_this_iter = False
        self._degraded_phases: set = set()      # cleared each iteration
        self._degraded_since_plan = 0
        self._rollbacks_since_plan = 0

    # ------------------------------------------------------------ registration
    def register(self, name: str, spec: Any = None, *,
                 size_bytes: Optional[int] = None,
                 payload: Any = None, chunkable: bool = False,
                 pinned: bool = False,
                 static_refs: Optional[float] = None,
                 manage_payload: Optional[bool] = None) -> DataObject:
        """``unimem_malloc``, pytree-native.

        ``spec`` may be an integer byte size or a JAX pytree whose leaves
        carry ``shape``/``dtype`` (real arrays or ``ShapeDtypeStruct``\\ s);
        for a pytree, each leaf's byte span is recorded on the object so
        downstream attribution can align to leaf boundaries.  Concrete
        array pytrees are kept as the object's movable ``payload`` unless
        ``manage_payload=False`` (register sizes only — the runtime then
        tracks tiers logically, e.g. for donated training state).
        ``static_refs`` feeds the initial-placement compiler analysis."""
        leaf_spans = None
        if spec is not None:
            if isinstance(spec, int):
                size_bytes = spec
            else:
                import jax
                leaves_with_path = jax.tree_util.tree_flatten_with_path(spec)[0]
                spans, off, concrete = [], 0, True
                for path, leaf in leaves_with_path:
                    shape = getattr(leaf, "shape", ())
                    dtype = getattr(leaf, "dtype", None)
                    if dtype is None:
                        raise TypeError(
                            f"leaf {jax.tree_util.keystr(path)} of {name!r} "
                            "has no shape/dtype; register(size_bytes=...) "
                            "for opaque objects")
                    nbytes = int(dtype.itemsize)
                    for d in shape:
                        nbytes *= int(d)
                    spans.append((jax.tree_util.keystr(path), off, nbytes))
                    off += nbytes
                    if isinstance(leaf, jax.ShapeDtypeStruct):
                        concrete = False
                leaf_spans = spans
                size_bytes = off
                if payload is None and concrete and manage_payload is not False:
                    payload = spec
        if size_bytes is None:
            if payload is None:
                raise ValueError(f"register({name!r}): need a pytree spec, "
                                 "size_bytes, or payload")
            import jax
            size_bytes = sum(l.size * l.dtype.itemsize
                             for l in jax.tree_util.tree_leaves(payload))
        obj = self.registry.alloc(name, int(size_bytes), chunkable=chunkable,
                                  payload=payload, pinned=pinned)
        obj.leaf_spans = leaf_spans
        if static_refs is not None:
            self._static_refs[name] = static_refs
        return obj

    def tenant(self, name: str, *, priority: float = 1.0,
               slo: float = 1.0) -> TenantHandle:
        """Declare (or re-fetch) a tenant namespace.

        The returned handle scopes ``register``/``phase`` under
        ``"<name>/"``, so two tenants may both register a ``"kv"`` object
        (distinct qualified names) while a same-tenant duplicate still
        trips the registry's duplicate check.  Access attribution,
        profiles, capacity/channel shares, and fault-log entries all
        carry the tenant id via the name prefix.  Re-declaring an
        existing tenant with different QoS parameters is an error —
        contracts don't silently drift mid-run."""
        spec = TenantSpec(name, priority=priority, slo=slo)
        have = self.tenants.get(name)
        if have is not None:
            if have != spec:
                raise ValueError(
                    f"tenant {name!r} already declared with "
                    f"priority={have.priority:g}, slo={have.slo:g}")
            return TenantHandle(self, have)
        self.tenants[name] = spec
        return TenantHandle(self, spec)

    def attach_source(self, source: Optional[InstrumentationSource]) -> None:
        """Install the instrumentation source consulted at every phase exit
        (explicit keyword overrides on ``phase(...)`` still win)."""
        self.source = source

    # ------------------------------------------------------------- loop set-up
    def _resolved_straggler_factor(self) -> Optional[float]:
        """Explicit config wins; otherwise straggler detection arms itself
        (factor 4.0) whenever faults are injected — the no-deadlock
        guarantee against stuck handles — and stays off fault-free."""
        if self.config.straggler_factor is not None:
            return self.config.straggler_factor
        return 4.0 if self.config.fault_spec is not None else None

    def _make_mover(self):
        if self.config.mover == "slack":
            return SlackAwareMover(
                self.registry, self.backend,
                retry_limit=self.config.copy_retry_limit,
                straggler_factor=self._resolved_straggler_factor())
        if self.config.mover == "fifo":
            return ProactiveMover(self.registry, self.backend,
                                  retry_limit=self.config.copy_retry_limit)
        raise ValueError(f"unknown mover {self.config.mover!r}")

    def _start_loop(self, phase_names: Sequence[str]) -> None:
        """(Re)initialize loop state.  A re-entered loop must not inherit
        the previous loop's plan, drift baselines, or accumulated profiles
        (the ``start_loop`` re-entry bug): everything derived from profiled
        iterations is reset here."""
        self._phase_names = list(phase_names)
        self._phase_ids = {n: i for i, n in enumerate(self._phase_names)}
        self._iteration = 0
        self._profiling = True
        self._profiled_iters = 0
        self.plan = None
        self._baseline_pending = False
        self._plan_n_phases = 0
        self._drift_scope = None
        self._events_this_iter = []
        self._iter_open = False
        self._open_phase = None
        self._iter_stall_s = 0.0
        self._iter_elapsed_s = 0.0
        self._iter_phase_elapsed = {}
        self._measuring_baseline = False
        self._measure_pending = False
        self._cal_rounds_left = 0
        self._cf_dirty = False
        self._cal_best = None
        self._cal_snapshot = None
        self.last_measured_iteration_time = None
        self.last_pred_err = None
        self._faults_this_iter = False
        self._degraded_phases = set()
        self._degraded_since_plan = 0
        self._rollbacks_since_plan = 0
        self.profiler.clear()
        self.monitor = VariationMonitor(threshold=self.config.drift_threshold)
        self.graph = PhaseGraph(
            [Phase(i, n) for i, n in enumerate(self._phase_names)])
        self.mover = self._make_mover()
        self._loop_started = True
        if self.config.enable_initial_placement and self._static_refs:
            placed = initial_mod.initial_placement(
                self.registry, self._static_refs, self.capacity)
            place = getattr(self.backend, "place", None)
            for name in placed:
                if place is not None:   # allocation-time placement: no copy
                    place(self.registry[name], "fast")
                else:
                    try:
                        self.backend.start_move(self.registry[name], "fast")
                    except CopyError:
                        # initial placement is a best-effort hint — a
                        # failed placement copy just means the object
                        # starts slow and the plan fetches it later
                        continue

    def _ensure_loop(self) -> None:
        if not self._loop_started:
            self._start_loop([])

    def _phase_id(self, name: str) -> int:
        """Resolve a phase name, auto-registering it on first use."""
        idx = self._phase_ids.get(name)
        if idx is not None:
            return idx
        idx = len(self._phase_names)
        self._phase_ids[name] = idx
        self._phase_names.append(name)
        if self.graph is not None:
            self.graph.phases.append(Phase(idx, name))
        return idx

    # --------------------------------------------------------------- contexts
    @contextlib.contextmanager
    def iteration(self):
        """One main-loop iteration (``unimem_start``/``unimem_end``): the
        loop auto-starts on first entry; profiling, planning and drift
        bookkeeping run at exit.  An exception abandons the iteration's
        buffered events so the next iteration starts clean."""
        self._ensure_loop()
        if self._iter_open:
            raise RuntimeError("iterations cannot nest")
        self._begin_iteration()
        try:
            yield self
        except BaseException:
            self._iter_open = False
            self._open_phase = None
            self._events_this_iter = []
            raise
        self._end_iteration()

    @contextlib.contextmanager
    def phase(self, name, *, accesses: Optional[Dict[str, float]] = None,
              time_shares: Optional[Dict[str, float]] = None,
              access_bins: Optional[Dict[str, Sequence[float]]] = None,
              elapsed: Optional[float] = None):
        """One phase of the iteration.  ``name`` is a phase name
        (auto-registered on first use) or a pre-registered phase index.

        Entry fences and triggers proactive moves; exit records the phase's
        elapsed time (explicit ``elapsed`` > the source's virtual time >
        the context's wall clock) and folds the instrumentation into the
        profiler/monitor.  Explicit keyword instrumentation wins over the
        attached source; an exception closes the phase without recording
        (a crashed phase's timing is garbage), so a phase can never be
        left open."""
        self._ensure_loop()
        if not self._iter_open:
            raise RuntimeError(
                f"phase({name!r}) outside an iteration; wrap the loop body "
                "in `with rt.iteration():`")
        if self._open_phase is not None:
            raise RuntimeError(
                f"phase {self._open_phase!r} is still open; phases cannot "
                "nest")
        if isinstance(name, int):
            if not 0 <= name < len(self._phase_names):
                raise IndexError(f"phase index {name} out of range "
                                 f"(registered: {self._phase_names})")
            index = name
        else:
            index = self._phase_id(name)
        pname = self._phase_names[index]
        self._open_phase = pname
        stall = self._phase_begin(index)
        ctx = PhaseContext(name=pname, index=index, stall_s=stall)
        t0 = _time.perf_counter()
        try:
            yield ctx
        except BaseException:
            self._open_phase = None
            raise
        wall = _time.perf_counter() - t0
        sample = None
        if self.source is not None:
            # per-field precedence: explicit keyword > source > measured
            # (an explicit accesses override must not silently discard the
            # source's virtual elapsed or its access_bins)
            sample = self.source.collect(pname)
            if accesses is None:
                accesses = sample.accesses
            if time_shares is None:
                time_shares = sample.time_shares
            if access_bins is None:
                access_bins = sample.access_bins
            if elapsed is None:
                elapsed = sample.elapsed
        ctx.elapsed = elapsed if elapsed is not None else wall
        ctx.sample = sample
        self._open_phase = None
        self._phase_end(index, elapsed=ctx.elapsed, accesses=accesses,
                        time_shares=time_shares, access_bins=access_bins)

    # ------------------------------------------------------------- main loop
    def _begin_iteration(self) -> None:
        self._iter_open = True
        self._events_this_iter = []
        self._iter_stall_s = 0.0
        self._iter_elapsed_s = 0.0
        self._iter_phase_elapsed = {}
        self._degraded_phases = set()
        # The plan's prediction made observable: the first *settled*
        # iteration after a (re)plan — the one that begins with the
        # monitor-baseline window already closed, so the plan's one-time
        # enactment transient (bulk fetches landing mid-iteration) does
        # not contaminate the steady-state measurement the feedback
        # regresses against.  Its measured time (phase elapsed + fence
        # stalls) closes the loop at _end_iteration.
        self._measuring_baseline = (self._measure_pending
                                    and self.plan is not None
                                    and not self._baseline_pending
                                    and not self._profiling)

    def _phase_begin(self, index: int) -> float:
        """Enter phase ``index``: fence + trigger proactive moves.  Returns
        the fence stall in seconds (simulated backends) — real backends
        block and return 0.

        The mover is driven with the phase count the plan was *built*
        against, not the live one: auto-registration can grow the phase
        list under a live plan (a conditional eval/ckpt phase entered
        mid-loop), and a changed modulus would re-wrap negative
        trigger_phase moves onto the wrong boundary.  A phase the plan has
        never seen has no moves keyed to it — skip the mover entirely."""
        if self.plan is not None and self.mover is not None:
            n = self._plan_n_phases or len(self._phase_names)
            if index >= n:
                return 0.0
            stall = self.mover.on_phase_start(self.plan, index, n)
            self._drain_mover_faults()
            self._iter_stall_s += stall
            return stall
        return 0.0

    def _phase_end(self, index: int, *, elapsed: float,
                   accesses: Optional[Dict[str, float]] = None,
                   time_shares: Optional[Dict[str, float]] = None,
                   access_bins: Optional[Dict[str, Sequence[float]]] = None
                   ) -> None:
        """Leave phase ``index``.  ``accesses`` are the true per-object
        main-memory access counts for this execution (the instrumentation
        the paper gets from PEBS sampling); ``access_bins`` optionally
        carries each object's access distribution over its byte range
        (per-chunk attribution — the sampled address histogram)."""
        if not self.config.chunk_aware:
            access_bins = None
        ev = PhaseTraceEvent(phase_index=index, time=elapsed,
                             accesses=dict(accesses or {}),
                             time_shares=time_shares,
                             access_bins=access_bins)
        self._events_this_iter.append(ev)
        self._iter_elapsed_s += elapsed
        self._iter_phase_elapsed[index] = (
            self._iter_phase_elapsed.get(index, 0.0) + elapsed)
        if self._profiling:
            # Scoped drift response: only the drifted phases re-observe, so
            # every other phase's profile state stays bitwise identical and
            # its standing plan decision remains provably reusable.  A
            # phase whose access *set* visibly changed joins the scope even
            # if its time held (instrumentation is collected every
            # iteration, so the check is free).
            if (self._drift_scope is not None
                    and index not in self._drift_scope
                    and self._access_set_drifted(ev)):
                self._drift_scope.add(index)
                self.profiler.decay(self.config.replan_decay,
                                    phases=[index])
            if self._drift_scope is None or index in self._drift_scope:
                self.profiler.observe(ev)
        elif self._baseline_pending:
            # First iteration after (re)planning: phase times now reflect the
            # enacted placement — record them as the monitor baseline (the
            # paper monitors performance *after* data movement).
            self.monitor.set_baseline(index, elapsed)
            if index == len(self._phase_names) - 1:
                self._baseline_pending = False
        else:
            # a phase served degraded this iteration carries a *confirmed*
            # fault slowdown — the monitor skips its debounce for it
            drift = self.monitor.observe(
                index, elapsed, faulted=index in self._degraded_phases)
            if drift is not None:
                self._reprofile()

    def _end_iteration(self) -> None:
        self._iter_open = False
        self._iteration += 1
        if self._profiling:
            self._profiled_iters += 1
            if self._profiled_iters >= self.config.profile_iterations:
                self._build_plan()
                self._profiling = False
                self._profiled_iters = 0
            elif self.config.histogram_refine:
                # Multi-resolution refinement between profiling iterations
                # (never after the last: a split without a subsequent
                # observation carries no new information): the next
                # iteration's sampled addresses land in the refined bins,
                # so the hot head resolves finer at the same bin budget.
                # Scoped to the drifted phases during a scoped drift
                # response, so every other phase's profile state — and its
                # standing plan decision — stays bitwise intact.
                self.profiler.refine_histograms(
                    self.config.histogram_bins,
                    phases=(sorted(self._drift_scope)
                            if self._drift_scope is not None else None))
        elif self._baseline_pending and self._events_this_iter:
            # variable phase sets: if the baseline iteration did not reach
            # the last registered phase, close the baseline window here
            self._baseline_pending = False
        if (self._measuring_baseline and not self._baseline_pending
                and self.plan is not None and self._events_this_iter):
            self._measuring_baseline = False
            self._measure_pending = False
            self._on_baseline_measured(self._iter_elapsed_s
                                       + self._iter_stall_s)
        # Continuous calibration: every Nth iteration re-arms a settled
        # measurement so the feedback keeps folding between plan epochs
        # (per-epoch measurements stay the primary signal — the periodic
        # re-arm only fires when no measurement is already in flight).
        N = self.config.calibrate_every
        if (N and self.config.calibrate_feedback and self.plan is not None
                and not self._profiling and not self._baseline_pending
                and not self._measure_pending and not self._measuring_baseline
                and self._iteration % N == 0):
            self._measure_pending = True
            self._cal_rounds_left = max(self._cal_rounds_left, 1)
        # any failure path this iteration triggers the tier-state audit
        # (self-healing); heal-time correctives may fault too — drain them
        self._drain_mover_faults()
        if self._faults_this_iter:
            self._faults_this_iter = False
            self.audit_tiers()
            self._drain_mover_faults()
            self._faults_this_iter = False

    # --------------------------------------------------------- fault handling
    def _drain_mover_faults(self) -> bool:
        """Collect the mover's DegradedServe/EvictionRollback events into
        the session log (stamped with the iteration) and update counters.
        Returns True when new events were drained."""
        events = getattr(self.mover, "fault_events", None)
        if not events:
            return False
        n = self._plan_n_phases or len(self._phase_names) or 1
        for ev in events:
            ev.iteration = self._iteration
            if self.tenants and getattr(ev, "tenant", None) is None:
                ev.tenant = tenant_of(ev.obj, self.tenants)
            if self.config.host is not None:
                ev.host = self.config.host
            self.fault_log.append(ev)
            if isinstance(ev, DegradedServe):
                self.n_degraded_serves += 1
                self._degraded_since_plan += 1
                self._degraded_phases.add(ev.phase_index % n)
            else:
                self.n_eviction_rollbacks += 1
                self._rollbacks_since_plan += 1
        events.clear()
        self._faults_this_iter = True
        return True

    def _audit_violations(self) -> List[str]:
        """Cross-check runtime residency, the mover's in-flight book, and
        the capacity book.  Violation-free on every fault-free run *and*
        after every handled failure (rollbacks keep residency consistent
        by never flipping tiers)."""
        violations: List[str] = []
        for obj in self.registry:
            if obj.tier not in ("fast", "slow"):
                violations.append(
                    f"{obj.name}: invalid tier {obj.tier!r}")
        inflight = (getattr(self.mover, "_inflight", None) or {}
                    if self.mover is not None else {})
        evict_inflight = set()
        for name, h in inflight.items():
            if _handle_orphaned(self.registry, name, h):
                violations.append(
                    f"{name}: in-flight handle for a retired object")
            elif (getattr(h, "dst", None) == "slow"
                    and not getattr(h, "landed", False)):
                evict_inflight.add(name)
        # Capacity book.  Evictions are issued lazily (at their trigger
        # phase), so settled fast residency legitimately overshoots the
        # budget *between* an object's fetch and its scheduled departure —
        # only bytes with no booked departure count against capacity.  A
        # departure is booked by an in-flight eviction (landing flips the
        # tier) or a plan-scheduled one (the cyclic schedule re-evicts
        # every iteration, which is also what re-absorbs a rolled-back
        # eviction).  The heal's corrective evictions land in the
        # in-flight set, which is what makes healing convergent.
        planned_evict: set = set()
        planned_fast: set = set()
        if self.plan is not None:
            planned_evict = {m.obj for m in self.plan.moves
                             if m.dst == "slow"}
            for residents in self.plan.residents:
                planned_fast |= set(residents)
            for obj in self.registry:
                if (obj.tier == "fast" and not obj.pinned
                        and obj.name not in planned_fast
                        and obj.name not in planned_evict
                        and obj.name not in evict_inflight):
                    violations.append(
                        f"{obj.name}: fast residency diverged from the "
                        f"plan (placed slow everywhere, no eviction booked)")
        booked = evict_inflight | planned_evict
        fast_bytes = sum(o.size_bytes for o in self.registry
                         if o.tier == "fast" and o.name not in booked)
        if fast_bytes > self.capacity:
            violations.append(
                f"capacity: {fast_bytes} standing fast bytes (no booked "
                f"departure) exceed the fast tier's {self.capacity}")
        return violations

    def audit_tiers(self, heal: bool = True) -> TierAudit:
        """Tier-state reconciliation audit (run automatically after any
        failure path; assertable in tests).  Divergence self-heals with a
        one-shot corrective reconciliation via :meth:`_restore_plan` —
        the same mechanics the calibration revert uses."""
        self.n_audits += 1
        violations = self._audit_violations()
        if not violations:
            return TierAudit(violations=[])
        self.n_audit_violations += len(violations)
        if not heal or self.plan is None:
            return TierAudit(violations=violations, healed=False,
                             clean_after_heal=False)
        self.n_heals += 1
        self._restore_plan(self.plan)
        post = self._audit_violations()
        return TierAudit(violations=violations, healed=True,
                         clean_after_heal=not post)

    # ------------------------------------------------------------- internals
    def _pipeline_state(self) -> "policy_mod.PipelineState":
        """Characterized inputs for the placement-policy pipeline.  The
        standing program (when a plan is live and incremental replanning is
        on) lets the solve stage re-solve only the phases whose inputs
        changed."""
        # A CF fold moves every cached benefit value without touching any
        # reuse fingerprint (profile versions and registry generation are
        # unchanged), so after one the standing program must be dropped
        # wholesale — scoped reuse would splice stale-benefit decisions
        # into the recalibrated plan.
        standing = (self.plan
                    if (self.config.incremental_replan
                        and not self._cf_dirty
                        and isinstance(self.plan, policy_mod.PlanProgram))
                    else None)
        return policy_mod.PipelineState(
            machine=self.machine, registry=self.registry, graph=self.graph,
            profiler=self.profiler, planner=self.planner,
            capacity=self.capacity, config=self.config, standing=standing,
            tenants=dict(self.tenants) if self.tenants else None,
            drift_scope=(sorted(self._drift_scope)
                         if self._drift_scope is not None
                         and standing is not None else None))

    def _build_plan(self, *, recalibration: bool = False) -> None:
        assert self.graph is not None
        self.plan = self.policy.build(self._pipeline_state())
        self._drift_scope = None
        self._cf_dirty = False
        if self.plan is None:
            return
        if (self.config.host is not None
                and isinstance(self.plan, policy_mod.PlanProgram)):
            self.plan.host = self.config.host
        if ((self._degraded_since_plan or self._rollbacks_since_plan)
                and isinstance(self.plan, policy_mod.PlanProgram)):
            # fault-bearing rebuild: stamp the provenance (an *extra*
            # entry — the canonical stage list is untouched)
            self.plan.provenance.append(policy_mod.fault_provenance(
                self._degraded_since_plan, self._rollbacks_since_plan,
                self.profiler.epoch, self.registry.generation,
                hist_epoch=getattr(self.profiler, "hist_epoch", 0)))
        self._degraded_since_plan = 0
        self._rollbacks_since_plan = 0
        # Admission-control provenance: every tenant the bandwidth
        # partition demoted to serve-from-slow this epoch gets a
        # DegradedServe entry (phase -1 = whole-tenant, not one fetch).
        # Logged directly — not via mover fault_events — so the chaos
        # counters and the fault-triggered audit stay untouched.
        for t, why in sorted(
                (getattr(self.plan, "tenant_admission", None) or {}).items()):
            self.n_admission_demotions += 1
            self.fault_log.append(DegradedServe(
                obj=t, phase_index=-1, reason=f"admission:{why}",
                iteration=self._iteration, tenant=t,
                host=self.config.host))
        if not recalibration:
            # a profiling-driven build opens a new plan epoch: re-arm the
            # calibration-correction budget and the best-measured memory
            self._cal_rounds_left = self.config.calibration_rounds
            self._cal_best = None
            self._cal_snapshot = None
        self._measure_pending = True
        self._plan_n_phases = len(self._phase_names)
        self._baseline_pending = True
        self.monitor.consume_events()
        # Enact iteration-start moves for the new plan immediately.
        if self.mover is not None:
            if hasattr(self.mover, "load_plan"):
                self.mover.load_plan(self.plan, self.graph)
            self.mover.on_phase_start(self.plan, 0, self._plan_n_phases)
            self._drain_mover_faults()

    def _on_baseline_measured(self, measured: float) -> None:
        """Calibration feedback — the live extension of
        :func:`perfmodel.calibrate`'s CF idiom (paper §3.1.2) to in-loop
        observations.  The first settled iteration after a (re)plan is
        the plan's own prediction made observable: ``measured`` is its
        phase elapsed plus fence stalls, directly comparable to
        ``predicted_iteration_time`` (baseline − modeled gain + unhidden
        movement cost).  When the relative error exceeds the tolerance,
        two measurement channels the session already separates fold
        corrections into the constants:

        * **per-phase elapsed** — each phase's realized gain (profiled
          baseline time minus measured time) against the plan's booked
          per-class gains regresses multiplicative corrections onto
          ``cf_bw`` / ``cf_lat`` (:func:`perfmodel.solve_gain_folds`;
          only a per-class fold can change the knapsack's ranking);
        * **fence stalls** — measured stall over booked unhidden movement
          cost calibrates the movement-price factor ``cf_move``.

        The plan is then rebuilt under the corrected model — bounded by
        ``calibration_rounds`` per plan epoch so a noisy workload cannot
        thrash the solve."""
        assert self.plan is not None
        plan = self.plan
        predicted = plan.predicted_iteration_time
        self.last_measured_iteration_time = measured
        self.last_pred_err = (abs(predicted - measured) / measured
                              if measured > 0 else None)
        if not self.config.calibrate_feedback:
            return
        if self._cal_best is None or measured < self._cal_best[0]:
            self._cal_best = (measured, self.cf, plan)
        # The epoch closes when the correction budget is spent, the model
        # believes itself (predicted within tolerance of measured), or the
        # fold trajectory is demonstrably worsening — the corrected model's
        # plan measures more than half a tolerance band worse than the
        # epoch's best.  The early stop matters as much as the folds: every
        # additional excursion iteration both runs slow *and* pollutes the
        # profiler history the eventual revert rebuilds from.
        band = 1.0 + 0.5 * self.config.calibration_tolerance
        worsening = (self._cal_best is not None
                     and measured > self._cal_best[0] * band)
        closing = (self._cal_rounds_left <= 0 or self.last_pred_err is None
                   or self.last_pred_err <= self.config.calibration_tolerance
                   or worsening)
        if closing:
            # Best-of-measured safety net, decided once per epoch: the fold
            # trajectory may climb through worse intermediate plans and can
            # also end *honest but pessimal* — a self-consistent model whose
            # plan measures worse than the uncorrected one.  Reverting
            # restores the epoch's best *plan*, not just its constants:
            # re-solving under the old constants is a lottery, because the
            # knapsack weighs benefit minus fetch cost and objects the
            # excursion already moved fast are selected for free while the
            # best plan's picks now carry fetch costs (placement lock-in).
            # Near-ties inside the band stay on the current constants.
            (best_meas, best_cf, best_plan) = (
                self._cal_best if self._cal_best is not None
                else (measured, self.cf, plan))
            snapshot, self._cal_snapshot = self._cal_snapshot, None
            self._cal_rounds_left = 0
            self._cal_best = None
            if best_cf is not self.cf and measured > best_meas * band:
                best_cf = dataclasses.replace(
                    best_cf, provenance=best_cf.provenance
                    + (f"online:revert(iter{self._iteration})",))
                self.cf = best_cf
                self.planner.cf = best_cf
                if snapshot is not None:
                    # the excursion's iterations ran under thrashing plans;
                    # drop the history they contaminated (identity-preserving
                    # restore: other components hold the same object) so the
                    # restored plan's standing state and any later drift
                    # replan see the inputs that produced it.
                    self.profiler.__dict__.clear()
                    self.profiler.__dict__.update(snapshot)
                self._cf_dirty = False
                self._restore_plan(best_plan)
            return
        rows = []
        pb, gb, gl = (plan.phase_baseline, plan.phase_gain_bw,
                      plan.phase_gain_lat)
        for idx, elapsed in sorted(self._iter_phase_elapsed.items()):
            if idx < len(pb) and idx < len(gb) and idx < len(gl) \
                    and (gb[idx] != 0.0 or gl[idx] != 0.0):
                rows.append((gb[idx], gl[idx], pb[idx] - elapsed))
        mult_bw, mult_lat = (perfmodel.solve_gain_folds(rows)
                             if rows else (1.0, 1.0))
        booked_cost = sum(m.est_unhidden_cost for m in plan.moves)
        # nothing booked -> the stall ratio is unattributable; stay put
        mult_move = (self._iter_stall_s / booked_cost
                     if booked_cost > 1e-12 else 1.0)
        new_cf = perfmodel.fold_online(
            self.cf, gain_bw=mult_bw, gain_lat=mult_lat, move=mult_move,
            blend=self.config.calibration_blend,
            note=self._fold_note())
        if new_cf is self.cf:
            return
        if self._cal_snapshot is None:
            self._cal_snapshot = copy.deepcopy(self.profiler.__dict__)
        self._cal_rounds_left -= 1
        self.n_recalibrations += 1
        self.cf = new_cf
        self.planner.cf = new_cf
        self._cf_dirty = True
        self._build_plan(recalibration=True)

    def _fold_note(self) -> str:
        """Provenance note for an online CF fold.  With tenants declared,
        names the namespaces whose phases contributed measurements this
        iteration, so a fold's origin is attributable per tenant."""
        note = f"iter{self._iteration}"
        if not self.tenants:
            return note
        seen = set()
        for idx in self._iter_phase_elapsed:
            if 0 <= idx < len(self._phase_names):
                t = tenant_of(self._phase_names[idx], self.tenants)
                if t is not None:
                    seen.add(t)
        if seen:
            note += "[" + ",".join(sorted(seen)) + "]"
        return note

    def _restore_plan(self, plan: PlacementPlan) -> None:
        """Re-enact a previously measured plan from the live tier state.

        The plan's recurring schedule encodes its phase-to-phase rotation,
        and move issue is idempotent (an object already at its destination
        is skipped), so resuming the schedule is sound once the tier state
        is reconciled to the plan's iteration-start residency: corrective
        fetches bring missing residents in, corrective evictions push out
        stragglers the excursion left behind (without them the restored
        plan would silently enjoy more than its capacity-checked budget).
        The correctives are enacted *once*, through a throwaway copy of the
        plan, and the session keeps the pristine plan: the mover replays
        ``plan.moves`` every iteration, so a corrective baked into the
        standing plan would recur — evicting an object the plan re-fetches
        mid-iteration each time around, a permanent thrash cycle the plan
        never asked for.  ``est_unhidden_cost`` stays 0 because they are
        one-time reconciliation moves, not per-iteration plan cost."""
        assert self.graph is not None
        want0 = plan.residents[0] if plan.residents else set()
        corrective: List[MoveOp] = []
        for obj in self.registry:
            if obj.pinned:
                continue
            if obj.name in want0:
                if obj.tier != "fast":
                    corrective.append(
                        MoveOp(obj.name, "fast", 0, 0, obj.size_bytes))
            elif obj.tier == "fast":
                corrective.append(
                    MoveOp(obj.name, "slow", 0, 0, obj.size_bytes))
        enact = plan
        if corrective:
            enact = dataclasses.replace(
                plan, moves=list(plan.moves) + corrective,
                schedule=(list(plan.schedule) + emit_schedule(
                    corrective, self.graph, self.machine.copy_bw)
                    if plan.schedule else []))
        self.plan = plan
        self._drift_scope = None
        self._measure_pending = True
        self._plan_n_phases = len(self._phase_names)
        self._baseline_pending = True
        self.monitor.consume_events()
        if self.mover is not None:
            if hasattr(self.mover, "load_plan"):
                self.mover.load_plan(enact, self.graph)
            self.mover.on_phase_start(enact, 0, self._plan_n_phases)
            self._drain_mover_faults()

    def _reprofile(self) -> None:
        """Drift response.  Incremental (default): keep serving the current
        plan, decay the profile history so fresh observations dominate, and
        rebuild from the live tier state when enough iterations re-profiled —
        the plan is never dropped, so no iteration runs unplaced.  Legacy:
        the paper's full reset.

        With ``scoped_replan`` and a standing program, the re-profiling
        itself is *scoped to the drifted phases*: only their histories are
        decayed and re-observed, every other phase's profile state stays
        bitwise identical, and the rebuild re-solves O(drifted phases)
        knapsacks instead of O(plan).  A phase that drifted without
        tripping the monitor is caught on the next cycle (its post-replan
        baseline re-arms the monitor)."""
        self.n_replans += 1
        if self.config.incremental_replan and self.plan is not None:
            self.n_incremental_replans += 1
            drifted = set(self.monitor.drifted_phases())
            scope = None
            if (self.config.scoped_replan and drifted
                    and isinstance(self.plan, policy_mod.PlanProgram)):
                scope = drifted
            self._drift_scope = scope
            self.profiler.decay(
                self.config.replan_decay,
                phases=sorted(scope) if scope is not None else None)
            if self.config.histogram_refine:
                # refine before the re-profiling window opens so the
                # re-observed iterations sample into the adapted bins (a
                # re-heated region's bins split; the re-split pass can
                # then cut below the old coarse ceiling at rebuild)
                self.profiler.refine_histograms(
                    self.config.histogram_bins,
                    phases=sorted(scope) if scope is not None else None)
            self._profiling = True
            self._profiled_iters = 0
        else:
            self._drift_scope = None
            self.profiler.clear()
            self._profiling = True
            self._profiled_iters = 0
            self.plan = None
            self._iteration = 0
        # Drift fires mid-iteration: the phases already executed this
        # iteration (including the drifted one) were routed to the monitor,
        # not the profiler — replay them so the re-profiling window covers
        # the full iteration, not just the phases after the drift.
        for ev in self._events_this_iter:
            if (self._drift_scope is not None
                    and ev.phase_index not in self._drift_scope
                    and self._access_set_drifted(ev)):
                self._drift_scope.add(ev.phase_index)
                self.profiler.decay(self.config.replan_decay,
                                    phases=[ev.phase_index])
            if self._drift_scope is None or ev.phase_index in self._drift_scope:
                self.profiler.observe(ev)

    def _access_set_drifted(self, ev: PhaseTraceEvent) -> bool:
        """Access-mix drift the time-based monitor cannot see: an object
        carrying a material share of this execution's accesses has no
        profile entry for the phase (it appeared), or a profiled hot
        object received none (it vanished)."""
        total = sum(ev.accesses.values())
        profs = self.profiler.profiles_for_phase(ev.phase_index)
        if total > 0.0:
            for obj, acc in ev.accesses.items():
                if acc > 0.05 * total and obj not in profs:
                    return True
        ptotal = sum(p.data_access for p in profs.values())
        for obj, p in profs.items():
            if (p.data_access > 0.05 * max(ptotal, 1.0)
                    and ev.accesses.get(obj, 0.0) <= 0.0):
                return True
        return False

    # ------------------------------------------------------------- reporting
    def phase_names(self) -> List[str]:
        """Registered phases in first-use order."""
        return list(self._phase_names)

    def stats(self) -> Dict[str, Any]:
        mv = self.mover.stats if self.mover else None
        busy = getattr(self.backend, "busy_seconds", None)
        copy_busy_s = busy() if busy is not None else None
        overlap_time = None
        if copy_busy_s and mv is not None:
            overlap_time = max(0.0, 1.0 - mv.fence_stall_s / copy_busy_s)
        return dict(
            iteration=self._iteration,
            strategy=self.plan.strategy if self.plan else None,
            predicted_iteration_time=(self.plan.predicted_iteration_time
                                      if self.plan else None),
            mover=self.config.mover,
            n_moves=mv.n_moves if mv else 0,
            moved_bytes=mv.moved_bytes if mv else 0,
            overlap_fraction=mv.overlap_fraction if mv else None,
            fence_stall_s=mv.fence_stall_s if mv else 0.0,
            copy_busy_s=copy_busy_s,
            overlap_time_fraction=overlap_time,
            fast_resident_bytes=self.registry.bytes_in_tier("fast"),
            n_objects=len(self.registry),
            n_replans=self.n_replans,
            n_incremental_replans=self.n_incremental_replans,
            measured_iteration_time=self.last_measured_iteration_time,
            pred_err=self.last_pred_err,
            cf_bw=self.cf.cf_bw,
            cf_lat=self.cf.cf_lat,
            cf_move=self.cf.cf_move,
            n_recalibrations=self.n_recalibrations,
            # fault tolerance (all zero / empty on a fault-free run)
            n_retries=mv.n_retries if mv else 0,
            n_degraded_serves=self.n_degraded_serves,
            n_eviction_rollbacks=self.n_eviction_rollbacks,
            fault_log_dropped=getattr(self.fault_log, "dropped", 0),
            # multi-tenancy (zero / empty without declared tenants)
            n_tenants=len(self.tenants),
            n_admission_demotions=self.n_admission_demotions,
            n_straggler_reissues=mv.n_straggler_reissues if mv else 0,
            n_audits=self.n_audits,
            n_audit_violations=self.n_audit_violations,
            n_heals=self.n_heals,
            channel_health=(self.mover.health.summary()
                            if hasattr(self.mover, "health") else {}),
            # multi-host provenance (None on the unclustered path)
            host=self.config.host,
        )
