"""String-keyed copy-backend registry (runtime API v2).

Backends used to be wired ad hoc: ``UnimemRuntime`` defaulted to
``JaxTierBackend``, the simulator reached into the runtime to swap in
``SimTierBackend``/``ChannelSimBackend``, and adding a new copy engine
meant touching every constructor.  The registry makes the backend a config
string (``RuntimeConfig.backend = "sim" | "jax" | "jax_async"``) resolved
through one factory table, so new engines (the ROADMAP's CUDA-stream-style
channels, a CPU memcpy pool, ...) register themselves without changing any
driver.

Factory signature: ``factory(machine, **options) -> TierBackend``.  All
factories must tolerate unknown keyword options (each driver passes its
full option set — ``now_fn``, ``mover``, ``channels`` — and every factory
picks what it understands).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List

from .faults import ChaosBackend, FaultSpec
from .mover import (AsyncJaxTierBackend, ChannelSimBackend, CpuPoolBackend,
                    CrossHostBackend, JaxTierBackend, SimTierBackend)
from .tiers import MachineProfile

BackendFactory = Callable[..., Any]

_REGISTRY: Dict[str, BackendFactory] = {}


def register_backend(name: str, factory: BackendFactory,
                     *, overwrite: bool = False) -> None:
    """Register a copy-backend factory under ``name``."""
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {name!r} is already registered "
                         "(pass overwrite=True to replace it)")
    _REGISTRY[name] = factory


def available_backends() -> List[str]:
    return sorted(_REGISTRY)


def make_backend(name: str, machine: MachineProfile, **options: Any):
    """Instantiate the backend registered under ``name``."""
    factory = _REGISTRY.get(name)
    if factory is None:
        raise ValueError(f"unknown copy backend {name!r}; registered: "
                         f"{available_backends()}")
    return factory(machine, **options)


# ---------------------------------------------------------------------------
# built-in backends
# ---------------------------------------------------------------------------
def _sim_factory(machine: MachineProfile, *, now_fn=None, mover: str = "slack",
                 channels: int = 2, priorities=None, **_: Any):
    """Simulated copy engine matched to the configured migration engine:
    the slack mover gets the multi-channel engine (tier flips on landing;
    optional per-channel ``priorities`` confine bulk evictions to the
    lowest-priority channels), the FIFO baseline the single serial
    queue."""
    if now_fn is None:
        now_fn = lambda: 0.0            # noqa: E731 — static virtual clock
    if mover == "slack":
        return ChannelSimBackend(machine, now_fn, channels=channels,
                                 priorities=priorities)
    return SimTierBackend(machine, now_fn)


def _cpu_pool_factory(machine: MachineProfile, *, pool_workers: int = 2,
                      **_: Any):
    """Host-side memcpy thread pool (ROADMAP: CPU copy engine) — numpy
    leaves copied on worker threads, tier flips on landing."""
    return CpuPoolBackend(machine, workers=pool_workers)


def _cross_host_factory(machine: MachineProfile, *, links=None, now_fn=None,
                        default_link=None, on_land=None, **_: Any):
    """Shard-migration engine over modeled interconnect links: prices
    peer-host pulls with per-link bandwidth/latency and a bounded number
    of send/recv channel pairs per link.  ``links`` is an
    :class:`~.perfmodel.InterconnectModel` (or a ``{(src, dst): LinkSpec}``
    mapping; ``default_link`` prices unnamed pairs)."""
    from .perfmodel import InterconnectModel
    if not isinstance(links, InterconnectModel):
        links = InterconnectModel(links, default=default_link)
    if now_fn is None:
        now_fn = lambda: 0.0            # noqa: E731 — static virtual clock
    return CrossHostBackend(links, now_fn, on_land=on_land)


def _chaos_factory(machine: MachineProfile, *, chaos_inner: str = "jax_async",
                   fault_spec=None, **options: Any):
    """Fault-injecting decorator over any registered backend:
    ``make_backend("chaos", machine, chaos_inner="sim", fault_spec=spec)``
    wraps the inner backend in :class:`~.faults.ChaosBackend`.  With no
    ``fault_spec`` the wrapper injects nothing (a pass-through useful for
    testing the decorator plumbing itself)."""
    if chaos_inner == "chaos":
        raise ValueError("chaos backend cannot wrap itself")
    inner = make_backend(chaos_inner, machine, **options)
    return ChaosBackend(inner, fault_spec or FaultSpec(),
                        host=options.get("host"))


register_backend("sim", _sim_factory)
register_backend("jax", lambda machine, **_: JaxTierBackend(machine))
register_backend("jax_async", lambda machine, **_: AsyncJaxTierBackend(machine))
register_backend("cpu_pool", _cpu_pool_factory)
register_backend("chaos", _chaos_factory)
register_backend("cross_host", _cross_host_factory)
