"""Unimem core: runtime data management on heterogeneous memory (the paper's
contribution, adapted to TPU memory tiers)."""

from .backends import available_backends, make_backend, register_backend
from .data_objects import DataObject, ObjectRegistry
from .faults import (ChannelHealth, ChaosBackend, CopyError, CopyFailedError,
                     CopyTimeoutError, DegradedServe, EvictionRollback,
                     FaultLog, FaultSpec, TransientCopyError, host_sub_seed)
from .histogram import Histogram, uniform_mass
from .instrumentation import (InstrumentationSource, ManualSource,
                              PhaseSample, XlaCostAnalysisSource)
from .knapsack import Item, solve as knapsack_solve
from .monitor import VariationMonitor
from .mover import (AsyncJaxTierBackend, ChannelSimBackend, CpuPoolBackend,
                    CrossHostBackend, JaxTierBackend, MoveRecord,
                    ProactiveMover, SimTierBackend, SlackAwareMover)
from .perfmodel import (CalibrationConstants, InterconnectModel, LinkSpec,
                        Sensitivity, benefit, calibrate, classify,
                        consumed_bandwidth, cross_host_cost,
                        link_transfer_time, movement_cost, weight)
from .phase import (Phase, PhaseGraph, PhaseKind, PhaseTraceEvent,
                    build_phase_graph)
from .planner import (MoveOp, PhaseDecision, PlacementPlan, Planner,
                      ScheduledMove, emit_schedule)
from .policy import (BandwidthPartitionPolicy, PipelineState, PlacementPolicy,
                     PlanProgram, StageProvenance, UnimemPolicy,
                     available_policies, make_policy, register_policy)
from .profiler import ObjectPhaseProfile, PhaseProfiler
from .runtime import RuntimeConfig, UnimemRuntime
from .session import PhaseContext, Session, TierAudit
from .tenancy import (TENANT_SEP, TenantHandle, TenantSpec, apportion,
                      capacity_shares, channel_shares, per_tenant_p99,
                      tenant_of)
from .tiers import (MachineProfile, TierSpec, PROFILES, PAPER_DRAM_NVM,
                    STT_RAM, PCRAM, RERAM, TPU_V5E, TPU_V5E_VMEM,
                    V5E_PEAK_FLOPS_BF16, V5E_HBM_BW, V5E_ICI_BW)

__all__ = [
    "DataObject", "ObjectRegistry", "Histogram", "uniform_mass",
    "Item", "knapsack_solve",
    "VariationMonitor", "JaxTierBackend", "AsyncJaxTierBackend",
    "CpuPoolBackend", "ProactiveMover", "SimTierBackend",
    "ChannelSimBackend", "SlackAwareMover", "MoveRecord",
    "available_backends", "make_backend", "register_backend",
    "InstrumentationSource", "ManualSource", "PhaseSample",
    "XlaCostAnalysisSource", "Session", "PhaseContext", "TierAudit",
    "ChannelHealth", "ChaosBackend", "CopyError", "CopyFailedError",
    "CopyTimeoutError", "DegradedServe", "EvictionRollback", "FaultLog",
    "FaultSpec", "TransientCopyError", "host_sub_seed",
    "TENANT_SEP", "TenantHandle", "TenantSpec", "apportion",
    "capacity_shares", "channel_shares", "per_tenant_p99", "tenant_of",
    "BandwidthPartitionPolicy", "CrossHostBackend",
    "CalibrationConstants", "InterconnectModel", "LinkSpec", "Sensitivity",
    "benefit", "calibrate", "classify", "consumed_bandwidth",
    "cross_host_cost", "link_transfer_time", "movement_cost", "weight",
    "Phase", "PhaseGraph", "PhaseKind", "PhaseTraceEvent", "build_phase_graph",
    "MoveOp", "PhaseDecision", "PlacementPlan", "Planner", "ScheduledMove",
    "emit_schedule",
    "PipelineState", "PlacementPolicy", "PlanProgram", "StageProvenance",
    "UnimemPolicy", "available_policies", "make_policy", "register_policy",
    "ObjectPhaseProfile", "PhaseProfiler",
    "RuntimeConfig", "UnimemRuntime",
    "MachineProfile", "TierSpec", "PROFILES", "PAPER_DRAM_NVM", "STT_RAM",
    "PCRAM", "RERAM", "TPU_V5E", "TPU_V5E_VMEM",
    "V5E_PEAK_FLOPS_BF16", "V5E_HBM_BW", "V5E_ICI_BW",
]
