"""Multi-tenant serving layer: namespaces, QoS weights, resource shares.

The runtime plans placement for one workload; production serving
multiplexes many concurrent request streams — tenants — with different
hot sets over one fast tier and one set of copy channels.  This module
is the shared vocabulary that threads tenancy through every layer:

* :class:`TenantSpec` / :class:`TenantHandle` — a tenant's QoS contract
  (priority, SLO) and the session-scoped registration namespace
  (``rt.tenant("a").register("kv", ...)`` registers ``"a/kv"``; the
  registry's duplicate check then rejects same-tenant duplicates while
  cross-tenant name collisions resolve to distinct qualified names).
* :func:`tenant_of` — ownership attribution for any object or phase
  name, chunk-suffix aware (``"a/kv#3"`` belongs to tenant ``"a"``).
* :func:`apportion` — the shared largest-remainder integerization
  kernel (optionally demand-capped) behind both share functions and the
  cluster coordinator's link-share splits.
* :func:`capacity_shares` — work-conserving weighted water-filling of
  fast-tier bytes across tenants: each tenant's share is proportional
  to its QoS weight but capped at its demand, and capacity a sated
  tenant cannot use is redistributed to the still-hungry ones, so the
  shares always sum to ``min(capacity, total demand)``.
* :func:`channel_shares` — largest-remainder apportionment of the copy
  channels by the same weights (every channel is owned by exactly one
  tenant; tenants borrow idle foreign channels work-conservingly at the
  backend, see ``ChannelSimBackend.start_move(prefer=...)``).
* :func:`admission_control` — demote cold or hopelessly over-quota
  tenants to serve-from-slow before the per-tenant solves run, so a
  whale cannot thrash the long tail's hot set (the ``DegradedServe``
  provenance records every demotion).
* :func:`per_tenant_p99` — the serving metric: per-tenant p99 of the
  per-iteration time attributed to the tenant's phases.

Everything here is pure bookkeeping over names and numbers — no
session, planner, or backend state — so the policy, mover, benchmarks
and tests can all consume one implementation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple

#: separator between a tenant namespace and the object/phase name it owns
TENANT_SEP = "/"


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's QoS contract.

    ``priority`` scales the tenant's claim on contested resources
    linearly; ``slo`` is its relative latency budget (1.0 = baseline,
    0.5 = twice as strict).  The partitioning weight is
    ``priority / slo`` — a stricter SLO buys a larger share at equal
    priority."""

    name: str
    priority: float = 1.0
    slo: float = 1.0

    def __post_init__(self):
        if not self.name or TENANT_SEP in self.name or "#" in self.name:
            raise ValueError(
                f"invalid tenant name {self.name!r}: must be non-empty and "
                f"contain neither {TENANT_SEP!r} nor '#'")
        if self.priority <= 0 or self.slo <= 0:
            raise ValueError(
                f"tenant {self.name!r}: priority and slo must be positive")

    @property
    def weight(self) -> float:
        return self.priority / self.slo


def qualify(tenant: str, name: str) -> str:
    """The tenant-qualified registry/phase name."""
    return f"{tenant}{TENANT_SEP}{name}"


def tenant_of(name: str,
              tenants: Optional[Mapping[str, Any]] = None) -> Optional[str]:
    """The tenant owning ``name``, or None for an unqualified name.

    Chunk names inherit their parent's tenant (``"a/kv#3"`` -> ``"a"``).
    With ``tenants`` given, only prefixes naming a registered tenant
    count — an object that merely contains the separator stays unowned.
    """
    base = name.split("#", 1)[0]
    if TENANT_SEP not in base:
        return None
    t = base.split(TENANT_SEP, 1)[0]
    if tenants is not None and t not in tenants:
        return None
    return t or None


class TenantHandle:
    """Session-scoped tenant namespace: ``register``/``phase`` qualify
    their names with the tenant prefix, everything else passes through.
    Obtained from :meth:`~.session.Session.tenant`."""

    def __init__(self, session: Any, spec: TenantSpec):
        self.session = session
        self.spec = spec

    @property
    def name(self) -> str:
        return self.spec.name

    def register(self, name: str, spec: Any = None, **kw: Any):
        return self.session.register(qualify(self.spec.name, name), spec,
                                     **kw)

    def phase(self, name: str, **kw: Any):
        return self.session.phase(qualify(self.spec.name, name), **kw)

    def iteration(self):
        return self.session.iteration()

    def __repr__(self) -> str:
        return (f"TenantHandle({self.spec.name!r}, "
                f"priority={self.spec.priority:g}, slo={self.spec.slo:g})")


# ---------------------------------------------------------------------------
# resource partitioning
# ---------------------------------------------------------------------------
def apportion(total: int, quotas: Mapping[str, float],
              caps: Optional[Mapping[str, int]] = None) -> Dict[str, int]:
    """Largest-remainder integerization of fractional quotas.

    Floors every quota, then hands the leftover units one at a time to
    the largest fractional remainders (ties break by name, so the result
    is deterministic).  With ``caps`` given, no key is floored or topped
    up past its cap and the leftover is distributed round-robin over the
    remainder ordering until either the total is reached or every key is
    capped — so conservation holds exactly whenever the caps admit it:
    ``sum(out) == min(total, sum(caps))``, and without caps
    ``sum(out) == total`` (for ``total >= 0``).

    This is the one shared apportionment kernel behind
    :func:`capacity_shares` (byte shares capped at demand),
    :func:`channel_shares` (copy-channel counts, uncapped) and the
    cluster coordinator's link-share splits
    (:meth:`~repro.distributed.coordinator.ClusterCoordinator`).
    """
    keys = list(quotas)
    out = {k: int(quotas[k]) for k in keys}
    if caps is not None:
        out = {k: min(max(0, int(caps.get(k, 0))), out[k]) for k in keys}
    leftover = int(total) - sum(out.values())
    by_frac = sorted(keys, key=lambda k: (-(quotas[k] - out[k]), k))
    if caps is None:
        for k in by_frac:
            if leftover <= 0:
                break
            out[k] += 1
            leftover -= 1
        return out
    i = 0
    while leftover > 0 and by_frac:
        k = by_frac[i % len(by_frac)]
        if out[k] < caps.get(k, 0):
            out[k] += 1
            leftover -= 1
        i += 1
        if i > 2 * len(by_frac) and all(
                out[k] >= caps.get(k, 0) for k in by_frac):
            break
    return out


def capacity_shares(capacity_bytes: int,
                    tenants: Mapping[str, TenantSpec],
                    demand: Mapping[str, int]) -> Dict[str, int]:
    """Work-conserving weighted water-filling of the fast tier.

    Each round distributes the remaining capacity across the still-hungry
    tenants proportionally to weight, capped at each tenant's remaining
    demand; sated tenants leave the pool and their surplus is
    redistributed.  Terminates in <= len(tenants)+1 rounds (every round
    either sates a tenant or exhausts the capacity).  The integerized
    shares satisfy ``sum(shares) == min(capacity, sum(demand))`` exactly
    (largest-remainder rounding), and no share exceeds its demand."""
    need = {t: max(0, int(demand.get(t, 0))) for t in tenants}
    shares = {t: 0.0 for t in tenants}
    remaining = float(max(0, capacity_bytes))
    active = {t for t in tenants if need[t] > 0}
    while remaining > 1e-9 and active:
        wsum = sum(tenants[t].weight for t in active)
        alloc = {t: remaining * tenants[t].weight / wsum for t in active}
        spent = 0.0
        sated = set()
        for t in sorted(active):
            give = min(alloc[t], need[t] - shares[t])
            shares[t] += give
            spent += give
            if shares[t] >= need[t] - 1e-6:
                sated.add(t)
        remaining -= spent
        active -= sated
        if spent <= 1e-12:
            break
    # integerize exactly: floor, then hand the leftover bytes to the
    # largest fractional remainders (never past a tenant's demand)
    target = min(max(0, int(capacity_bytes)), sum(need.values()))
    return apportion(target, shares, caps=need)


def channel_shares(n_channels: int,
                   tenants: Mapping[str, TenantSpec]) -> Dict[str, List[int]]:
    """Largest-remainder apportionment of the copy channels by weight.

    Every channel is owned by exactly one tenant (the lists partition
    ``range(n_channels)``); a tenant whose quota rounds to zero owns no
    channel and simply uses whatever is idle (the backend's
    work-conserving borrow rule).  Deterministic: ties break by name."""
    if not tenants or n_channels <= 0:
        return {t: [] for t in tenants}
    wsum = sum(s.weight for s in tenants.values())
    quota = {t: n_channels * s.weight / wsum for t, s in tenants.items()}
    counts = apportion(n_channels, quota)
    out: Dict[str, List[int]] = {t: [] for t in tenants}
    ch = 0
    for t in sorted(tenants, key=lambda t: (-counts[t], t)):
        for _ in range(counts[t]):
            out[t].append(ch)
            ch += 1
    return out


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------
def admission_control(tenants: Mapping[str, TenantSpec],
                      traffic: Mapping[str, float],
                      footprint: Mapping[str, int],
                      capacity_bytes: int, *,
                      heat_floor: float = 0.0,
                      churn_guard: Optional[float] = None,
                      hot_bytes: Optional[Mapping[str, int]] = None
                      ) -> Dict[str, str]:
    """Decide which tenants are demoted to serve-from-slow this epoch.

    Returns ``{tenant: reason}`` for every demoted tenant.  Two tests:

    * **cold**: a tenant whose access density (traffic per footprint
      byte) is below ``heat_floor`` times the mean density of the
      trafficked tenants — its bytes would occupy fast capacity that
      hot tenants can convert into far more slack.
    * **over-quota churn**: with ``churn_guard`` set, a tenant whose
      per-phase hot set exceeds ``churn_guard`` times the share it
      would get even owning the whole remaining pool alone is demoted —
      its share could never hold a useful fraction of any phase's
      working set, so serving it from fast would be pure thrash.

    Both knobs default off (no demotion); the session exposes them as
    ``RuntimeConfig.tenant_admission_heat`` / ``tenant_churn_guard``."""
    demoted: Dict[str, str] = {}
    dens = {t: traffic.get(t, 0.0) / max(1, footprint.get(t, 0))
            for t in tenants}
    trafficked = [d for d in dens.values() if d > 0.0]
    mean_dens = sum(trafficked) / len(trafficked) if trafficked else 0.0
    if heat_floor > 0.0 and mean_dens > 0.0:
        for t in sorted(tenants):
            if dens[t] < heat_floor * mean_dens:
                demoted[t] = (f"cold: density {dens[t]:.3g} < "
                              f"{heat_floor:g} x mean {mean_dens:.3g}")
    if churn_guard is not None and hot_bytes:
        survivors = {t: s for t, s in tenants.items() if t not in demoted}
        if survivors:
            shares = capacity_shares(
                capacity_bytes, survivors,
                {t: footprint.get(t, 0) for t in survivors})
            for t in sorted(survivors):
                hot = hot_bytes.get(t, 0)
                if shares.get(t, 0) > 0 and hot > churn_guard * shares[t]:
                    demoted[t] = (f"over-quota: hot set {hot} > "
                                  f"{churn_guard:g} x share {shares[t]}")
    return demoted


# ---------------------------------------------------------------------------
# the serving metric
# ---------------------------------------------------------------------------
def per_tenant_p99(trace: Iterable[Any], phase_names: List[str],
                   tenants: Mapping[str, Any], *,
                   steady_frac: float = 0.5,
                   q: float = 0.99) -> Dict[str, float]:
    """Per-tenant p99 of per-iteration serving time.

    ``trace`` holds phase executions with ``iteration`` / ``phase_index``
    / ``stall_s`` / ``duration_s`` (the simulator's ``PhaseExec``).  A
    tenant's per-iteration time is the sum of stall+compute over the
    phases its namespace owns; the quantile is taken over the steady
    tail (the last ``steady_frac`` of iterations, skipping profiling and
    enactment warm-up)."""
    per: Dict[str, Dict[int, float]] = {}
    for ev in trace:
        if ev.phase_index >= len(phase_names):
            continue
        t = tenant_of(phase_names[ev.phase_index], tenants)
        if t is None:
            continue
        per.setdefault(t, {})[ev.iteration] = (
            per.get(t, {}).get(ev.iteration, 0.0)
            + ev.stall_s + ev.duration_s)
    out: Dict[str, float] = {}
    for t, by_iter in per.items():
        times = [by_iter[i] for i in sorted(by_iter)]
        tail = times[int(len(times) * (1.0 - steady_frac)):] or times
        s = sorted(tail)
        idx = min(len(s) - 1, int(round(q * (len(s) - 1))))
        out[t] = s[idx]
    return out


def split_by_tenant(names: Iterable[str],
                    tenants: Mapping[str, Any]
                    ) -> Tuple[Dict[str, List[str]], List[str]]:
    """Partition ``names`` into per-tenant lists plus the unowned rest."""
    owned: Dict[str, List[str]] = {t: [] for t in tenants}
    rest: List[str] = []
    for n in names:
        t = tenant_of(n, tenants)
        if t is None:
            rest.append(n)
        else:
            owned[t].append(n)
    return owned, rest
