"""Phases and the phase graph.

Paper §2.1: an iterative application decomposes into *phases* delimited by
MPI operations (here: collectives / jit-step boundaries).  Non-blocking
communication is merged into the following phase; the completion op is a
phase.  Each phase references a known set of target data objects.

The phase graph supplies the two facts the performance model needs:

* per-(phase, object) access counts (filled by the profiler), and
* the *earliest dependency-safe trigger point* for moving an object needed by
  phase ``i``: walking backwards from ``i``, the first phase that references
  the object is ``j-1``; the move may start at the beginning of phase ``j``
  (paper Fig 5).  The overlap window is the execution time of phases
  ``j .. i-1``.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Sequence, Tuple


class PhaseKind(enum.Enum):
    COMPUTE = "compute"
    COMM = "comm"


@dataclasses.dataclass
class Phase:
    """One phase of the iteration.

    ``refs`` maps object name -> number of main-memory accesses in this phase
    (the profiler's ``#data_access``).  ``time`` is the measured (or
    simulated) phase execution time in seconds.
    """

    index: int
    name: str
    kind: PhaseKind = PhaseKind.COMPUTE
    refs: Dict[str, float] = dataclasses.field(default_factory=dict)
    time: float = 0.0

    def references(self, obj: str) -> bool:
        return self.refs.get(obj, 0.0) > 0.0


class PhaseGraph:
    """Ordered phases of one iteration of the main loop."""

    def __init__(self, phases: Sequence[Phase]):
        self.phases: List[Phase] = list(phases)
        for i, p in enumerate(self.phases):
            if p.index != i:
                raise ValueError(f"phase {p.name} has index {p.index} != {i}")

    def __len__(self) -> int:
        return len(self.phases)

    def __iter__(self):
        return iter(self.phases)

    def __getitem__(self, i: int) -> Phase:
        return self.phases[i]

    def objects(self) -> List[str]:
        names: List[str] = []
        seen = set()
        for p in self.phases:
            for o in p.refs:
                if o not in seen:
                    seen.add(o)
                    names.append(o)
        return names

    def iteration_time(self) -> float:
        return sum(p.time for p in self.phases)

    # ---- dependency-safe trigger points (paper Fig 5) ----------------------
    def trigger_point(self, obj: str, phase_index: int) -> int:
        """Earliest phase at whose *start* a move of ``obj`` (needed by phase
        ``phase_index``) may be triggered.

        Walk backwards (wrapping around the iteration, since the loop is
        iterative) until a phase referencing ``obj`` is found; the trigger is
        the phase right after it.  If no other phase references the object,
        the move can be triggered a full iteration ahead — we cap the window
        at one iteration and return the phase after ``phase_index`` of the
        previous iteration, expressed as ``phase_index - (n-1)`` steps back
        (may be negative == previous iteration).
        """
        n = len(self.phases)
        for back in range(1, n):
            j = phase_index - back
            if self.phases[j % n].references(obj):
                return j + 1  # may be negative: previous iteration
        return phase_index - (n - 1)

    def overlap_window(self, obj: str, phase_index: int) -> float:
        """``mem_comp_overlap`` of Eq. (4): time between the trigger point and
        the start of ``phase_index``."""
        return self.window_between(self.trigger_point(obj, phase_index),
                                   phase_index)

    def window_between(self, trigger_phase: int, needed_by: int) -> float:
        """Execution time between the start of ``trigger_phase`` and the start
        of ``needed_by`` (``trigger_phase`` may be negative: previous
        iteration).  This is the copy window a scheduled move can overlap."""
        n = len(self.phases)
        total = 0.0
        for k in range(trigger_phase, needed_by):
            total += self.phases[k % n].time
        return total

    def phases_referencing(self, obj: str) -> List[int]:
        return [p.index for p in self.phases if p.references(obj)]


@dataclasses.dataclass
class PhaseTraceEvent:
    """Raw instrumentation for one dynamic phase execution (profiler input)."""

    phase_index: int
    time: float                      # seconds
    # true access counts per object for this execution (pre-sampling)
    accesses: Dict[str, float] = dataclasses.field(default_factory=dict)
    # fraction of the phase's time attributable to each object's memory
    # accesses (what PEBS's per-object sample fraction measures); optional —
    # the profiler falls back to access-count shares.
    time_shares: Optional[Dict[str, float]] = None
    # true access distribution over each object's byte range (relative
    # weights over equal-width bins — the address histogram a PEBS sample
    # stream would bin); optional — objects without an entry are profiled at
    # object granularity only.  The profiler resamples these with seeded
    # multinomial noise (per-chunk attribution, paper §3.2 extended).
    access_bins: Optional[Dict[str, Sequence[float]]] = None


def build_phase_graph(
    names_and_refs: Sequence[Tuple[str, Dict[str, float]]],
    kinds: Optional[Sequence[PhaseKind]] = None,
    times: Optional[Sequence[float]] = None,
) -> PhaseGraph:
    """Convenience constructor from (name, refs) pairs."""
    phases = []
    for i, (name, refs) in enumerate(names_and_refs):
        phases.append(Phase(
            index=i, name=name,
            kind=kinds[i] if kinds else PhaseKind.COMPUTE,
            refs=dict(refs),
            time=times[i] if times else 0.0))
    return PhaseGraph(phases)
