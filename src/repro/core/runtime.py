"""UnimemRuntime — compatibility facade over the v2 runtime session.

Paper API mapping (Table 2), v2 session surface, and the deprecated
imperative shims this facade keeps alive:

=================  ==========================================================
unimem_init        ``UnimemRuntime(machine, ...)``
unimem_malloc      ``rt.register(name, pytree_or_size, ...)``
                   (deprecated: ``rt.alloc(name, size_bytes=...)``)
unimem_start/end   ``with rt.iteration(): with rt.phase("fwd"): ...``
                   (deprecated: ``start_loop`` / ``begin_iteration`` /
                   ``phase_begin`` / ``phase_end`` / ``end_iteration``)
PMPI wrapper       phase boundaries are the ``rt.phase(...)`` contexts
                   (collective / jit-step boundaries), exactly as PMPI
                   interception delimits them
=================  ==========================================================

All orchestration lives in :class:`~.session.Session`; the shims below
delegate to the same internals the context managers use, so old-style and
new-style drivers produce **bit-identical** plans (parity-tested).  New
code should use the session API; the shims emit ``DeprecationWarning``.

See :mod:`.session` for the workflow semantics (profile -> plan -> move ->
monitor, incremental replanning, per-chunk attribution) and
:mod:`.instrumentation` / :mod:`.backends` for the pluggable
instrumentation-source and copy-backend layers.
"""

from __future__ import annotations

import warnings
from typing import Any, Dict, List, Optional, Sequence

from .data_objects import DataObject
from .session import PhaseContext, RuntimeConfig, Session

__all__ = ["RuntimeConfig", "UnimemRuntime", "PhaseContext"]


def _deprecated(old: str, new: str) -> None:
    warnings.warn(f"UnimemRuntime.{old} is deprecated; use {new} "
                  "(see README MIGRATION)", DeprecationWarning, stacklevel=3)


class UnimemRuntime(Session):
    """The v2 :class:`~.session.Session` plus the paper's Table-2 imperative
    API as deprecated, delegating shims."""

    # ------------------------------------------------------------- allocation
    def alloc(self, name: str, *, size_bytes: Optional[int] = None,
              payload: Any = None, chunkable: bool = False,
              pinned: bool = False,
              static_refs: Optional[float] = None) -> DataObject:
        """Deprecated ``unimem_malloc`` shim -> :meth:`Session.register`."""
        _deprecated("alloc(...)", "register(name, pytree_or_size, ...)")
        return self.register(name, size_bytes=size_bytes, payload=payload,
                             chunkable=chunkable, pinned=pinned,
                             static_refs=static_refs)

    # ------------------------------------------------------------- main loop
    def start_loop(self, phase_names: List[str],
                   static_refs: Optional[Dict[str, float]] = None) -> None:
        """Deprecated ``unimem_start`` shim: declare the loop's phase
        structure upfront.  The session auto-starts the loop and
        auto-registers phases on first use instead."""
        _deprecated("start_loop(...)",
                    "with rt.iteration(): (phases auto-register)")
        self._static_refs.update(static_refs or {})
        self._start_loop(phase_names)

    def begin_iteration(self) -> None:
        _deprecated("begin_iteration()", "with rt.iteration():")
        self._ensure_loop()
        self._begin_iteration()

    def phase_begin(self, index: int) -> float:
        _deprecated("phase_begin(i)", "with rt.phase(name):")
        return self._phase_begin(index)

    def phase_end(self, index: int, *, elapsed: float,
                  accesses: Optional[Dict[str, float]] = None,
                  time_shares: Optional[Dict[str, float]] = None,
                  access_bins: Optional[Dict[str, Sequence[float]]] = None
                  ) -> None:
        _deprecated("phase_end(i, ...)",
                    "with rt.phase(name, ...) / an InstrumentationSource")
        self._phase_end(index, elapsed=elapsed, accesses=accesses,
                        time_shares=time_shares, access_bins=access_bins)

    def end_iteration(self) -> None:
        _deprecated("end_iteration()", "with rt.iteration():")
        self._end_iteration()
