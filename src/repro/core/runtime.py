"""UnimemRuntime — the facade tying profiling, modeling, planning and
proactive movement together (paper Fig 8 workflow, Table 2 API).

Paper API mapping:

=================  =========================================================
unimem_init        ``UnimemRuntime(machine, ...)``
unimem_malloc      ``rt.alloc(name, size_bytes | payload, chunkable=...)``
unimem_start/end   ``rt.run_iteration(...)`` / ``rt.phase(...)`` contexts
PMPI wrapper       phase boundaries are declared by the caller (collective /
                   jit-step boundaries), exactly as PMPI interception does
=================  =========================================================

Workflow (paper §3.1): iteration 1 profiles each phase; at its end the
planner builds a placement plan (best of phase-local / cross-phase-global);
from iteration 2 on the proactive mover enforces the plan, and the variation
monitor re-triggers profiling when a phase drifts >10%.

**Incremental replanning** (beyond the paper): when the monitor fires, the
runtime does *not* throw the plan away and serve unplaced iterations while
it re-profiles.  Instead it keeps executing the current plan, down-weights
the accumulated profiles (:meth:`PhaseProfiler.decay`) so the next profiled
iterations dominate, and then rebuilds the plan from the *current* registry
tier state — the planner's initial residents are whatever the old plan left
in the fast tier, so the emitted moves are exactly the diff between the old
and new placements.  Once a first plan exists, ``self.plan`` is never None
again.

**Per-chunk attribution** (``RuntimeConfig.chunk_aware``): instrumentation
may report each object's access distribution over its byte range
(``phase_end(..., access_bins=...)``).  The profiler resamples it with
seeded multinomial noise; ``auto_partition`` then splits chunkable objects
along the measured access CDF (skew-aware bisection) and per-phase chunk
reference counts come from histogram mass rather than uniform size
fractions — so the knapsack can pick exactly the hot head of a skewed
object.  With ``chunk_aware=False`` the runtime reproduces the paper's
object-granularity profiling and equal chunking.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time as _time
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence

from . import initial as initial_mod
from . import partition as partition_mod
from .data_objects import DataObject, ObjectRegistry
from .monitor import VariationMonitor
from .mover import (JaxTierBackend, ProactiveMover, SlackAwareMover,
                    TierBackend)
from .perfmodel import CalibrationConstants
from .phase import Phase, PhaseGraph, PhaseKind, PhaseTraceEvent
from .planner import PlacementPlan, Planner
from .profiler import PhaseProfiler
from .tiers import MachineProfile


@dataclasses.dataclass
class RuntimeConfig:
    fast_capacity_bytes: Optional[int] = None   # default: machine.fast.capacity
    enable_initial_placement: bool = True
    enable_partitioning: bool = True
    enable_local_search: bool = True
    enable_global_search: bool = True
    drift_threshold: float = 0.10
    profile_iterations: int = 1
    seed: int = 0
    # Migration engine: "slack" = slack-aware multi-channel scheduler (the
    # overlap engine), "fifo" = the paper's single-queue phase-boundary mover.
    mover: str = "slack"
    copy_channels: int = 2          # concurrent copy channels ("slack" only)
    # Hot-chunk placement pipeline: ingest per-chunk attribution
    # (access_bins), partition along the measured access CDF, attribute
    # chunk references from histogram mass.  False reproduces the paper's
    # object-granularity profiling + equal chunking.
    chunk_aware: bool = True
    # Drift response: keep serving the current plan while re-profiling, then
    # emit only the diff moves.  False restores the paper's full reset
    # (plan dropped, iterations served unplaced until re-profiled).
    incremental_replan: bool = True
    # How much accumulated profile weight survives a drift event (0 = start
    # from scratch, 1 = new observations barely move the running means).
    replan_decay: float = 0.25


class UnimemRuntime:
    def __init__(self, machine: MachineProfile,
                 config: Optional[RuntimeConfig] = None,
                 backend: Optional[TierBackend] = None,
                 cf: Optional[CalibrationConstants] = None):
        self.machine = machine
        self.config = config or RuntimeConfig()
        self.registry = ObjectRegistry()
        self.backend = backend or JaxTierBackend(machine)
        self.cf = cf or CalibrationConstants()
        self.capacity = (self.config.fast_capacity_bytes
                         if self.config.fast_capacity_bytes is not None
                         else machine.fast.capacity_bytes)
        self.profiler = PhaseProfiler(machine, seed=self.config.seed)
        self.monitor = VariationMonitor(threshold=self.config.drift_threshold)
        self.planner = Planner(machine, self.registry, self.cf, self.capacity)
        self.mover: Optional[ProactiveMover] = None
        self.plan: Optional[PlacementPlan] = None
        self.graph: Optional[PhaseGraph] = None
        self._phase_names: List[str] = []
        self._iteration = 0
        self._events_this_iter: List[PhaseTraceEvent] = []
        self._profiling = True
        self._profiled_iters = 0
        self._baseline_pending = False
        self._static_refs: Dict[str, float] = {}
        self.n_replans = 0              # drift-triggered replan cycles
        self.n_incremental_replans = 0  # ... served without dropping the plan

    # ------------------------------------------------------------- allocation
    def alloc(self, name: str, *, size_bytes: Optional[int] = None,
              payload: Any = None, chunkable: bool = False,
              pinned: bool = False,
              static_refs: Optional[float] = None) -> DataObject:
        """``unimem_malloc``: register a target data object."""
        if size_bytes is None:
            if payload is None:
                raise ValueError("need size_bytes or payload")
            import jax
            size_bytes = sum(l.size * l.dtype.itemsize
                             for l in jax.tree_util.tree_leaves(payload))
        obj = self.registry.alloc(name, int(size_bytes), chunkable=chunkable,
                                  payload=payload, pinned=pinned)
        if static_refs is not None:
            self._static_refs[name] = static_refs
        return obj

    # ------------------------------------------------------------- main loop
    def start_loop(self, phase_names: List[str],
                   static_refs: Optional[Dict[str, float]] = None) -> None:
        """``unimem_start``: declare the loop's phase structure."""
        self._phase_names = list(phase_names)
        self._static_refs.update(static_refs or {})
        self._iteration = 0
        self._profiling = True
        self._profiled_iters = 0
        self.graph = PhaseGraph([Phase(i, n) for i, n in enumerate(phase_names)])
        self.mover = self._make_mover()
        if self.config.enable_initial_placement and self._static_refs:
            placed = initial_mod.initial_placement(
                self.registry, self._static_refs, self.capacity)
            place = getattr(self.backend, "place", None)
            for name in placed:
                if place is not None:   # allocation-time placement: no copy
                    place(self.registry[name], "fast")
                else:
                    self.backend.start_move(self.registry[name], "fast")

    def _make_mover(self):
        if self.config.mover == "slack":
            return SlackAwareMover(self.registry, self.backend)
        if self.config.mover == "fifo":
            return ProactiveMover(self.registry, self.backend)
        raise ValueError(f"unknown mover {self.config.mover!r}")

    def begin_iteration(self) -> None:
        self._events_this_iter = []

    def phase_begin(self, index: int) -> float:
        """Enter phase ``index``: fence + trigger proactive moves.  Returns the
        fence stall in seconds (simulated backends) — real backends block and
        return 0."""
        if self.plan is not None and self.mover is not None:
            return self.mover.on_phase_start(self.plan, index,
                                             len(self._phase_names))
        return 0.0

    def phase_end(self, index: int, *, elapsed: float,
                  accesses: Optional[Dict[str, float]] = None,
                  time_shares: Optional[Dict[str, float]] = None,
                  access_bins: Optional[Dict[str, Sequence[float]]] = None
                  ) -> None:
        """Leave phase ``index``.  ``accesses`` are the true per-object
        main-memory access counts for this execution (the instrumentation the
        paper gets from PEBS sampling); ``access_bins`` optionally carries
        each object's access distribution over its byte range (per-chunk
        attribution — the sampled address histogram)."""
        if not self.config.chunk_aware:
            access_bins = None
        ev = PhaseTraceEvent(phase_index=index, time=elapsed,
                             accesses=dict(accesses or {}),
                             time_shares=time_shares,
                             access_bins=access_bins)
        self._events_this_iter.append(ev)
        if self._profiling:
            self.profiler.observe(ev)
        elif self._baseline_pending:
            # First iteration after (re)planning: phase times now reflect the
            # enacted placement — record them as the monitor baseline (the
            # paper monitors performance *after* data movement).
            self.monitor.set_baseline(index, elapsed)
            if index == len(self._phase_names) - 1:
                self._baseline_pending = False
        else:
            drift = self.monitor.observe(index, elapsed)
            if drift is not None:
                self._reprofile()

    @contextlib.contextmanager
    def phase(self, index: int, *, accesses: Optional[Dict[str, float]] = None):
        """Context-manager wrapper over phase_begin/phase_end for real
        (wall-clock) execution."""
        self.phase_begin(index)
        t0 = _time.perf_counter()
        yield
        self.phase_end(index, elapsed=_time.perf_counter() - t0,
                       accesses=accesses)

    def end_iteration(self) -> None:
        self._iteration += 1
        if self._profiling:
            self._profiled_iters += 1
            if self._profiled_iters >= self.config.profile_iterations:
                self._build_plan()
                self._profiling = False
                self._profiled_iters = 0

    # ------------------------------------------------------------- internals
    def _build_plan(self) -> None:
        assert self.graph is not None
        self.profiler.annotate_graph(self.graph)
        if self.config.enable_partitioning:
            newly = partition_mod.auto_partition(
                self.registry, self.graph, self.capacity,
                profiler=self.profiler,
                skew_aware=self.config.chunk_aware)
            if not newly:
                # Replan with parents partitioned on an earlier build:
                # annotate_graph just rewrote parent-name refs from the
                # parent-keyed profiles, so re-attribute them to chunks with
                # the freshest histograms.  (auto_partition already did this
                # for anything it partitioned; without chunk_aware the
                # profiler has no histograms and size fractions apply.)
                partition_mod.resplit_refs(self.graph, self.registry,
                                           self.profiler)
        plans = []
        if self.config.enable_local_search:
            plans.append(self.planner.plan_local(self.graph, self.profiler))
        if self.config.enable_global_search:
            plans.append(self.planner.plan_global(self.graph, self.profiler))
        if not plans:
            self.plan = None
            return
        self.plan = min(plans, key=lambda p: p.predicted_iteration_time)
        self._baseline_pending = True
        self.monitor.consume_events()
        # Enact iteration-start moves for the new plan immediately.
        if self.mover is not None:
            if hasattr(self.mover, "load_plan"):
                self.mover.load_plan(self.plan, self.graph)
            self.mover.on_phase_start(self.plan, 0, len(self._phase_names))

    def _reprofile(self) -> None:
        """Drift response.  Incremental (default): keep serving the current
        plan, decay the profile history so fresh observations dominate, and
        rebuild from the live tier state when enough iterations re-profiled —
        the plan is never dropped, so no iteration runs unplaced.  Legacy:
        the paper's full reset."""
        self.n_replans += 1
        if self.config.incremental_replan and self.plan is not None:
            self.n_incremental_replans += 1
            self.profiler.decay(self.config.replan_decay)
            self._profiling = True
            self._profiled_iters = 0
        else:
            self.profiler.clear()
            self._profiling = True
            self._profiled_iters = 0
            self.plan = None
            self._iteration = 0
        # Drift fires mid-iteration: the phases already executed this
        # iteration (including the drifted one) were routed to the monitor,
        # not the profiler — replay them so the re-profiling window covers
        # the full iteration, not just the phases after the drift.
        for ev in self._events_this_iter:
            self.profiler.observe(ev)

    # ------------------------------------------------------------- reporting
    def stats(self) -> Dict[str, Any]:
        mv = self.mover.stats if self.mover else None
        busy = getattr(self.backend, "busy_seconds", None)
        copy_busy_s = busy() if busy is not None else None
        overlap_time = None
        if copy_busy_s and mv is not None:
            overlap_time = max(0.0, 1.0 - mv.fence_stall_s / copy_busy_s)
        return dict(
            iteration=self._iteration,
            strategy=self.plan.strategy if self.plan else None,
            predicted_iteration_time=(self.plan.predicted_iteration_time
                                      if self.plan else None),
            mover=self.config.mover,
            n_moves=mv.n_moves if mv else 0,
            moved_bytes=mv.moved_bytes if mv else 0,
            overlap_fraction=mv.overlap_fraction if mv else None,
            fence_stall_s=mv.fence_stall_s if mv else 0.0,
            copy_busy_s=copy_busy_s,
            overlap_time_fraction=overlap_time,
            fast_resident_bytes=self.registry.bytes_in_tier("fast"),
            n_objects=len(self.registry),
            n_replans=self.n_replans,
            n_incremental_replans=self.n_incremental_replans,
        )
