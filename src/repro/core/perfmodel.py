"""Unimem performance models — Eq. (1)-(5) of the paper, verbatim.

* Eq. (1) consumed-bandwidth estimate for a (phase, object) pair
* classification: bandwidth-sensitive (>= t1% of BW_peak), latency-sensitive
  (< t2%), mixed otherwise (benefit = max of the two models)
* Eq. (2) benefit for bandwidth-sensitive objects, with CF_bw
* Eq. (3) benefit for latency-sensitive objects, with CF_lat
* Eq. (4) movement cost with proactive overlap
* Eq. (5) knapsack weight w = BFT - COST - extra_COST

CF_bw / CF_lat are measured once per machine by running a STREAM-like and a
pointer-chasing-like calibration workload (paper §3.1.2) — see
:func:`calibrate` which runs them through the discrete-event simulator (the
platform stand-in on a CPU-only container).
"""

from __future__ import annotations

import dataclasses
import enum
import warnings
from typing import Dict, Mapping, Optional, Tuple

from .profiler import ObjectPhaseProfile
from .tiers import MachineProfile

T1_BANDWIDTH = 0.80   # paper: t1 = 80 (% of BW_peak)
T2_LATENCY = 0.10     # paper: t2 = 10 (% of BW_peak)


class Sensitivity(enum.Enum):
    BANDWIDTH = "bandwidth"
    LATENCY = "latency"
    MIXED = "mixed"


@dataclasses.dataclass(frozen=True)
class CalibrationConstants:
    """CF_bw / CF_lat (paper §3.1.2) plus the online-feedback state.

    The calibration feedback loop folds live predicted-vs-measured
    corrections *into the same constants* the static microbenchmarks
    produce: per-phase realized gains regress multiplicative corrections
    onto ``cf_bw`` / ``cf_lat`` (the two benefit classes can be
    mis-calibrated in opposite directions, and only a per-class fold can
    change the knapsack's ranking), while measured fence stalls calibrate
    ``cf_move`` — a movement-price scale applied to the Eq. (4)/eviction
    costs.  All folds are multiplicative, so at the defaults every benefit
    and cost value is bitwise identical to the pre-feedback model
    (``x * 1.0 == x`` for float64).  ``provenance`` records where each
    constant came from — a measured microbenchmark, a
    degenerate-denominator fallback, or an online fold — so a fallback or
    fold can never masquerade as a measured calibration."""

    cf_bw: float = 1.0
    cf_lat: float = 1.0
    cf_move: float = 1.0
    provenance: Tuple[str, ...] = ()


# --------------------------------------------------------------------------
# Eq. (1): BW_data_obj = (#data_access * cacheline) /
#          ((#samples_with_access / #samples) * phase_time)
# --------------------------------------------------------------------------
def consumed_bandwidth(p: ObjectPhaseProfile, machine: MachineProfile) -> float:
    frac = p.samples_with_access / max(p.n_samples, 1.0)
    denom = frac * p.phase_time
    if denom <= 0.0:
        return 0.0
    return p.accessed_bytes / denom


def classify(p: ObjectPhaseProfile, machine: MachineProfile,
             *, t1: float = T1_BANDWIDTH, t2: float = T2_LATENCY) -> Sensitivity:
    bw = consumed_bandwidth(p, machine)
    peak = machine.bw_peak
    if bw >= t1 * peak:
        return Sensitivity.BANDWIDTH
    if bw < t2 * peak:
        return Sensitivity.LATENCY
    return Sensitivity.MIXED


# --------------------------------------------------------------------------
# Eq. (2): BFT_bw = (#acc*line/NVM_bw - #acc*line/DRAM_bw) * CF_bw
# Eq. (3): BFT_lat = (#acc*NVM_lat - #acc*DRAM_lat) * CF_lat
# --------------------------------------------------------------------------
def benefit_bw(p: ObjectPhaseProfile, machine: MachineProfile,
               cf: CalibrationConstants) -> float:
    accessed = p.accessed_bytes
    return (accessed / machine.slow.bw - accessed / machine.fast.bw) * cf.cf_bw


def benefit_lat(p: ObjectPhaseProfile, machine: MachineProfile,
                cf: CalibrationConstants) -> float:
    return (p.data_access * machine.slow.lat
            - p.data_access * machine.fast.lat) * cf.cf_lat


def benefit(p: ObjectPhaseProfile, machine: MachineProfile,
            cf: CalibrationConstants,
            sensitivity: Optional[Sensitivity] = None) -> float:
    """BFT_data_obj for moving the object slow->fast for this phase."""
    s = sensitivity or classify(p, machine)
    if s is Sensitivity.BANDWIDTH:
        return benefit_bw(p, machine, cf)
    if s is Sensitivity.LATENCY:
        return benefit_lat(p, machine, cf)
    return max(benefit_bw(p, machine, cf), benefit_lat(p, machine, cf))


def gain_class(p: ObjectPhaseProfile, machine: MachineProfile,
               cf: CalibrationConstants) -> str:
    """Which benefit model a (phase, object) pair's gain is booked under:
    ``"bw"`` (Eq. 2) or ``"lat"`` (Eq. 3).  MIXED resolves to the model
    :func:`benefit` actually took the max from (ties go to bandwidth,
    matching the vectorized path) — the attribution key the calibration
    feedback uses to regress per-class realization factors."""
    s = classify(p, machine)
    if s is Sensitivity.BANDWIDTH:
        return "bw"
    if s is Sensitivity.LATENCY:
        return "lat"
    return ("bw" if benefit_bw(p, machine, cf) >= benefit_lat(p, machine, cf)
            else "lat")


def benefit_batch(data_access, n_samples, samples_with_access, phase_time,
                  cacheline_bytes, machine: MachineProfile,
                  cf: CalibrationConstants, return_class: bool = False):
    """Vectorized Eq. (1)-(3): classification + benefit for N profiles at
    once (the planner's hot path at chunk counts in the thousands).

    Element-for-element this performs the same float64 operations as the
    scalar :func:`benefit` path, so the two agree bitwise.  With
    ``return_class`` the resolved benefit class per element (0 = bw,
    1 = lat, mirroring :func:`gain_class`) is returned alongside the
    values — the calibration feedback's attribution key.
    """
    import numpy as np

    da = np.asarray(data_access, dtype=np.float64)
    ns = np.asarray(n_samples, dtype=np.float64)
    swa = np.asarray(samples_with_access, dtype=np.float64)
    pt = np.asarray(phase_time, dtype=np.float64)
    line = np.asarray(cacheline_bytes, dtype=np.float64)

    accessed = da * line
    denom = (swa / np.maximum(ns, 1.0)) * pt
    with np.errstate(divide="ignore", invalid="ignore"):
        bw = np.where(denom > 0.0, accessed / denom, 0.0)
    bft_bw = ((accessed / machine.slow.bw - accessed / machine.fast.bw)
              * cf.cf_bw)
    bft_lat = ((da * machine.slow.lat - da * machine.fast.lat)
               * cf.cf_lat)
    peak = machine.bw_peak
    vals = np.where(bw >= T1_BANDWIDTH * peak, bft_bw,
                    np.where(bw < T2_LATENCY * peak, bft_lat,
                             np.maximum(bft_bw, bft_lat)))
    if not return_class:
        return vals
    # class attribution mirroring :func:`gain_class`: MIXED resolves to
    # the winning model, ties to bandwidth
    cls = np.where(bw >= T1_BANDWIDTH * peak, 0,
                   np.where(bw < T2_LATENCY * peak, 1,
                            np.where(bft_lat > bft_bw, 1, 0)))
    return vals, cls


# --------------------------------------------------------------------------
# Eq. (4): COST = max(size/copy_bw - mem_comp_overlap, 0)
# --------------------------------------------------------------------------
def movement_cost(size_bytes: float, machine: MachineProfile,
                  overlap_window: float) -> float:
    return max(size_bytes / machine.copy_bw - overlap_window, 0.0)


def movement_cost_batch(size_bytes, machine: MachineProfile,
                        overlap_windows) -> np.ndarray:
    """Elementwise :func:`movement_cost` over aligned arrays — the same
    IEEE float64 expression (divide, subtract, clamp), so each element is
    bitwise equal to the scalar call."""
    import numpy as np
    return np.maximum(
        np.asarray(size_bytes, dtype=np.float64) / machine.copy_bw
        - np.asarray(overlap_windows, dtype=np.float64), 0.0)


# --------------------------------------------------------------------------
# Eq. (5): w = BFT - COST - extra_COST
# --------------------------------------------------------------------------
def weight(bft: float, cost: float, extra_cost: float = 0.0) -> float:
    return bft - cost - extra_cost


# --------------------------------------------------------------------------
# cross-host extension: per-link interconnect pricing.  Eq. (4) prices an
# intra-host tier move against the DRAM<->NVM copy engine; a shard pulled
# from a peer host instead crosses a modeled interconnect link with its
# own bandwidth, per-transfer setup latency, and a bounded number of
# concurrent send/recv channel pairs.  The coordinator compares the two
# prices when choosing between local NVM->DRAM promotion and a peer pull.
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """One directed interconnect link between two hosts.

    ``bandwidth`` is the sustained point-to-point rate in bytes/s (e.g.
    ``tiers.V5E_ICI_BW`` for on-pod ICI, ~25-50x less for DCN);
    ``latency`` the per-transfer setup cost in seconds (rendezvous +
    first-byte); ``channel_pairs`` how many concurrent send/recv pairs
    the link sustains at full rate (transfers beyond that queue)."""

    name: str
    bandwidth: float
    latency: float = 0.0
    channel_pairs: int = 1

    def __post_init__(self):
        if self.bandwidth <= 0:
            raise ValueError(f"link {self.name!r}: bandwidth must be > 0")
        if self.latency < 0 or self.channel_pairs < 1:
            raise ValueError(
                f"link {self.name!r}: latency must be >= 0 and "
                f"channel_pairs >= 1")


def link_transfer_time(size_bytes: float, link: LinkSpec) -> float:
    """Wire time for one shard over one send/recv pair: setup + stream."""
    return link.latency + size_bytes / link.bandwidth


def cross_host_cost(size_bytes: float, link: LinkSpec,
                    overlap_window: float = 0.0) -> float:
    """Eq. (4) analogue for a peer-host pull: the unhidden remainder of
    the link transfer after overlapping ``overlap_window`` seconds of
    compute.  The setup latency overlaps too — the rendezvous happens
    while compute runs, exactly like the copy engine's ramp."""
    return max(link_transfer_time(size_bytes, link) - overlap_window, 0.0)


class InterconnectModel:
    """The cluster's link table: host-pair -> :class:`LinkSpec`.

    Lookup is direction-aware with a symmetric fallback (most fabrics
    are full-duplex and symmetric; an asymmetric pair can still be
    registered per direction), and an optional ``default`` link prices
    pairs the table does not name — the "flat fabric" shorthand the sim
    uses for N virtual hosts on one switch."""

    def __init__(self, links: Optional[Mapping[Tuple[str, str],
                                               LinkSpec]] = None,
                 default: Optional[LinkSpec] = None):
        self._links: Dict[Tuple[str, str], LinkSpec] = dict(links or {})
        self.default = default

    def link(self, src: str, dst: str) -> LinkSpec:
        spec = self._links.get((src, dst)) or self._links.get((dst, src))
        if spec is None:
            spec = self.default
        if spec is None:
            raise KeyError(f"no interconnect link registered for "
                           f"{src!r} -> {dst!r} and no default")
        return spec

    def pairs(self) -> Dict[Tuple[str, str], LinkSpec]:
        return dict(self._links)

    def __repr__(self) -> str:
        return (f"InterconnectModel({len(self._links)} links, "
                f"default={self.default!r})")


# --------------------------------------------------------------------------
# CF calibration (paper §3.1.2): run a bandwidth-bound (STREAM-like) and a
# latency-bound (pointer-chasing-like) workload; CF = measured / predicted.
# --------------------------------------------------------------------------
def _cf_ratio(measured: float, predicted: float, name: str
              ) -> Tuple[float, str]:
    """measured/predicted with an *audited* fallback: a degenerate
    denominator yields CF=1.0, warns, and is recorded in provenance so it
    can never masquerade as a measured calibration."""
    if predicted <= 0.0:
        warnings.warn(
            f"calibrate: degenerate predicted time for {name} "
            f"(predicted={predicted!r}); falling back to CF=1.0",
            RuntimeWarning, stacklevel=3)
        return 1.0, f"{name}:fallback(predicted={predicted:g})"
    return measured / predicted, f"{name}:measured"


def solve_gain_folds(rows, *, ridge: float = 0.05, lo: float = 0.05,
                     hi: float = 20.0) -> Tuple[float, float]:
    """Per-class benefit realization factors from one measured iteration.

    ``rows`` holds one ``(booked_bw, booked_lat, realized)`` triple per
    phase: the plan's Eq. (2)/Eq. (3) gain booked for that phase, split by
    benefit class, and the gain the measurement realized (profiled
    baseline phase time minus measured phase time).  Because Eq. (2)/(3)
    are linear in the CFs, the multiplicative corrections ``(a, b)`` that
    would have made the prediction match solve the least-squares system
    ``a*booked_bw + b*booked_lat ≈ realized`` over the phases.

    A single scalar correction cannot do this: scaling both classes by
    the same factor preserves the knapsack's ranking, and the two classes
    are routinely mis-calibrated in *opposite* directions (a strict
    rotation's latency gains over-credit while its bandwidth gains are
    honest).  Phases with only one class booked pin that class's factor;
    the ridge term (scaled to the problem, pulling toward the neutral
    1.0) keeps a class nobody booked — or a degenerate, collinear system
    — at its current calibration instead of letting the solve invent a
    correction for it.  Results are clipped to ``[lo, hi]``."""
    s_bb = s_bl = s_ll = y_b = y_l = 0.0
    for g_bw, g_lat, realized in rows:
        s_bb += g_bw * g_bw
        s_bl += g_bw * g_lat
        s_ll += g_lat * g_lat
        y_b += g_bw * realized
        y_l += g_lat * realized
    lam = ridge * max(s_bb, s_ll)
    if lam <= 0.0:
        return 1.0, 1.0
    a11, a12, a22 = s_bb + lam, s_bl, s_ll + lam
    b1, b2 = y_b + lam, y_l + lam        # the prior pulls toward 1.0
    det = a11 * a22 - a12 * a12
    if det <= 0.0:
        return 1.0, 1.0
    a = (b1 * a22 - b2 * a12) / det
    b = (b2 * a11 - b1 * a12) / det
    clip = lambda x: min(max(x, lo), hi)
    return clip(a), clip(b)


def fold_online(cf: CalibrationConstants, *, gain_bw: float = 1.0,
                gain_lat: float = 1.0, move: float = 1.0,
                blend: float = 1.0, lo: float = 0.05, hi: float = 20.0,
                note: str = "") -> CalibrationConstants:
    """Fold one iteration's multiplicative corrections into the constants.

    ``gain_bw`` / ``gain_lat`` come from :func:`solve_gain_folds`;
    ``move`` is the measured-stall over booked-unhidden-cost ratio (the
    movement-price realization).  Each factor is EMA-blended toward 1.0
    (``blend`` = 1.0 applies it fully) and clipped to ``[lo, hi]`` so one
    noisy iteration can neither zero nor explode the model; ``cf_move``
    is additionally clipped cumulatively (its neutral point is an
    absolute 1.0, unlike the measured ``cf_bw``/``cf_lat``).  Returns
    ``cf`` unchanged (the same object) when every fold is a no-op."""
    def damp(m: float) -> float:
        m = 1.0 + blend * (m - 1.0)
        return min(max(m, lo), hi)

    f_bw, f_lat, f_move = damp(gain_bw), damp(gain_lat), damp(move)
    new_bw = cf.cf_bw * f_bw
    new_lat = cf.cf_lat * f_lat
    new_move = min(max(cf.cf_move * f_move, lo), hi)
    if (new_bw, new_lat, new_move) == (cf.cf_bw, cf.cf_lat, cf.cf_move):
        return cf
    tag = (f"online(bw*{f_bw:.3g},lat*{f_lat:.3g},move*{f_move:.3g}"
           f"{';' + note if note else ''})")
    return dataclasses.replace(
        cf, cf_bw=float(new_bw), cf_lat=float(new_lat),
        cf_move=float(new_move), provenance=cf.provenance + (tag,))


def calibrate(machine: MachineProfile, *, seed: int = 0) -> CalibrationConstants:
    """Measure CF_bw / CF_lat against the discrete-event simulator.

    Predicted time uses the same formulas the runtime will use online
    (accessed_bytes / fast_bw and accesses x fast_lat, per the paper); the
    "measured" time is the simulator executing the same access stream on the
    fast tier.  The ratio absorbs sampling loss and overlap effects.
    """
    from ..sim.engine import simulate_stream_time, simulate_chase_time
    from .profiler import PhaseProfiler
    from .phase import PhaseTraceEvent

    # ---- STREAM-like: touch 64 MiB sequentially on the fast tier ----------
    n_bytes = 64 * 1024 * 1024
    accesses = n_bytes / machine.cacheline_bytes
    measured_bw_time = simulate_stream_time(machine, n_bytes, tier="fast")
    prof = PhaseProfiler(machine, seed=seed)
    prof.observe(PhaseTraceEvent(phase_index=0, time=measured_bw_time,
                                 accesses={"stream": accesses}))
    p = prof.profile(0, "stream")
    predicted = (p.data_access * machine.cacheline_bytes) / machine.fast.bw
    cf_bw, prov_bw = _cf_ratio(measured_bw_time, predicted, "cf_bw")

    # ---- pChase-like: dependent accesses, single chain ---------------------
    n_chase = 1_000_000
    measured_lat_time = simulate_chase_time(machine, n_chase, tier="fast")
    prof2 = PhaseProfiler(machine, seed=seed + 1)
    prof2.observe(PhaseTraceEvent(phase_index=0, time=measured_lat_time,
                                  accesses={"chase": float(n_chase)}))
    p2 = prof2.profile(0, "chase")
    predicted_lat = p2.data_access * machine.fast.lat
    cf_lat, prov_lat = _cf_ratio(measured_lat_time, predicted_lat, "cf_lat")

    return CalibrationConstants(cf_bw=float(cf_bw), cf_lat=float(cf_lat),
                                provenance=(prov_bw, prov_lat))
