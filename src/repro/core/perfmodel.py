"""Unimem performance models — Eq. (1)-(5) of the paper, verbatim.

* Eq. (1) consumed-bandwidth estimate for a (phase, object) pair
* classification: bandwidth-sensitive (>= t1% of BW_peak), latency-sensitive
  (< t2%), mixed otherwise (benefit = max of the two models)
* Eq. (2) benefit for bandwidth-sensitive objects, with CF_bw
* Eq. (3) benefit for latency-sensitive objects, with CF_lat
* Eq. (4) movement cost with proactive overlap
* Eq. (5) knapsack weight w = BFT - COST - extra_COST

CF_bw / CF_lat are measured once per machine by running a STREAM-like and a
pointer-chasing-like calibration workload (paper §3.1.2) — see
:func:`calibrate` which runs them through the discrete-event simulator (the
platform stand-in on a CPU-only container).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional

from .profiler import ObjectPhaseProfile
from .tiers import MachineProfile

T1_BANDWIDTH = 0.80   # paper: t1 = 80 (% of BW_peak)
T2_LATENCY = 0.10     # paper: t2 = 10 (% of BW_peak)


class Sensitivity(enum.Enum):
    BANDWIDTH = "bandwidth"
    LATENCY = "latency"
    MIXED = "mixed"


@dataclasses.dataclass(frozen=True)
class CalibrationConstants:
    cf_bw: float = 1.0
    cf_lat: float = 1.0


# --------------------------------------------------------------------------
# Eq. (1): BW_data_obj = (#data_access * cacheline) /
#          ((#samples_with_access / #samples) * phase_time)
# --------------------------------------------------------------------------
def consumed_bandwidth(p: ObjectPhaseProfile, machine: MachineProfile) -> float:
    frac = p.samples_with_access / max(p.n_samples, 1.0)
    denom = frac * p.phase_time
    if denom <= 0.0:
        return 0.0
    return p.accessed_bytes / denom


def classify(p: ObjectPhaseProfile, machine: MachineProfile,
             *, t1: float = T1_BANDWIDTH, t2: float = T2_LATENCY) -> Sensitivity:
    bw = consumed_bandwidth(p, machine)
    peak = machine.bw_peak
    if bw >= t1 * peak:
        return Sensitivity.BANDWIDTH
    if bw < t2 * peak:
        return Sensitivity.LATENCY
    return Sensitivity.MIXED


# --------------------------------------------------------------------------
# Eq. (2): BFT_bw = (#acc*line/NVM_bw - #acc*line/DRAM_bw) * CF_bw
# Eq. (3): BFT_lat = (#acc*NVM_lat - #acc*DRAM_lat) * CF_lat
# --------------------------------------------------------------------------
def benefit_bw(p: ObjectPhaseProfile, machine: MachineProfile,
               cf: CalibrationConstants) -> float:
    accessed = p.accessed_bytes
    return (accessed / machine.slow.bw - accessed / machine.fast.bw) * cf.cf_bw


def benefit_lat(p: ObjectPhaseProfile, machine: MachineProfile,
                cf: CalibrationConstants) -> float:
    return (p.data_access * machine.slow.lat
            - p.data_access * machine.fast.lat) * cf.cf_lat


def benefit(p: ObjectPhaseProfile, machine: MachineProfile,
            cf: CalibrationConstants,
            sensitivity: Optional[Sensitivity] = None) -> float:
    """BFT_data_obj for moving the object slow->fast for this phase."""
    s = sensitivity or classify(p, machine)
    if s is Sensitivity.BANDWIDTH:
        return benefit_bw(p, machine, cf)
    if s is Sensitivity.LATENCY:
        return benefit_lat(p, machine, cf)
    return max(benefit_bw(p, machine, cf), benefit_lat(p, machine, cf))


def benefit_batch(data_access, n_samples, samples_with_access, phase_time,
                  cacheline_bytes, machine: MachineProfile,
                  cf: CalibrationConstants):
    """Vectorized Eq. (1)-(3): classification + benefit for N profiles at
    once (the planner's hot path at chunk counts in the thousands).

    Element-for-element this performs the same float64 operations as the
    scalar :func:`benefit` path, so the two agree bitwise.
    """
    import numpy as np

    da = np.asarray(data_access, dtype=np.float64)
    ns = np.asarray(n_samples, dtype=np.float64)
    swa = np.asarray(samples_with_access, dtype=np.float64)
    pt = np.asarray(phase_time, dtype=np.float64)
    line = np.asarray(cacheline_bytes, dtype=np.float64)

    accessed = da * line
    denom = (swa / np.maximum(ns, 1.0)) * pt
    with np.errstate(divide="ignore", invalid="ignore"):
        bw = np.where(denom > 0.0, accessed / denom, 0.0)
    bft_bw = (accessed / machine.slow.bw - accessed / machine.fast.bw) * cf.cf_bw
    bft_lat = (da * machine.slow.lat - da * machine.fast.lat) * cf.cf_lat
    peak = machine.bw_peak
    return np.where(bw >= T1_BANDWIDTH * peak, bft_bw,
                    np.where(bw < T2_LATENCY * peak, bft_lat,
                             np.maximum(bft_bw, bft_lat)))


# --------------------------------------------------------------------------
# Eq. (4): COST = max(size/copy_bw - mem_comp_overlap, 0)
# --------------------------------------------------------------------------
def movement_cost(size_bytes: float, machine: MachineProfile,
                  overlap_window: float) -> float:
    return max(size_bytes / machine.copy_bw - overlap_window, 0.0)


# --------------------------------------------------------------------------
# Eq. (5): w = BFT - COST - extra_COST
# --------------------------------------------------------------------------
def weight(bft: float, cost: float, extra_cost: float = 0.0) -> float:
    return bft - cost - extra_cost


# --------------------------------------------------------------------------
# CF calibration (paper §3.1.2): run a bandwidth-bound (STREAM-like) and a
# latency-bound (pointer-chasing-like) workload; CF = measured / predicted.
# --------------------------------------------------------------------------
def calibrate(machine: MachineProfile, *, seed: int = 0) -> CalibrationConstants:
    """Measure CF_bw / CF_lat against the discrete-event simulator.

    Predicted time uses the same formulas the runtime will use online
    (accessed_bytes / fast_bw and accesses x fast_lat, per the paper); the
    "measured" time is the simulator executing the same access stream on the
    fast tier.  The ratio absorbs sampling loss and overlap effects.
    """
    from ..sim.engine import simulate_stream_time, simulate_chase_time
    from .profiler import PhaseProfiler
    from .phase import PhaseTraceEvent

    # ---- STREAM-like: touch 64 MiB sequentially on the fast tier ----------
    n_bytes = 64 * 1024 * 1024
    accesses = n_bytes / machine.cacheline_bytes
    measured_bw_time = simulate_stream_time(machine, n_bytes, tier="fast")
    prof = PhaseProfiler(machine, seed=seed)
    prof.observe(PhaseTraceEvent(phase_index=0, time=measured_bw_time,
                                 accesses={"stream": accesses}))
    p = prof.profile(0, "stream")
    predicted = (p.data_access * machine.cacheline_bytes) / machine.fast.bw
    cf_bw = measured_bw_time / predicted if predicted > 0 else 1.0

    # ---- pChase-like: dependent accesses, single chain ---------------------
    n_chase = 1_000_000
    measured_lat_time = simulate_chase_time(machine, n_chase, tier="fast")
    prof2 = PhaseProfiler(machine, seed=seed + 1)
    prof2.observe(PhaseTraceEvent(phase_index=0, time=measured_lat_time,
                                  accesses={"chase": float(n_chase)}))
    p2 = prof2.profile(0, "chase")
    predicted_lat = p2.data_access * machine.fast.lat
    cf_lat = measured_lat_time / predicted_lat if predicted_lat > 0 else 1.0

    return CalibrationConstants(cf_bw=float(cf_bw), cf_lat=float(cf_lat))
