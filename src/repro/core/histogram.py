"""Multi-resolution access histograms (variable-width-bin span trees).

The profiler's per-(phase, object) address histograms used to be fixed-width
numpy arrays frozen at the instrumentation's bin count: the partitioner's
min-chunk floor was one instrumentation bin wide, and a coalesced chunk
could never re-split below that ceiling.  :class:`Histogram` replaces the
raw array with an explicit *variable-width* binning of the object's byte
range (fractional ``edges`` over [0, 1] plus per-bin ``counts``), so the
measured resolution can differ across the range — fine bins over the hot
head, coarse bins over the cold tail — under a bounded total bin budget.

**Adaptive refinement** (:meth:`refined`) re-bins the accumulated mass by
greedy equi-mass bisection: the heaviest span is split first, repeatedly,
until the bin budget is exhausted (or spans reach ``min_width``).  Hot
regions therefore gain resolution while cold regions implicitly coarsen to
pay for it — the rebuilt edge set *forgets* cold fine edges.  A freshly
split bin carries half its parent's mass (the piecewise-constant
assumption); the *next* profiling iteration's sampled observations then
fill the finer bins with true sub-structure, which is why refinement runs
between profiling iterations, not after the last one.

**Exact mass conservation** is the representation's contract: refinement,
coarsening and decay never create or destroy accumulated mass (the
property tests pin round-trips).  Splits assign exact binary halves;
re-binning redistributes by piecewise-constant integrals over a partition
of [0, 1].

**Legacy parity**: a histogram whose edges are the canonical uniform grid
takes the bitwise-identical arithmetic path of the pre-multi-res
fixed-width code (:func:`uniform_mass`), so disabling refinement
reproduces the old pipeline's plans exactly (the parity goldens in
``tests/test_histogram.py``).
"""

from __future__ import annotations

import math
from typing import List, Optional, Sequence, Union

import numpy as np


def uniform_mass(weights: Sequence[float], lo_frac: float,
                 hi_frac: float) -> float:
    """Integral of the piecewise-constant density described by ``weights``
    (relative weights over equal-width bins spanning [0, 1]) over the
    fractional range [lo_frac, hi_frac) — the legacy fixed-width-bin
    arithmetic, kept bit-identical (plans with refinement off must match
    the pre-multi-res pipeline exactly)."""
    w = np.asarray(weights, dtype=np.float64)
    total = w.sum()
    if total <= 0.0 or w.size == 0:
        return max(0.0, hi_frac - lo_frac)      # uniform fallback
    b = w.size
    lo = min(max(lo_frac, 0.0), 1.0) * b
    hi = min(max(hi_frac, 0.0), 1.0) * b
    if hi <= lo:
        return 0.0
    lo_i, hi_i = int(math.floor(lo)), int(math.ceil(hi))
    mass = w[lo_i:hi_i].sum()
    mass -= (lo - lo_i) * w[lo_i]                       # clip partial head
    if hi_i > hi:
        mass -= (hi_i - hi) * w[min(hi_i, b) - 1]       # clip partial tail
    return float(max(mass, 0.0) / total)


def _uniform_edges(n: int) -> np.ndarray:
    return np.arange(n + 1, dtype=np.float64) / n


class Histogram:
    """Variable-width-bin access histogram over an object's byte range.

    ``edges`` are strictly-increasing byte *fractions* with ``edges[0] == 0``
    and ``edges[-1] == 1``; ``counts[k]`` is the accumulated mass observed
    in ``[edges[k], edges[k+1])``.  Immutable by convention: every mutation
    returns a new instance (accumulation and decay in the profiler swap the
    stored reference)."""

    __slots__ = ("edges", "counts", "_uniform")

    def __init__(self, edges: Sequence[float], counts: Sequence[float]):
        e = np.asarray(edges, dtype=np.float64)
        c = np.asarray(counts, dtype=np.float64)
        if e.ndim != 1 or c.ndim != 1 or e.size != c.size + 1 or c.size == 0:
            raise ValueError("need n+1 edges for n >= 1 counts")
        if e[0] != 0.0 or e[-1] != 1.0 or np.any(np.diff(e) <= 0.0):
            raise ValueError("edges must increase strictly from 0.0 to 1.0")
        self.edges = e
        self.counts = c
        # canonical uniform grids take the legacy bitwise arithmetic path
        self._uniform = bool(np.array_equal(e, _uniform_edges(c.size)))

    # ------------------------------------------------------------ constructors
    @classmethod
    def uniform(cls, n_bins: int,
                counts: Optional[Sequence[float]] = None) -> "Histogram":
        """Equal-width histogram (the legacy representation's shape)."""
        if counts is None:
            counts = np.zeros(n_bins, dtype=np.float64)
        return cls(_uniform_edges(n_bins), counts)

    @classmethod
    def from_weights(cls, weights: Sequence[float]) -> "Histogram":
        """Wrap a legacy fixed-width weight array (instrumentation-native
        uniform bins) as a histogram."""
        w = np.clip(np.asarray(weights, dtype=np.float64), 0.0, None)
        return cls.uniform(w.size, w)

    # ------------------------------------------------------------------ basics
    @property
    def n_bins(self) -> int:
        return int(self.counts.size)

    def __len__(self) -> int:
        return self.n_bins

    @property
    def is_uniform(self) -> bool:
        return self._uniform

    @property
    def total(self) -> float:
        return float(self.counts.sum())

    @property
    def widths(self) -> np.ndarray:
        return np.diff(self.edges)

    @property
    def weights(self) -> np.ndarray:
        """Normalized per-bin mass (sums to 1; zeros when empty)."""
        t = self.counts.sum()
        return self.counts / t if t > 0.0 else np.zeros_like(self.counts)

    def same_edges(self, other: "Histogram") -> bool:
        return np.array_equal(self.edges, other.edges)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Histogram(n_bins={self.n_bins}, total={self.total:.3g}, "
                f"uniform={self._uniform})")

    # -------------------------------------------------------------------- mass
    def mass_fraction(self, lo_frac: float, hi_frac: float) -> float:
        """Fraction of total accumulated mass in [lo_frac, hi_frac) under
        the piecewise-constant density (uniform fallback when empty)."""
        if self._uniform:
            # bitwise-identical to the legacy flow, which normalized the
            # accumulated counts (the old ``bin_weights`` array) before
            # integrating — parity goldens depend on the exact arithmetic
            t = float(self.counts.sum())
            w = self.counts / t if t > 0.0 else self.counts
            return uniform_mass(w, lo_frac, hi_frac)
        lo = min(max(lo_frac, 0.0), 1.0)
        hi = min(max(hi_frac, 0.0), 1.0)
        total = self.counts.sum()
        if total <= 0.0:
            return max(0.0, hi - lo)
        if hi <= lo:
            return 0.0
        e = self.edges
        overlap = np.minimum(hi, e[1:]) - np.maximum(lo, e[:-1])
        frac = np.clip(overlap, 0.0, None) / np.diff(e)
        return float(max((self.counts * frac).sum(), 0.0) / total)

    def mass(self, lo_frac: float, hi_frac: float) -> float:
        """Absolute accumulated mass in [lo_frac, hi_frac)."""
        return self.mass_fraction(lo_frac, hi_frac) * self.total

    def finest_width(self, lo_frac: float = 0.0,
                     hi_frac: float = 1.0) -> float:
        """Width (byte fraction) of the narrowest bin overlapping
        [lo_frac, hi_frac) — the local measurement resolution, which bounds
        how finely the partitioner may meaningfully cut there."""
        lo = min(max(lo_frac, 0.0), 1.0)
        hi = min(max(hi_frac, 0.0), 1.0)
        if hi <= lo:
            return 1.0
        e = self.edges
        i = int(np.searchsorted(e, lo, side="right")) - 1
        j = int(np.searchsorted(e, hi, side="left"))
        i = max(i, 0)
        j = min(max(j, i + 1), e.size - 1)
        return float(np.diff(e[i:j + 1]).min())

    # ------------------------------------------------------------ accumulation
    def add(self, other: "Histogram") -> "Histogram":
        """Sum of two same-edged histograms (observation accumulation)."""
        if not self.same_edges(other):
            raise ValueError("cannot add histograms with different edges")
        return Histogram(self.edges, self.counts + other.counts)

    def scaled(self, factor: float) -> "Histogram":
        """Decay: every bin's mass scaled by ``factor`` (shape preserved —
        mass conservation holds trivially per bin)."""
        return Histogram(self.edges, self.counts * factor)

    def project(self, truth: Union["Histogram", Sequence[float]]
                ) -> Optional[np.ndarray]:
        """Probability, per bin of *this* histogram's edges, that an
        observed address falls in the bin, given the true access density
        ``truth`` (a legacy uniform weight array or another histogram at
        the instrumentation's native resolution) — the multinomial
        p-vector the profiler's sampling model draws from.

        When the truth is a plain array matching this histogram's uniform
        grid, the p-vector is the legacy ``w / w.sum()`` bitwise (so the
        seeded RNG stream — and therefore every sampled count — is
        identical to the fixed-width code)."""
        if not isinstance(truth, Histogram):
            w = np.asarray(truth, dtype=np.float64)
            if w.ndim != 1 or w.size == 0:
                return None
            w = np.clip(w, 0.0, None)
            total = w.sum()
            if total <= 0.0:
                return None
            if self._uniform and w.size == self.n_bins:
                return w / total            # legacy bitwise path
            truth = Histogram.uniform(w.size, w)
        if truth.total <= 0.0:
            return None
        # vectorized piecewise-constant integration: the cumulative mass is
        # piecewise linear in the truth's edges, so one np.interp at the
        # target edges replaces a per-bin mass_fraction loop (the sampling
        # hot path runs once per observation)
        cum = np.concatenate([[0.0], np.cumsum(truth.counts)])
        p = np.diff(np.interp(self.edges, truth.edges, cum))
        p = np.clip(p, 0.0, None)
        s = p.sum()
        if s <= 0.0:
            return None
        return p / s

    # -------------------------------------------------------------- refinement
    def rebinned(self, edges: Sequence[float]) -> "Histogram":
        """Redistribute the accumulated mass onto a new edge set by
        piecewise-constant integration (exact conservation: the new bins
        partition [0, 1], so the masses sum to the old total)."""
        e = np.asarray(edges, dtype=np.float64)
        total = self.total
        counts = np.array([self.mass_fraction(lo, hi) * total
                           for lo, hi in zip(e[:-1], e[1:])])
        return Histogram(e, counts)

    def refined(self, budget: int, *, min_width: float = 1.0 / 4096,
                hot_ratio: float = 2.0) -> "Histogram":
        """One adaptive refinement pass over the current bins (span-tree
        split/merge — the existing edges are *evolved*, never rebuilt, so
        repeated refinement converges instead of diffusing accumulated
        mass):

        * every *hot* bin — mass above ``hot_ratio`` x the budget-average —
          splits at its midpoint, each half keeping exactly half the mass
          (information-neutral: the next profiling iteration's sampled
          addresses fill in the true sub-structure);
        * while over ``budget``, the adjacent pair with the least combined
          mass merges (cold regions coarsen to pay for hot refinement;
          freshly split halves are exempt, so a split cannot be undone in
          the same pass).

        Mass is conserved exactly (binary halves, pairwise sums).  Returns
        ``self`` unchanged when no bin qualifies — callers use edge
        equality to decide whether the resolution epoch advances.  Once
        every bin's mass sits below the hot threshold (or hot bins reach
        ``min_width``), the edge set is a fixed point."""
        total = self.total
        if total <= 0.0 or budget < 1:
            return self
        thresh = hot_ratio * total / budget
        edges: List[float] = list(self.edges)
        counts: List[float] = list(self.counts)
        fresh: List[bool] = [False] * len(counts)

        def merge_coldest(exclude: Optional[int] = None) -> Optional[int]:
            cands = [k for k in range(len(counts) - 1)
                     if not (fresh[k] or fresh[k + 1])
                     and k != exclude and k + 1 != exclude]
            if not cands:
                return None
            k = min(cands, key=lambda k: (counts[k] + counts[k + 1],
                                          edges[k]))
            counts[k:k + 2] = [counts[k] + counts[k + 1]]
            fresh[k:k + 2] = [False]
            del edges[k + 1]
            return k

        # 1) coarsen into budget (instrumentation finer than the budget)
        while len(counts) > budget:
            if merge_coldest() is None:
                break
        # 2) split hot bins hottest-first, paying for each split with a
        #    cold merge once at budget — the bin count never exceeds it
        while True:
            best = None
            for k in range(len(counts)):
                if fresh[k] or counts[k] <= thresh:
                    continue
                if edges[k + 1] - edges[k] <= 2.0 * min_width:
                    continue
                if best is None or counts[k] > counts[best]:
                    best = k
            if best is None:
                break
            if len(counts) >= budget:
                m = merge_coldest(exclude=best)
                if m is None:
                    break
                if m < best:
                    best -= 1
            mid = (edges[best] + edges[best + 1]) / 2.0
            half = counts[best] / 2.0
            edges.insert(best + 1, mid)
            counts[best:best + 1] = [half, half]
            fresh[best:best + 1] = [True, True]
        e = np.asarray(edges)
        if np.array_equal(e, self.edges):
            return self
        return Histogram(e, counts)
