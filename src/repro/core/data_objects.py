"""Target data objects.

The paper's runtime manages *target data objects* — arrays the programmer
registers with ``unimem_malloc``.  Here a :class:`DataObject` names a logical
array (or group of arrays, e.g. one transformer layer's weights, one KV-cache
block, one optimizer-state shard) whose tier residency the runtime controls.

Objects may be *chunkable* (paper §3.2 "Handling large data objects"): 1-D
regular arrays can be split into chunks that are placed independently.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, List, Optional


@dataclasses.dataclass
class DataObject:
    """A managed data object.

    ``payload`` optionally binds a real JAX array (or pytree of arrays);
    simulation-only objects carry just ``size_bytes``.
    """

    name: str
    size_bytes: int
    chunkable: bool = False
    payload: Any = None
    # Filled by partition.partition_object for chunks of a parent object.
    parent: Optional[str] = None
    chunk_index: Optional[int] = None
    # Current tier name, maintained by the mover / simulator.
    tier: str = "slow"
    pinned: bool = False   # pinned objects are never moved (e.g. SSM state)

    def __post_init__(self):
        if self.size_bytes < 0:
            raise ValueError(f"negative size for {self.name}")

    @property
    def is_chunk(self) -> bool:
        return self.parent is not None


class ObjectRegistry:
    """Registry of target data objects (the ``unimem_malloc`` table)."""

    def __init__(self) -> None:
        self._objs: Dict[str, DataObject] = {}

    def register(self, obj: DataObject) -> DataObject:
        if obj.name in self._objs:
            raise KeyError(f"duplicate data object {obj.name!r}")
        self._objs[obj.name] = obj
        return obj

    def alloc(self, name: str, size_bytes: int, *, chunkable: bool = False,
              payload: Any = None, tier: str = "slow",
              pinned: bool = False) -> DataObject:
        return self.register(DataObject(
            name=name, size_bytes=size_bytes, chunkable=chunkable,
            payload=payload, tier=tier, pinned=pinned))

    def __getitem__(self, name: str) -> DataObject:
        return self._objs[name]

    def __contains__(self, name: str) -> bool:
        return name in self._objs

    def __iter__(self) -> Iterator[DataObject]:
        return iter(self._objs.values())

    def __len__(self) -> int:
        return len(self._objs)

    def names(self) -> List[str]:
        return list(self._objs.keys())

    def total_bytes(self) -> int:
        return sum(o.size_bytes for o in self._objs.values())

    def in_tier(self, tier: str) -> List[DataObject]:
        return [o for o in self._objs.values() if o.tier == tier]

    def bytes_in_tier(self, tier: str) -> int:
        return sum(o.size_bytes for o in self._objs.values() if o.tier == tier)

    def remove(self, name: str) -> None:
        del self._objs[name]
