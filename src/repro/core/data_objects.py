"""Target data objects.

The paper's runtime manages *target data objects* — arrays the programmer
registers with ``unimem_malloc``.  Here a :class:`DataObject` names a logical
array (or group of arrays, e.g. one transformer layer's weights, one KV-cache
block, one optimizer-state shard) whose tier residency the runtime controls.

Objects may be *chunkable* (paper §3.2 "Handling large data objects"): 1-D
regular arrays can be split into chunks that are placed independently.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, List, Optional, Tuple


@dataclasses.dataclass
class DataObject:
    """A managed data object.

    ``payload`` optionally binds a real JAX array (or pytree of arrays);
    simulation-only objects carry just ``size_bytes``.  ``leaf_spans``
    records the byte span of each pytree leaf inside the object
    (``(path, offset, nbytes)`` in flatten order) when the object was
    registered from a pytree — chunk attribution and partition boundaries
    can then align to leaf boundaries.
    """

    name: str
    size_bytes: int
    chunkable: bool = False
    payload: Any = None
    leaf_spans: Optional[List[Tuple[str, int, int]]] = None
    # Filled by partition.partition_object for chunks of a parent object.
    parent: Optional[str] = None
    chunk_index: Optional[int] = None
    # Current tier name, maintained by the mover / simulator.
    tier: str = "slow"
    pinned: bool = False   # pinned objects are never moved (e.g. SSM state)

    def __post_init__(self):
        if self.size_bytes < 0:
            raise ValueError(f"negative size for {self.name}")

    @property
    def is_chunk(self) -> bool:
        return self.parent is not None


class ObjectRegistry:
    """Registry of target data objects (the ``unimem_malloc`` table)."""

    def __init__(self) -> None:
        self._objs: Dict[str, DataObject] = {}
        # live chunk count per parent name: O(1) collision checks even at
        # thousands of registered chunks (the planner-scale regime)
        self._chunks_of: Dict[str, int] = {}
        #: chunk generation: bumped on every registration/removal, so plan
        #: provenance can record which registry shape produced a decision
        self.generation = 0

    def register(self, obj: DataObject) -> DataObject:
        if obj.name in self._objs:
            raise ValueError(
                f"duplicate data object {obj.name!r}: a registered object "
                "already holds this name (re-registering would orphan its "
                "tier and chunk state)")
        if self._chunks_of.get(obj.name, 0) > 0:
            example = next(o.name for o in self._objs.values()
                           if o.parent == obj.name)
            raise ValueError(
                f"duplicate data object {obj.name!r}: it was partitioned "
                f"and its chunks (e.g. {example!r}) are live; registering "
                "a new object under the parent name would orphan their "
                "chunk state")
        self.generation += 1
        self._objs[obj.name] = obj
        if obj.parent is not None:
            self._chunks_of[obj.parent] = \
                self._chunks_of.get(obj.parent, 0) + 1
        return obj

    def alloc(self, name: str, size_bytes: int, *, chunkable: bool = False,
              payload: Any = None, tier: str = "slow",
              pinned: bool = False) -> DataObject:
        return self.register(DataObject(
            name=name, size_bytes=size_bytes, chunkable=chunkable,
            payload=payload, tier=tier, pinned=pinned))

    def __getitem__(self, name: str) -> DataObject:
        return self._objs[name]

    def __contains__(self, name: str) -> bool:
        return name in self._objs

    def __iter__(self) -> Iterator[DataObject]:
        return iter(self._objs.values())

    def __len__(self) -> int:
        return len(self._objs)

    def names(self) -> List[str]:
        return list(self._objs.keys())

    def total_bytes(self) -> int:
        return sum(o.size_bytes for o in self._objs.values())

    def in_tier(self, tier: str) -> List[DataObject]:
        return [o for o in self._objs.values() if o.tier == tier]

    def bytes_in_tier(self, tier: str) -> int:
        return sum(o.size_bytes for o in self._objs.values() if o.tier == tier)

    def remove(self, name: str) -> None:
        self.generation += 1
        obj = self._objs.pop(name)
        if obj.parent is not None:
            left = self._chunks_of.get(obj.parent, 0) - 1
            if left > 0:
                self._chunks_of[obj.parent] = left
            else:
                self._chunks_of.pop(obj.parent, None)
