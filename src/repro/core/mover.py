"""Proactive data movement (paper §3.1.2 "cost", §3.3 "implementation").

The paper uses a helper thread and a shared FIFO queue: the main thread
enqueues movement requests at trigger points; the helper thread performs them
in the background; phase entry fences the moves that phase depends on.

Here the "helper thread" is whatever the backend provides:

* :class:`JaxTierBackend` — ``jax.device_put`` between memory kinds.  The
  dispatch is asynchronous (JAX returns immediately); the fence is
  ``block_until_ready`` on the moved leaves.  On TPU the copy engine runs in
  the background exactly like the paper's helper thread; on the CPU backend
  the same code path is exercised with host memory kinds.
* :class:`SimTierBackend` — a simulated copy engine with a FIFO service
  queue, used by the discrete-event simulator and the benchmarks.
* :class:`ChannelSimBackend` — a simulated *multi-channel* copy engine:
  up to N copies in flight at once, sharing the engine's aggregate
  bandwidth; tier flips only when a copy lands (no phase may consume an
  object mid-flight).
* :class:`CpuPoolBackend` — a host-side ``memcpy`` thread pool: each move
  copies the object's (numpy/host) leaves on a worker thread, duck-typing
  the same ``settle``/``complete``/``is_done``/``start_move(after=)``
  scheduler surface as the async backends — tier flips only when the
  worker finishes and the copy is settled or fenced.

Two movers execute a placement program (the
:class:`~.policy.PlanProgram` IR — or any
:class:`~.planner.PlacementPlan`, which the IR subsumes) against a
backend:

* :class:`ProactiveMover` — the paper's baseline: a FIFO queue serviced in
  plan order, fences only at phase boundaries.
* :class:`SlackAwareMover` — the overlap engine: walks the plan's emitted
  schedule, computes per-move slack (latest start such that the object lands
  before its first consuming phase), releases moves most-urgent-first onto
  the channels, and consumes ``chunkable`` objects chunk-by-chunk so early
  chunks are read from the fast tier while later chunks are still in flight
  (double buffering).  Fence stalls appear only when slack is truly
  exhausted.

**The backend contract** (duck-typed; :class:`TierBackend` is the minimal
protocol):

* ``start_move(obj, dst) -> handle`` issues one asynchronous copy.  It may
  raise :class:`~.faults.TransientCopyError` — the movers retry with
  exponential backoff bounded by the move's slack deadline.  Optional
  keywords: ``after=`` chains the copy behind a predecessor handle,
  ``avoid=`` is a set of channels the chooser must skip (quarantined
  channels; see :class:`~.faults.ChannelHealth`), ``prefer=`` is the set
  of channels the copy's tenant owns under a bandwidth partition (the
  chooser favors them but borrows idle foreign channels
  work-conservingly; see :mod:`~.tenancy`).
* ``wait(handle, timeout=None)`` is the **bounded-wait contract**: with a
  timeout it must raise :class:`~.faults.CopyTimeoutError` instead of
  blocking past the bound (simulated backends compare the remaining
  virtual stall against the timeout; real backends poll readiness against
  a wall-clock deadline).  With ``timeout=None`` the legacy blocking
  behavior is preserved.  ``wait``/``complete`` raise
  :class:`~.faults.CopyFailedError` for a copy that errored at land time —
  the tier never flips, so a failed eviction's residency rolls back and a
  failed fetch demotes to slow-tier service.
* Backends with in-flight semantics additionally expose ``settle(now)``
  (land finished copies without blocking), ``complete(handle)``,
  ``is_done(handle)``, and optionally ``cancel(handle)`` (abort an
  in-flight copy without a tier flip — straggler reissue and deadline
  abandonment need it).

Failure handling lives in the movers (not the session): per-move retry
with slack-bounded exponential backoff, straggler detection
(in-flight time exceeding ``straggler_factor`` times the priced copy
time) with cancel-and-reissue on a different channel, a per-channel
health state machine feeding the channel chooser, and demotion of
undeliverable fetches to :class:`~.faults.DegradedServe` events the
session logs and the monitor treats as drift.  All of it is inert
without injected faults: the retry loop runs ``start_move`` once, the
health machine stays empty, and traces are bitwise identical.
"""

from __future__ import annotations

import dataclasses
import math
import time as _time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Protocol

import jax

from .data_objects import DataObject, ObjectRegistry
from .faults import (ChannelHealth, CopyError, CopyTimeoutError,
                     DegradedServe, EvictionRollback, TransientCopyError)
from .phase import PhaseGraph
from .planner import MoveOp, PlacementPlan, ScheduledMove
from .tenancy import tenant_of
from .tiers import MachineProfile


class TierBackend(Protocol):
    """Minimal copy-backend protocol (full contract in the module
    docstring): ``wait`` honors the bounded-wait contract — with a
    ``timeout`` it raises :class:`~.faults.CopyTimeoutError` instead of
    blocking past the bound."""

    def start_move(self, obj: DataObject, dst: str) -> Any: ...
    def wait(self, handle: Any, timeout: Optional[float] = None) -> Any: ...


# ---------------------------------------------------------------------------
class JaxTierBackend:
    """Moves real JAX arrays between memory kinds with ``jax.device_put``."""

    def __init__(self, machine: MachineProfile):
        self.machine = machine

    def _sharding_for(self, leaf: jax.Array, kind: Optional[str]):
        s = leaf.sharding
        if kind is None:
            return s
        try:
            return s.with_memory_kind(kind)
        except Exception:
            return s   # backend without memory kinds: logical move only

    def start_move(self, obj: DataObject, dst: str) -> Any:
        tier = self.machine.fast if dst == "fast" else self.machine.slow
        kind = tier.memory_kind
        if obj.payload is None:
            obj.tier = dst
            return None
        leaves, treedef = jax.tree_util.tree_flatten(obj.payload)
        moved = [jax.device_put(l, self._sharding_for(l, kind)) for l in leaves]
        obj.payload = jax.tree_util.tree_unflatten(treedef, moved)
        obj.tier = dst
        return moved

    @staticmethod
    def _wait_leaves(leaves, timeout: Optional[float], what: str) -> None:
        """Fence leaves; with a timeout, poll readiness against a
        wall-clock deadline instead of blocking (bounded-wait contract)."""
        if timeout is None:
            for leaf in leaves:
                leaf.block_until_ready()
            return
        deadline = _time.monotonic() + timeout
        pending = list(leaves)
        while True:
            pending = [l for l in pending
                       if not getattr(l, "is_ready", lambda: True)()]
            if not pending:
                return
            if _time.monotonic() >= deadline:
                raise CopyTimeoutError(
                    f"{what}: {len(pending)} leaves still not ready after "
                    f"{timeout:.3f}s")
            _time.sleep(min(1e-3, timeout / 10))

    def wait(self, handle: Any, timeout: Optional[float] = None) -> None:
        if handle:
            self._wait_leaves(handle, timeout, "device_put fence")


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _AsyncJaxCopy:
    """One in-flight async device_put (a whole object's leaves)."""

    obj: DataObject
    dst: str
    leaves: List[Any]
    landed: bool = False


class AsyncJaxTierBackend(JaxTierBackend):
    """Asynchronous ``jax.device_put`` with per-leaf fencing.

    ``jax.device_put`` dispatches immediately and the TPU copy engine runs
    in the background; unlike :class:`JaxTierBackend` (which flips the
    object's tier at dispatch and fences all leaves at once), this backend
    defers the tier flip until the copy *lands* — matching the simulator's
    in-flight semantics — and exposes the scheduler surface the slack-aware
    mover duck-types on:

    * :meth:`settle` polls ``jax.Array.is_ready()`` per leaf and lands
      every finished copy **without blocking**, so phase boundaries overlap
      with copies still in flight instead of stalling on them;
    * :meth:`wait` / :meth:`complete` fence one copy with per-leaf
      ``block_until_ready`` (the consuming fence pays only for its own
      object's leaves, not the whole in-flight set).
    """

    def __init__(self, machine: MachineProfile):
        super().__init__(machine)
        self._open: List[_AsyncJaxCopy] = []

    def start_move(self, obj: DataObject, dst: str,
                   after: Optional[_AsyncJaxCopy] = None) -> Any:
        # ``after`` chains a fetch behind the eviction freeing its space:
        # dispatching both immediately would transiently co-resident the
        # incoming and outgoing bytes (an OOM risk when the fast tier is
        # sized near capacity), so fence the predecessor's leaves first.
        if after is not None and not getattr(after, "landed", True):
            for leaf in after.leaves:
                leaf.block_until_ready()
            self._land(after)
        tier = self.machine.fast if dst == "fast" else self.machine.slow
        kind = tier.memory_kind
        if obj.payload is None:
            obj.tier = dst          # logical object: nothing to copy
            return None
        leaves, treedef = jax.tree_util.tree_flatten(obj.payload)
        moved = [jax.device_put(l, self._sharding_for(l, kind))
                 for l in leaves]
        obj.payload = jax.tree_util.tree_unflatten(treedef, moved)
        h = _AsyncJaxCopy(obj, dst, moved)
        self._open.append(h)
        return h

    def _land(self, h: _AsyncJaxCopy) -> None:
        if not h.landed:
            h.obj.tier = h.dst
            h.landed = True
        # drop the handle (and its strong refs to the moved leaves) even
        # when the caller fences via wait/complete and never settles —
        # the FIFO mover does exactly that
        try:
            self._open.remove(h)
        except ValueError:
            pass

    def wait(self, handle: Optional[_AsyncJaxCopy],
             timeout: Optional[float] = None) -> float:
        if handle is not None:
            self._wait_leaves(handle.leaves, timeout,
                              f"async copy of {handle.obj.name}")
            self._land(handle)
        return 0.0              # real backend: the fence blocked, no stall

    def complete(self, handle: Optional[_AsyncJaxCopy]) -> None:
        self.wait(handle)

    def is_done(self, handle: Optional[_AsyncJaxCopy]) -> bool:
        """Non-blocking completion probe (the slack mover uses it to keep
        in-flight evictions off the critical path)."""
        if handle is None or handle.landed:
            return True
        return all(getattr(l, "is_ready", lambda: True)()
                   for l in handle.leaves)

    def settle(self, now: float = 0.0) -> None:
        """Land every copy whose leaves are all ready — without blocking."""
        for h in list(self._open):          # _land prunes as it lands
            if all(getattr(l, "is_ready", lambda: True)()
                   for l in h.leaves):
                self._land(h)


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _PoolCopy:
    """One in-flight copy on the CPU memcpy pool."""

    obj: DataObject
    dst: str
    future: Any                 # concurrent.futures.Future -> copied leaves
    treedef: Any = None
    landed: bool = False


class CpuPoolBackend:
    """CPU ``memcpy`` thread pool — the host-memory analogue of the async
    device backends (ROADMAP: multi-backend copy engines).

    Each :meth:`start_move` submits the object's leaf copies to a worker
    pool and returns immediately; the worker materializes copied leaves
    (``np.array(leaf, copy=True)``) off the critical path.  Like the other
    in-flight backends, the object's ``tier`` (and its relocated payload)
    flips only when the finished copy is *landed* — by a non-blocking
    :meth:`settle`, or by the consuming fence's :meth:`wait`/:meth:`complete`.
    ``start_move(after=...)`` chains a fetch behind the eviction freeing
    its space: the worker blocks on the predecessor's future, never the
    caller.  Payload-free (logical) objects flip immediately, matching
    :class:`JaxTierBackend`."""

    def __init__(self, machine: MachineProfile, workers: int = 2):
        import concurrent.futures
        self.machine = machine
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=max(1, workers), thread_name_prefix="unimem-memcpy")
        self._open: List[_PoolCopy] = []

    @staticmethod
    def _copy_leaves(leaves: List[Any], predecessor: Optional[Any]) -> List[Any]:
        import numpy as np
        if predecessor is not None:
            predecessor.result()        # worker waits, caller never does
        return [np.array(l, copy=True) for l in leaves]

    def start_move(self, obj: DataObject, dst: str,
                   after: Optional[_PoolCopy] = None) -> Optional[_PoolCopy]:
        if self._pool is None:
            raise RuntimeError("CpuPoolBackend is shut down")
        if obj.payload is None:
            obj.tier = dst              # logical object: nothing to copy
            return None
        leaves, treedef = jax.tree_util.tree_flatten(obj.payload)
        pred = after.future if (after is not None
                                and not after.landed) else None
        fut = self._pool.submit(self._copy_leaves, leaves, pred)
        h = _PoolCopy(obj, dst, fut, treedef)
        self._open.append(h)
        return h

    def _land(self, h: _PoolCopy) -> None:
        if not h.landed:
            h.obj.payload = jax.tree_util.tree_unflatten(
                h.treedef, h.future.result())
            h.obj.tier = h.dst
            h.landed = True
        try:
            self._open.remove(h)
        except ValueError:
            pass

    def wait(self, handle: Optional[_PoolCopy],
             timeout: Optional[float] = None) -> float:
        if handle is not None:
            import concurrent.futures
            try:
                handle.future.result(timeout=timeout)
            except concurrent.futures.TimeoutError:
                raise CopyTimeoutError(
                    f"pool copy of {handle.obj.name} still running after "
                    f"{timeout:.3f}s") from None
            self._land(handle)
        return 0.0                      # real backend: the fence blocked

    def complete(self, handle: Optional[_PoolCopy]) -> None:
        self.wait(handle)

    def is_done(self, handle: Optional[_PoolCopy]) -> bool:
        return (handle is None or handle.landed
                or handle.future.done())

    def settle(self, now: float = 0.0) -> None:
        """Land every finished copy — without blocking."""
        for h in list(self._open):      # _land prunes as it lands
            if h.future.done():
                self._land(h)

    def shutdown(self, wait: bool = True) -> None:
        """Idempotent teardown: the first call releases the worker pool,
        every later call (including del-after-shutdown) is a no-op.
        Errors surface to the caller — only ``__del__`` swallows them,
        and only because interpreter teardown may have already torn down
        the executor machinery underneath us."""
        pool, self._pool = getattr(self, "_pool", None), None
        if pool is not None:
            pool.shutdown(wait=wait)

    def __del__(self):
        # sessions resolve backends through the registry and have no
        # teardown hook; without this, every discarded session would leak
        # its idle worker threads until interpreter exit
        try:
            self.shutdown(wait=False)
        except Exception:
            pass    # interpreter-exit race: executor already dismantled


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _SimCopy:
    obj: str
    dst: str
    size_bytes: int
    start: float = 0.0
    done: float = 0.0


class SimTierBackend:
    """FIFO copy engine for the discrete-event simulator.

    ``now_fn`` reads the simulation clock; completion times respect a single
    serial copy engine at ``machine.copy_bw`` (the paper's helper thread)."""

    def __init__(self, machine: MachineProfile, now_fn: Callable[[], float]):
        self.machine = machine
        self.now_fn = now_fn
        self._engine_free_at = 0.0
        self.copies: List[_SimCopy] = []

    def place(self, obj: DataObject, dst: str) -> None:
        """Allocation-time placement: no copy, the object starts in ``dst``
        (paper §3.2 initial placement happens at ``unimem_malloc``)."""
        obj.tier = dst

    def start_move(self, obj: DataObject, dst: str) -> _SimCopy:
        now = self.now_fn()
        start = max(now, self._engine_free_at)
        dur = obj.size_bytes / self.machine.copy_bw
        c = _SimCopy(obj.name, dst, obj.size_bytes, start, start + dur)
        self._engine_free_at = c.done
        self.copies.append(c)
        obj.tier = dst
        return c

    def wait(self, handle: _SimCopy, timeout: Optional[float] = None) -> float:
        """Returns the stall (seconds past ``now``) the fence must absorb.
        With a ``timeout``, a copy that would stall past the bound raises
        instead (virtual-time bounded-wait semantics)."""
        stall = max(0.0, handle.done - self.now_fn())
        if timeout is not None and stall > timeout:
            raise CopyTimeoutError(
                f"sim copy of {handle.obj} needs {stall:.4f}s "
                f"> timeout {timeout:.4f}s")
        return stall


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _ChannelCopy:
    """One in-flight copy on the multi-channel engine."""

    obj: DataObject
    dst: str
    size_bytes: int
    start: float
    done: float
    channel: int
    rate: float
    issued_at: float
    landed: bool = False


class ChannelSimBackend:
    """Simulated multi-channel copy engine.

    ``channels`` copies may be in flight concurrently, one per channel; a
    copy issued while ``k`` other channels are busy is served at
    ``copy_bw / (k+1)`` (the engine's aggregate bandwidth is shared among
    concurrent transfers; a lone copy gets the full engine, matching the
    FIFO baseline's service rate).  The rate is fixed at issue time, which
    keeps completion times deterministic and monotone in issue order per
    channel.

    **Prioritized channels** (CUDA-stream-style): ``priorities`` assigns
    each channel a priority class.  Bulk demotion traffic (evictions,
    ``dst == "slow"``) may only queue on the *minimum*-priority channels,
    while urgent fetches pick the earliest-free channel of any class — so
    a burst of evictions can never head-of-line-block the fetch a phase
    is about to fence on.  ``None`` (or all-equal priorities) reproduces
    the unprioritized engine exactly.

    Unlike :class:`SimTierBackend`, an object's ``tier`` flips only when its
    copy *lands* — callers advance landings with :meth:`settle` (at phase
    boundaries) or force completion with :meth:`complete` after absorbing a
    fence stall.  A phase can therefore never observe fast-tier service for
    data still in flight.
    """

    def __init__(self, machine: MachineProfile, now_fn: Callable[[], float],
                 channels: int = 2,
                 priorities: Optional[List[int]] = None):
        if channels < 1:
            raise ValueError("need at least one copy channel")
        self.machine = machine
        self.now_fn = now_fn
        self.channels = channels
        self.priorities = list(priorities) if priorities is not None else None
        if self.priorities is not None and len(self.priorities) != channels:
            raise ValueError(
                f"priorities must name every channel: got "
                f"{len(self.priorities)} for {channels} channels")
        if self.priorities is None or len(set(self.priorities)) <= 1:
            self._bulk_channels: List[int] = list(range(channels))
        else:
            lowest = min(self.priorities)
            self._bulk_channels = [c for c, p in enumerate(self.priorities)
                                   if p == lowest]
        self._free_at = [0.0] * channels
        self.copies: List[_ChannelCopy] = []

    def place(self, obj: DataObject, dst: str) -> None:
        """Allocation-time placement: no copy, the object starts in ``dst``
        (paper §3.2 initial placement happens at ``unimem_malloc``)."""
        obj.tier = dst

    def start_move(self, obj: DataObject, dst: str,
                   after: Optional[_ChannelCopy] = None,
                   avoid: Optional[set] = None,
                   prefer: Optional[frozenset] = None) -> _ChannelCopy:
        """Issue a copy on the earliest-free channel.  ``after`` delays the
        start until another copy lands (eviction -> incoming chaining: the
        incoming copy cannot begin until its space is free).  ``avoid``
        names channels the chooser must skip (quarantined by the mover's
        health machine) — ignored when it would leave no channel at all.
        ``prefer`` names the channels this copy's tenant *owns* (bandwidth
        partitioning): the chooser picks the earliest-free preferred
        channel, but work-conservingly borrows an *idle* non-preferred
        channel rather than queue behind a busy owned one — a tenant's
        reserved bandwidth shields it from others, never strands capacity.

        Contention: copies active while this one starts are re-rated to the
        equal share ``copy_bw / n`` (their completed bytes are preserved and
        their queued successors shift later), so the engine's aggregate
        bandwidth never exceeds ``copy_bw``.  Rates are not raised back when
        a copy finishes — a deterministic, slightly conservative model."""
        now = self.now_fn()
        # bulk demotions are confined to the minimum-priority channels;
        # fetches pick the earliest-free channel of any class
        allowed = self._bulk_channels if dst == "slow" else range(self.channels)
        if avoid:
            healthy = [c for c in allowed if c not in avoid]
            if healthy:
                allowed = healthy
        ch = min(allowed, key=lambda c: self._free_at[c])
        if prefer:
            pref = [c for c in allowed if c in prefer]
            if pref:
                owned = min(pref, key=lambda c: self._free_at[c])
                if self._free_at[owned] > now:
                    idle = [c for c in allowed if self._free_at[c] <= now]
                    ch = min(idle) if idle else owned
                else:
                    ch = owned
        start = max(now, self._free_at[ch])
        if after is not None:
            start = max(start, after.done)
        active = [c for c in self.copies
                  if not c.landed and c.channel != ch
                  and c.start <= start < c.done]
        rate = self.machine.copy_bw / (len(active) + 1)
        for c in active:
            if c.rate <= rate:
                continue
            remaining = (c.done - start) * c.rate
            delta = (start + remaining / rate) - c.done
            c.rate = rate
            self._shift_channel(c.channel, c.done, delta)
            c.done += delta
        dur = obj.size_bytes / rate
        copy = _ChannelCopy(obj, dst, obj.size_bytes, start, start + dur,
                            ch, rate, issued_at=now)
        self._free_at[ch] = max(self._free_at[ch], copy.done)
        self.copies.append(copy)
        return copy

    def _shift_channel(self, ch: int, from_time: float, delta: float) -> None:
        """Push the queued copies of ``ch`` (start >= from_time) later by
        ``delta`` — their predecessor just slowed down."""
        if delta <= 0:
            return
        for c in self.copies:
            if c.channel == ch and not c.landed and c.start >= from_time - 1e-12:
                c.start += delta
                c.done += delta
        self._free_at[ch] += delta

    def wait(self, handle: _ChannelCopy,
             timeout: Optional[float] = None) -> float:
        """Stall (seconds past ``now``) a fence on this copy must absorb.
        With a ``timeout``, a copy that would stall past the bound raises
        instead (virtual-time bounded-wait semantics; a stuck handle's
        infinite stall always raises)."""
        stall = max(0.0, handle.done - self.now_fn())
        if timeout is not None and stall > timeout:
            raise CopyTimeoutError(
                f"channel copy of {handle.obj.name} needs {stall:.4f}s "
                f"> timeout {timeout:.4f}s")
        return stall

    def cancel(self, handle: _ChannelCopy) -> bool:
        """Abort an in-flight copy: retired without a tier flip.  If the
        copy was its channel's tail (including a stuck copy wedging the
        channel at +inf), the channel frees immediately — this is how the
        mover un-wedges a quarantined channel."""
        if handle.landed:
            return False
        handle.landed = True
        aborted_at = max(self.now_fn(), handle.start)
        if self._free_at[handle.channel] <= handle.done:
            self._free_at[handle.channel] = aborted_at
        handle.done = aborted_at    # occupied the channel until aborted
        return True

    def complete(self, handle: _ChannelCopy) -> None:
        """Mark the copy landed (the caller absorbed any remaining stall).

        Earlier unlanded copies of the same object (a superseded
        direction-flip, e.g. an eviction the completing fetch was chained
        after) are retired without a tier flip — otherwise a later
        ``settle`` would apply their stale flip on top of this one."""
        if handle.landed:
            return
        for c in self.copies:
            if (not c.landed and c.obj is handle.obj
                    and c.done <= handle.done and c is not handle):
                c.landed = True
        handle.obj.tier = handle.dst
        handle.landed = True

    def settle(self, now: float) -> None:
        """Land every copy whose completion time has passed, in completion
        order (two in-flight copies of one object — an eviction chained
        into a re-fetch — must flip the tier in ``done`` order)."""
        for c in sorted((c for c in self.copies if not c.landed),
                        key=lambda c: c.done):
            if c.done <= now:
                c.obj.tier = c.dst
                c.landed = True

    def max_concurrency(self) -> int:
        """Peak number of copies simultaneously in flight (for invariants)."""
        events = []
        for c in self.copies:
            events.append((c.start, 1))
            events.append((c.done, -1))
        peak = cur = 0
        # at equal timestamps, land (-1) before launch (+1): back-to-back
        # copies on one channel are serial, not concurrent
        for _, delta in sorted(events, key=lambda e: (e[0], e[1])):
            cur += delta
            peak = max(peak, cur)
        return peak

    def busy_seconds(self) -> float:
        return sum(c.done - c.start for c in self.copies)


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _CrossHostCopy:
    """One in-flight shard migration over an interconnect link."""

    obj: DataObject
    dst: str                    # destination *tier* on the destination host
    src_host: str
    dst_host: str
    size_bytes: int
    start: float
    done: float
    channel: int                # send/recv pair index on the link
    link_name: str
    landed: bool = False


class CrossHostBackend:
    """Simulated shard-migration engine over modeled interconnect links.

    Where :class:`ChannelSimBackend` models one host's DRAM<->NVM copy
    engine, this backend models the *fabric between hosts*: each
    directed host pair resolves to a :class:`~.perfmodel.LinkSpec`
    through an :class:`~.perfmodel.InterconnectModel`, and each link
    sustains ``channel_pairs`` concurrent **send/recv channel pairs** —
    a transfer occupies one sender-side and one receiver-side endpoint
    for its full wire time (``latency + size/bandwidth``), and transfers
    beyond the pair budget queue on the earliest-free pair, exactly like
    the intra-host engine's channels.

    The tier flip happens only at land time (``settle``/``complete``),
    and an optional ``on_land`` callback performs the cluster-level
    handoff (re-homing the object from the source host's registry to the
    destination's) — the backend itself stays pure virtual-time
    bookkeeping so it composes with :class:`~.faults.ChaosBackend` like
    any other registered backend.
    """

    def __init__(self, links: "InterconnectModel",
                 now_fn: Callable[[], float],
                 on_land: Optional[Callable[[_CrossHostCopy], None]] = None):
        self.links = links
        self.now_fn = now_fn
        self.on_land = on_land
        # (src_host, dst_host, pair) -> time the pair frees up
        self._free_at: Dict[tuple, float] = {}
        self.copies: List[_CrossHostCopy] = []

    def start_move(self, obj: DataObject, dst: str, *,
                   src_host: str, dst_host: str,
                   after: Optional[_CrossHostCopy] = None) -> _CrossHostCopy:
        """Issue one shard pull ``src_host`` -> ``dst_host`` landing in
        tier ``dst``; picks the link's earliest-free send/recv pair."""
        if src_host == dst_host:
            raise ValueError(
                f"cross-host move of {obj.name!r} needs distinct hosts, "
                f"got {src_host!r} on both ends")
        link = self.links.link(src_host, dst_host)
        now = self.now_fn()
        key_of = lambda pair: (src_host, dst_host, pair)
        ch = min(range(link.channel_pairs),
                 key=lambda p: self._free_at.get(key_of(p), 0.0))
        start = max(now, self._free_at.get(key_of(ch), 0.0))
        if after is not None:
            start = max(start, after.done)
        dur = link.latency + obj.size_bytes / link.bandwidth
        copy = _CrossHostCopy(obj, dst, src_host, dst_host, obj.size_bytes,
                              start, start + dur, ch, link.name)
        self._free_at[key_of(ch)] = copy.done
        self.copies.append(copy)
        return copy

    def wait(self, handle: _CrossHostCopy,
             timeout: Optional[float] = None) -> float:
        stall = max(0.0, handle.done - self.now_fn())
        if timeout is not None and stall > timeout:
            raise CopyTimeoutError(
                f"cross-host copy of {handle.obj.name} "
                f"({handle.src_host}->{handle.dst_host}) needs "
                f"{stall:.4f}s > timeout {timeout:.4f}s")
        return stall

    def cancel(self, handle: _CrossHostCopy) -> bool:
        if handle.landed:
            return False
        handle.landed = True
        aborted_at = max(self.now_fn(), handle.start)
        key = (handle.src_host, handle.dst_host, handle.channel)
        if self._free_at.get(key, 0.0) <= handle.done:
            self._free_at[key] = aborted_at
        handle.done = aborted_at
        return True

    def _land(self, copy: _CrossHostCopy) -> None:
        copy.obj.tier = copy.dst
        copy.landed = True
        if self.on_land is not None:
            self.on_land(copy)

    def complete(self, handle: _CrossHostCopy) -> None:
        if not handle.landed:
            self._land(handle)

    def settle(self, now: float) -> None:
        for c in sorted((c for c in self.copies if not c.landed),
                        key=lambda c: c.done):
            if c.done <= now:
                self._land(c)

    def is_done(self, handle: _CrossHostCopy) -> bool:
        return handle.landed or handle.done <= self.now_fn()

    def busy_seconds(self) -> float:
        return sum(c.done - c.start for c in self.copies)


def _handle_orphaned(registry: ObjectRegistry, name: str, handle: Any) -> bool:
    """True when an in-flight handle's object was retired from the
    registry — by name, or by identity when the handle carries the
    DataObject (a rebuild may re-register a merged chunk under the same
    name; the handle still points at the orphan)."""
    if name not in registry:
        return True
    dob = getattr(handle, "obj", None)
    return isinstance(dob, DataObject) and dob is not registry[name]


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class MoveStats:
    n_moves: int = 0
    moved_bytes: int = 0
    fence_stall_s: float = 0.0
    overlapped_moves: int = 0
    # fault-tolerance counters (all zero on a fault-free run)
    n_retries: int = 0              # transient start_move failures retried
    n_degraded: int = 0             # fetches demoted to slow-tier service
    n_failed_evictions: int = 0     # evictions rolled back (residency kept)
    n_straggler_reissues: int = 0   # copies cancelled + reissued elsewhere

    @property
    def overlap_fraction(self) -> float:
        return self.overlapped_moves / self.n_moves if self.n_moves else 1.0


class ProactiveMover:
    """Executes a :class:`PlacementPlan` against a tier backend.

    * at the start of phase ``i``: fence moves with ``needed_by == i`` (they
      must have completed), then trigger moves whose ``trigger_phase`` maps to
      ``i`` (they run in the background toward their ``needed_by`` phase).
    """

    def __init__(self, registry: ObjectRegistry, backend: TierBackend,
                 retry_limit: int = 3):
        self.registry = registry
        self.backend = backend
        self.retry_limit = retry_limit
        self._inflight: Dict[str, Any] = {}     # obj -> handle
        self._queue: Deque[MoveOp] = deque()
        self.stats = MoveStats()
        #: DegradedServe / EvictionRollback events, drained by the session
        self.fault_events: List[Any] = []

    def _fault(self, m: MoveOp, phase_index: int, reason: str,
               channel: int = -1) -> None:
        if m.dst == "slow":
            self.stats.n_failed_evictions += 1
            self.fault_events.append(EvictionRollback(
                obj=m.obj, phase_index=phase_index, reason=reason,
                channel=channel))
        else:
            self.stats.n_degraded += 1
            self.fault_events.append(DegradedServe(
                obj=m.obj, phase_index=phase_index, reason=reason,
                channel=channel))

    def load_plan(self, plan: PlacementPlan, graph: Optional[PhaseGraph] = None
                  ) -> None:
        """Bind a freshly-built plan: drop in-flight handles whose object
        was retired by the rebuild (a coalesce pass removes chunk objects
        and may re-register merged chunks under the *same names* — a
        stale handle would alias the orphaned object's copy onto the new
        chunk and silently swallow its first move)."""
        for name in list(self._inflight):
            if _handle_orphaned(self.registry, name, self._inflight[name]):
                self._inflight.pop(name)    # orphan lands in the background

    def on_phase_start(self, plan: PlacementPlan, phase_index: int,
                       n_phases: int) -> float:
        """Fence + trigger.  Returns fence stall seconds (sim backend) or 0."""
        stall = 0.0
        # 1. fence
        for m in plan.fences_for_phase(phase_index):
            h = self._inflight.pop(m.obj, None)
            if h is not None:
                try:
                    s = self.backend.wait(h)
                except CopyError:
                    # the copy never delivered: a fetch serves slow this
                    # iteration, a failed eviction keeps its residency
                    self._fault(m, phase_index, "late_fail",
                                getattr(h, "channel", -1))
                    continue
                if isinstance(s, (int, float)):
                    stall += float(s)
                    if s <= 0.0:
                        self.stats.overlapped_moves += 1
                else:
                    self.stats.overlapped_moves += 1
        self.stats.fence_stall_s += stall
        # 2. trigger
        for m in plan.moves_for_phase(phase_index, n_phases):
            obj = self.registry[m.obj]
            if obj.tier == m.dst:
                continue
            # dependency safety: never start moving an object the current
            # phase itself references unless the move is fenced right here.
            h = self._start_with_retry(obj, m, phase_index)
            if h is None and obj.tier != m.dst:
                continue            # retries exhausted (fault recorded)
            self.stats.n_moves += 1
            self.stats.moved_bytes += m.size_bytes
            if m.needed_by == phase_index:
                try:
                    s = self.backend.wait(h)
                except CopyError:
                    self._fault(m, phase_index, "late_fail",
                                getattr(h, "channel", -1))
                    continue
                if isinstance(s, (int, float)):
                    stall += float(s)
                    if s <= 0.0:
                        self.stats.overlapped_moves += 1
                else:
                    self.stats.overlapped_moves += 1
            else:
                self._inflight[m.obj] = h
        return stall

    def _start_with_retry(self, obj: DataObject, m: MoveOp,
                          phase_index: int) -> Optional[Any]:
        attempts = 0
        while True:
            try:
                return self.backend.start_move(obj, m.dst)
            except TransientCopyError:
                attempts += 1
                if attempts > self.retry_limit:
                    self._fault(m, phase_index, "retries_exhausted")
                    return None
                self.stats.n_retries += 1

    def drain(self) -> None:
        for obj, h in list(self._inflight.items()):
            try:
                self.backend.wait(h)
            except CopyError:
                pass                # draining: the copy's fate is recorded
            del self._inflight[obj]


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class MoveRecord:
    """Audit record of one issued move (property tests consume these)."""

    obj: str
    dst: str
    trigger_phase: int
    needed_by: int
    size_bytes: int
    issued_at: float            # virtual time the scheduler released the move
    start: float                # virtual time the copy began on its channel
    done: float                 # virtual time the copy landed
    channel: int
    slack_s: float
    fenced_at: float = float("nan")   # virtual time of the consuming fence
    fence_stall_s: float = 0.0
    superseded: bool = False          # overwritten by a direction-flip move


class SlackAwareMover:
    """Slack-aware asynchronous migration scheduler.

    Lookahead over the plan's emitted schedule (:class:`ScheduledMove`): at
    each phase boundary the mover

    1. *settles* the backend — copies that landed flip their object's tier;
    2. *releases* the moves whose trigger window opens here, tightest slack
       first (ties broken by predicted benefit per byte), onto the backend's
       copy channels.  Evictions are released before fetches, and a fetch
       this same phase consumes is chained after the last eviction (its
       space is only free then — paper Fig 6);
    3. *fences* the moves this phase consumes.  Plain objects stall for the
       maximum remaining copy time; chunked objects are consumed chunk by
       chunk (chunk ``k``'s virtual consume point is the phase start plus
       the phase-time fraction of the sibling bytes preceding it), so a late
       chunk stalls only its own remainder — double buffering.  Evictions
       are never fenced: the phase does not read evicted data.

    Works against any :class:`TierBackend`; the timing-aware paths activate
    when the backend exposes the simulator's ``settle``/``complete``/``done``
    surface (blocking backends such as :class:`JaxTierBackend` fence with
    zero recorded stall, exactly like :class:`ProactiveMover`).
    """

    def __init__(self, registry: ObjectRegistry, backend: TierBackend,
                 graph: Optional[PhaseGraph] = None, retry_limit: int = 3,
                 straggler_factor: Optional[float] = None):
        self.registry = registry
        self.backend = backend
        self.graph = graph
        #: max transient-failure retries per move (beyond the slack bound)
        self.retry_limit = retry_limit
        #: in-flight copy exceeding ``straggler_factor`` x its priced time
        #: is cancelled and reissued on another channel; the same factor
        #: bounds fence waits (deadline abandonment).  ``None`` disables
        #: both — the fault-free default (contention alone legitimately
        #: slows sim copies by up to ``channels`` x).
        self.straggler_factor = straggler_factor
        self.health = ChannelHealth()
        #: tenant -> owned copy channels, from the plan's bandwidth
        #: partition (empty = no tenancy, chooser untouched)
        self.channel_prefs: Dict[str, frozenset] = {}
        #: DegradedServe / EvictionRollback events, drained by the session
        self.fault_events: List[Any] = []
        self._inflight: Dict[str, Any] = {}      # obj name -> handle
        self._records: Dict[str, MoveRecord] = {}  # obj name -> open record
        self.trace: List[MoveRecord] = []
        self.stats = MoveStats()

    # ------------------------------------------------------------------ utils
    def load_plan(self, plan: PlacementPlan, graph: PhaseGraph) -> None:
        """Bind the profiled phase graph (phase-time estimates for the
        chunk-consumption model and slack fallbacks), and drop in-flight
        handles whose object was retired by the rebuild (coalesced chunk
        names can be reused by merged chunks; a stale handle would match
        the new chunk's first move as 'already in flight' and swallow
        it)."""
        self.graph = graph
        self.channel_prefs = {
            t: frozenset(chs) for t, chs in
            (getattr(plan, "tenant_channels", None) or {}).items()}
        for name in list(self._inflight):
            if _handle_orphaned(self.registry, name, self._inflight[name]):
                self._inflight.pop(name)
                self._finish_record(name, float("nan"), 0.0, superseded=True)

    def _now(self) -> float:
        now_fn = getattr(self.backend, "now_fn", None)
        return now_fn() if now_fn is not None else 0.0

    def _done_of(self, handle: Any) -> Optional[float]:
        return getattr(handle, "done", None)

    def _complete(self, handle: Any) -> None:
        complete = getattr(self.backend, "complete", None)
        if complete is not None and handle is not None:
            complete(handle)

    def _count_fence(self, stall: float) -> None:
        if stall <= 1e-12:
            self.stats.overlapped_moves += 1

    # ------------------------------------------------------------- fault paths
    def _fault(self, obj: str, dst: str, phase_index: int, reason: str,
               channel: int = -1, slack_s: float = 0.0) -> None:
        """Record a failed move: an undeliverable fetch demotes to
        slow-tier service (DegradedServe), a failed eviction keeps its
        residency (EvictionRollback).  The session drains these."""
        if dst == "slow":
            self.stats.n_failed_evictions += 1
            self.fault_events.append(EvictionRollback(
                obj=obj, phase_index=phase_index, reason=reason,
                channel=channel))
        else:
            self.stats.n_degraded += 1
            self.fault_events.append(DegradedServe(
                obj=obj, phase_index=phase_index, reason=reason,
                channel=channel, slack_s=slack_s))

    def _fail_inflight(self, name: str, h: Any, phase_index: int,
                       reason: str, now: float) -> None:
        """Retire a failed/abandoned in-flight copy: fault event, channel
        strike, bookkeeping closed.  The tier never flipped, so the plan
        replay (or next replan) naturally reissues the move."""
        ch = getattr(h, "channel", -1)
        self.health.record_fault(ch if isinstance(ch, int) else -1)
        self._fault(name, getattr(h, "dst", "fast"), phase_index, reason,
                    ch if isinstance(ch, int) else -1)
        self._inflight.pop(name, None)
        self._finish_record(name, now, 0.0)

    def _deadline_for(self, size_bytes: int) -> Optional[float]:
        """Max fence wait for a copy of this size (straggler_factor x its
        priced full-bandwidth time); None = unbounded (fault-free mode)."""
        if self.straggler_factor is None:
            return None
        bw = getattr(getattr(self.backend, "machine", None), "copy_bw", 0.0)
        if not bw:
            return None
        return self.straggler_factor * (size_bytes / bw)

    def _cancel(self, handle: Any) -> bool:
        cancel = getattr(self.backend, "cancel", None)
        return bool(cancel(handle)) if cancel is not None else False

    @staticmethod
    def _service_exceeded(h: Any, deadline: Optional[float]) -> bool:
        """True when the copy's *service* time (channel occupancy) exceeds
        the deadline.  Queue wait is excluded on purpose: a copy delayed
        behind a long queue on a healthy channel is contention, not a
        fault, and striking its channel would cascade into quarantining
        the whole engine.  Non-finite times (a stuck handle, or a copy
        queued behind one on a wedged channel) always exceed."""
        if deadline is None:
            return False
        start, done = getattr(h, "start", None), getattr(h, "done", None)
        if start is None or done is None:
            return False
        if not math.isfinite(done) or not math.isfinite(start):
            return True
        return (done - start) > deadline

    def _prefer_for(self, name: str) -> Optional[frozenset]:
        """The channels this object's tenant owns under the plan's
        bandwidth partition, or None (no tenancy / unowned object)."""
        if not self.channel_prefs:
            return None
        t = tenant_of(name, self.channel_prefs)
        return self.channel_prefs.get(t) if t is not None else None

    def _start_move_raw(self, obj: DataObject, dst: str,
                        after: Any = None, avoid: Optional[set] = None,
                        prefer: Optional[frozenset] = None) -> Any:
        if prefer:
            try:
                if avoid:
                    return self.backend.start_move(obj, dst, after=after,
                                                   avoid=avoid, prefer=prefer)
                return self.backend.start_move(obj, dst, after=after,
                                               prefer=prefer)
            except TypeError:   # backend without tenant channel preference
                pass
        try:
            if avoid:
                return self.backend.start_move(obj, dst, after=after,
                                               avoid=avoid)
            return self.backend.start_move(obj, dst, after=after)
        except TypeError:       # backend without dependency chaining
            return self.backend.start_move(obj, dst)

    def _start_with_retry(self, entry: ScheduledMove, obj: DataObject,
                          after: Any, now: float) -> Optional[Any]:
        """Issue with exponential backoff on transient failures, bounded
        by the move's slack (a retry that would already land the copy
        late is pointless — demote instead) and by ``retry_limit``."""
        m = entry.op
        avoid = self.health.avoid()
        prefer = self._prefer_for(m.obj)
        b0 = max(1e-6, 0.1 * entry.duration_s)
        budget = max(entry.slack_s, b0)     # always worth one retry
        backoff, spent, attempts = b0, 0.0, 0
        while True:
            try:
                return self._start_move_raw(obj, m.dst, after, avoid, prefer)
            except TransientCopyError:
                attempts += 1
                spent += backoff
                if attempts > self.retry_limit or spent > budget:
                    self._fault(m.obj, m.dst, m.needed_by,
                                "retries_exhausted", slack_s=entry.slack_s)
                    return None
                self.stats.n_retries += 1
                backoff *= 2.0

    def _sweep_failures(self, phase_index: int, now: float) -> None:
        """Purge in-flight handles that late-failed (retired by the chaos
        settle with no tier flip): record the fault and drop them so the
        plan replay reissues instead of treating them as still pending."""
        for name, h in list(self._inflight.items()):
            if (getattr(h, "_chaos_fail", False)
                    and getattr(h, "landed", False)):
                self._fail_inflight(name, h, phase_index, "late_fail", now)

    def _detect_stragglers(self, phase_index: int, now: float) -> None:
        """Cancel-and-reissue copies stuck past their deadline: an
        in-flight copy that has been running ``straggler_factor`` x its
        priced time (including stuck handles at done=+inf) is aborted,
        its channel struck, and the copy reissued avoiding that channel."""
        f = self.straggler_factor
        if f is None:
            return
        bw = getattr(getattr(self.backend, "machine", None), "copy_bw", 0.0)
        if not bw:
            return
        for name, h in list(self._inflight.items()):
            start, done = getattr(h, "start", None), getattr(h, "done", None)
            if (start is None or done is None
                    or getattr(h, "landed", False) or done <= now):
                continue
            priced = getattr(h, "size_bytes", 0) / bw
            if now < start + f * priced:
                continue
            ch = getattr(h, "channel", -1)
            if not self._cancel(h):
                continue
            self.health.record_fault(ch)
            self.stats.n_straggler_reissues += 1
            obj = self.registry[name] if name in self.registry else None
            if obj is None:
                self._inflight.pop(name, None)
                self._finish_record(name, now, 0.0, superseded=True)
                continue
            avoid = {ch} | self.health.avoid()
            try:
                h2 = self._start_move_raw(obj, h.dst, None, avoid,
                                          self._prefer_for(name))
            except CopyError:
                self._fail_inflight(name, h, phase_index,
                                    "straggler_reissue_failed", now)
                continue
            self._inflight[name] = h2
            rec = self._records.get(name)
            if rec is not None:
                rec.channel = getattr(h2, "channel", rec.channel)
                rec.start = getattr(h2, "start", rec.start)
                d2 = self._done_of(h2)
                rec.done = d2 if d2 is not None else rec.done

    # ------------------------------------------------------------------ fence
    def _fence(self, plan: PlacementPlan, phase_index: int,
               now: float) -> float:
        """Absorb remaining copy time for every move this phase consumes.

        Evictions are *not* fenced: the phase never reads the evicted data,
        and a fetch that depends on the freed space was chained after the
        eviction copy at release time — the eviction itself stays off the
        critical path (unlike the FIFO baseline, which stalls on it)."""
        singles: List[Any] = []
        groups: Dict[str, List[Any]] = {}
        for m in plan.fences_for_phase(phase_index):
            h = self._inflight.get(m.obj)
            if h is None:
                continue
            if m.dst == "slow":
                # eviction: never fenced (the phase does not read evicted
                # data); once landed it counts as a fully-overlapped move.
                # Timing-less backends are probed with their non-blocking
                # is_done (blocking here — e.g. the async jax backend's
                # complete() — would put the eviction back on the critical
                # path while recording zero stall).
                done = self._done_of(h)
                if done is not None:
                    landed = done <= now
                else:
                    probe = getattr(self.backend, "is_done", None)
                    landed = probe(h) if probe is not None else True
                if landed:
                    self._inflight.pop(m.obj)
                    try:
                        self._complete(h)
                    except CopyError:
                        self._fail_inflight(m.obj, h, phase_index,
                                            "late_fail", now)
                        continue
                    self.stats.overlapped_moves += 1
                    self.health.record_success(getattr(h, "channel", -1))
                    self._finish_record(m.obj, now, 0.0)
                continue
            self._inflight.pop(m.obj)
            dob = self.registry[m.obj] if m.obj in self.registry else None
            if dob is not None and dob.parent is not None:
                groups.setdefault(dob.parent, []).append((dob, m, h))
            else:
                singles.append((m, h))

        stall = 0.0
        for m, h in singles:
            done = self._done_of(h)
            if done is None:
                # blocking backend (real arrays): the fence must block
                # here — but never past the straggler deadline
                try:
                    self.backend.wait(h, timeout=self._deadline_for(
                        m.size_bytes))
                except TypeError:
                    self.backend.wait(h)
                except CopyError:
                    self._cancel(h)
                    self._fail_inflight(m.obj, h, phase_index,
                                        "deadline", now)
                    continue
                s = 0.0
            else:
                s = max(0.0, done - now)
                if self._service_exceeded(h, self._deadline_for(m.size_bytes)):
                    # stuck/straggling copy: abandon rather than deadlock;
                    # the phase serves this object from the slow tier
                    self._cancel(h)
                    self._fail_inflight(m.obj, h, phase_index,
                                        "deadline", now)
                    continue
            # parallel channels: waiting on all fenced copies costs the max
            stall = max(stall, s)
            self._count_fence(s)
            try:
                self._complete(h)
            except CopyError:
                self._fail_inflight(m.obj, h, phase_index, "late_fail", now)
                continue
            self.health.record_success(getattr(h, "channel", -1))
            self._finish_record(m.obj, now, s)

        phase_est = (self.graph[phase_index].time
                     if self.graph is not None else 0.0)
        t0 = now + stall
        extra_max = 0.0
        for parent, entries in groups.items():
            extra_max = max(extra_max,
                            self._fence_chunks(parent, entries, t0, phase_est,
                                               phase_index))
        stall += extra_max
        self.stats.fence_stall_s += stall
        return stall

    def _fence_chunks(self, parent: str, entries: List[Any], t0: float,
                      phase_est: float, phase_index: int = 0) -> float:
        """Double-buffered consumption of one chunked object.

        Chunks are consumed in index order across the phase; chunk ``k``'s
        consume point is ``t0 + phase_est * frac(bytes before k)``.  A chunk
        landing after its consume point stalls only its own remainder; the
        stall pushes every later consume point back (``extra``)."""
        siblings = sorted((o for o in self.registry if o.parent == parent),
                          key=lambda o: o.chunk_index or 0)
        total = sum(o.size_bytes for o in siblings) or 1
        before: Dict[str, int] = {}
        acc = 0
        for o in siblings:
            before[o.name] = acc
            acc += o.size_bytes
        extra = 0.0
        for dob, m, h in sorted(entries, key=lambda e: e[0].chunk_index or 0):
            consume = t0 + extra + phase_est * (before[dob.name] / total)
            done = self._done_of(h)
            if done is None:
                try:    # blocking backend: fence the chunk (bounded)
                    self.backend.wait(h, timeout=self._deadline_for(
                        m.size_bytes))
                except TypeError:
                    self.backend.wait(h)
                except CopyError:
                    self._cancel(h)
                    self._fail_inflight(m.obj, h, phase_index,
                                        "deadline", consume)
                    continue
                late = 0.0
            else:
                late = max(0.0, done - consume)
                if self._service_exceeded(h, self._deadline_for(m.size_bytes)):
                    # a stuck/straggling chunk: abandon, serve it slow
                    self._cancel(h)
                    self._fail_inflight(m.obj, h, phase_index,
                                        "deadline", consume)
                    continue
            extra += late
            self._count_fence(late)
            try:
                self._complete(h)
            except CopyError:
                self._fail_inflight(m.obj, h, phase_index, "late_fail",
                                    consume)
                continue
            self.health.record_success(getattr(h, "channel", -1))
            self._finish_record(m.obj, consume, late)
        return extra

    def _finish_record(self, obj: str, fenced_at: float, stall: float,
                       superseded: bool = False) -> None:
        rec = self._records.pop(obj, None)
        if rec is not None:
            rec.fenced_at = fenced_at
            rec.fence_stall_s = stall
            rec.superseded = superseded

    # ---------------------------------------------------------------- release
    def _release(self, plan: PlacementPlan, phase_index: int, n_phases: int,
                 now: float) -> None:
        """Issue the moves whose trigger window opens at this phase, most
        urgent first.  Fetches the entered phase itself consumes are chained
        after the evictions freeing their space; the subsequent fence absorbs
        whatever copy time remains."""
        if plan.schedule:
            entries = plan.scheduled_for_phase(phase_index, n_phases)
        else:   # hand-built plan without timing: wrap the raw ops
            entries = [ScheduledMove(m, 0.0, 0.0, 0.0)
                       for m in plan.moves_for_phase(phase_index, n_phases)]
        evictions = [e for e in entries if e.op.dst == "slow"]
        fetches = [e for e in entries if e.op.dst != "slow"]

        last_evict = None
        for e in evictions:
            h = self._issue(e, now)
            if h is not None:
                last_evict = h

        for e in fetches:
            same_phase = e.op.needed_by % n_phases == phase_index % n_phases
            self._issue(e, now, after=last_evict if same_phase else None)

    def _issue(self, entry: ScheduledMove, now: float,
               after: Any = None) -> Optional[Any]:
        m = entry.op
        if m.obj not in self.registry:
            return None
        obj = self.registry[m.obj]
        pending = self._inflight.get(m.obj)
        if pending is not None:
            if getattr(pending, "dst", None) == m.dst:
                return None     # identical move already in flight
            # direction flip (e.g. re-fetch of an object whose eviction is
            # still in flight): chain after the pending copy.  The pending
            # copy was never fenced, so it ran entirely in the background.
            if after is None or (getattr(pending, "done", 0.0)
                                 > getattr(after, "done", 0.0)):
                after = pending
            self.stats.overlapped_moves += 1
            self._finish_record(m.obj, now, 0.0, superseded=True)
        elif obj.tier == m.dst:
            return None
        h = self._start_with_retry(entry, obj, after, now)
        if h is None and obj.tier != m.dst:
            return None     # retries exhausted (fault recorded); a payload-
                            # free logical flip returns None *after* flipping
        self.stats.n_moves += 1
        self.stats.moved_bytes += m.size_bytes
        self._inflight[m.obj] = h
        rec = MoveRecord(
            obj=m.obj, dst=m.dst, trigger_phase=m.trigger_phase,
            needed_by=m.needed_by, size_bytes=m.size_bytes, issued_at=now,
            start=getattr(h, "start", now),
            done=self._done_of(h) if self._done_of(h) is not None else now,
            channel=getattr(h, "channel", 0), slack_s=entry.slack_s)
        self._records[m.obj] = rec
        self.trace.append(rec)
        return h

    # ------------------------------------------------------------- entrypoint
    def on_phase_start(self, plan: PlacementPlan, phase_index: int,
                       n_phases: int) -> float:
        now = self._now()
        settle = getattr(self.backend, "settle", None)
        if settle is not None:
            settle(now)
        # failure upkeep (both no-ops on a fault-free run): purge copies
        # that late-failed at settle, then cancel-and-reissue stragglers
        self._sweep_failures(phase_index, now)
        self._detect_stragglers(phase_index, now)
        # release first so moves this phase both triggers AND consumes flow
        # through the same fence logic (incl. chunk-granular consumption)
        self._release(plan, phase_index, n_phases, now)
        return self._fence(plan, phase_index, now)

    def drain(self) -> None:
        for name, h in list(self._inflight.items()):
            try:
                self.backend.wait(h)
                self._complete(h)
            except CopyError:
                pass            # draining: the copy's fate is recorded
            del self._inflight[name]
