"""Proactive data movement (paper §3.1.2 "cost", §3.3 "implementation").

The paper uses a helper thread and a shared FIFO queue: the main thread
enqueues movement requests at trigger points; the helper thread performs them
in the background; phase entry fences the moves that phase depends on.

Here the "helper thread" is whatever the backend provides:

* :class:`JaxTierBackend` — ``jax.device_put`` between memory kinds.  The
  dispatch is asynchronous (JAX returns immediately); the fence is
  ``block_until_ready`` on the moved leaves.  On TPU the copy engine runs in
  the background exactly like the paper's helper thread; on the CPU backend
  the same code path is exercised with host memory kinds.
* :class:`SimTierBackend` — a simulated copy engine with a FIFO service
  queue, used by the discrete-event simulator and the benchmarks.
"""

from __future__ import annotations

import dataclasses
import time as _time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Protocol

import jax

from .data_objects import DataObject, ObjectRegistry
from .planner import MoveOp, PlacementPlan
from .tiers import MachineProfile


class TierBackend(Protocol):
    def start_move(self, obj: DataObject, dst: str) -> Any: ...
    def wait(self, handle: Any) -> None: ...


# ---------------------------------------------------------------------------
class JaxTierBackend:
    """Moves real JAX arrays between memory kinds with ``jax.device_put``."""

    def __init__(self, machine: MachineProfile):
        self.machine = machine

    def _sharding_for(self, leaf: jax.Array, kind: Optional[str]):
        s = leaf.sharding
        if kind is None:
            return s
        try:
            return s.with_memory_kind(kind)
        except Exception:
            return s   # backend without memory kinds: logical move only

    def start_move(self, obj: DataObject, dst: str) -> Any:
        tier = self.machine.fast if dst == "fast" else self.machine.slow
        kind = tier.memory_kind
        if obj.payload is None:
            obj.tier = dst
            return None
        leaves, treedef = jax.tree_util.tree_flatten(obj.payload)
        moved = [jax.device_put(l, self._sharding_for(l, kind)) for l in leaves]
        obj.payload = jax.tree_util.tree_unflatten(treedef, moved)
        obj.tier = dst
        return moved

    def wait(self, handle: Any) -> None:
        if handle:
            for leaf in handle:
                leaf.block_until_ready()


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _SimCopy:
    obj: str
    dst: str
    size_bytes: int
    start: float = 0.0
    done: float = 0.0


class SimTierBackend:
    """FIFO copy engine for the discrete-event simulator.

    ``now_fn`` reads the simulation clock; completion times respect a single
    serial copy engine at ``machine.copy_bw`` (the paper's helper thread)."""

    def __init__(self, machine: MachineProfile, now_fn: Callable[[], float]):
        self.machine = machine
        self.now_fn = now_fn
        self._engine_free_at = 0.0
        self.copies: List[_SimCopy] = []

    def start_move(self, obj: DataObject, dst: str) -> _SimCopy:
        now = self.now_fn()
        start = max(now, self._engine_free_at)
        dur = obj.size_bytes / self.machine.copy_bw
        c = _SimCopy(obj.name, dst, obj.size_bytes, start, start + dur)
        self._engine_free_at = c.done
        self.copies.append(c)
        obj.tier = dst
        return c

    def wait(self, handle: _SimCopy) -> float:
        """Returns the stall (seconds past ``now``) the fence must absorb."""
        return max(0.0, handle.done - self.now_fn())


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class MoveStats:
    n_moves: int = 0
    moved_bytes: int = 0
    fence_stall_s: float = 0.0
    overlapped_moves: int = 0

    @property
    def overlap_fraction(self) -> float:
        return self.overlapped_moves / self.n_moves if self.n_moves else 1.0


class ProactiveMover:
    """Executes a :class:`PlacementPlan` against a tier backend.

    * at the start of phase ``i``: fence moves with ``needed_by == i`` (they
      must have completed), then trigger moves whose ``trigger_phase`` maps to
      ``i`` (they run in the background toward their ``needed_by`` phase).
    """

    def __init__(self, registry: ObjectRegistry, backend: TierBackend):
        self.registry = registry
        self.backend = backend
        self._inflight: Dict[str, Any] = {}     # obj -> handle
        self._queue: Deque[MoveOp] = deque()
        self.stats = MoveStats()

    def on_phase_start(self, plan: PlacementPlan, phase_index: int,
                       n_phases: int) -> float:
        """Fence + trigger.  Returns fence stall seconds (sim backend) or 0."""
        stall = 0.0
        # 1. fence
        for m in plan.fences_for_phase(phase_index):
            h = self._inflight.pop(m.obj, None)
            if h is not None:
                s = self.backend.wait(h)
                if isinstance(s, (int, float)):
                    stall += float(s)
                    if s <= 0.0:
                        self.stats.overlapped_moves += 1
                else:
                    self.stats.overlapped_moves += 1
        self.stats.fence_stall_s += stall
        # 2. trigger
        for m in plan.moves_for_phase(phase_index, n_phases):
            obj = self.registry[m.obj]
            if obj.tier == m.dst:
                continue
            # dependency safety: never start moving an object the current
            # phase itself references unless the move is fenced right here.
            h = self.backend.start_move(obj, m.dst)
            self.stats.n_moves += 1
            self.stats.moved_bytes += m.size_bytes
            if m.needed_by == phase_index:
                s = self.backend.wait(h)
                if isinstance(s, (int, float)):
                    stall += float(s)
                    if s <= 0.0:
                        self.stats.overlapped_moves += 1
                else:
                    self.stats.overlapped_moves += 1
            else:
                self._inflight[m.obj] = h
        return stall

    def drain(self) -> None:
        for obj, h in list(self._inflight.items()):
            self.backend.wait(h)
            del self._inflight[obj]
