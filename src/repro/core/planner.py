"""Data placement decision (paper §3.1.3).

Two searches, both driven by Eq. (1)-(5) and solved as 0/1 knapsacks:

* **phase-local search** — phases are decided one by one in order, with full
  knowledge of what earlier decisions left resident in the fast tier.
  Candidates are the objects the phase references; each candidate's weight is
  ``w = BFT - COST - extra_COST`` where ``extra_COST`` prices evicting
  just-big-enough non-candidate residents.  Moves are scheduled at the
  earliest dependency-safe trigger point (Fig 5) so the proactive mover can
  overlap them.
* **cross-phase global search** — one knapsack over per-object benefit summed
  across all phases; a single placement for the whole iteration, no
  steady-state movement.

The planner predicts the iteration time of each plan with the same models and
keeps the better one (the paper's best-of-two).

**Scale.** The planner must stay cheap at chunk counts in the thousands
(skew-aware partitioning can emit dozens of chunks per large object).  The
default ``vectorized`` mode batches all per-(phase, candidate) profile
lookups and Eq. (1)-(3) benefit evaluations into numpy (:class:`_ProfileView`
— chunk attribution fractions come from the profiler's measured histograms,
computed once per (phase, parent) instead of rescanning the registry per
candidate), prices candidate evictions against a prefix-summed evictable
list instead of re-sorting residents per candidate, and solves the knapsack
with a packed-bitset keep table.  ``vectorized=False`` preserves the
original per-candidate scalar path — the oracle for equivalence tests and
the baseline for the planner-latency benchmark; both modes produce
identical plans.
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from . import knapsack, perfmodel
from .data_objects import ObjectRegistry
from .partition import bin_mass, chunk_spans
from .perfmodel import CalibrationConstants
from .phase import PhaseGraph
from .profiler import PhaseProfiler
from .tiers import MachineProfile


@dataclasses.dataclass(frozen=True)
class MoveOp:
    """One scheduled tier move.

    ``trigger_phase`` may be negative: trigger in the *previous* iteration,
    ``n + trigger_phase`` phases from its start.  ``est_unhidden_cost`` is the
    Eq. (4) cost the model expects to remain on the critical path.
    ``est_benefit`` is the Eq. (5) benefit that justified the move — the
    slack-aware scheduler uses it to break priority ties."""

    obj: str
    dst: str                     # "fast" | "slow"
    trigger_phase: int
    needed_by: int               # phase index whose start fences the move
    size_bytes: int
    est_unhidden_cost: float = 0.0
    est_benefit: float = 0.0


@dataclasses.dataclass(frozen=True)
class ScheduledMove:
    """A MoveOp with timing annotations: *when* to start it, not just where
    the object lives (the schedule-emission path of the slack-aware mover).

    ``window_s`` is the compute time between the move's trigger point and the
    start of its consuming phase; ``duration_s`` the copy time at full engine
    bandwidth; ``slack_s = window_s - duration_s`` is how long the move's
    start may be delayed past its trigger before it lands late.  Negative
    slack means the fence will stall no matter what — those moves are issued
    first."""

    op: MoveOp
    window_s: float
    duration_s: float
    slack_s: float

    @property
    def urgency(self) -> tuple:
        """Sort key: tightest slack first, then biggest benefit per byte."""
        density = self.op.est_benefit / max(self.op.size_bytes, 1)
        return (self.slack_s, -density)


@dataclasses.dataclass
class PlacementPlan:
    strategy: str                            # "local" | "global" | "none"
    residents: List[Set[str]]                # per phase: fast-tier residents
    moves: List[MoveOp]
    predicted_iteration_time: float
    baseline_iteration_time: float
    # Timing-annotated schedule (one entry per MoveOp), emitted by the
    # planner when it has a profiled graph; movers that don't need timing
    # (the FIFO baseline) simply ignore it.
    schedule: List[ScheduledMove] = dataclasses.field(default_factory=list)

    def moves_for_phase(self, phase_index: int, n_phases: int) -> List[MoveOp]:
        """Moves triggered at the start of ``phase_index`` (wrapping)."""
        return [m for m in self.moves
                if m.trigger_phase % n_phases == phase_index % n_phases]

    def fences_for_phase(self, phase_index: int) -> List[MoveOp]:
        return [m for m in self.moves if m.needed_by == phase_index]

    def scheduled_for_phase(self, phase_index: int,
                            n_phases: int) -> List["ScheduledMove"]:
        """Schedule entries released at the start of ``phase_index``, most
        urgent first."""
        out = [s for s in self.schedule
               if s.op.trigger_phase % n_phases == phase_index % n_phases]
        return sorted(out, key=lambda s: s.urgency)

    @property
    def total_moved_bytes(self) -> int:
        return sum(m.size_bytes for m in self.moves)


def emit_schedule(moves: Sequence[MoveOp], graph, copy_bw: float
                  ) -> List[ScheduledMove]:
    """Annotate each move with its copy window, duration and slack."""
    out: List[ScheduledMove] = []
    for m in moves:
        window = graph.window_between(m.trigger_phase, m.needed_by)
        duration = m.size_bytes / copy_bw
        out.append(ScheduledMove(m, window, duration, window - duration))
    return out


# ---------------------------------------------------------------------------
class _ProfileView:
    """Batched profile/benefit lookups for one (graph, profiler) pair.

    Replaces the per-candidate scalar path (a registry scan per chunk lookup
    plus a scalar Eq. (1)-(3) evaluation per candidate) with one numpy
    evaluation per phase.  Chunk attribution fractions — measured-histogram
    mass over the chunk's byte span, size fraction when no histogram exists —
    are computed once per (phase, parent).  Values agree bitwise with the
    scalar path."""

    def __init__(self, planner: "Planner", profiler: PhaseProfiler):
        self.planner = planner
        self.profiler = profiler
        reg = planner.registry
        self._spans: Dict[str, List[Tuple[str, int, int]]] = {}
        for parent in sorted({o.parent for o in reg if o.parent is not None}):
            self._spans[parent] = [(c.name, lo, hi)
                                   for c, lo, hi in chunk_spans(reg, parent)]
        # (phase, parent) -> {chunk name: attribution fraction}
        self._fracs: Dict[Tuple[int, str], Dict[str, float]] = {}
        # phase -> {obj: benefit or None (no profile)}
        self._benefit: Dict[int, Dict[str, Optional[float]]] = {}
        # (phase, obj) -> scalar-path result, for objects outside ensure()'s
        # candidate sets (e.g. residents carried over from earlier phases)
        self._fallback: Dict[Tuple[int, str], float] = {}

    def _chunk_fracs(self, phase: int, parent: str) -> Dict[str, float]:
        key = (phase, parent)
        cached = self._fracs.get(key)
        if cached is not None:
            return cached
        spans = self._spans[parent]
        total = sum(hi - lo for _, lo, hi in spans) or 1
        pp = self.profiler.profile(phase, parent)
        bins = pp.bin_weights if pp is not None else None
        if bins is None:
            out = {name: (hi - lo) / total for name, lo, hi in spans}
        else:
            out = {name: bin_mass(bins, lo / total, hi / total)
                   for name, lo, hi in spans}
        self._fracs[key] = out
        return out

    def ensure(self, phase: int, objs: Sequence[str]) -> None:
        """Batch-compute benefits for every not-yet-cached object."""
        cache = self._benefit.setdefault(phase, {})
        reg = self.planner.registry
        rows: List[Tuple[str, float, float, float, float, float]] = []
        for o in objs:
            if o in cache:
                continue
            p = self.profiler.profile(phase, o)
            if p is not None:
                rows.append((o, p.data_access, p.n_samples,
                             p.samples_with_access, p.phase_time,
                             p.cacheline_bytes))
                continue
            dob = reg[o] if o in reg else None
            pp = (self.profiler.profile(phase, dob.parent)
                  if dob is not None and dob.parent is not None else None)
            if pp is None:
                cache[o] = None
                continue
            frac = self._chunk_fracs(phase, dob.parent).get(o, 0.0)
            rows.append((o, pp.data_access * frac, pp.n_samples,
                         max(pp.samples_with_access * frac, 1.0),
                         pp.phase_time, pp.cacheline_bytes))
        if not rows:
            return
        names = [r[0] for r in rows]
        cols = np.array([r[1:] for r in rows], dtype=np.float64)
        bft = perfmodel.benefit_batch(
            cols[:, 0], cols[:, 1], cols[:, 2], cols[:, 3], cols[:, 4],
            self.planner.machine, self.planner.cf)
        for name, b in zip(names, bft):
            cache[name] = float(b)

    def has_profile(self, phase: int, obj: str) -> bool:
        return self._benefit.get(phase, {}).get(obj) is not None

    def benefit(self, phase: int, obj: str) -> float:
        b = self._benefit.get(phase, {}).get(obj)
        if b is not None:
            return b
        # outside ensure()'s candidate sets (residents carried over from
        # earlier phases): the exact scalar path, memoized — its registry
        # scan must not run once per (phase, resident)
        key = (phase, obj)
        val = self._fallback.get(key)
        if val is None:
            val = self.planner._benefit_scalar(self.profiler, phase, obj)
            self._fallback[key] = val
        return val


class _Evictables:
    """Prefix-summed evictable residents for one phase's candidate loop:
    answers "how many bytes must leave to fit ``deficit``" in O(log n)
    instead of a fresh sort + scan per candidate."""

    def __init__(self, sizes: List[int]):
        # ``sizes`` already in the canonical (size, name) eviction order
        self._cum: List[int] = []
        acc = 0
        for s in sizes:
            acc += s
            self._cum.append(acc)

    def quote(self, deficit: int) -> Optional[int]:
        """Bytes freed by evicting the minimal prefix covering ``deficit``,
        or None when even evicting everything is not enough."""
        i = bisect.bisect_left(self._cum, deficit)
        if i >= len(self._cum):
            return None
        return self._cum[i]


class Planner:
    def __init__(self, machine: MachineProfile, registry: ObjectRegistry,
                 cf: Optional[CalibrationConstants] = None,
                 fast_capacity_bytes: Optional[int] = None,
                 vectorized: bool = True):
        self.machine = machine
        self.registry = registry
        self.cf = cf or CalibrationConstants()
        self.capacity = (fast_capacity_bytes if fast_capacity_bytes is not None
                         else machine.fast.capacity_bytes)
        self.vectorized = vectorized

    # ------------------------------------------------------------------ util
    def _profile(self, profiler: PhaseProfiler, phase: int, obj: str):
        p = profiler.profile(phase, obj)
        if p is not None:
            return p
        # Chunk of a partitioned object: scale the parent's profile by the
        # chunk's share of the parent's accesses — measured-histogram mass
        # over the chunk's byte span when per-chunk attribution exists, size
        # fraction otherwise (regular 1-D references, paper §3.2).
        dob = self.registry[obj] if obj in self.registry else None
        if dob is not None and dob.parent is not None:
            pp = profiler.profile(phase, dob.parent)
            if pp is not None:
                spans = chunk_spans(self.registry, dob.parent)
                total = sum(hi - lo for _, lo, hi in spans) or 1
                bins = pp.bin_weights
                if bins is None:
                    frac = dob.size_bytes / total
                else:
                    lo = next(l for c, l, _ in spans if c.name == dob.name)
                    frac = bin_mass(bins, lo / total,
                                    (lo + dob.size_bytes) / total)
                return dataclasses.replace(
                    pp, obj=obj, data_access=pp.data_access * frac,
                    samples_with_access=max(pp.samples_with_access * frac, 1.0))
        return None

    def _benefit_scalar(self, profiler: PhaseProfiler, phase: int,
                        obj: str) -> float:
        p = self._profile(profiler, phase, obj)
        if p is None:
            return 0.0
        return perfmodel.benefit(p, self.machine, self.cf)

    # kept as the public scalar entry point (tests, legacy mode)
    _benefit = _benefit_scalar

    def _initial_residents(self) -> Set[str]:
        return {o.name for o in self.registry if o.tier == "fast"}

    def _solve(self, items, capacity):
        if self.vectorized:
            return knapsack.solve(items, capacity)
        return knapsack.solve_reference(items, capacity)

    def _make_view(self, profiler: PhaseProfiler) -> Optional[_ProfileView]:
        return _ProfileView(self, profiler) if self.vectorized else None

    # ----------------------------------------------------------- local search
    def plan_local(self, graph: PhaseGraph, profiler: PhaseProfiler) -> PlacementPlan:
        view = self._make_view(profiler)
        residents: Set[str] = self._initial_residents()
        originally_slow: Set[str] = {o.name for o in self.registry
                                     if o.tier != "fast"}
        placements: List[Set[str]] = []
        moves: List[MoveOp] = []
        size = lambda o: self.registry[o].size_bytes
        resident_bytes = sum(size(o) for o in residents)

        for ph in graph:
            in_reg = [o for o in ph.refs if o in self.registry]
            if view is not None:
                view.ensure(ph.index, in_reg)
                cands = [o for o in in_reg
                         if view.has_profile(ph.index, o)
                         and not self.registry[o].pinned]
                bft_of = lambda o: view.benefit(ph.index, o)
            else:
                cands = [o for o in in_reg
                         if self._profile(profiler, ph.index, o) is not None
                         and not self.registry[o].pinned]
                bft_of = lambda o: self._benefit_scalar(profiler, ph.index, o)
            free = self.capacity - resident_bytes
            # deterministic tie-break by name: hash-order of the residents
            # set must never leak into the plan
            evict_order = sorted(
                (r for r in residents
                 if r not in ph.refs and not self.registry[r].pinned),
                key=lambda r: (size(r), r))
            evictables = _Evictables([size(r) for r in evict_order])
            items: List[knapsack.Item] = []
            meta: Dict[str, Dict] = {}
            for o in cands:
                bft = bft_of(o)
                if o in residents:
                    # already resident: keeping it costs nothing
                    items.append(knapsack.Item(o, bft, size(o)))
                    meta[o] = dict(cost=0.0, extra=0.0, resident=True)
                    continue
                overlap = graph.overlap_window(o, ph.index)
                cost = perfmodel.movement_cost(size(o), self.machine, overlap)
                extra = 0.0
                deficit = size(o) - free
                if deficit > 0:
                    # Space frees only when the evictee is dropped at this
                    # phase's start -> the incoming copy cannot overlap
                    # earlier phases (paper Fig 6: movement respects the
                    # availability of DRAM space).
                    cost = perfmodel.movement_cost(size(o), self.machine, 0.0)
                    evict_bytes = evictables.quote(deficit)
                    if evict_bytes is None:
                        continue   # cannot fit even with evictions
                    extra = evict_bytes / self.machine.copy_bw
                w = perfmodel.weight(bft, cost, extra)
                items.append(knapsack.Item(o, w, size(o)))
                meta[o] = dict(cost=cost, extra=extra, resident=False, bft=bft)

            chosen = set(self._solve(items, self.capacity))

            # Enact: move chosen non-residents in, evicting just enough.
            for o in sorted(chosen, key=lambda o: (-size(o), o)):
                if o in residents:
                    continue
                needed_evict = False
                deficit = size(o) - (self.capacity - resident_bytes)
                if deficit > 0:
                    needed_evict = True
                    evictable = sorted(
                        (r for r in residents
                         if r not in ph.refs and r not in chosen
                         and not self.registry[r].pinned),
                        key=lambda r: (size(r), r))
                    freed = 0
                    for r in evictable:
                        if freed >= deficit:
                            break
                        residents.discard(r)
                        resident_bytes -= size(r)
                        freed += size(r)
                        moves.append(MoveOp(r, "slow", ph.index, ph.index,
                                            size(r),
                                            size(r) / self.machine.copy_bw))
                    if freed < deficit:
                        # Cannot fit even after evicting everything allowed:
                        # skip the object but *keep* the evictions — they act
                        # as early space-clearing for the next phases' moves,
                        # and dropping them measurably regresses the chunked
                        # scenario workloads (graph_chase 1.32 -> 1.44
                        # normalized) even though the Eq.(4)/(5) model books
                        # them as pure cost.
                        continue
                # Eviction serializes with the incoming copy: trigger at the
                # phase itself (space is only free then).
                trig = (ph.index if needed_evict
                        else graph.trigger_point(o, ph.index))
                m = meta[o]
                moves.append(MoveOp(o, "fast", trig, ph.index, size(o),
                                    m["cost"], est_benefit=m.get("bft", 0.0)))
                residents.add(o)
                resident_bytes += size(o)
            placements.append(set(residents))

        # Predicted steady-state iteration time: baseline minus the realized
        # per-phase benefits of everything resident (that profiling saw in
        # the slow tier), plus the unhidden movement/eviction costs.
        predicted = graph.iteration_time()
        for ph in graph:
            for o in sorted(placements[ph.index]):   # fixed fp-sum order
                if o in originally_slow:
                    if view is not None:
                        predicted -= view.benefit(ph.index, o)
                    else:
                        predicted -= self._benefit_scalar(profiler, ph.index, o)
        predicted += sum(m.est_unhidden_cost for m in moves)
        return PlacementPlan("local", placements, moves,
                             max(predicted, 0.0), graph.iteration_time(),
                             emit_schedule(moves, graph, self.machine.copy_bw))

    # ---------------------------------------------------------- global search
    def plan_global(self, graph: PhaseGraph, profiler: PhaseProfiler) -> PlacementPlan:
        view = self._make_view(profiler)
        n = len(graph)
        size = lambda o: self.registry[o].size_bytes
        objs = [o for o in graph.objects()
                if o in self.registry and not self.registry[o].pinned]
        totals = {o: 0.0 for o in objs}
        for p in graph:
            if view is not None:
                view.ensure(p.index, objs)
                for o in objs:
                    b = view._benefit[p.index].get(o)
                    totals[o] += b if b is not None else 0.0
            else:
                for o in objs:
                    totals[o] += self._benefit_scalar(profiler, p.index, o)
        items = [knapsack.Item(o, totals[o], size(o)) for o in objs]
        chosen = set(self._solve(items, self.capacity))

        moves: List[MoveOp] = []
        predicted = graph.iteration_time()
        residents0 = self._initial_residents()
        originally_slow = {o.name for o in self.registry if o.tier != "fast"}
        by = {it.name: it for it in items}
        first_ref = {}
        for p in graph:
            for o in p.refs:
                first_ref.setdefault(o, p.index)
        for o in sorted(residents0 - chosen):   # deterministic move order
            moves.append(MoveOp(o, "slow", 0, 0, size(o), 0.0))
        for o in sorted(chosen, key=lambda o: (first_ref.get(o, 0), o)):
            if o in originally_slow:
                predicted -= by[o].value
            if o not in residents0:
                # One-time move, dispatched at iteration start and fenced at
                # the object's first use so it overlaps the leading phases
                # (this is what makes the paper's Table-4 overlap percentages
                # non-zero for global placements).
                moves.append(MoveOp(o, "fast", 0, first_ref.get(o, 0),
                                    size(o), 0.0, est_benefit=by[o].value))
        placements = [set(chosen)] * n
        return PlacementPlan("global", list(placements), moves,
                             max(predicted, 0.0), graph.iteration_time(),
                             emit_schedule(moves, graph, self.machine.copy_bw))

    # ----------------------------------------------------------- best of two
    def plan(self, graph: PhaseGraph, profiler: PhaseProfiler) -> PlacementPlan:
        local = self.plan_local(graph, profiler)
        glob = self.plan_global(graph, profiler)
        return local if local.predicted_iteration_time < glob.predicted_iteration_time else glob
