"""Data placement decision (paper §3.1.3).

Two searches, both driven by Eq. (1)-(5) and solved as 0/1 knapsacks:

* **phase-local search** — phases are decided one by one in order, with full
  knowledge of what earlier decisions left resident in the fast tier.
  Candidates are the objects the phase references; each candidate's weight is
  ``w = BFT - COST - extra_COST`` where ``extra_COST`` prices evicting
  just-big-enough non-candidate residents.  Moves are scheduled at the
  earliest dependency-safe trigger point (Fig 5) so the proactive mover can
  overlap them.
* **cross-phase global search** — one knapsack over per-object benefit summed
  across all phases; a single placement for the whole iteration, no
  steady-state movement.

The planner predicts the iteration time of each plan with the same models and
keeps the better one (the paper's best-of-two).

**Scale.** The planner must stay cheap at chunk counts in the thousands
(skew-aware partitioning can emit dozens of chunks per large object).  The
default ``vectorized`` mode batches all per-(phase, candidate) profile
lookups and Eq. (1)-(3) benefit evaluations into numpy (:class:`_ProfileView`
— chunk attribution fractions come from the profiler's measured histograms,
computed once per (phase, parent) instead of rescanning the registry per
candidate), prices candidate evictions against a prefix-summed evictable
list instead of re-sorting residents per candidate, and solves the knapsack
with a packed-bitset keep table.  ``vectorized=False`` preserves the
original per-candidate scalar path — the oracle for equivalence tests and
the baseline for the planner-latency benchmark; both modes produce
identical plans.

**Scoped replanning.** ``plan_local`` records one :class:`PhaseDecision`
per phase: the residency it entered with, a *fingerprint* of every input
the phase's solve read (reference set, candidate benefits, dependency-safe
trigger points and overlap windows), and the decision it produced (moves,
exit residency).  A replan handed the standing decisions
(``plan_local(..., standing=...)``) re-solves **only** the phases whose
entry state or fingerprint changed and splices the cached decisions for
the rest — so a localized drift re-solves O(affected phases) knapsacks
instead of O(plan), while remaining *provably equal* to a full replan:
any phase whose inputs changed in any way fails the fingerprint match and
is re-solved, and residency changes cascade until the entry state
re-converges with the cached trajectory.
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from . import knapsack, perfmodel
from .data_objects import ObjectRegistry
from .partition import bin_mass, chunk_spans
from .perfmodel import CalibrationConstants
from .phase import PhaseGraph
from .profiler import PhaseProfiler
from .tiers import MachineProfile


@dataclasses.dataclass(frozen=True)
class MoveOp:
    """One scheduled tier move.

    ``trigger_phase`` may be negative: trigger in the *previous* iteration,
    ``n + trigger_phase`` phases from its start.  ``est_unhidden_cost`` is the
    Eq. (4) cost the model expects to remain on the critical path.
    ``est_benefit`` is the Eq. (5) benefit that justified the move — the
    slack-aware scheduler uses it to break priority ties."""

    obj: str
    dst: str                     # "fast" | "slow"
    trigger_phase: int
    needed_by: int               # phase index whose start fences the move
    size_bytes: int
    est_unhidden_cost: float = 0.0
    est_benefit: float = 0.0


@dataclasses.dataclass(frozen=True)
class ScheduledMove:
    """A MoveOp with timing annotations: *when* to start it, not just where
    the object lives (the schedule-emission path of the slack-aware mover).

    ``window_s`` is the compute time between the move's trigger point and the
    start of its consuming phase; ``duration_s`` the copy time at full engine
    bandwidth; ``slack_s = window_s - duration_s`` is how long the move's
    start may be delayed past its trigger before it lands late.  Negative
    slack means the fence will stall no matter what — those moves are issued
    first."""

    op: MoveOp
    window_s: float
    duration_s: float
    slack_s: float

    @property
    def urgency(self) -> tuple:
        """Sort key: tightest slack first, then biggest benefit per byte."""
        density = self.op.est_benefit / max(self.op.size_bytes, 1)
        return (self.slack_s, -density)


@dataclasses.dataclass(frozen=True)
class PhaseDecision:
    """One phase's local-search solve, recorded for scoped replanning.

    ``fingerprint`` captures every input the phase's knapsack read beyond
    the entry residency: the phase's reference set, each candidate's
    Eq. (1)-(3) benefit, and each candidate's dependency-safe trigger point
    and overlap window (which couple the phase to the rest of the graph's
    measured times).  A replan may reuse the decision verbatim iff the
    entry state *and* the fingerprint match bitwise — anything else
    re-solves, which is what makes scoped replans provably equal to full
    replans."""

    phase_index: int
    entry_residents: frozenset
    entry_bytes: int
    fingerprint: tuple
    moves: Tuple[MoveOp, ...]
    exit_residents: frozenset
    exit_bytes: int
    # Eq. (1)-(3) benefit of every placed object, cached so a replan that
    # reuses this decision can also reuse its predicted-time term without
    # re-batching benefits (values are bitwise-reproducible from the same
    # profile version, so the cache never changes the plan).
    benefits: Optional[Dict[str, float]] = dataclasses.field(
        default=None, compare=False)
    reused: bool = dataclasses.field(default=False, compare=False)


@dataclasses.dataclass
class PlacementPlan:
    strategy: str                            # "local" | "global" | "none"
    residents: List[Set[str]]                # per phase: fast-tier residents
    moves: List[MoveOp]
    predicted_iteration_time: float
    baseline_iteration_time: float
    # Timing-annotated schedule (one entry per MoveOp), emitted by the
    # planner when it has a profiled graph; movers that don't need timing
    # (the FIFO baseline) simply ignore it.
    schedule: List[ScheduledMove] = dataclasses.field(default_factory=list)
    # Per-phase solve records from the local search (empty for global
    # plans): the standing state a scoped replan re-solves against.
    phase_decisions: List[PhaseDecision] = dataclasses.field(
        default_factory=list, repr=False, compare=False)
    # Per-phase benefit contributions from the global search (empty for
    # local plans): the scoped replan's cache for the global totals.
    global_contribs: List["GlobalContrib"] = dataclasses.field(
        default_factory=list, repr=False, compare=False)
    # (times, per-phase positive-ref key tuples) of the graph this plan was
    # built against.  When a replan's digest matches, every trigger point
    # and overlap window is provably unchanged, so phase reuse needs no
    # per-candidate window computation at all (the scoped fast path).
    graph_digest: Optional[tuple] = dataclasses.field(
        default=None, repr=False, compare=False)
    # Per-phase prediction decomposition for the calibration feedback: the
    # profiled baseline phase times and the booked slow->fast gain per
    # phase split by benefit class ("bw" = Eq. 2, "lat" = Eq. 3).  One
    # measured iteration then yields one realized-gain equation per phase,
    # which is what makes the per-class correction factors identifiable
    # (a whole-iteration scalar cannot separate the classes).
    phase_baseline: List[float] = dataclasses.field(
        default_factory=list, repr=False, compare=False)
    phase_gain_bw: List[float] = dataclasses.field(
        default_factory=list, repr=False, compare=False)
    phase_gain_lat: List[float] = dataclasses.field(
        default_factory=list, repr=False, compare=False)

    def moves_for_phase(self, phase_index: int, n_phases: int) -> List[MoveOp]:
        """Moves triggered at the start of ``phase_index`` (wrapping)."""
        return [m for m in self.moves
                if m.trigger_phase % n_phases == phase_index % n_phases]

    def fences_for_phase(self, phase_index: int) -> List[MoveOp]:
        return [m for m in self.moves if m.needed_by == phase_index]

    def scheduled_for_phase(self, phase_index: int,
                            n_phases: int) -> List["ScheduledMove"]:
        """Schedule entries released at the start of ``phase_index``, most
        urgent first."""
        out = [s for s in self.schedule
               if s.op.trigger_phase % n_phases == phase_index % n_phases]
        return sorted(out, key=lambda s: s.urgency)

    @property
    def total_moved_bytes(self) -> int:
        return sum(m.size_bytes for m in self.moves)


def emit_schedule(moves: Sequence[MoveOp], graph, copy_bw: float
                  ) -> List[ScheduledMove]:
    """Annotate each move with its copy window, duration and slack."""
    out: List[ScheduledMove] = []
    for m in moves:
        window = graph.window_between(m.trigger_phase, m.needed_by)
        duration = m.size_bytes / copy_bw
        out.append(ScheduledMove(m, window, duration, window - duration))
    return out


# ---------------------------------------------------------------------------
class _ProfileView:
    """Batched profile/benefit lookups for one (graph, profiler) pair.

    Replaces the per-candidate scalar path (a registry scan per chunk lookup
    plus a scalar Eq. (1)-(3) evaluation per candidate) with one numpy
    evaluation per phase.  Chunk attribution fractions — measured-histogram
    mass over the chunk's byte span, size fraction when no histogram exists —
    are computed once per (phase, parent).  Values agree bitwise with the
    scalar path."""

    def __init__(self, planner: "Planner", profiler: PhaseProfiler):
        self.planner = planner
        self.profiler = profiler
        reg = planner.registry
        self._spans: Dict[str, List[Tuple[str, int, int]]] = {}
        for parent in sorted({o.parent for o in reg if o.parent is not None}):
            self._spans[parent] = [(c.name, lo, hi)
                                   for c, lo, hi in chunk_spans(reg, parent)]
        # (phase, parent) -> {chunk name: attribution fraction}
        self._fracs: Dict[Tuple[int, str], Dict[str, float]] = {}
        # phase -> {obj: benefit or None (no profile)}
        self._benefit: Dict[int, Dict[str, Optional[float]]] = {}
        # phase -> {obj: resolved benefit class "bw" | "lat"}
        self._class: Dict[int, Dict[str, str]] = {}
        # (phase, obj) -> scalar-path result, for objects outside ensure()'s
        # candidate sets (e.g. residents carried over from earlier phases)
        self._fallback: Dict[Tuple[int, str], float] = {}
        self._fallback_class: Dict[Tuple[int, str], str] = {}

    def _chunk_fracs(self, phase: int, parent: str) -> Dict[str, float]:
        key = (phase, parent)
        cached = self._fracs.get(key)
        if cached is not None:
            return cached
        spans = self._spans[parent]
        total = sum(hi - lo for _, lo, hi in spans) or 1
        pp = self.profiler.profile(phase, parent)
        bins = pp.bin_weights if pp is not None else None
        if bins is None:
            out = {name: (hi - lo) / total for name, lo, hi in spans}
        else:
            out = {name: bin_mass(bins, lo / total, hi / total)
                   for name, lo, hi in spans}
        self._fracs[key] = out
        return out

    def ensure(self, phase: int, objs: Sequence[str]) -> None:
        """Batch-compute benefits for every not-yet-cached object."""
        cache = self._benefit.setdefault(phase, {})
        reg = self.planner.registry
        rows: List[Tuple[str, float, float, float, float, float]] = []
        for o in objs:
            if o in cache:
                continue
            p = self.profiler.profile(phase, o)
            if p is not None:
                rows.append((o, p.data_access, p.n_samples,
                             p.samples_with_access, p.phase_time,
                             p.cacheline_bytes))
                continue
            dob = reg[o] if o in reg else None
            pp = (self.profiler.profile(phase, dob.parent)
                  if dob is not None and dob.parent is not None else None)
            if pp is None:
                cache[o] = None
                continue
            frac = self._chunk_fracs(phase, dob.parent).get(o, 0.0)
            rows.append((o, pp.data_access * frac, pp.n_samples,
                         max(pp.samples_with_access * frac, 1.0),
                         pp.phase_time, pp.cacheline_bytes))
        if not rows:
            return
        names = [r[0] for r in rows]
        cols = np.array([r[1:] for r in rows], dtype=np.float64)
        bft, cls = perfmodel.benefit_batch(
            cols[:, 0], cols[:, 1], cols[:, 2], cols[:, 3], cols[:, 4],
            self.planner.machine, self.planner.cf, return_class=True)
        ccache = self._class.setdefault(phase, {})
        for name, b, c in zip(names, bft, cls):
            cache[name] = float(b)
            ccache[name] = "lat" if c else "bw"

    def has_profile(self, phase: int, obj: str) -> bool:
        return self._benefit.get(phase, {}).get(obj) is not None

    def benefit(self, phase: int, obj: str) -> float:
        b = self._benefit.get(phase, {}).get(obj)
        if b is not None:
            return b
        # outside ensure()'s candidate sets (residents carried over from
        # earlier phases): the exact scalar path, memoized — its registry
        # scan must not run once per (phase, resident)
        key = (phase, obj)
        val = self._fallback.get(key)
        if val is None:
            val = self.planner._benefit_scalar(self.profiler, phase, obj)
            self._fallback[key] = val
        return val

    def gain_class(self, phase: int, obj: str) -> str:
        """Resolved benefit class of ``(phase, obj)`` — batch-cached when
        :meth:`ensure` computed the benefit, scalar-memoized otherwise
        (the same fallback population as :meth:`benefit`)."""
        c = self._class.get(phase, {}).get(obj)
        if c is not None:
            return c
        key = (phase, obj)
        c = self._fallback_class.get(key)
        if c is None:
            c = self.planner._gain_class_scalar(self.profiler, phase, obj)
            self._fallback_class[key] = c
        return c


class _WindowIndex:
    """O(log n) dependency-safe trigger points for one plan build.

    ``graph.trigger_point`` walks backwards through the phase list per
    (object, phase) query — O(n) dictionary probes each, and the planner
    issues one query per candidate.  This index inverts the graph once
    (object -> sorted referencing phases) and answers each query with a
    bisect, returning *bitwise-identical* trigger indices; the overlap
    window itself is still summed by ``graph.window_between`` so plan
    float values are unchanged."""

    def __init__(self, graph: PhaseGraph):
        self.graph = graph
        self.n = len(graph)
        by: Dict[str, List[int]] = {}
        for p in graph:
            for o, v in p.refs.items():
                if v > 0.0:
                    by.setdefault(o, []).append(p.index)  # ascending
        self._by = by

    def trigger(self, obj: str, phase_index: int) -> int:
        n = self.n
        refs = self._by.get(obj)
        if refs:
            i = bisect.bisect_left(refs, phase_index)
            if i > 0:                       # nearest referencing phase < p
                return refs[i - 1] + 1
            if refs[-1] > phase_index:      # wrap into the previous iter
                return refs[-1] - n + 1
        return phase_index - (n - 1)

    def pair(self, obj: str, phase_index: int) -> Tuple[int, float]:
        t = self.trigger(obj, phase_index)
        return (t, self.graph.window_between(t, phase_index))


@dataclasses.dataclass(eq=False)
class GlobalContrib:
    """One phase's per-object benefit contributions to the cross-phase
    global search, with the profile version / registry generation they
    were computed against — the scoped replan's reuse key for the global
    totals.  ``row`` is aligned with ``objs``; full and scoped builds sum
    the same per-phase rows the same way, so reuse keeps the totals
    bitwise identical to a full recompute."""

    phase_index: int
    version: Tuple[int, int]
    generation: int
    objs: Tuple[str, ...]
    row: np.ndarray


def graph_digest(graph: PhaseGraph) -> tuple:
    """(measured times, per-phase positively-referenced object tuples) —
    everything trigger points and overlap windows are derived from."""
    return (tuple(p.time for p in graph),
            tuple(tuple(o for o, v in p.refs.items() if v > 0.0)
                  for p in graph))


class _Evictables:
    """Prefix-summed evictable residents for one phase's candidate loop:
    answers "how many bytes must leave to fit ``deficit``" in O(log n)
    instead of a fresh sort + scan per candidate."""

    def __init__(self, sizes: List[int]):
        # ``sizes`` already in the canonical (size, name) eviction order
        self._cum: List[int] = []
        acc = 0
        for s in sizes:
            acc += s
            self._cum.append(acc)

    def quote(self, deficit: int) -> Optional[int]:
        """Bytes freed by evicting the minimal prefix covering ``deficit``,
        or None when even evicting everything is not enough."""
        i = bisect.bisect_left(self._cum, deficit)
        if i >= len(self._cum):
            return None
        return self._cum[i]


class Planner:
    def __init__(self, machine: MachineProfile, registry: ObjectRegistry,
                 cf: Optional[CalibrationConstants] = None,
                 fast_capacity_bytes: Optional[int] = None,
                 vectorized: bool = True,
                 enact_consistent: bool = False):
        self.machine = machine
        self.registry = registry
        self.cf = cf or CalibrationConstants()
        self.capacity = (fast_capacity_bytes if fast_capacity_bytes is not None
                         else machine.fast.capacity_bytes)
        self.vectorized = vectorized
        # Enactment-consistent drop order for the local solve (multi-res
        # mode): when the knapsack declines a referenced resident that
        # enactment can never actually evict, the selection over-commits
        # the budget and the last-enacted chosen objects are dropped.
        # Legacy enacts size-descending — the smallest chosen go last,
        # which under multi-resolution refinement are exactly the fine
        # hot-head chunks — so this flag switches enactment to
        # benefit-density order (shortfall lands on the coldest chosen
        # bytes).  Off by default: legacy plans stay bit-identical.
        self.enact_consistent = enact_consistent

    # ------------------------------------------------------------ move pricing
    def price_fetch(self, size_bytes: int, overlap_window: float) -> float:
        """Eq. (4) unhidden cost of one slow->fast copy given its overlap
        window — the single pricing authority for *both* searches, so the
        best-of-two chooser always compares cost-inclusive numbers priced
        the same way (a cost-free global estimate against a cost-inclusive
        local one is how the original chooser bug crept in)."""
        cost = perfmodel.movement_cost(size_bytes, self.machine,
                                       overlap_window)
        if self.enact_consistent:
            # churn guard (see _solve_phase): an overlappable copy still
            # spends real copy bandwidth and serves slow until it lands
            cost = max(cost, size_bytes / self.machine.copy_bw)
        return cost * self.cf.cf_move

    def price_eviction(self, size_bytes: int) -> float:
        """Space-clearing demotion: the outgoing copy serializes with the
        incoming one, so its full copy time lands on the critical path.
        Scaled — like :meth:`price_fetch` — by the online-calibrated
        movement-price factor (``cf_move`` is 1.0 until the calibration
        feedback folds a measured stall ratio into it)."""
        return size_bytes / self.machine.copy_bw * self.cf.cf_move

    # ------------------------------------------------------------------ util
    def _profile(self, profiler: PhaseProfiler, phase: int, obj: str):
        p = profiler.profile(phase, obj)
        if p is not None:
            return p
        # Chunk of a partitioned object: scale the parent's profile by the
        # chunk's share of the parent's accesses — measured-histogram mass
        # over the chunk's byte span when per-chunk attribution exists, size
        # fraction otherwise (regular 1-D references, paper §3.2).
        dob = self.registry[obj] if obj in self.registry else None
        if dob is not None and dob.parent is not None:
            pp = profiler.profile(phase, dob.parent)
            if pp is not None:
                spans = chunk_spans(self.registry, dob.parent)
                total = sum(hi - lo for _, lo, hi in spans) or 1
                bins = pp.bin_weights
                if bins is None:
                    frac = dob.size_bytes / total
                else:
                    lo = next(l for c, l, _ in spans if c.name == dob.name)
                    frac = bin_mass(bins, lo / total,
                                    (lo + dob.size_bytes) / total)
                return dataclasses.replace(
                    pp, obj=obj, data_access=pp.data_access * frac,
                    samples_with_access=max(pp.samples_with_access * frac, 1.0))
        return None

    def _benefit_scalar(self, profiler: PhaseProfiler, phase: int,
                        obj: str) -> float:
        p = self._profile(profiler, phase, obj)
        if p is None:
            return 0.0
        return perfmodel.benefit(p, self.machine, self.cf)

    def _gain_class_scalar(self, profiler: PhaseProfiler, phase: int,
                           obj: str) -> str:
        """Benefit class ("bw" | "lat") a (phase, obj) gain is booked
        under — the calibration feedback's attribution key."""
        p = self._profile(profiler, phase, obj)
        if p is None:
            return "bw"
        return perfmodel.gain_class(p, self.machine, self.cf)

    # kept as the public scalar entry point (tests, legacy mode)
    _benefit = _benefit_scalar

    def _initial_residents(self) -> Set[str]:
        return {o.name for o in self.registry if o.tier == "fast"}

    def _solve(self, items, capacity):
        if self.vectorized:
            return knapsack.solve(items, capacity)
        return knapsack.solve_reference(items, capacity)

    def _make_view(self, profiler: PhaseProfiler) -> Optional[_ProfileView]:
        return _ProfileView(self, profiler) if self.vectorized else None

    # ----------------------------------------------------------- local search
    def _phase_candidates(self, profiler: PhaseProfiler, ph
                          ) -> Tuple[List[str], List[str]]:
        """Registry-present references and knapsack candidates of a phase,
        *without* computing any benefits (a reused phase never pays for
        them).  Matches the view/scalar profile-existence conditions: a
        candidate has a direct profile or a profiled parent."""
        in_reg = [o for o in ph.refs if o in self.registry]
        cands: List[str] = []
        for o in in_reg:
            dob = self.registry[o]
            if dob.pinned:
                continue
            if profiler.profile(ph.index, o) is not None:
                cands.append(o)
            elif (dob.parent is not None
                  and profiler.profile(ph.index, dob.parent) is not None):
                cands.append(o)
        return in_reg, cands

    def _phase_fingerprint(self, profiler: PhaseProfiler, ph,
                           cands: Sequence[str],
                           windows: Dict[str, Tuple[int, float]]) -> tuple:
        """Everything the phase's solve reads besides the entry residency,
        compressed to an exact reuse key:

        * ``profiler.phase_version`` — identifies the phase's accumulated
          profile state, which determines its refs (the attribute stage
          derives them from profiles), its candidates and their benefits;
        * ``registry.generation`` — identifies the chunk registry shape
          (sizes, parents, pinned flags are immutable per name);
        * per-candidate trigger points and overlap windows — the coupling
          to *other* phases' measured times and reference sets.  Windows
          are recorded only for the candidates the solve actually reads
          them for (the non-resident ones: ``windows`` omits residents) —
          a reuse check only compares fingerprints after the entry
          residency matched, so the resident split is identical on both
          sides.

        Precondition (the pipeline's attribute/partition stages): the
        graph's refs/times are derived from the profiler state, never
        hand-mutated between builds."""
        return (profiler.phase_version(ph.index), self.registry.generation,
                tuple((o, windows[o][0], windows[o][1]) if o in windows
                      else (o,) for o in cands))

    def _solve_phase(self, ph, cands, bft_of, windows,
                     entry_residents: Set[str], entry_bytes: int):
        """One phase's knapsack + enactment against the entry residency.
        Returns (exit_residents, exit_bytes, moves)."""
        size = lambda o: self.registry[o].size_bytes
        residents = set(entry_residents)
        resident_bytes = entry_bytes
        free = self.capacity - resident_bytes
        # deterministic tie-break by name: hash-order of the residents
        # set must never leak into the plan
        evict_order = sorted(
            (r for r in residents
             if r not in ph.refs and not self.registry[r].pinned),
            key=lambda r: (size(r), r))
        evictables = _Evictables([size(r) for r in evict_order])
        items: List[knapsack.Item] = []
        meta: Dict[str, Dict] = {}
        for o in cands:
            bft = bft_of(o)
            if o in residents:
                # already resident: keeping it costs nothing
                items.append(knapsack.Item(o, bft, size(o)))
                meta[o] = dict(cost=0.0, extra=0.0, resident=True, bft=bft)
                continue
            overlap = windows[o][1]
            cost = self.price_fetch(size(o), overlap)
            extra = 0.0
            deficit = size(o) - free
            if deficit > 0:
                # Space frees only when the evictee is dropped at this
                # phase's start -> the incoming copy cannot overlap
                # earlier phases (paper Fig 6: movement respects the
                # availability of DRAM space).
                cost = self.price_fetch(size(o), 0.0)
                evict_bytes = evictables.quote(deficit)
                if evict_bytes is None:
                    continue   # cannot fit even with evictions
                extra = self.price_eviction(evict_bytes)
            w = perfmodel.weight(bft, cost, extra)
            items.append(knapsack.Item(o, w, size(o)))
            meta[o] = dict(cost=cost, extra=extra, resident=False, bft=bft)

        chosen = set(self._solve(items, self.capacity))

        # Enactment order decides which chosen objects lose out when the
        # knapsack's selection cannot fully materialize (it may decline a
        # referenced resident — e.g. a phase's working buffer — that the
        # mover can never actually evict, leaving less room than the solve
        # assumed).  The legacy order is size-descending, which enacts the
        # *smallest* chosen last — under multi-resolution refinement those
        # are exactly the fine hot-head chunks, so ``enact_consistent``
        # switches to benefit-density order: any shortfall then drops the
        # coldest chosen bytes instead of the hottest.
        if self.enact_consistent:
            order = sorted(chosen, key=lambda o: (
                -meta[o].get("bft", 0.0) / max(size(o), 1), o))
        else:
            order = sorted(chosen, key=lambda o: (-size(o), o))
        moves: List[MoveOp] = []
        # Enact: move chosen non-residents in, evicting just enough.
        for o in order:
            if o in residents:
                continue
            needed_evict = False
            deficit = size(o) - (self.capacity - resident_bytes)
            if deficit > 0:
                needed_evict = True
                evictable = sorted(
                    (r for r in residents
                     if r not in ph.refs and r not in chosen
                     and not self.registry[r].pinned),
                    key=lambda r: (size(r), r))
                freed = 0
                for r in evictable:
                    if freed >= deficit:
                        break
                    residents.discard(r)
                    resident_bytes -= size(r)
                    freed += size(r)
                    moves.append(MoveOp(r, "slow", ph.index, ph.index,
                                        size(r),
                                        self.price_eviction(size(r))))
                if freed < deficit:
                    # Cannot fit even after evicting everything allowed:
                    # skip the object but *keep* the evictions — they act
                    # as early space-clearing for the next phases' moves,
                    # and dropping them measurably regresses the chunked
                    # scenario workloads (graph_chase 1.32 -> 1.44
                    # normalized) even though the Eq.(4)/(5) model books
                    # them as pure cost.
                    continue
            # Eviction serializes with the incoming copy: trigger at the
            # phase itself (space is only free then).
            trig = (ph.index if needed_evict else windows[o][0])
            m = meta[o]
            moves.append(MoveOp(o, "fast", trig, ph.index, size(o),
                                m["cost"], est_benefit=m.get("bft", 0.0)))
            residents.add(o)
            resident_bytes += size(o)
        return residents, resident_bytes, tuple(moves)

    def _placement_benefits(self, profiler: PhaseProfiler,
                            view: Optional[_ProfileView], phase_index: int,
                            placement: Set[str]) -> Dict[str, float]:
        """Eq. (1)-(3) benefit of every placed object, batch-ensured —
        the predicted-time inputs cached on the phase's decision."""
        if view is not None:
            view.ensure(phase_index, list(placement))
            return {o: view.benefit(phase_index, o) for o in placement}
        return {o: self._benefit_scalar(profiler, phase_index, o)
                for o in placement}

    def plan_local(self, graph: PhaseGraph, profiler: PhaseProfiler, *,
                   standing: Optional[Sequence[PhaseDecision]] = None,
                   standing_digest: Optional[tuple] = None
                   ) -> PlacementPlan:
        """Phase-local search.  With ``standing`` (the previous plan's
        :class:`PhaseDecision` list), phases whose entry state and input
        fingerprint still match reuse the cached decision without
        re-solving — the scoped replan path (plans are equal to a full
        replan by construction).

        ``standing_digest`` (the previous plan's ``graph_digest``) enables
        the fast path: when the graph's measured times and reference sets
        are unchanged, every trigger point and overlap window is provably
        unchanged too, so reuse checks reduce to (profile version, registry
        generation, entry residency) and skip per-candidate window
        computation entirely."""
        view = self._make_view(profiler)
        widx: Optional[_WindowIndex] = None     # built on first slow-path use
        digest = graph_digest(graph)
        windows_static = standing is not None and standing_digest == digest
        residents: Set[str] = self._initial_residents()
        originally_slow: Set[str] = {o.name for o in self.registry
                                     if o.tier != "fast"}
        placements: List[Set[str]] = []
        moves: List[MoveOp] = []
        decisions: List[PhaseDecision] = []
        bmaps: List[Optional[Dict[str, float]]] = []
        resident_bytes = sum(self.registry[o].size_bytes for o in residents)

        for ph in graph:
            d: Optional[PhaseDecision] = None
            s = (standing[ph.index]
                 if standing is not None and ph.index < len(standing)
                 else None)
            if (windows_static and s is not None
                    and s.entry_residents == residents
                    and s.entry_bytes == resident_bytes
                    and s.fingerprint[:2] == (
                        profiler.phase_version(ph.index),
                        self.registry.generation)):
                # fast path: unchanged graph digest ⇒ unchanged windows ⇒
                # the full fingerprint would match too
                d = dataclasses.replace(s, reused=True)
            if d is None:
                if widx is None:
                    widx = _WindowIndex(graph)
                in_reg, cands = self._phase_candidates(profiler, ph)
                windows = {o: widx.pair(o, ph.index) for o in cands
                           if o not in residents}
                fp = self._phase_fingerprint(profiler, ph, cands, windows)
                if (s is not None and s.entry_residents == residents
                        and s.entry_bytes == resident_bytes
                        and s.fingerprint == fp):
                    d = dataclasses.replace(s, reused=True)
            if d is None:
                if view is not None:
                    view.ensure(ph.index, in_reg)
                    bft_of = lambda o: view.benefit(ph.index, o)
                else:
                    bft_of = lambda o: self._benefit_scalar(
                        profiler, ph.index, o)
                exit_res, exit_bytes, ph_moves = self._solve_phase(
                    ph, cands, bft_of, windows, residents, resident_bytes)
                bmap = self._placement_benefits(profiler, view, ph.index,
                                                exit_res)
                d = PhaseDecision(
                    phase_index=ph.index,
                    entry_residents=frozenset(residents),
                    entry_bytes=resident_bytes, fingerprint=fp,
                    moves=ph_moves, exit_residents=frozenset(exit_res),
                    exit_bytes=exit_bytes, benefits=bmap)
            else:
                bmap = d.benefits
            moves.extend(d.moves)
            residents = set(d.exit_residents)
            resident_bytes = d.exit_bytes
            placements.append(set(d.exit_residents))
            decisions.append(d)
            bmaps.append(bmap)

        # Predicted steady-state iteration time: baseline minus the realized
        # per-phase benefits of everything resident (that profiling saw in
        # the slow tier), plus the unhidden movement/eviction costs.
        # Benefit values come from each decision's cache (batch-ensured at
        # solve time; bitwise-reproducible, so reuse cannot change them).
        predicted = graph.iteration_time()
        gain_bw = [0.0] * len(graph)
        gain_lat = [0.0] * len(graph)
        cls_of = ((lambda i, o: view.gain_class(i, o)) if view is not None
                  else (lambda i, o: self._gain_class_scalar(profiler, i, o)))
        for ph in graph:
            bmap = bmaps[ph.index]
            if bmap is None:    # decision from a pre-cache serialized plan
                bmap = self._placement_benefits(profiler, view, ph.index,
                                                placements[ph.index])
            for o in sorted(placements[ph.index]):   # fixed fp-sum order
                if o in originally_slow:
                    g = bmap[o]
                    predicted -= g
                    if g != 0.0:
                        if cls_of(ph.index, o) == "lat":
                            gain_lat[ph.index] += g
                        else:
                            gain_bw[ph.index] += g
        predicted += sum(m.est_unhidden_cost for m in moves)
        return PlacementPlan("local", placements, moves,
                             max(predicted, 0.0), graph.iteration_time(),
                             emit_schedule(moves, graph, self.machine.copy_bw),
                             phase_decisions=decisions,
                             graph_digest=digest,
                             phase_baseline=[p.time for p in graph],
                             phase_gain_bw=gain_bw, phase_gain_lat=gain_lat)

    # ---------------------------------------------------------- global search
    def plan_global(self, graph: PhaseGraph, profiler: PhaseProfiler, *,
                    standing_global: Optional[Sequence[GlobalContrib]] = None
                    ) -> PlacementPlan:
        """Cross-phase global search.  With ``standing_global`` (the
        previous plan's per-phase benefit contributions), phases whose
        profile version and registry generation still match reuse their
        recorded contributions — the totals are summed in phase order
        either way, so the result is bitwise identical to a full
        recompute."""
        view = self._make_view(profiler)
        n = len(graph)
        size = lambda o: self.registry[o].size_bytes
        objs = [o for o in graph.objects()
                if o in self.registry and not self.registry[o].pinned]
        objs_t = tuple(objs)
        contribs_out: List[GlobalContrib] = []
        for p in graph:
            version = profiler.phase_version(p.index)
            row: Optional[np.ndarray] = None
            if standing_global is not None and p.index < len(standing_global):
                g = standing_global[p.index]
                if (g.version == version
                        and g.generation == self.registry.generation
                        and g.objs == objs_t):
                    row = g.row
            if row is None:
                if view is not None:
                    view.ensure(p.index, objs)
                    cache = view._benefit[p.index]
                    vals = []
                    for o in objs:
                        b = cache.get(o)
                        vals.append(b if b is not None else 0.0)
                else:
                    vals = [self._benefit_scalar(profiler, p.index, o)
                            for o in objs]
                row = np.asarray(vals, dtype=np.float64)
            contribs_out.append(GlobalContrib(
                phase_index=p.index, version=version,
                generation=self.registry.generation, objs=objs_t, row=row))
        if contribs_out and objs:
            totals_vec = np.vstack([g.row for g in contribs_out]).sum(axis=0)
        else:
            totals_vec = np.zeros(len(objs))
        totals = {o: float(totals_vec[i]) for i, o in enumerate(objs)}
        items = [knapsack.Item(o, totals[o], size(o)) for o in objs]
        chosen = set(self._solve(items, self.capacity))

        moves: List[MoveOp] = []
        predicted = graph.iteration_time()
        residents0 = self._initial_residents()
        originally_slow = {o.name for o in self.registry if o.tier != "fast"}
        by = {it.name: it for it in items}
        first_ref = {}
        for p in graph:
            for o in p.refs:
                first_ref.setdefault(o, p.index)
        for o in sorted(residents0 - chosen):   # deterministic move order
            moves.append(MoveOp(o, "slow", 0, 0, size(o),
                                self.price_eviction(size(o))))
        for o in sorted(chosen, key=lambda o: (first_ref.get(o, 0), o)):
            if o in originally_slow:
                predicted -= by[o].value
            if o not in residents0:
                # One-time move, dispatched at iteration start and fenced at
                # the object's first use so it overlaps the leading phases
                # (this is what makes the paper's Table-4 overlap percentages
                # non-zero for global placements).  Priced through the same
                # Eq. (4) helper as the local search — the overlap window is
                # the compute between dispatch and the fence — so the
                # best-of-two chooser compares cost-inclusive numbers on
                # both sides.
                fence = first_ref.get(o, 0)
                window = graph.window_between(0, fence)
                moves.append(MoveOp(o, "fast", 0, fence, size(o),
                                    self.price_fetch(size(o), window),
                                    est_benefit=by[o].value))
        predicted += sum(m.est_unhidden_cost for m in moves)
        # Per-phase gain decomposition for the calibration feedback: the
        # chosen slow objects' per-phase contributions, split by benefit
        # class (the per-object totals the knapsack saw are these same
        # rows summed over phases).
        gain_bw = [0.0] * n
        gain_lat = [0.0] * n
        cls_of = ((lambda i, o: view.gain_class(i, o)) if view is not None
                  else (lambda i, o: self._gain_class_scalar(profiler, i, o)))
        chosen_slow = [i for i, o in enumerate(objs)
                       if o in chosen and o in originally_slow]
        for g in contribs_out:
            for i in chosen_slow:
                v = float(g.row[i])
                if v != 0.0:
                    if cls_of(g.phase_index, objs[i]) == "lat":
                        gain_lat[g.phase_index] += v
                    else:
                        gain_bw[g.phase_index] += v
        placements = [set(chosen)] * n
        return PlacementPlan("global", list(placements), moves,
                             max(predicted, 0.0), graph.iteration_time(),
                             emit_schedule(moves, graph, self.machine.copy_bw),
                             global_contribs=contribs_out,
                             phase_baseline=[p.time for p in graph],
                             phase_gain_bw=gain_bw, phase_gain_lat=gain_lat)

    # ----------------------------------------------------------- best of two
    def plan(self, graph: PhaseGraph, profiler: PhaseProfiler, *,
             standing: Optional[Sequence[PhaseDecision]] = None,
             standing_global: Optional[Sequence[GlobalContrib]] = None,
             standing_digest: Optional[tuple] = None) -> PlacementPlan:
        local = self.plan_local(graph, profiler, standing=standing,
                                standing_digest=standing_digest)
        glob = self.plan_global(graph, profiler,
                                standing_global=standing_global)
        return local if local.predicted_iteration_time < glob.predicted_iteration_time else glob
