"""Data placement decision (paper §3.1.3).

Two searches, both driven by Eq. (1)-(5) and solved as 0/1 knapsacks:

* **phase-local search** — phases are decided one by one in order, with full
  knowledge of what earlier decisions left resident in the fast tier.
  Candidates are the objects the phase references; each candidate's weight is
  ``w = BFT - COST - extra_COST`` where ``extra_COST`` prices evicting
  just-big-enough non-candidate residents.  Moves are scheduled at the
  earliest dependency-safe trigger point (Fig 5) so the proactive mover can
  overlap them.
* **cross-phase global search** — one knapsack over per-object benefit summed
  across all phases; a single placement for the whole iteration, no
  steady-state movement.

The planner predicts the iteration time of each plan with the same models and
keeps the better one (the paper's best-of-two).

**Scale.** The planner is a serving-tick operation: a scoped replan at
10k-100k chunks must land in O(10 ms).  The default ``vectorized`` mode is
an end-to-end array program — candidate extraction, Eq. (1)-(3) benefit
evaluation, Eq. (4) move pricing, eviction quoting and the knapsack itself
all run over numpy arrays (:class:`_ProfileView` blocks per (phase, parent),
:class:`_PhaseLayout` per phase) with no per-candidate Python loop left on
the hot path.  ``vectorized=False`` preserves the original per-candidate
scalar path — the oracle for equivalence tests and the baseline for the
planner-latency benchmark; both modes produce identical plans bit for bit.

**Amortization.** All shape-dependent preprocessing is cached on the
planner across ticks and invalidated by the exact inputs it derives from:
chunk spans and registry lookup tables per ``registry.generation``
(:class:`_GenCache`), profile blocks per ``profiler.phase_version``
(:class:`_ProfileView.refresh`), candidate layouts per (phase refs,
generation, profiled parents) (:class:`_PhaseLayout`), trigger points and
overlap windows per graph digest (:class:`_TriggerIndex`), and the
cross-phase candidate universe per (digest, generation).  A tick that
drifts one phase recomputes that phase's blocks and row and nothing else.

**Scoped replanning.** ``plan_local`` records one :class:`PhaseDecision`
per phase: the residency it entered with, a *fingerprint* of every input
the phase's solve read (reference set, candidate benefits, dependency-safe
trigger points and overlap windows), and the decision it produced (moves,
exit residency).  A replan handed the standing decisions
(``plan_local(..., standing=...)``) re-solves **only** the phases whose
entry state or fingerprint changed and splices the cached decisions for
the rest — so a localized drift re-solves O(affected phases) knapsacks
instead of O(plan), while remaining *provably equal* to a full replan:
any phase whose inputs changed in any way fails the fingerprint match and
is re-solved, and residency changes cascade until the entry state
re-converges with the cached trajectory.

``plan_global`` is scoped the same way: per-phase benefit rows
(:class:`GlobalContrib`) are reused when their (profile version, registry
generation, object universe) key still matches, the totals are re-summed
from the rows in phase order (never incrementally updated — float
summation order is part of the bit-identity contract), and the whole
decision is memoized so a zero-drift rebuild returns it outright.  When
the chooser supplies the local plan's predicted time (``prune_above``), a
fractional-knapsack upper bound on the global gains can prove "global
cannot win this rebuild" and skip the solve entirely; the pruned result
carries a certified lower bound on the global predicted time, so the
best-of-two chooser picks the same winner it would have with a full
solve.
"""

from __future__ import annotations

import bisect
import dataclasses
import hashlib
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from . import knapsack, perfmodel
from .data_objects import ObjectRegistry
from .partition import bin_mass, chunk_spans
from .perfmodel import CalibrationConstants
from .phase import PhaseGraph
from .profiler import PhaseProfiler
from .tiers import MachineProfile


@dataclasses.dataclass(frozen=True)
class MoveOp:
    """One scheduled tier move.

    ``trigger_phase`` may be negative: trigger in the *previous* iteration,
    ``n + trigger_phase`` phases from its start.  ``est_unhidden_cost`` is the
    Eq. (4) cost the model expects to remain on the critical path.
    ``est_benefit`` is the Eq. (5) benefit that justified the move — the
    slack-aware scheduler uses it to break priority ties."""

    obj: str
    dst: str                     # "fast" | "slow"
    trigger_phase: int
    needed_by: int               # phase index whose start fences the move
    size_bytes: int
    est_unhidden_cost: float = 0.0
    est_benefit: float = 0.0


@dataclasses.dataclass(frozen=True)
class ScheduledMove:
    """A MoveOp with timing annotations: *when* to start it, not just where
    the object lives (the schedule-emission path of the slack-aware mover).

    ``window_s`` is the compute time between the move's trigger point and the
    start of its consuming phase; ``duration_s`` the copy time at full engine
    bandwidth; ``slack_s = window_s - duration_s`` is how long the move's
    start may be delayed past its trigger before it lands late.  Negative
    slack means the fence will stall no matter what — those moves are issued
    first."""

    op: MoveOp
    window_s: float
    duration_s: float
    slack_s: float

    @property
    def urgency(self) -> tuple:
        """Sort key: tightest slack first, then biggest benefit per byte."""
        density = self.op.est_benefit / max(self.op.size_bytes, 1)
        return (self.slack_s, -density)


@dataclasses.dataclass(frozen=True)
class PhaseDecision:
    """One phase's local-search solve, recorded for scoped replanning.

    ``fingerprint`` captures every input the phase's knapsack read beyond
    the entry residency: the phase's reference set, each candidate's
    Eq. (1)-(3) benefit, and each candidate's dependency-safe trigger point
    and overlap window (which couple the phase to the rest of the graph's
    measured times).  A replan may reuse the decision verbatim iff the
    entry state *and* the fingerprint match bitwise — anything else
    re-solves, which is what makes scoped replans provably equal to full
    replans."""

    phase_index: int
    entry_residents: frozenset
    entry_bytes: int
    fingerprint: tuple
    moves: Tuple[MoveOp, ...]
    exit_residents: frozenset
    exit_bytes: int
    # Eq. (1)-(3) benefit of every placed object, cached so a replan that
    # reuses this decision can also reuse its predicted-time term without
    # re-batching benefits (values are bitwise-reproducible from the same
    # profile version, so the cache never changes the plan).
    benefits: Optional[Dict[str, float]] = dataclasses.field(
        default=None, compare=False)
    # Resolved benefit class ("bw" | "lat") of every placed object whose
    # benefit is non-zero — the calibration decomposition's attribution
    # key, cached for the same reason as ``benefits`` (classes are a pure
    # function of the same profile version).  ``None`` on decisions from
    # pre-cache serialized plans; the decomposition falls back to the
    # scalar classifier for those.
    classes: Optional[Dict[str, str]] = dataclasses.field(
        default=None, compare=False)
    reused: bool = dataclasses.field(default=False, compare=False)


@dataclasses.dataclass
class PlacementPlan:
    strategy: str                            # "local" | "global" | "none"
    residents: List[Set[str]]                # per phase: fast-tier residents
    moves: List[MoveOp]
    predicted_iteration_time: float
    baseline_iteration_time: float
    # Timing-annotated schedule (one entry per MoveOp), emitted by the
    # planner when it has a profiled graph; movers that don't need timing
    # (the FIFO baseline) simply ignore it.
    schedule: List[ScheduledMove] = dataclasses.field(default_factory=list)
    # Per-phase solve records from the local search (empty for global
    # plans): the standing state a scoped replan re-solves against.
    phase_decisions: List[PhaseDecision] = dataclasses.field(
        default_factory=list, repr=False, compare=False)
    # Per-phase benefit contributions from the global search (empty for
    # local plans): the scoped replan's cache for the global totals.
    global_contribs: List["GlobalContrib"] = dataclasses.field(
        default_factory=list, repr=False, compare=False)
    # (times, per-phase positive-ref key tuples) of the graph this plan was
    # built against.  When a replan's digest matches, every trigger point
    # and overlap window is provably unchanged, so phase reuse needs no
    # per-candidate window computation at all (the scoped fast path).
    graph_digest: Optional[tuple] = dataclasses.field(
        default=None, repr=False, compare=False)
    # Per-phase prediction decomposition for the calibration feedback: the
    # profiled baseline phase times and the booked slow->fast gain per
    # phase split by benefit class ("bw" = Eq. 2, "lat" = Eq. 3).  One
    # measured iteration then yields one realized-gain equation per phase,
    # which is what makes the per-class correction factors identifiable
    # (a whole-iteration scalar cannot separate the classes).
    phase_baseline: List[float] = dataclasses.field(
        default_factory=list, repr=False, compare=False)
    phase_gain_bw: List[float] = dataclasses.field(
        default_factory=list, repr=False, compare=False)
    phase_gain_lat: List[float] = dataclasses.field(
        default_factory=list, repr=False, compare=False)
    # How the global search resolved this build: "solved" (fresh solve),
    # "reused" (whole-decision memo hit — zero drift), or "pruned" (the
    # dominance bound proved local wins and the solve was skipped; the
    # plan's predicted time is then a certified *lower bound*).  The
    # chooser copies these onto whichever plan it returns, so callers see
    # the global search's reuse behaviour regardless of the winner.
    global_mode: str = dataclasses.field(
        default="solved", repr=False, compare=False)
    global_rows_reused: int = dataclasses.field(
        default=0, repr=False, compare=False)

    def moves_for_phase(self, phase_index: int, n_phases: int) -> List[MoveOp]:
        """Moves triggered at the start of ``phase_index`` (wrapping)."""
        return [m for m in self.moves
                if m.trigger_phase % n_phases == phase_index % n_phases]

    def fences_for_phase(self, phase_index: int) -> List[MoveOp]:
        return [m for m in self.moves if m.needed_by == phase_index]

    def scheduled_for_phase(self, phase_index: int,
                            n_phases: int) -> List["ScheduledMove"]:
        """Schedule entries released at the start of ``phase_index``, most
        urgent first."""
        out = [s for s in self.schedule
               if s.op.trigger_phase % n_phases == phase_index % n_phases]
        return sorted(out, key=lambda s: s.urgency)

    @property
    def total_moved_bytes(self) -> int:
        return sum(m.size_bytes for m in self.moves)


def emit_schedule(moves: Sequence[MoveOp], graph, copy_bw: float
                  ) -> List[ScheduledMove]:
    """Annotate each move with its copy window, duration and slack."""
    out: List[ScheduledMove] = []
    for m in moves:
        window = graph.window_between(m.trigger_phase, m.needed_by)
        duration = m.size_bytes / copy_bw
        out.append(ScheduledMove(m, window, duration, window - duration))
    return out


# ---------------------------------------------------------------------------
# amortized per-generation registry tables
# ---------------------------------------------------------------------------
class _GenCache:
    """Registry lookup tables computed once per ``registry.generation``:
    sizes, pinned flags and parents per name, plus lazily-built chunk
    spans per parent (the partition attribution order every consumer of
    ``chunk_spans`` must agree on).  Names/sizes/parents/pins are
    immutable per name, so generation (plus a length check for
    registration without a bump) is the exact invalidation key; tiers are
    mutable and deliberately *not* cached here."""

    __slots__ = ("generation", "count", "sizes", "pinned", "parent_of",
                 "_spans", "_span_idx", "_span_total", "_span_sizes")

    def __init__(self, registry: ObjectRegistry):
        self.generation = registry.generation
        sizes: Dict[str, int] = {}
        pinned: Set[str] = set()
        parent_of: Dict[str, str] = {}
        for o in registry:
            sizes[o.name] = o.size_bytes
            if o.pinned:
                pinned.add(o.name)
            if o.parent is not None:
                parent_of[o.name] = o.parent
        self.count = len(sizes)
        self.sizes = sizes
        self.pinned = pinned
        self.parent_of = parent_of
        self._spans: Dict[str, List[Tuple[str, int, int]]] = {}
        self._span_idx: Dict[str, Dict[str, int]] = {}
        self._span_total: Dict[str, int] = {}
        self._span_sizes: Dict[str, np.ndarray] = {}

    def spans(self, registry: ObjectRegistry, parent: str
              ) -> List[Tuple[str, int, int]]:
        s = self._spans.get(parent)
        if s is None:
            s = self._spans[parent] = [
                (c.name, lo, hi) for c, lo, hi in chunk_spans(registry, parent)]
            self._span_total[parent] = sum(hi - lo for _, lo, hi in s) or 1
        return s

    def span_total(self, registry: ObjectRegistry, parent: str) -> int:
        self.spans(registry, parent)
        return self._span_total[parent]

    def span_idx(self, registry: ObjectRegistry, parent: str
                 ) -> Dict[str, int]:
        d = self._span_idx.get(parent)
        if d is None:
            d = self._span_idx[parent] = {
                name: i for i, (name, _, _) in
                enumerate(self.spans(registry, parent))}
        return d

    def span_sizes(self, registry: ObjectRegistry, parent: str) -> np.ndarray:
        a = self._span_sizes.get(parent)
        if a is None:
            a = self._span_sizes[parent] = np.array(
                [hi - lo for _, lo, hi in self.spans(registry, parent)],
                dtype=np.int64)
        return a


_MISSING = object()


# ---------------------------------------------------------------------------
class _ProfileView:
    """Batched profile/benefit lookups for one (planner, profiler) pair,
    held across ticks.

    Replaces the per-candidate scalar path (a registry scan per chunk lookup
    plus a scalar Eq. (1)-(3) evaluation per candidate) with one numpy
    evaluation per (phase, parent) block.  Chunk attribution fractions —
    measured-histogram mass over the chunk's byte span, size fraction when
    no histogram exists — are computed once per (phase, parent).  Values
    agree bitwise with the scalar path.

    Everything cached here is a pure function of (profiler state at that
    phase's version, registry generation, calibration constants):
    :meth:`refresh` evicts exactly the phases whose profile version moved,
    and the planner rebuilds the view outright on generation / profiler /
    calibration changes — so cross-tick reuse can never change a plan."""

    def __init__(self, planner: "Planner", profiler: PhaseProfiler):
        self.planner = planner
        self.profiler = profiler
        self.generation = planner.registry.generation
        self.cf = planner.cf
        # phase -> profile version the caches below were filled under
        self._versions: Dict[int, tuple] = {}
        # phase -> profiles_for_phase() snapshot
        self._direct: Dict[int, Dict] = {}
        # phase -> {parent: attribution-fraction array aligned with spans}
        self._fracs: Dict[int, Dict[str, np.ndarray]] = {}
        # phase -> {parent: (benefit array, class array) | None}
        self._blocks: Dict[int, Dict[str, Optional[tuple]]] = {}
        # phase -> {obj: benefit or None (no profile)}
        self._benefit: Dict[int, Dict[str, Optional[float]]] = {}
        # phase -> {obj: resolved benefit class "bw" | "lat"}
        self._class: Dict[int, Dict[str, str]] = {}
        # scalar-path fallbacks for objects outside ensure()'s candidate
        # sets (e.g. residents carried over from earlier phases)
        self._fallback: Dict[int, Dict[str, float]] = {}
        self._fallback_class: Dict[int, Dict[str, str]] = {}

    _CACHES = ("_versions", "_direct", "_fracs", "_blocks", "_benefit",
               "_class", "_fallback", "_fallback_class")

    def refresh(self) -> None:
        """Evict every phase whose profile version drifted since its
        caches were filled (called once per plan build)."""
        stale = [ph for ph, ver in self._versions.items()
                 if self.profiler.phase_version(ph) != ver]
        for ph in stale:
            for name in self._CACHES:
                getattr(self, name).pop(ph, None)

    def _touch(self, phase: int) -> None:
        if phase not in self._versions:
            self._versions[phase] = self.profiler.phase_version(phase)

    def direct(self, phase: int) -> Dict:
        """The phase's direct profiles (name -> AccessProfile snapshot)."""
        d = self._direct.get(phase)
        if d is None:
            self._touch(phase)
            d = self._direct[phase] = self.profiler.profiles_for_phase(phase)
        return d

    def _frac_arr(self, phase: int, parent: str) -> np.ndarray:
        per = self._fracs.setdefault(phase, {})
        arr = per.get(parent)
        if arr is None:
            planner = self.planner
            gen = planner._gen()
            spans = gen.spans(planner.registry, parent)
            total = gen.span_total(planner.registry, parent)
            pp = self.direct(phase).get(parent)
            bins = pp.bin_weights if pp is not None else None
            if bins is None:
                arr = gen.span_sizes(planner.registry, parent) / total
            else:
                arr = np.array(
                    [bin_mass(bins, lo / total, hi / total)
                     for _, lo, hi in spans], dtype=np.float64)
            per[parent] = arr
        return arr

    def _pblock(self, phase: int, parent: str) -> Optional[tuple]:
        """(benefit, class) arrays for every chunk of ``parent`` in span
        order, or None when the parent has no profile at this phase.  One
        ``benefit_batch`` per (phase, parent) — elementwise identical to
        the scalar per-chunk path."""
        per = self._blocks.setdefault(phase, {})
        blk = per.get(parent, _MISSING)
        if blk is not _MISSING:
            return blk
        self._touch(phase)
        pp = self.direct(phase).get(parent)
        if pp is None:
            per[parent] = None
            return None
        frac = self._frac_arr(phase, parent)
        planner = self.planner
        vals, cls = perfmodel.benefit_batch(
            pp.data_access * frac, pp.n_samples,
            np.maximum(pp.samples_with_access * frac, 1.0),
            pp.phase_time, pp.cacheline_bytes,
            planner.machine, planner.cf, return_class=True)
        blk = (vals, cls)
        per[parent] = blk
        return blk

    def ensure(self, phase: int, objs: Sequence[str]) -> None:
        """Batch-compute benefits for every not-yet-cached object."""
        self._touch(phase)
        cache = self._benefit.setdefault(phase, {})
        ccache = self._class.setdefault(phase, {})
        planner = self.planner
        gen = planner._gen()
        direct = self.direct(phase)
        d_names: List[str] = []
        d_prof: List = []
        for o in objs:
            if o in cache:
                continue
            p = direct.get(o)
            if p is not None:
                d_names.append(o)
                d_prof.append(p)
                continue
            par = gen.parent_of.get(o)
            pp = direct.get(par) if par is not None else None
            if pp is None:
                cache[o] = None
                continue
            blk = self._pblock(phase, par)
            idx = gen.span_idx(planner.registry, par)[o]
            cache[o] = float(blk[0][idx])
            ccache[o] = "lat" if blk[1][idx] else "bw"
        if not d_prof:
            return
        cols = np.array(
            [(p.data_access, p.n_samples, p.samples_with_access,
              p.phase_time, p.cacheline_bytes) for p in d_prof],
            dtype=np.float64)
        bft, cls = perfmodel.benefit_batch(
            cols[:, 0], cols[:, 1], cols[:, 2], cols[:, 3], cols[:, 4],
            planner.machine, planner.cf, return_class=True)
        for name, b, c in zip(d_names, bft, cls):
            cache[name] = float(b)
            ccache[name] = "lat" if c else "bw"

    def has_profile(self, phase: int, obj: str) -> bool:
        return self._benefit.get(phase, {}).get(obj) is not None

    def benefit(self, phase: int, obj: str) -> float:
        b = self._benefit.get(phase, {}).get(obj)
        if b is not None:
            return b
        # outside ensure()'s candidate sets (residents carried over from
        # earlier phases): the exact scalar path, memoized — its registry
        # scan must not run once per (phase, resident)
        self._touch(phase)
        per = self._fallback.setdefault(phase, {})
        val = per.get(obj)
        if val is None:
            val = self.planner._benefit_scalar(self.profiler, phase, obj)
            per[obj] = val
        return val

    def gain_class(self, phase: int, obj: str) -> str:
        """Resolved benefit class of ``(phase, obj)`` — batch-cached when
        :meth:`ensure` computed the benefit, scalar-memoized otherwise
        (the same fallback population as :meth:`benefit`)."""
        c = self._class.get(phase, {}).get(obj)
        if c is not None:
            return c
        self._touch(phase)
        per = self._fallback_class.setdefault(phase, {})
        c = per.get(obj)
        if c is None:
            c = self.planner._gain_class_scalar(self.profiler, phase, obj)
            per[obj] = c
        return c


class _WindowIndex:
    """O(log n) dependency-safe trigger points for one plan build.

    ``graph.trigger_point`` walks backwards through the phase list per
    (object, phase) query — O(n) dictionary probes each, and the planner
    issues one query per candidate.  This index inverts the graph once
    (object -> sorted referencing phases) and answers each query with a
    bisect, returning *bitwise-identical* trigger indices; the overlap
    window itself is still summed by ``graph.window_between`` so plan
    float values are unchanged."""

    def __init__(self, graph: PhaseGraph):
        self.graph = graph
        self.n = len(graph)
        by: Dict[str, List[int]] = {}
        for p in graph:
            for o, v in p.refs.items():
                if v > 0.0:
                    by.setdefault(o, []).append(p.index)  # ascending
        self._by = by

    def trigger(self, obj: str, phase_index: int) -> int:
        n = self.n
        refs = self._by.get(obj)
        if refs:
            i = bisect.bisect_left(refs, phase_index)
            if i > 0:                       # nearest referencing phase < p
                return refs[i - 1] + 1
            if refs[-1] > phase_index:      # wrap into the previous iter
                return refs[-1] - n + 1
        return phase_index - (n - 1)

    def pair(self, obj: str, phase_index: int) -> Tuple[int, float]:
        t = self.trigger(obj, phase_index)
        return (t, self.graph.window_between(t, phase_index))


class _TriggerIndex:
    """:class:`_WindowIndex` held across ticks, keyed on the graph digest.

    Same bitwise-identical trigger/window answers, plus two memo layers
    the serving tick needs: equal referencing-phase tuples are interned so
    all chunks of one parent (identical reference patterns) share a single
    trigger memo entry, and ``window_between`` sums are memoized per
    (trigger, phase) — the digest pins every measured time and positive
    reference set these derive from, so reuse cannot change a value."""

    def __init__(self, graph: PhaseGraph):
        self.graph = graph
        self.n = len(graph)
        by: Dict[str, List[int]] = {}
        for p in graph:
            for o, v in p.refs.items():
                if v > 0.0:
                    by.setdefault(o, []).append(p.index)  # ascending
        canon: Dict[tuple, tuple] = {}
        self._refs: Dict[str, tuple] = {
            o: canon.setdefault(t, t)
            for o, t in ((o, tuple(l)) for o, l in by.items())}
        self._tmemo: Dict[Tuple[int, int], int] = {}
        self._wmemo: Dict[Tuple[int, int], float] = {}

    def _trig(self, refs: Optional[tuple], phase_index: int) -> int:
        if refs:
            key = (id(refs), phase_index)
            t = self._tmemo.get(key)
            if t is None:
                i = bisect.bisect_left(refs, phase_index)
                if i > 0:
                    t = refs[i - 1] + 1
                elif refs[-1] > phase_index:
                    t = refs[-1] - self.n + 1
                else:
                    t = phase_index - (self.n - 1)
                self._tmemo[key] = t
            return t
        return phase_index - (self.n - 1)

    def trigger(self, obj: str, phase_index: int) -> int:
        return self._trig(self._refs.get(obj), phase_index)

    def window(self, trigger: int, phase_index: int) -> float:
        key = (trigger, phase_index)
        w = self._wmemo.get(key)
        if w is None:
            w = self._wmemo[key] = self.graph.window_between(
                trigger, phase_index)
        return w

    def pair(self, obj: str, phase_index: int) -> Tuple[int, float]:
        t = self.trigger(obj, phase_index)
        return (t, self.window(t, phase_index))


@dataclasses.dataclass(eq=False)
class GlobalContrib:
    """One phase's per-object benefit contributions to the cross-phase
    global search, with the profile version / registry generation they
    were computed against — the scoped replan's reuse key for the global
    totals.  ``row`` is aligned with ``objs``; full and scoped builds sum
    the same per-phase rows the same way, so reuse keeps the totals
    bitwise identical to a full recompute.  ``cls_row`` (0 = "bw",
    1 = "lat", aligned with ``row``) caches the resolved benefit classes
    for the calibration decomposition; optional — ``None`` on rows from
    scalar-mode builds or pre-cache serialized plans, for which the
    decomposition falls back to the scalar classifier."""

    phase_index: int
    version: Tuple[int, int]
    generation: int
    objs: Tuple[str, ...]
    row: np.ndarray
    cls_row: Optional[np.ndarray] = None


def graph_digest(graph: PhaseGraph) -> tuple:
    """(measured times, per-phase positively-referenced object tuples) —
    everything trigger points and overlap windows are derived from."""
    return (tuple(p.time for p in graph),
            tuple(tuple(o for o, v in p.refs.items() if v > 0.0)
                  for p in graph))


def _fp_hash(names_blob: bytes, mask_bytes: bytes,
             trig: np.ndarray, win: np.ndarray) -> str:
    """Constant-size digest of a phase's per-candidate fingerprint stream:
    candidate names (solve order), the resident/non-resident split, and
    the non-resident trigger points and overlap windows.  Collapsing the
    O(candidates) tuple the fingerprint used to carry into 16 bytes keeps
    decision records O(1) at 100k chunks; both the scalar and the array
    path hash the identical byte stream, so fingerprints stay comparable
    across modes."""
    h = hashlib.blake2b(digest_size=16)
    h.update(names_blob)
    h.update(b"\x00\x01")
    h.update(mask_bytes)
    h.update(b"\x00\x02")
    h.update(np.ascontiguousarray(trig, dtype=np.int64).tobytes())
    h.update(b"\x00\x03")
    h.update(np.ascontiguousarray(win, dtype=np.float64).tobytes())
    return h.hexdigest()


class _Evictables:
    """Prefix-summed evictable residents for one phase's candidate loop:
    answers "how many bytes must leave to fit ``deficit``" in O(log n)
    instead of a fresh sort + scan per candidate."""

    def __init__(self, sizes: List[int]):
        # ``sizes`` already in the canonical (size, name) eviction order
        self._cum: List[int] = []
        acc = 0
        for s in sizes:
            acc += s
            self._cum.append(acc)

    def quote(self, deficit: int) -> Optional[int]:
        """Bytes freed by evicting the minimal prefix covering ``deficit``,
        or None when even evicting everything is not enough."""
        i = bisect.bisect_left(self._cum, deficit)
        if i >= len(self._cum):
            return None
        return self._cum[i]


@dataclasses.dataclass(eq=False)
class _PhaseLayout:
    """One phase's candidate extraction, cached across ticks.

    Everything here is a pure function of (the phase's reference keys,
    the registry generation, which of the phase's parents have profiles)
    — candidate names in solve order, their sizes, the scatter positions
    of each profiled parent's chunks, and (keyed separately on the graph
    digest) the per-candidate trigger points and overlap windows.  An
    intensity-only drift changes none of these, so a scoped re-solve of
    the drifted phase skips straight to benefit scatter + pricing."""

    names_key: tuple                 # digest names tuple validity handle
    n_refs: int
    generation: int
    direct_keys: frozenset
    cands: List[str]
    cand_pos: Dict[str, int]
    sizes: np.ndarray                # int64, aligned with cands
    szf: np.ndarray                  # float64 copy for pricing
    parent_groups: List[Tuple[str, np.ndarray, np.ndarray]]
    direct_cands: List[Tuple[int, str]]
    names_blob: bytes
    digest: Optional[tuple] = None   # digest trig/win were computed under
    trig: Optional[np.ndarray] = None
    win: Optional[np.ndarray] = None


@dataclasses.dataclass(eq=False)
class _GlobalLayout:
    """The cross-phase candidate universe, cached per (digest, generation):
    first-reference order over the graph's objects, sizes, scatter
    positions per profiled parent, and each object's first referencing
    phase (the move fence)."""

    digest: tuple
    generation: int
    objs: List[str]
    objs_t: Tuple[str, ...]
    pos: Dict[str, int]
    sizes: np.ndarray                # int64, aligned with objs
    first_ref: Dict[str, int]
    parent_groups: List[Tuple[str, np.ndarray, np.ndarray]]


def _fractional_ub(values: np.ndarray, sizes: np.ndarray,
                   capacity: int) -> float:
    """LP-relaxation upper bound on the 0/1 knapsack optimum: greedy by
    value density with a fractional last item.  Quantization in the exact
    solver only rounds sizes *up* (shrinking the feasible set), so this
    also bounds the quantized optimum — which makes ``baseline - ub`` a
    certified lower bound on the global plan's predicted time."""
    pos = values > 0.0
    v = values[pos]
    if not len(v) or capacity <= 0:
        return 0.0
    s = sizes[pos].astype(np.float64)
    order = np.argsort(-(v / np.maximum(s, 1.0)))
    v = v[order]
    s = s[order]
    cum = np.cumsum(s)
    k = int(np.searchsorted(cum, float(capacity), side="left"))
    ub = float(v[:k].sum())
    if k < len(v):
        prev = float(cum[k - 1]) if k else 0.0
        ub += float(v[k]) * ((capacity - prev) / s[k])
    return ub


class Planner:
    def __init__(self, machine: MachineProfile, registry: ObjectRegistry,
                 cf: Optional[CalibrationConstants] = None,
                 fast_capacity_bytes: Optional[int] = None,
                 vectorized: bool = True,
                 enact_consistent: bool = False):
        self.machine = machine
        self.registry = registry
        self.cf = cf or CalibrationConstants()
        self.capacity = (fast_capacity_bytes if fast_capacity_bytes is not None
                         else machine.fast.capacity_bytes)
        self.vectorized = vectorized
        # Enactment-consistent drop order for the local solve (multi-res
        # mode): when the knapsack declines a referenced resident that
        # enactment can never actually evict, the selection over-commits
        # the budget and the last-enacted chosen objects are dropped.
        # Legacy enacts size-descending — the smallest chosen go last,
        # which under multi-resolution refinement are exactly the fine
        # hot-head chunks — so this flag switches enactment to
        # benefit-density order (shortfall lands on the coldest chosen
        # bytes).  Off by default: legacy plans stay bit-identical.
        self.enact_consistent = enact_consistent
        # cross-tick caches (all invalidated by the exact inputs they
        # derive from; see the class docstrings)
        self._gen_cache: Optional[_GenCache] = None
        self._view: Optional[_ProfileView] = None
        self._digest_state: Optional[tuple] = None
        self._win_state: Optional[Tuple[tuple, _TriggerIndex]] = None
        self._phase_layouts: Dict[int, _PhaseLayout] = {}
        self._global_layout: Optional[_GlobalLayout] = None
        self._global_memo: Optional[Dict] = None
        self._tier_snapshot: Optional[Set[str]] = None

    # ------------------------------------------------------------ move pricing
    def price_fetch(self, size_bytes: int, overlap_window: float) -> float:
        """Eq. (4) unhidden cost of one slow->fast copy given its overlap
        window — the single pricing authority for *both* searches, so the
        best-of-two chooser always compares cost-inclusive numbers priced
        the same way (a cost-free global estimate against a cost-inclusive
        local one is how the original chooser bug crept in)."""
        cost = perfmodel.movement_cost(size_bytes, self.machine,
                                       overlap_window)
        if self.enact_consistent:
            # churn guard (see _solve_phase): an overlappable copy still
            # spends real copy bandwidth and serves slow until it lands
            cost = max(cost, size_bytes / self.machine.copy_bw)
        return cost * self.cf.cf_move

    def price_eviction(self, size_bytes: int) -> float:
        """Space-clearing demotion: the outgoing copy serializes with the
        incoming one, so its full copy time lands on the critical path.
        Scaled — like :meth:`price_fetch` — by the online-calibrated
        movement-price factor (``cf_move`` is 1.0 until the calibration
        feedback folds a measured stall ratio into it)."""
        return size_bytes / self.machine.copy_bw * self.cf.cf_move

    # ------------------------------------------------------------------ util
    def _gen(self) -> _GenCache:
        c = self._gen_cache
        if (c is None or c.generation != self.registry.generation
                or c.count != len(self.registry)):
            c = self._gen_cache = _GenCache(self.registry)
        return c

    def _profile(self, profiler: PhaseProfiler, phase: int, obj: str):
        p = profiler.profile(phase, obj)
        if p is not None:
            return p
        # Chunk of a partitioned object: scale the parent's profile by the
        # chunk's share of the parent's accesses — measured-histogram mass
        # over the chunk's byte span when per-chunk attribution exists, size
        # fraction otherwise (regular 1-D references, paper §3.2).
        gen = self._gen()
        par = gen.parent_of.get(obj)
        if par is not None:
            pp = profiler.profile(phase, par)
            if pp is not None:
                size = gen.sizes[obj]
                bins = pp.bin_weights
                if self.vectorized:
                    total = gen.span_total(self.registry, par)
                    if bins is None:
                        frac = size / total
                    else:
                        spans = gen.spans(self.registry, par)
                        lo = spans[gen.span_idx(self.registry, par)[obj]][1]
                        frac = bin_mass(bins, lo / total,
                                        (lo + size) / total)
                else:
                    # Frozen pre-optimization reference (like
                    # knapsack.solve_reference): spans are recomputed per
                    # candidate, never amortized — the planner-latency
                    # benchmark's baseline must not inherit the caches it
                    # is measured against.  Same float expressions, so the
                    # oracle plans stay bit-identical.
                    spans = chunk_spans(self.registry, par)
                    total = sum(hi - lo for _, lo, hi in spans) or 1
                    if bins is None:
                        frac = size / total
                    else:
                        lo = next(l for c, l, _ in spans if c.name == obj)
                        frac = bin_mass(bins, lo / total,
                                        (lo + size) / total)
                return dataclasses.replace(
                    pp, obj=obj, data_access=pp.data_access * frac,
                    samples_with_access=max(pp.samples_with_access * frac, 1.0))
        return None

    def _benefit_scalar(self, profiler: PhaseProfiler, phase: int,
                        obj: str) -> float:
        p = self._profile(profiler, phase, obj)
        if p is None:
            return 0.0
        return perfmodel.benefit(p, self.machine, self.cf)

    def _gain_class_scalar(self, profiler: PhaseProfiler, phase: int,
                           obj: str) -> str:
        """Benefit class ("bw" | "lat") a (phase, obj) gain is booked
        under — the calibration feedback's attribution key."""
        p = self._profile(profiler, phase, obj)
        if p is None:
            return "bw"
        return perfmodel.gain_class(p, self.machine, self.cf)

    # kept as the public scalar entry point (tests, legacy mode)
    _benefit = _benefit_scalar

    def _initial_residents(self) -> Set[str]:
        return {o.name for o in self.registry if o.tier == "fast"}

    def _fast_tier(self) -> Set[str]:
        """Current fast-tier names — one registry pass per plan build;
        doubles as the default entry residency and as the complement used
        for "originally slow" membership (every queried name is a registry
        member, so ``o not in fast`` is exactly the legacy
        ``tier != "fast"`` set test).  :meth:`plan` shares one snapshot
        between its two searches (tiers cannot move while planning), so
        the best-of-two pays for a single scan."""
        snap = self._tier_snapshot
        if snap is not None:
            return snap
        return {o.name for o in self.registry if o.tier == "fast"}

    def _entry_residents(self, fast_tier: Set[str]) -> Set[str]:
        """Entry residency, honouring per-instance ``_initial_residents``
        overrides (the bandwidth-partition clamp installs one)."""
        f = self.__dict__.get("_initial_residents")
        if f is not None:
            return set(f())
        if type(self)._initial_residents is not Planner._initial_residents:
            return set(self._initial_residents())
        return set(fast_tier)

    def _solve(self, items, capacity):
        if self.vectorized:
            return knapsack.solve(items, capacity)
        return knapsack.solve_reference(items, capacity)

    def _get_view(self, profiler: PhaseProfiler) -> Optional[_ProfileView]:
        if not self.vectorized:
            return None
        v = self._view
        if (v is None or v.profiler is not profiler
                or v.generation != self.registry.generation
                or v.cf is not self.cf):
            v = self._view = _ProfileView(self, profiler)
        else:
            v.refresh()
        return v

    def _make_view(self, profiler: PhaseProfiler) -> Optional[_ProfileView]:
        return self._get_view(profiler)

    def _graph_digest(self, graph: PhaseGraph,
                      profiler: PhaseProfiler) -> tuple:
        """:func:`graph_digest`, with the per-phase positive-name tuples
        cached by (profile version, registry generation) — the pipeline's
        attribute/partition stages derive each phase's refs from exactly
        those inputs, so an unchanged version pins an unchanged tuple.
        Phases the profiler has never observed (version counters still
        zero — hand-built graphs in tests) are never cached."""
        st = self._digest_state
        if st is None or st[0] is not graph or st[1] is not profiler:
            st = self._digest_state = (graph, profiler, {})
        cache = st[2]
        generation = self.registry.generation
        names: List[tuple] = []
        for p in graph:
            ver = profiler.phase_version(p.index)
            ent = cache.get(p.index)
            if (ent is not None and ent[0] == ver and ent[1] == generation
                    and ver[1:] != (0, 0)):
                names.append(ent[2])
            else:
                t = tuple(o for o, v in p.refs.items() if v > 0.0)
                cache[p.index] = (ver, generation, t)
                names.append(t)
        return (tuple(p.time for p in graph), tuple(names))

    def _windex(self, graph: PhaseGraph, digest: tuple) -> _TriggerIndex:
        ws = self._win_state
        if ws is not None and ws[0] == digest:
            return ws[1]
        w = _TriggerIndex(graph)
        self._win_state = (digest, w)
        return w

    # ----------------------------------------------------------- local search
    def _phase_candidates(self, profiler: PhaseProfiler, ph
                          ) -> Tuple[List[str], List[str]]:
        """Registry-present references and knapsack candidates of a phase,
        *without* computing any benefits (a reused phase never pays for
        them).  Matches the view/scalar profile-existence conditions: a
        candidate has a direct profile or a profiled parent."""
        in_reg = [o for o in ph.refs if o in self.registry]
        cands: List[str] = []
        for o in in_reg:
            dob = self.registry[o]
            if dob.pinned:
                continue
            if profiler.profile(ph.index, o) is not None:
                cands.append(o)
            elif (dob.parent is not None
                  and profiler.profile(ph.index, dob.parent) is not None):
                cands.append(o)
        return in_reg, cands

    def _phase_fingerprint(self, profiler: PhaseProfiler, ph,
                           cands: Sequence[str],
                           windows: Dict[str, Tuple[int, float]]) -> tuple:
        """Everything the phase's solve reads besides the entry residency,
        compressed to an exact reuse key ``(profile version, registry
        generation, blake2b over the candidate stream)``:

        * ``profiler.phase_version`` — identifies the phase's accumulated
          profile state, which determines its refs (the attribute stage
          derives them from profiles), its candidates and their benefits;
        * ``registry.generation`` — identifies the chunk registry shape
          (sizes, parents, pinned flags are immutable per name);
        * the hashed stream — candidate names in solve order, the
          resident/non-resident split, and per-candidate trigger points
          and overlap windows (the coupling to *other* phases' measured
          times and reference sets).  Windows are recorded only for the
          candidates the solve actually reads them for (the non-resident
          ones: ``windows`` omits residents) — a reuse check only
          compares fingerprints after the entry residency matched, so the
          resident split is identical on both sides.

        Precondition (the pipeline's attribute/partition stages): the
        graph's refs/times are derived from the profiler state, never
        hand-mutated between builds."""
        names_blob = "\x00".join(cands).encode("utf-8")
        mask = bytes(bytearray(0 if o in windows else 1 for o in cands))
        nr = [o for o in cands if o in windows]
        trig = np.array([windows[o][0] for o in nr], dtype=np.int64)
        win = np.array([windows[o][1] for o in nr], dtype=np.float64)
        return (profiler.phase_version(ph.index), self.registry.generation,
                _fp_hash(names_blob, mask, trig, win))

    def _phase_layout(self, graph: PhaseGraph, ph, gen: _GenCache,
                      view: _ProfileView, digest: tuple,
                      names_key: tuple) -> _PhaseLayout:
        """Cached candidate extraction for one phase (see
        :class:`_PhaseLayout`); rebuilds only when the phase's reference
        keys, the registry generation or the set of profiled parents
        changed, and refreshes the trigger/window arrays only when the
        graph digest moved."""
        direct = view.direct(ph.index)
        dkeys = frozenset(direct)
        lay = self._phase_layouts.get(ph.index)
        if (lay is None or lay.generation != gen.generation
                or lay.n_refs != len(ph.refs)
                or lay.names_key != names_key
                or lay.direct_keys != dkeys):
            reg = self.registry
            sizes_d = gen.sizes
            pinned = gen.pinned
            parent_of = gen.parent_of
            cands: List[str] = []
            cand_pos: Dict[str, int] = {}
            sizes: List[int] = []
            pgroups: Dict[str, Tuple[List[int], List[int]]] = {}
            direct_cands: List[Tuple[int, str]] = []
            for o in ph.refs:
                sz = sizes_d.get(o)
                if sz is None or o in pinned:
                    continue
                if o in direct:
                    par = None
                else:
                    par = parent_of.get(o)
                    if par is None or par not in direct:
                        continue
                i = len(cands)
                if par is None:
                    direct_cands.append((i, o))
                else:
                    g = pgroups.get(par)
                    if g is None:
                        g = pgroups[par] = ([], [])
                    g[0].append(i)
                    g[1].append(gen.span_idx(reg, par)[o])
                cand_pos[o] = i
                cands.append(o)
                sizes.append(sz)
            sz_arr = np.asarray(sizes, dtype=np.int64)
            lay = _PhaseLayout(
                names_key=names_key, n_refs=len(ph.refs),
                generation=gen.generation, direct_keys=dkeys,
                cands=cands, cand_pos=cand_pos, sizes=sz_arr,
                szf=sz_arr.astype(np.float64),
                parent_groups=[(par, np.asarray(ix, dtype=np.int64),
                                np.asarray(si, dtype=np.int64))
                               for par, (ix, si) in pgroups.items()],
                direct_cands=direct_cands,
                names_blob="\x00".join(cands).encode("utf-8"))
            self._phase_layouts[ph.index] = lay
        if lay.digest != digest:
            windex = self._windex(graph, digest)
            refs_of = windex._refs
            trigs: List[int] = []
            last = _MISSING
            last_t = 0
            for o in lay.cands:
                r = refs_of.get(o)
                if r is last and r is not None:
                    t = last_t
                else:
                    t = windex._trig(r, ph.index)
                    last, last_t = r, t
                trigs.append(t)
            wmemo: Dict[int, float] = {}
            wins: List[float] = []
            for t in trigs:
                w = wmemo.get(t)
                if w is None:
                    w = wmemo[t] = windex.window(t, ph.index)
                wins.append(w)
            lay.trig = np.asarray(trigs, dtype=np.int64)
            lay.win = np.asarray(wins, dtype=np.float64)
            lay.digest = digest
        return lay

    def _layout_benefits(self, view: _ProfileView, phase: int,
                         lay: _PhaseLayout) -> np.ndarray:
        """Eq. (1)-(3) benefit of every layout candidate, scattered from
        the view's per-parent blocks (one ``benefit_batch`` per profiled
        parent) plus one batch over the direct-profile candidates —
        elementwise identical to the per-candidate scalar path."""
        bft = np.zeros(len(lay.cands), dtype=np.float64)
        direct = view.direct(phase)
        for par, positions, span_idx in lay.parent_groups:
            blk = view._pblock(phase, par)
            if blk is not None:
                bft[positions] = blk[0][span_idx]
        if lay.direct_cands:
            dpos = [i for i, _ in lay.direct_cands]
            profs = [direct[o] for _, o in lay.direct_cands]
            cols = np.array(
                [(p.data_access, p.n_samples, p.samples_with_access,
                  p.phase_time, p.cacheline_bytes) for p in profs],
                dtype=np.float64)
            bft[dpos] = perfmodel.benefit_batch(
                cols[:, 0], cols[:, 1], cols[:, 2], cols[:, 3], cols[:, 4],
                self.machine, self.cf)
        return bft

    def _entry_shed(self, graph: PhaseGraph, residents: Set[str],
                    resident_bytes: int
                    ) -> Tuple[Set[str], int, List[MoveOp]]:
        """Entry-residency reconciliation: when the entry residency
        overshoots the fast-tier budget (a mid-rotation rebuild after the
        capacity shrank, or partially-enacted moves), shed the
        lowest-traffic unpinned residents at phase 0 until the plan starts
        within budget — the aggregate-path mirror of the
        bandwidth-partition entry clamp, priced through the same
        Eq. (4) eviction authority as every other demotion.  Deterministic
        (traffic then name), so scoped and full replans shed
        identically."""
        over = resident_bytes - self.capacity
        if over <= 0:
            return residents, resident_bytes, []
        gen = self._gen()
        traffic: Dict[str, float] = {}
        for o in residents:
            t = 0.0
            for p in graph:
                t += p.refs.get(o, 0.0)
            traffic[o] = t
        moves: List[MoveOp] = []
        for o in sorted(residents, key=lambda o: (traffic[o], o)):
            if resident_bytes <= self.capacity:
                break
            if o in gen.pinned:
                continue
            size = gen.sizes[o]
            residents.discard(o)
            resident_bytes -= size
            moves.append(MoveOp(o, "slow", 0, 0, size,
                                self.price_eviction(size)))
        return residents, resident_bytes, moves

    def _solve_phase(self, ph, cands, bft_of, windows,
                     entry_residents: Set[str], entry_bytes: int):
        """One phase's knapsack + enactment against the entry residency
        (the scalar oracle path).  Returns (exit_residents, exit_bytes,
        moves)."""
        size = lambda o: self.registry[o].size_bytes
        residents = set(entry_residents)
        resident_bytes = entry_bytes
        free = self.capacity - resident_bytes
        # deterministic tie-break by name: hash-order of the residents
        # set must never leak into the plan
        evict_order = sorted(
            (r for r in residents
             if r not in ph.refs and not self.registry[r].pinned),
            key=lambda r: (size(r), r))
        evictables = _Evictables([size(r) for r in evict_order])
        items: List[knapsack.Item] = []
        meta: Dict[str, Dict] = {}
        for o in cands:
            bft = bft_of(o)
            if o in residents:
                # already resident: keeping it costs nothing
                items.append(knapsack.Item(o, bft, size(o)))
                meta[o] = dict(cost=0.0, extra=0.0, resident=True, bft=bft)
                continue
            overlap = windows[o][1]
            cost = self.price_fetch(size(o), overlap)
            extra = 0.0
            deficit = size(o) - free
            if deficit > 0:
                # Space frees only when the evictee is dropped at this
                # phase's start -> the incoming copy cannot overlap
                # earlier phases (paper Fig 6: movement respects the
                # availability of DRAM space).
                cost = self.price_fetch(size(o), 0.0)
                evict_bytes = evictables.quote(deficit)
                if evict_bytes is None:
                    continue   # cannot fit even with evictions
                extra = self.price_eviction(evict_bytes)
            w = perfmodel.weight(bft, cost, extra)
            items.append(knapsack.Item(o, w, size(o)))
            meta[o] = dict(cost=cost, extra=extra, resident=False, bft=bft)

        chosen = set(self._solve(items, self.capacity))
        return self._enact_phase(ph, chosen,
                                 {o: (m["cost"], m["bft"])
                                  for o, m in meta.items()},
                                 lambda o: windows[o][0],
                                 residents, resident_bytes)

    def _solve_phase_arrays(self, ph, lay: _PhaseLayout, bft: np.ndarray,
                            res_mask: np.ndarray,
                            entry_residents: Set[str], entry_bytes: int):
        """The array-program :meth:`_solve_phase`: candidate pricing,
        eviction quoting and feasibility masking as elementwise numpy over
        the cached layout, then the array knapsack.  Bit-identical plans:
        the same float expressions evaluated elementwise, candidates in
        the same order (infeasible ones masked, order preserved), and the
        same enactment loop."""
        gen = self._gen()
        sizes_d = gen.sizes
        residents = set(entry_residents)
        resident_bytes = entry_bytes
        free = self.capacity - resident_bytes
        refs = ph.refs
        evict_order = sorted(
            (r for r in residents
             if r not in refs and r not in gen.pinned),
            key=lambda r: (sizes_d[r], r))
        cum = np.cumsum(np.fromiter((sizes_d[r] for r in evict_order),
                                    dtype=np.int64, count=len(evict_order)))
        copy_bw = self.machine.copy_bw
        cfm = self.cf.cf_move
        base = lay.szf / copy_bw
        cost = perfmodel.movement_cost_batch(lay.szf, self.machine, lay.win)
        # deficit candidates cannot overlap earlier phases (space frees at
        # the phase itself): their cost is the zero-window price
        cost0 = np.maximum(base, 0.0)
        if self.enact_consistent:
            cost = np.maximum(cost, base)
        cost = cost * cfm
        cost0 = cost0 * cfm
        deficit = lay.sizes - free
        needs = (deficit > 0) & ~res_mask
        extra = np.zeros(len(lay.cands), dtype=np.float64)
        feasible = np.ones(len(lay.cands), dtype=bool)
        if needs.any():
            if len(cum):
                idx = np.searchsorted(cum, deficit[needs], side="left")
                ok = idx < len(cum)
                quote = cum[np.minimum(idx, len(cum) - 1)]
                feasible[needs] = ok
                extra[needs] = np.where(ok, quote / copy_bw * cfm, 0.0)
            else:
                feasible[needs] = False
        cost_eff = np.where(needs, cost0, cost)
        value = np.where(res_mask, bft, (bft - cost_eff) - extra)
        cost_eff = np.where(res_mask, 0.0, cost_eff)
        kept = np.flatnonzero(feasible)
        sel = knapsack.solve_arrays(value[kept], lay.sizes[kept],
                                    self.capacity)
        cands = lay.cands
        chosen: Set[str] = set()
        meta: Dict[str, Tuple[float, float]] = {}
        for i in kept[sel]:
            i = int(i)
            o = cands[i]
            chosen.add(o)
            meta[o] = (float(cost_eff[i]), float(bft[i]))
        trig_arr = lay.trig
        cand_pos = lay.cand_pos
        return self._enact_phase(ph, chosen, meta,
                                 lambda o: int(trig_arr[cand_pos[o]]),
                                 residents, resident_bytes)

    def _enact_phase(self, ph, chosen: Set[str],
                     meta: Dict[str, Tuple[float, float]], trig_of,
                     residents: Set[str], resident_bytes: int):
        """Enactment shared by both solve paths.  The order decides which
        chosen objects lose out when the knapsack's selection cannot fully
        materialize (it may decline a referenced resident — e.g. a
        phase's working buffer — that the mover can never actually evict,
        leaving less room than the solve assumed).  The legacy order is
        size-descending, which enacts the *smallest* chosen last — under
        multi-resolution refinement those are exactly the fine hot-head
        chunks, so ``enact_consistent`` switches to benefit-density
        order: any shortfall then drops the coldest chosen bytes instead
        of the hottest."""
        size = lambda o: self.registry[o].size_bytes
        if self.enact_consistent:
            order = sorted(chosen, key=lambda o: (
                -meta[o][1] / max(size(o), 1), o))
        else:
            order = sorted(chosen, key=lambda o: (-size(o), o))
        moves: List[MoveOp] = []
        # Enact: move chosen non-residents in, evicting just enough.
        for o in order:
            if o in residents:
                continue
            needed_evict = False
            deficit = size(o) - (self.capacity - resident_bytes)
            if deficit > 0:
                needed_evict = True
                evictable = sorted(
                    (r for r in residents
                     if r not in ph.refs and r not in chosen
                     and not self.registry[r].pinned),
                    key=lambda r: (size(r), r))
                freed = 0
                for r in evictable:
                    if freed >= deficit:
                        break
                    residents.discard(r)
                    resident_bytes -= size(r)
                    freed += size(r)
                    moves.append(MoveOp(r, "slow", ph.index, ph.index,
                                        size(r),
                                        self.price_eviction(size(r))))
                if freed < deficit:
                    # Cannot fit even after evicting everything allowed:
                    # skip the object but *keep* the evictions — they act
                    # as early space-clearing for the next phases' moves,
                    # and dropping them measurably regresses the chunked
                    # scenario workloads (graph_chase 1.32 -> 1.44
                    # normalized) even though the Eq.(4)/(5) model books
                    # them as pure cost.
                    continue
            # Eviction serializes with the incoming copy: trigger at the
            # phase itself (space is only free then).
            trig = (ph.index if needed_evict else trig_of(o))
            cost, bft = meta[o]
            moves.append(MoveOp(o, "fast", trig, ph.index, size(o),
                                cost, est_benefit=bft))
            residents.add(o)
            resident_bytes += size(o)
        return residents, resident_bytes, tuple(moves)

    def _placement_benefits(self, profiler: PhaseProfiler,
                            view: Optional[_ProfileView], phase_index: int,
                            placement: Set[str]
                            ) -> Tuple[Dict[str, float], Dict[str, str]]:
        """Eq. (1)-(3) benefit (and resolved class, for every non-zero
        benefit) of every placed object, batch-ensured — the
        predicted-time inputs cached on the phase's decision."""
        if view is not None:
            view.ensure(phase_index, list(placement))
            bmap = {o: view.benefit(phase_index, o) for o in placement}
            cmap = {o: view.gain_class(phase_index, o)
                    for o, b in bmap.items() if b != 0.0}
        else:
            bmap = {o: self._benefit_scalar(profiler, phase_index, o)
                    for o in placement}
            cmap = {o: self._gain_class_scalar(profiler, phase_index, o)
                    for o, b in bmap.items() if b != 0.0}
        return bmap, cmap

    def plan_local(self, graph: PhaseGraph, profiler: PhaseProfiler, *,
                   standing: Optional[Sequence[PhaseDecision]] = None,
                   standing_digest: Optional[tuple] = None
                   ) -> PlacementPlan:
        """Phase-local search.  With ``standing`` (the previous plan's
        :class:`PhaseDecision` list), phases whose entry state and input
        fingerprint still match reuse the cached decision without
        re-solving — the scoped replan path (plans are equal to a full
        replan by construction).

        ``standing_digest`` (the previous plan's ``graph_digest``) enables
        the fast path: when the graph's measured times and reference sets
        are unchanged, every trigger point and overlap window is provably
        unchanged too, so reuse checks reduce to (profile version, registry
        generation, entry residency) and skip per-candidate window
        computation entirely."""
        view = self._get_view(profiler)
        gen = self._gen()
        generation = gen.generation
        digest = self._graph_digest(graph, profiler)
        windex: Optional[_TriggerIndex] = None  # built on first slow-path use
        windows_static = standing is not None and standing_digest == digest
        fast_tier = self._fast_tier()
        residents = self._entry_residents(fast_tier)
        resident_bytes = sum(gen.sizes[o] for o in residents)
        residents, resident_bytes, moves = self._entry_shed(
            graph, residents, resident_bytes)
        placements: List[Set[str]] = []
        decisions: List[PhaseDecision] = []
        bmaps: List[Optional[Dict[str, float]]] = []
        cmaps: List[Optional[Dict[str, str]]] = []

        for ph in graph:
            ver = profiler.phase_version(ph.index)
            d: Optional[PhaseDecision] = None
            bmap: Optional[Dict[str, float]] = None
            cmap: Optional[Dict[str, str]] = None
            s = (standing[ph.index]
                 if standing is not None and ph.index < len(standing)
                 else None)
            if (windows_static and s is not None
                    and s.entry_residents == residents
                    and s.entry_bytes == resident_bytes
                    and s.fingerprint[:2] == (ver, generation)):
                # fast path: unchanged graph digest ⇒ unchanged windows ⇒
                # the full fingerprint would match too
                d = dataclasses.replace(s, reused=True)
            if d is None and view is not None:
                lay = self._phase_layout(graph, ph, gen, view, digest,
                                         digest[1][ph.index])
                res_mask = np.zeros(len(lay.cands), dtype=bool)
                cand_pos = lay.cand_pos
                for r in residents:
                    i = cand_pos.get(r)
                    if i is not None:
                        res_mask[i] = True
                nonres = ~res_mask
                fp = (ver, generation,
                      _fp_hash(lay.names_blob,
                               res_mask.astype(np.uint8).tobytes(),
                               lay.trig[nonres], lay.win[nonres]))
                if (s is not None and s.entry_residents == residents
                        and s.entry_bytes == resident_bytes
                        and s.fingerprint == fp):
                    d = dataclasses.replace(s, reused=True)
                else:
                    bft = self._layout_benefits(view, ph.index, lay)
                    exit_res, exit_bytes, ph_moves = self._solve_phase_arrays(
                        ph, lay, bft, res_mask, residents, resident_bytes)
                    bmap, cmap = self._placement_benefits(
                        profiler, view, ph.index, exit_res)
                    d = PhaseDecision(
                        phase_index=ph.index,
                        entry_residents=frozenset(residents),
                        entry_bytes=resident_bytes, fingerprint=fp,
                        moves=ph_moves, exit_residents=frozenset(exit_res),
                        exit_bytes=exit_bytes, benefits=bmap, classes=cmap)
            elif d is None:
                in_reg, cands = self._phase_candidates(profiler, ph)
                if windex is None:
                    windex = self._windex(graph, digest)
                windows = {o: windex.pair(o, ph.index) for o in cands
                           if o not in residents}
                fp = self._phase_fingerprint(profiler, ph, cands, windows)
                if (s is not None and s.entry_residents == residents
                        and s.entry_bytes == resident_bytes
                        and s.fingerprint == fp):
                    d = dataclasses.replace(s, reused=True)
                else:
                    bft_of = lambda o: self._benefit_scalar(
                        profiler, ph.index, o)
                    exit_res, exit_bytes, ph_moves = self._solve_phase(
                        ph, cands, bft_of, windows, residents,
                        resident_bytes)
                    bmap, cmap = self._placement_benefits(
                        profiler, None, ph.index, exit_res)
                    d = PhaseDecision(
                        phase_index=ph.index,
                        entry_residents=frozenset(residents),
                        entry_bytes=resident_bytes, fingerprint=fp,
                        moves=ph_moves, exit_residents=frozenset(exit_res),
                        exit_bytes=exit_bytes, benefits=bmap, classes=cmap)
            if bmap is None:
                bmap = d.benefits
                cmap = d.classes
            moves.extend(d.moves)
            residents = set(d.exit_residents)
            resident_bytes = d.exit_bytes
            placements.append(set(d.exit_residents))
            decisions.append(d)
            bmaps.append(bmap)
            cmaps.append(cmap)

        # Predicted steady-state iteration time: baseline minus the realized
        # per-phase benefits of everything resident (that profiling saw in
        # the slow tier), plus the unhidden movement/eviction costs.
        # Benefit values come from each decision's cache (batch-ensured at
        # solve time; bitwise-reproducible, so reuse cannot change them).
        predicted = graph.iteration_time()
        gain_bw = [0.0] * len(graph)
        gain_lat = [0.0] * len(graph)
        cls_of = ((lambda i, o: view.gain_class(i, o)) if view is not None
                  else (lambda i, o: self._gain_class_scalar(profiler, i, o)))
        for ph in graph:
            bmap = bmaps[ph.index]
            cmap = cmaps[ph.index]
            if bmap is None:    # decision from a pre-cache serialized plan
                bmap, cmap = self._placement_benefits(
                    profiler, view, ph.index, placements[ph.index])
            for o in sorted(placements[ph.index]):   # fixed fp-sum order
                if o not in fast_tier:
                    g = bmap[o]
                    predicted -= g
                    if g != 0.0:
                        c = cmap.get(o) if cmap is not None else None
                        if c is None:
                            c = cls_of(ph.index, o)
                        if c == "lat":
                            gain_lat[ph.index] += g
                        else:
                            gain_bw[ph.index] += g
        predicted += sum(m.est_unhidden_cost for m in moves)
        return PlacementPlan("local", placements, moves,
                             max(predicted, 0.0), graph.iteration_time(),
                             emit_schedule(moves, graph, self.machine.copy_bw),
                             phase_decisions=decisions,
                             graph_digest=digest,
                             phase_baseline=[p.time for p in graph],
                             phase_gain_bw=gain_bw, phase_gain_lat=gain_lat)

    # ---------------------------------------------------------- global search
    def _global_layout_for(self, graph: PhaseGraph, digest: tuple,
                           gen: _GenCache) -> _GlobalLayout:
        gl = self._global_layout
        if (gl is not None and gl.generation == gen.generation
                and gl.digest == digest):
            return gl
        reg = self.registry
        sizes_d = gen.sizes
        pinned = gen.pinned
        parent_of = gen.parent_of
        first_ref: Dict[str, int] = {}
        objs: List[str] = []
        pos: Dict[str, int] = {}
        sizes: List[int] = []
        pgroups: Dict[str, Tuple[List[int], List[int]]] = {}
        for p in graph:
            for o in p.refs:
                if o in first_ref:
                    continue
                first_ref[o] = p.index
                sz = sizes_d.get(o)
                if sz is None or o in pinned:
                    continue
                i = len(objs)
                pos[o] = i
                objs.append(o)
                sizes.append(sz)
                par = parent_of.get(o)
                if par is not None:
                    g = pgroups.get(par)
                    if g is None:
                        g = pgroups[par] = ([], [])
                    g[0].append(i)
                    g[1].append(gen.span_idx(reg, par)[o])
        gl = _GlobalLayout(
            digest=digest, generation=gen.generation, objs=objs,
            objs_t=tuple(objs), pos=pos,
            sizes=np.asarray(sizes, dtype=np.int64), first_ref=first_ref,
            parent_groups=[(par, np.asarray(ix, dtype=np.int64),
                            np.asarray(si, dtype=np.int64))
                           for par, (ix, si) in pgroups.items()])
        self._global_layout = gl
        return gl

    def _global_row(self, view: _ProfileView, phase: int,
                    glay: _GlobalLayout) -> Tuple[np.ndarray, np.ndarray]:
        """One phase's benefit (and class) row over the global candidate
        universe, scattered from the view's per-parent blocks with direct
        profiles overriding (exactly the view's per-object precedence)."""
        nobj = len(glay.objs)
        row = np.zeros(nobj, dtype=np.float64)
        cls = np.zeros(nobj, dtype=np.uint8)
        direct = view.direct(phase)
        for par, positions, span_idx in glay.parent_groups:
            if par not in direct:
                continue
            blk = view._pblock(phase, par)
            row[positions] = blk[0][span_idx]
            cls[positions] = blk[1][span_idx]
        dpos: List[int] = []
        dprof: List = []
        pos = glay.pos
        for o, prof in direct.items():
            i = pos.get(o)
            if i is not None:
                dpos.append(i)
                dprof.append(prof)
        if dprof:
            cols = np.array(
                [(p.data_access, p.n_samples, p.samples_with_access,
                  p.phase_time, p.cacheline_bytes) for p in dprof],
                dtype=np.float64)
            vals, cl = perfmodel.benefit_batch(
                cols[:, 0], cols[:, 1], cols[:, 2], cols[:, 3], cols[:, 4],
                self.machine, self.cf, return_class=True)
            row[dpos] = vals
            cls[dpos] = cl
        return row, cls

    def plan_global(self, graph: PhaseGraph, profiler: PhaseProfiler, *,
                    standing_global: Optional[Sequence[GlobalContrib]] = None,
                    prune_above: Optional[float] = None
                    ) -> PlacementPlan:
        """Cross-phase global search.  With ``standing_global`` (the
        previous plan's per-phase benefit contributions), phases whose
        profile version and registry generation still match reuse their
        recorded contributions — the totals are summed in phase order
        either way, so the result is bitwise identical to a full
        recompute.  A zero-drift rebuild (same versions, generation,
        entry residency, capacity and calibration) returns the memoized
        decision outright (``global_mode="reused"``).

        ``prune_above`` (the local plan's predicted time) arms the
        dominance bound: when ``baseline - UB > prune_above`` for a
        fractional-knapsack upper bound UB on the attainable gains, the
        global plan provably cannot win the best-of-two and the solve is
        skipped (``global_mode="pruned"``); the returned plan's predicted
        time is then the certified lower bound ``baseline - UB``, which
        keeps the chooser's pick identical to a full solve (the chooser
        prefers local on ties, and the bound only fires when local wins
        strictly)."""
        view = self._get_view(profiler)
        gen = self._gen()
        generation = gen.generation
        n = len(graph)
        digest = self._graph_digest(graph, profiler)
        glay = self._global_layout_for(graph, digest, gen)
        objs = glay.objs
        objs_t = glay.objs_t
        versions = tuple(profiler.phase_version(p.index) for p in graph)
        fast_tier = self._fast_tier()
        residents0 = self._entry_residents(fast_tier)
        memo_key = (digest, generation, versions, frozenset(residents0),
                    self.capacity,
                    (self.cf.cf_bw, self.cf.cf_lat, self.cf.cf_move),
                    self.vectorized, self.enact_consistent)
        memo = self._global_memo
        if (memo is not None and memo["profiler"] is profiler
                and memo["key"] == memo_key):
            return PlacementPlan(
                "global", [set(memo["chosen"])] * n, list(memo["moves"]),
                memo["predicted"], memo["baseline"], list(memo["schedule"]),
                global_contribs=list(memo["contribs"]),
                phase_baseline=list(memo["phase_baseline"]),
                phase_gain_bw=list(memo["gain_bw"]),
                phase_gain_lat=list(memo["gain_lat"]),
                global_mode="reused", global_rows_reused=n)

        contribs_out: List[GlobalContrib] = []
        rows_reused = 0
        for p in graph:
            version = versions[p.index]
            row: Optional[np.ndarray] = None
            cls_row: Optional[np.ndarray] = None
            if standing_global is not None and p.index < len(standing_global):
                g = standing_global[p.index]
                if (g.version == version and g.generation == generation
                        and (g.objs is objs_t or g.objs == objs_t)):
                    row = g.row
                    cls_row = g.cls_row
                    rows_reused += 1
            if row is None:
                if view is not None:
                    row, cls_row = self._global_row(view, p.index, glay)
                else:
                    row = np.asarray(
                        [self._benefit_scalar(profiler, p.index, o)
                         for o in objs], dtype=np.float64)
            contribs_out.append(GlobalContrib(
                phase_index=p.index, version=version,
                generation=generation, objs=objs_t, row=row,
                cls_row=cls_row))
        if contribs_out and objs:
            totals_vec = np.vstack([g.row for g in contribs_out]).sum(axis=0)
        else:
            totals_vec = np.zeros(len(objs))
        baseline = graph.iteration_time()

        if prune_above is not None and len(objs):
            # Dominance bound: predicted_global >= baseline - V* >= lb for
            # any selection (the knapsack never picks non-positive values;
            # move/eviction costs only add; the final max(.., 0) only
            # raises).  The strict relative margin keeps float noise in
            # the bound from ever flipping a tie — the chooser prefers
            # local on exact ties, so pruning must fire only when local
            # wins outright.
            lb = baseline - _fractional_ub(totals_vec, glay.sizes,
                                           self.capacity)
            if lb > prune_above + 1e-9 * max(1.0, abs(prune_above)):
                return PlacementPlan(
                    "global", [set(residents0)] * n, [], float(lb),
                    baseline, [], global_contribs=contribs_out,
                    phase_baseline=[p.time for p in graph],
                    phase_gain_bw=[0.0] * n, phase_gain_lat=[0.0] * n,
                    global_mode="pruned", global_rows_reused=rows_reused)

        if self.vectorized:
            sel = knapsack.solve_arrays(totals_vec, glay.sizes, self.capacity)
            chosen = {objs[int(i)] for i in sel}
        else:
            items = [knapsack.Item(o, float(totals_vec[i]), gen.sizes[o])
                     for i, o in enumerate(objs)]
            chosen = set(knapsack.solve_reference(items, self.capacity))

        moves: List[MoveOp] = []
        predicted = baseline
        pos = glay.pos
        first_ref = glay.first_ref
        sizes_d = gen.sizes
        for o in sorted(residents0 - chosen):   # deterministic move order
            moves.append(MoveOp(o, "slow", 0, 0, sizes_d[o],
                                self.price_eviction(sizes_d[o])))
        windex = self._windex(graph, digest)
        for o in sorted(chosen, key=lambda o: (first_ref.get(o, 0), o)):
            val = float(totals_vec[pos[o]])
            if o not in fast_tier:
                predicted -= val
            if o not in residents0:
                # One-time move, dispatched at iteration start and fenced at
                # the object's first use so it overlaps the leading phases
                # (this is what makes the paper's Table-4 overlap percentages
                # non-zero for global placements).  Priced through the same
                # Eq. (4) helper as the local search — the overlap window is
                # the compute between dispatch and the fence — so the
                # best-of-two chooser compares cost-inclusive numbers on
                # both sides.
                fence = first_ref.get(o, 0)
                window = windex.window(0, fence)
                moves.append(MoveOp(o, "fast", 0, fence, sizes_d[o],
                                    self.price_fetch(sizes_d[o], window),
                                    est_benefit=val))
        predicted += sum(m.est_unhidden_cost for m in moves)
        # Per-phase gain decomposition for the calibration feedback: the
        # chosen slow objects' per-phase contributions, split by benefit
        # class (the per-object totals the knapsack saw are these same
        # rows summed over phases).
        gain_bw = [0.0] * n
        gain_lat = [0.0] * n
        cls_of = ((lambda i, o: view.gain_class(i, o)) if view is not None
                  else (lambda i, o: self._gain_class_scalar(profiler, i, o)))
        chosen_slow = sorted(pos[o] for o in chosen if o not in fast_tier)
        for g in contribs_out:
            cr = g.cls_row
            for i in chosen_slow:
                v = float(g.row[i])
                if v != 0.0:
                    lat = (bool(cr[i]) if cr is not None
                           else cls_of(g.phase_index, objs[i]) == "lat")
                    if lat:
                        gain_lat[g.phase_index] += v
                    else:
                        gain_bw[g.phase_index] += v
        predicted = max(predicted, 0.0)
        schedule = emit_schedule(moves, graph, self.machine.copy_bw)
        self._global_memo = dict(
            profiler=profiler, key=memo_key, chosen=frozenset(chosen),
            moves=list(moves), predicted=predicted, baseline=baseline,
            schedule=schedule, contribs=contribs_out,
            phase_baseline=[p.time for p in graph],
            gain_bw=gain_bw, gain_lat=gain_lat)
        return PlacementPlan("global", [set(chosen)] * n, moves,
                             predicted, baseline, schedule,
                             global_contribs=contribs_out,
                             phase_baseline=[p.time for p in graph],
                             phase_gain_bw=gain_bw, phase_gain_lat=gain_lat,
                             global_rows_reused=rows_reused)

    # ----------------------------------------------------------- best of two
    def plan(self, graph: PhaseGraph, profiler: PhaseProfiler, *,
             standing: Optional[Sequence[PhaseDecision]] = None,
             standing_global: Optional[Sequence[GlobalContrib]] = None,
             standing_digest: Optional[tuple] = None) -> PlacementPlan:
        self._tier_snapshot = self._fast_tier()
        try:
            local = self.plan_local(graph, profiler, standing=standing,
                                    standing_digest=standing_digest)
            glob = self.plan_global(
                graph, profiler, standing_global=standing_global,
                prune_above=local.predicted_iteration_time)
        finally:
            self._tier_snapshot = None
        chosen = (local
                  if local.predicted_iteration_time
                  < glob.predicted_iteration_time else glob)
        chosen.global_mode = glob.global_mode
        chosen.global_rows_reused = glob.global_rows_reused
        return chosen
