"""Placement-policy pipeline: a declarative Plan IR with pluggable stages.

The paper's runtime separates *characterizing* memory access from
*deciding* placement from *executing* moves (§3); PR 3 gave the first and
third their own pluggable layers (``InstrumentationSource``, the copy
backend registry).  This module does the same for the decision layer:
planning is a **pipeline of five registered stages**, each an
independently testable transform over (profiles, chunk registry, tier
state):

====================  =====================================================
``attribute``         write measured phase times + per-object access counts
                      into the phase graph (``PhaseProfiler.annotate_graph``)
``partition``         split oversized chunkable objects along the measured
                      access CDF and re-attribute references to chunks
                      (``partition.auto_partition`` / ``resplit_refs``);
                      optionally snap cuts to pytree leaf boundaries
``coalesce``          re-merge adjacent chunks whose measured densities
                      converged and whose tiers agree — caps registry
                      growth across drift sequences
                      (``partition.coalesce_chunks``)
``solve``             best-of-two knapsack search (phase-local /
                      cross-phase-global), scoped to the phases whose
                      inputs changed when a standing program is available
``schedule``          annotate every move with its copy window, duration
                      and slack (``planner.emit_schedule``)
====================  =====================================================

The pipeline's product is a :class:`PlanProgram` — an explicit,
JSON-serializable intermediate representation that carries the per-phase
residency sets, the move intents with slack deadlines, the per-phase
solve records (the standing state scoped replans re-solve against), and
the *provenance* of every stage run (which profile epoch and chunk
generation produced each decision).  ``PlanProgram`` subsumes
:class:`~.planner.PlacementPlan`'s query surface, so the movers consume
the IR directly.

Policies are selected by name through a string-keyed registry mirroring
:mod:`.backends` (``RuntimeConfig.policy = "unimem"`` →
:func:`make_policy`); a custom policy registers a factory with
:func:`register_policy` and may reuse, reorder, or replace any stage.

**Scoped replanning** falls out of the IR: when a standing program is
passed back into the solve stage, phases whose entry residency and input
fingerprint still match reuse their recorded decision without re-solving
(see :class:`~.planner.PhaseDecision`), so responding to a localized
drift costs O(affected phases' knapsacks) instead of O(plan) — and the
result is provably equal to a full replan, because any phase whose
inputs changed in any way fails the fingerprint match.
"""

from __future__ import annotations

import dataclasses
import json
from typing import (Any, Callable, Dict, List, Optional, Protocol, Sequence,
                    Tuple, runtime_checkable)

from . import partition as partition_mod
from . import tenancy as tenancy_mod
from .data_objects import ObjectRegistry
from .phase import Phase, PhaseGraph
from .planner import (GlobalContrib, MoveOp, PhaseDecision, PlacementPlan,
                      Planner, ScheduledMove, emit_schedule)
from .profiler import PhaseProfiler
from .tenancy import TenantSpec, tenant_of
from .tiers import MachineProfile

#: canonical stage order of the unimem pipeline
STAGE_NAMES = ("attribute", "partition", "coalesce", "solve", "schedule")


# ---------------------------------------------------------------------------
# IR
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class StageProvenance:
    """One pipeline stage run: what transformed the state, and against
    which profile epoch / registry chunk generation / histogram resolution
    epoch it ran."""

    stage: str
    policy: str
    profile_epoch: int
    chunk_generation: int
    detail: str = ""
    hist_epoch: int = 0
    # owning cluster host ("" on the single-host path): multi-host plans
    # carry per-host provenance so an aggregated global program records
    # which host's pipeline produced each stage
    host: str = ""


def fault_provenance(n_degraded: int, n_rollbacks: int, profile_epoch: int,
                     chunk_generation: int,
                     hist_epoch: int = 0) -> StageProvenance:
    """Extra provenance entry the session appends to a plan rebuilt after
    fault events (degraded serves / eviction rollbacks under the previous
    plan): it marks that the rebuild's profile inputs were shaped by
    failures, not only by workload drift.  Appended *in addition to* the
    canonical ``STAGE_NAMES`` stages, and only on fault-bearing rebuilds
    — fault-free provenance is byte-identical to the legacy pipeline."""
    return StageProvenance(
        stage="fault", policy="fault-replay", profile_epoch=profile_epoch,
        chunk_generation=chunk_generation,
        detail=(f"{n_degraded} degraded serves, {n_rollbacks} eviction "
                f"rollbacks since last plan"),
        hist_epoch=hist_epoch)


@dataclasses.dataclass
class PlanProgram(PlacementPlan):
    """The pipeline's product: a :class:`~.planner.PlacementPlan` plus the
    declarative bookkeeping that makes plans inspectable, serializable and
    incrementally re-solvable.

    ``phase_decisions`` (inherited) always holds the *local-search*
    records even when the global strategy won the best-of-two — they are
    the standing residency a scoped replan re-solves against.
    ``provenance`` records each stage run with the profile epoch and chunk
    generation it consumed; ``capacity_bytes`` pins the budget the solve
    ran under (a changed budget invalidates scoped reuse wholesale)."""

    policy: str = "unimem"
    provenance: List[StageProvenance] = dataclasses.field(
        default_factory=list)
    profile_epoch: int = 0
    chunk_generation: int = 0
    capacity_bytes: int = 0
    # histogram resolution epoch the build consumed: bumped whenever any
    # measured histogram is adaptively re-binned, so a program records
    # which profiling resolution produced its decisions
    hist_epoch: int = 0
    # Multi-tenant bandwidth partition (policy="bandwidth_partition"; all
    # empty on single-workload plans): the fast-tier byte share each
    # tenant's sub-solve ran under, the copy channels each tenant owns
    # (consumed by the mover's channel chooser), and the tenants admission
    # control demoted to serve-from-slow with the reason why.
    tenant_shares: Dict[str, int] = dataclasses.field(default_factory=dict)
    tenant_channels: Dict[str, List[int]] = dataclasses.field(
        default_factory=dict)
    tenant_admission: Dict[str, str] = dataclasses.field(
        default_factory=dict)
    # Multi-host cluster aggregation (policy="cluster"; all empty on
    # single-host plans): the host whose pipeline built this program
    # (None = unclustered), per-host residency sections keyed by host id
    # (each a JSON-safe summary of that host's solve: strategy,
    # residents, predicted/baseline times, capacity), and the cross-host
    # shard migrations the coordinator chose, priced over interconnect
    # links.
    host: Optional[str] = None
    host_sections: Dict[str, Any] = dataclasses.field(default_factory=dict)
    migrations: List[Dict[str, Any]] = dataclasses.field(
        default_factory=list)

    # ------------------------------------------------------------ construction
    @classmethod
    def from_plan(cls, plan: PlacementPlan, *, policy: str,
                  provenance: Sequence[StageProvenance],
                  profile_epoch: int, chunk_generation: int,
                  capacity_bytes: int, hist_epoch: int = 0,
                  phase_decisions: Optional[Sequence[PhaseDecision]] = None,
                  global_contribs: Optional[Sequence[GlobalContrib]] = None,
                  graph_digest: Optional[tuple] = None) -> "PlanProgram":
        return cls(
            strategy=plan.strategy, residents=plan.residents,
            moves=plan.moves,
            predicted_iteration_time=plan.predicted_iteration_time,
            baseline_iteration_time=plan.baseline_iteration_time,
            schedule=plan.schedule,
            phase_decisions=list(phase_decisions
                                 if phase_decisions is not None
                                 else plan.phase_decisions),
            global_contribs=list(global_contribs
                                 if global_contribs is not None
                                 else plan.global_contribs),
            graph_digest=(graph_digest if graph_digest is not None
                          else plan.graph_digest),
            phase_baseline=list(plan.phase_baseline),
            phase_gain_bw=list(plan.phase_gain_bw),
            phase_gain_lat=list(plan.phase_gain_lat),
            policy=policy, provenance=list(provenance),
            profile_epoch=profile_epoch, chunk_generation=chunk_generation,
            capacity_bytes=capacity_bytes, hist_epoch=hist_epoch)

    # ----------------------------------------------------------- serialization
    def to_dict(self) -> Dict[str, Any]:
        return dict(
            policy=self.policy, strategy=self.strategy,
            residents=[sorted(r) for r in self.residents],
            moves=[dataclasses.asdict(m) for m in self.moves],
            schedule=[dict(op=dataclasses.asdict(s.op), window_s=s.window_s,
                           duration_s=s.duration_s, slack_s=s.slack_s)
                      for s in self.schedule],
            predicted_iteration_time=self.predicted_iteration_time,
            baseline_iteration_time=self.baseline_iteration_time,
            phase_decisions=[dict(
                phase_index=d.phase_index,
                entry_residents=sorted(d.entry_residents),
                entry_bytes=d.entry_bytes,
                fingerprint=d.fingerprint,    # nested tuples -> JSON lists
                moves=[dataclasses.asdict(m) for m in d.moves],
                exit_residents=sorted(d.exit_residents),
                exit_bytes=d.exit_bytes,
                benefits=d.benefits,
                classes=d.classes) for d in self.phase_decisions],
            global_contribs=[dict(
                phase_index=g.phase_index, version=list(g.version),
                generation=g.generation, objs=list(g.objs),
                row=[float(v) for v in g.row],
                cls_row=([int(v) for v in g.cls_row]
                         if g.cls_row is not None else None))
                for g in self.global_contribs],
            graph_digest=self.graph_digest,   # nested tuples -> JSON lists
            phase_baseline=list(self.phase_baseline),
            phase_gain_bw=list(self.phase_gain_bw),
            phase_gain_lat=list(self.phase_gain_lat),
            provenance=[dataclasses.asdict(p) for p in self.provenance],
            profile_epoch=self.profile_epoch,
            chunk_generation=self.chunk_generation,
            capacity_bytes=self.capacity_bytes,
            hist_epoch=self.hist_epoch,
            tenant_shares=dict(self.tenant_shares),
            tenant_channels={t: list(c)
                             for t, c in self.tenant_channels.items()},
            tenant_admission=dict(self.tenant_admission),
            host=self.host,
            host_sections={h: dict(s)
                           for h, s in self.host_sections.items()},
            migrations=[dict(m) for m in self.migrations])

    def to_json(self, **kw: Any) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PlanProgram":
        def tuplify(x):
            return tuple(tuplify(e) for e in x) if isinstance(x, list) else x

        moves = [MoveOp(**m) for m in d["moves"]]
        schedule = [ScheduledMove(MoveOp(**s["op"]), s["window_s"],
                                  s["duration_s"], s["slack_s"])
                    for s in d["schedule"]]
        import numpy as np
        decisions = [PhaseDecision(
            phase_index=pd["phase_index"],
            entry_residents=frozenset(pd["entry_residents"]),
            entry_bytes=pd["entry_bytes"],
            fingerprint=tuplify(pd["fingerprint"]),
            moves=tuple(MoveOp(**m) for m in pd["moves"]),
            exit_residents=frozenset(pd["exit_residents"]),
            exit_bytes=pd["exit_bytes"],
            benefits=pd.get("benefits"),
            classes=pd.get("classes")) for pd in d["phase_decisions"]]
        contribs = [GlobalContrib(
            phase_index=g["phase_index"], version=tuple(g["version"]),
            generation=g["generation"], objs=tuple(g["objs"]),
            row=np.asarray(g["row"], dtype=np.float64),
            cls_row=(np.asarray(g["cls_row"], dtype=np.uint8)
                     if g.get("cls_row") is not None else None))
            for g in d.get("global_contribs", [])]
        digest = d.get("graph_digest")
        return cls(
            strategy=d["strategy"],
            residents=[set(r) for r in d["residents"]],
            moves=moves,
            predicted_iteration_time=d["predicted_iteration_time"],
            baseline_iteration_time=d["baseline_iteration_time"],
            schedule=schedule, phase_decisions=decisions,
            global_contribs=contribs,
            graph_digest=tuplify(digest) if digest is not None else None,
            phase_baseline=list(d.get("phase_baseline", [])),
            phase_gain_bw=list(d.get("phase_gain_bw", [])),
            phase_gain_lat=list(d.get("phase_gain_lat", [])),
            policy=d["policy"],
            provenance=[StageProvenance(**p) for p in d["provenance"]],
            profile_epoch=d["profile_epoch"],
            chunk_generation=d["chunk_generation"],
            capacity_bytes=d["capacity_bytes"],
            hist_epoch=d.get("hist_epoch", 0),
            tenant_shares={t: int(v) for t, v in
                           d.get("tenant_shares", {}).items()},
            tenant_channels={t: [int(c) for c in chs] for t, chs in
                             d.get("tenant_channels", {}).items()},
            tenant_admission=dict(d.get("tenant_admission", {})),
            host=d.get("host"),
            host_sections={h: dict(s) for h, s in
                           d.get("host_sections", {}).items()},
            migrations=[dict(m) for m in d.get("migrations", [])])

    @classmethod
    def from_json(cls, s: str) -> "PlanProgram":
        return cls.from_dict(json.loads(s))

    @property
    def reused_phases(self) -> int:
        return sum(1 for d in self.phase_decisions if d.reused)


# ---------------------------------------------------------------------------
# pipeline state
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PipelineState:
    """The mutable context threaded through the pipeline stages: the
    characterized inputs (graph, registry, profiler), the solver, the
    budget, the driving config (duck-typed — only the ``enable_*`` /
    ``chunk_aware`` / ``coalesce`` / ``scoped_replan`` / ``leaf_aligned``
    attributes are read), and the standing program a replan may re-solve
    against."""

    machine: MachineProfile
    registry: ObjectRegistry
    graph: PhaseGraph
    profiler: PhaseProfiler
    planner: Planner
    capacity: int
    config: Any
    standing: Optional[PlanProgram] = None
    provenance: List[StageProvenance] = dataclasses.field(
        default_factory=list)
    plan: Optional[PlacementPlan] = None        # set by the solve stage
    local_decisions: List[PhaseDecision] = dataclasses.field(
        default_factory=list)
    global_contribs: List[GlobalContrib] = dataclasses.field(
        default_factory=list)
    graph_digest: Optional[tuple] = None
    # declared tenant QoS contracts (None = single-workload pipeline) and
    # the partition the bandwidth_partition solve produced
    tenants: Optional[Dict[str, TenantSpec]] = None
    tenant_solution: Optional[Dict[str, Any]] = None
    # Phases the drift monitor identified as drifted this replan (None =
    # unscoped build).  The attribute/partition stages restrict their
    # rewrites to these phases when it is provably safe to do so (see
    # stage_attribute) — an undrifted phase's profile version and the
    # registry generation pin its attribution, so re-running it would
    # write identical values.
    drift_scope: Optional[Sequence[int]] = None

    def record(self, policy: str, stage: str, detail: str = "") -> None:
        self.provenance.append(StageProvenance(
            stage=stage, policy=policy,
            profile_epoch=self.profiler.epoch,
            chunk_generation=self.registry.generation, detail=detail,
            hist_epoch=getattr(self.profiler, "hist_epoch", 0),
            host=self._cfg("host", None) or ""))

    def _cfg(self, name: str, default: Any) -> Any:
        return getattr(self.config, name, default)


# ---------------------------------------------------------------------------
# stages
# ---------------------------------------------------------------------------
def _gated_drift_scope(state: PipelineState) -> Optional[List[int]]:
    """The drift scope, or None when scoped attribution is not provably
    safe: it requires a standing program from the same lineage (so every
    phase was attributed by a previous build), an unchanged registry
    generation (chunk spans pin the attribution), and single-resolution
    histograms (multi-res re-splitting re-attributes outside the scope)."""
    scope = state.drift_scope
    if scope is None:
        return None
    if (state.standing is None
            or not state._cfg("scoped_replan", True)
            or state.standing.chunk_generation != state.registry.generation
            or state._cfg("histogram_refine", False)):
        return None
    return sorted(scope)


def stage_attribute(state: PipelineState, policy: str = "unimem") -> None:
    """Write measured phase times and per-object access counts into the
    phase graph (objects faded below one access are de-referenced).

    During a scoped drift response only the drifted phases are rewritten:
    the session's re-profiling froze every other phase's profile state
    (bitwise), so their graph annotations from the previous build are
    already what a full pass would write."""
    scope = _gated_drift_scope(state)
    state.drift_scope = scope       # partition stage reuses the gated value
    state.profiler.annotate_graph(state.graph, phases=scope)
    state.record(policy, "attribute",
                 f"{len(state.graph)} phases annotated" if scope is None
                 else f"{len(scope)}/{len(state.graph)} phases annotated"
                      " (scoped)")


def stage_partition(state: PipelineState, policy: str = "unimem") -> None:
    """Split oversized chunkable objects (skew-aware when histograms are
    measured) and re-attribute per-phase references to chunks.  In
    multi-resolution mode (``histogram_refine``), additionally re-split
    existing chunks whose refined histograms resolved sub-chunk imbalance
    — the pass that lets a coalesced chunk re-split when drift re-heats
    it."""
    if not state._cfg("enable_partitioning", True):
        return
    multi_res = state._cfg("histogram_refine", False)
    newly = partition_mod.auto_partition(
        state.registry, state.graph, state.capacity,
        profiler=state.profiler,
        skew_aware=state._cfg("chunk_aware", True),
        leaf_aligned=state._cfg("leaf_aligned", False),
        multi_res=multi_res)
    if not newly:
        # Replan with parents partitioned on an earlier build: the
        # attribute stage just rewrote parent-name refs from the
        # parent-keyed profiles, so re-attribute them to chunks with the
        # freshest histograms.  (auto_partition already did this for
        # anything it partitioned; without chunk_aware the profiler has no
        # histograms and size fractions apply.)  Scoped in lockstep with
        # the attribute stage: a phase it skipped still holds the previous
        # build's chunk attribution, which this pass would reproduce.
        partition_mod.resplit_refs(state.graph, state.registry,
                                   state.profiler,
                                   phases=state.drift_scope)
    resplits = {}
    if multi_res and state._cfg("chunk_aware", True):
        resplits = partition_mod.resplit_hot_chunks(
            state.registry, state.graph, state.profiler, state.capacity,
            leaf_aligned=state._cfg("leaf_aligned", False))
    detail = f"split {len(newly)}" if newly else "re-attributed"
    if resplits:
        detail += "; resplit " + ";".join(
            f"{p}:{b}->{a}" for p, (b, a) in sorted(resplits.items()))
    state.record(policy, "partition", detail)


def stage_coalesce(state: PipelineState, policy: str = "unimem") -> None:
    """Re-merge adjacent chunks whose measured densities converged and
    whose tiers agree (caps registry growth across drift sequences)."""
    if not state._cfg("coalesce", True):
        return
    merged = partition_mod.coalesce_chunks(
        state.registry, state.graph, state.profiler, state.capacity)
    state.record(policy, "coalesce",
                 ";".join(f"{p}:{b}->{a}" for p, (b, a) in sorted(
                     merged.items())) or "no-op")


def solve_best(planner: Planner, graph: PhaseGraph, profiler: PhaseProfiler,
               config: Any,
               standing: Optional[Sequence[PhaseDecision]] = None,
               standing_global: Optional[Sequence[GlobalContrib]] = None,
               standing_digest: Optional[tuple] = None
               ) -> Tuple[Optional[PlacementPlan], List[PhaseDecision],
                          List[GlobalContrib], Optional[tuple]]:
    """The paper's best-of-two search with optional scoped solving.
    Returns (chosen plan or None, the local-search decisions, the
    global-search contributions, the graph digest) — the aux records are
    kept on the program regardless of which strategy won, so the *next*
    replan can scope."""
    plans: List[PlacementPlan] = []
    decisions: List[PhaseDecision] = []
    contribs: List[GlobalContrib] = []
    digest: Optional[tuple] = None
    local: Optional[PlacementPlan] = None
    glob: Optional[PlacementPlan] = None
    if getattr(config, "enable_local_search", True):
        local = planner.plan_local(graph, profiler, standing=standing,
                                   standing_digest=standing_digest)
        decisions = local.phase_decisions
        digest = local.graph_digest
        plans.append(local)
    if getattr(config, "enable_global_search", True):
        # the local predicted time arms the planner's dominance bound: a
        # global solve provably unable to win the best-of-two is skipped,
        # and the pruned plan's certified lower bound keeps min() below
        # picking the same winner (ties go to local either way)
        glob = planner.plan_global(
            graph, profiler, standing_global=standing_global,
            prune_above=(local.predicted_iteration_time
                         if local is not None else None))
        contribs = glob.global_contribs
        plans.append(glob)
    if not plans:
        return None, decisions, contribs, digest
    best = min(plans, key=lambda p: p.predicted_iteration_time)
    if glob is not None and best is not glob:
        # surface the global search's reuse behaviour on whichever plan
        # wins (plan() does the same)
        best.global_mode = glob.global_mode
        best.global_rows_reused = glob.global_rows_reused
    return best, decisions, contribs, digest


def stage_solve(state: PipelineState, policy: str = "unimem") -> None:
    """Best-of-two knapsack search.  With a compatible standing program
    and ``scoped_replan``, both searches reuse every phase whose profile
    version, registry generation, entry residency and cross-phase windows
    still match (O(affected phases), plans equal to a full replan by
    construction)."""
    standing = standing_global = standing_digest = None
    if (state.standing is not None
            and state._cfg("scoped_replan", True)
            and state.standing.capacity_bytes == state.planner.capacity):
        standing = state.standing.phase_decisions or None
        standing_global = state.standing.global_contribs or None
        standing_digest = state.standing.graph_digest
    (state.plan, state.local_decisions, state.global_contribs,
     state.graph_digest) = solve_best(
        state.planner, state.graph, state.profiler, state.config,
        standing=standing, standing_global=standing_global,
        standing_digest=standing_digest)
    reused = sum(1 for d in state.local_decisions if d.reused)
    detail = (f"{state.plan.strategy}; reused {reused}/"
              f"{len(state.local_decisions)} phase solves; "
              f"global {state.plan.global_mode} "
              f"({state.plan.global_rows_reused} rows reused)"
              if state.plan is not None else "no search enabled")
    state.record(policy, "solve", detail)


def stage_solve_lru(state: PipelineState, policy: str = "lru") -> None:
    """Clock/LRU baseline solve (ablation plugin): walk the phases in
    order; every object a phase references is touched (most recently
    used) and demand-fetched at that phase's own boundary — no lookahead
    window, so the fence pays the whole copy; to make room, the
    least-recently-used resident the phase does not reference is evicted.
    No Eq. (1)-(5) benefit model is consulted, which is exactly what the
    ablation measures."""
    graph, reg = state.graph, state.registry
    cap = state.planner.capacity
    size = lambda o: reg[o].size_bytes
    residents = {o.name for o in reg if o.tier == "fast"}
    resident_bytes = sum(size(o) for o in residents)
    last_use: Dict[str, int] = {}
    clock = 0
    moves: List[MoveOp] = []
    placements: List[set] = []
    for ph in graph:
        refs = [o for o in ph.refs if o in reg and ph.refs[o] > 0.0]
        # hotter references first: when not everything fits, the LRU
        # baseline still serves the phase's heaviest objects
        for o in sorted(refs, key=lambda o: (-ph.refs[o], o)):
            clock += 1
            last_use[o] = clock
            if o in residents or reg[o].pinned:
                continue
            sz = size(o)
            if sz > cap:
                continue
            while resident_bytes + sz > cap:
                victims = [r for r in residents
                           if r not in ph.refs and not reg[r].pinned]
                if not victims:
                    break
                v = min(victims, key=lambda r: (last_use.get(r, 0), r))
                residents.discard(v)
                resident_bytes -= size(v)
                moves.append(MoveOp(v, "slow", ph.index, ph.index, size(v),
                                    size(v) / state.machine.copy_bw))
            if resident_bytes + sz <= cap:
                residents.add(o)
                resident_bytes += sz
                moves.append(MoveOp(o, "fast", ph.index, ph.index, sz,
                                    sz / state.machine.copy_bw))
        placements.append(set(residents))
    state.plan = PlacementPlan(
        "lru", placements, moves, graph.iteration_time(),
        graph.iteration_time())
    state.record(policy, "solve", f"lru: {len(moves)} moves")


def stage_solve_interval(state: PipelineState,
                         policy: str = "interval") -> None:
    """Online interval-guidance solve (ablation plugin), after Olson et
    al.'s application guidance for heterogeneous memory (arxiv
    2110.02150): each phase is one profiling interval; an object's
    priority is an exponentially decayed accumulation of its per-interval
    access *density* (bytes of traffic per byte of footprint), so recent
    intervals dominate but persistent hotness is remembered across the
    loop.  At every interval boundary the policy greedily packs the
    highest-density objects into fast memory, evicting the coldest
    residents to make room — guidance comes entirely from the decayed
    interval profile; no Eq. (1)-(5) benefit model, no slack-window
    lookahead, and every move is a demand move priced at its full
    ``size/copy_bw`` boundary cost."""
    graph, reg = state.graph, state.registry
    cap = state.planner.capacity
    decay = state._cfg("interval_decay", 0.6)
    size = lambda o: reg[o].size_bytes
    heat: Dict[str, float] = {}
    residents = {o.name for o in reg if o.tier == "fast"}
    resident_bytes = sum(size(o) for o in residents)
    moves: List[MoveOp] = []
    placements: List[set] = []
    for ph in graph:
        for o in heat:
            heat[o] *= decay
        for o, traffic in ph.refs.items():
            if o in reg and traffic > 0.0:
                heat[o] = heat.get(o, 0.0) + traffic / max(size(o), 1)
        want: set = set()
        want_bytes = 0
        for o in sorted((o for o in heat if heat[o] > 0.0 and o in reg),
                        key=lambda o: (-heat[o], o)):
            sz = size(o)
            if reg[o].pinned or sz > cap:
                continue
            if want_bytes + sz <= cap:
                want.add(o)
                want_bytes += sz
        # coldest stragglers out first, hottest arrivals in afterwards —
        # both at this interval's boundary, the paper's guidance point
        for v in sorted(residents - want,
                        key=lambda o: (heat.get(o, 0.0), o)):
            if v not in reg or reg[v].pinned:
                continue
            residents.discard(v)
            resident_bytes -= size(v)
            moves.append(MoveOp(v, "slow", ph.index, ph.index, size(v),
                                size(v) / state.machine.copy_bw))
        for o in sorted(want - residents, key=lambda o: (-heat[o], o)):
            sz = size(o)
            if resident_bytes + sz > cap:
                continue
            residents.add(o)
            resident_bytes += sz
            moves.append(MoveOp(o, "fast", ph.index, ph.index, sz,
                                sz / state.machine.copy_bw))
        placements.append(set(residents))
    state.plan = PlacementPlan(
        "interval", placements, moves, graph.iteration_time(),
        graph.iteration_time())
    state.record(policy, "solve",
                 f"interval: {len(moves)} moves, decay={decay:g}")


def stage_solve_bandwidth_partition(
        state: PipelineState, policy: str = "bandwidth_partition") -> None:
    """Multi-tenant solve: admission control, QoS-weighted partitioning of
    the fast tier and the copy channels, then one scoped Unimem local
    solve per admitted tenant under its own byte share.

    The partition is computed by :mod:`.tenancy`: capacity water-fills by
    ``priority/slo`` weight capped at each tenant's demand (unused shares
    redistribute work-conservingly), channels apportion by largest
    remainder so every channel is owned by exactly one tenant.  Each
    admitted tenant then gets an *isolated* knapsack: a phase graph
    filtered to its namespace and a throwaway planner whose capacity is
    the tenant's share — so one whale can never out-bid the tail inside a
    shared knapsack, which is the entire point.  Demoted tenants' fast
    residents are evicted at phase 0 and the demotion is recorded in
    ``tenant_admission`` (the session logs ``DegradedServe`` provenance
    from it).  Objects outside every declared namespace form a pseudo
    tenant with neutral weight.  With **no** tenants declared this stage
    is byte-for-byte :func:`stage_solve` — single-workload plans stay
    bit-identical to the unimem pipeline."""
    tenants = state.tenants
    if not tenants:
        stage_solve(state, policy)
        return
    graph, reg, planner = state.graph, state.registry, state.planner
    cap = planner.capacity
    member: Dict[str, str] = {}     # object -> tenant key ("" = unowned)
    for o in reg:
        t = tenant_of(o.name, tenants)
        member[o.name] = t if t is not None else ""
    # every declared tenant partitions even when idle; the pseudo tenant
    # only exists if unowned objects are actually referenced
    class _Pseudo:
        weight = 1.0
    specs: Dict[str, Any] = dict(tenants)
    referenced = {o for ph in graph for o, v in ph.refs.items() if v > 0.0}
    if any(member.get(o, "") == "" for o in referenced):
        specs[""] = _Pseudo()
    demand = {t: 0 for t in specs}
    traffic = {t: 0.0 for t in specs}
    hot = {t: 0 for t in specs}
    for ph in graph:
        per_phase: Dict[str, int] = {}
        for o, v in ph.refs.items():
            if v <= 0.0 or o not in reg:
                continue
            t = member.get(o, "")
            if t not in specs:
                continue
            traffic[t] += v
            per_phase[t] = per_phase.get(t, 0) + reg[o].size_bytes
        for t, b in per_phase.items():
            hot[t] = max(hot[t], b)
    for o in reg:
        t = member.get(o.name, "")
        if t in specs and o.name in referenced:
            demand[t] += o.size_bytes
    # admission: only declared tenants can be demoted (the pseudo tenant
    # is the shared substrate, not a QoS contract)
    demoted = tenancy_mod.admission_control(
        tenants, traffic, demand, cap,
        heat_floor=state._cfg("tenant_admission_heat", 0.0) or 0.0,
        churn_guard=state._cfg("tenant_churn_guard", None),
        hot_bytes=hot)
    admitted = {t: s for t, s in specs.items() if t not in demoted}
    shares = tenancy_mod.capacity_shares(cap, admitted, demand)
    channels = tenancy_mod.channel_shares(
        state._cfg("copy_channels", 2) or 1,
        {t: s for t, s in admitted.items() if t in tenants})
    size = lambda o: reg[o].size_bytes
    moves: List[MoveOp] = []
    placements = [set() for _ in graph]
    n_ph = len(graph)
    B = graph.iteration_time()
    gain_bw = [0.0] * n_ph
    gain_lat = [0.0] * n_ph
    predicted = B
    for t in sorted(admitted):
        mem = {n for n, owner in member.items() if owner == t}
        fgraph = PhaseGraph([
            Phase(ph.index, ph.name, ph.kind,
                  {o: v for o, v in ph.refs.items() if o in mem}, ph.time)
            for ph in graph])
        share = shares.get(t, 0)
        sub = Planner(state.machine, reg, planner.cf, share,
                      vectorized=planner.vectorized,
                      enact_consistent=planner.enact_consistent)
        # Entry residency can overshoot the share: evictions are issued
        # lazily, so a rebuild mid-rotation (e.g. a calibration fold)
        # snapshots fast bytes whose departures were booked by the old
        # plan.  The local solve keeps entry residents it was never asked
        # to fetch, so an unclamped entry would bake the overshoot in as
        # permanent residency beyond the share.  Shed the lowest-traffic
        # residents down to the share and evict them at phase 0.
        init = {o.name for o in reg if o.tier == "fast" and o.name in mem}
        over = sum(size(o) for o in init) - share
        if over > 0:
            traffic_of = {n: 0.0 for n in init}
            for ph in fgraph:
                for o, v in ph.refs.items():
                    if o in traffic_of and v > 0.0:
                        traffic_of[o] += v
            for n in sorted(init, key=lambda n: (
                    traffic_of[n] / max(size(n), 1), n)):
                if over <= 0:
                    break
                if reg[n].pinned:
                    continue
                init.discard(n)
                over -= size(n)
                moves.append(MoveOp(n, "slow", 0, 0, size(n),
                                    size(n) / state.machine.copy_bw))
        sub._initial_residents = lambda init=init: set(init)
        local = sub.plan_local(fgraph, state.profiler)
        moves.extend(local.moves)
        for i, residents in enumerate(local.residents[:n_ph]):
            placements[i] |= residents
        predicted -= max(0.0, B - local.predicted_iteration_time)
        for i in range(min(n_ph, len(local.phase_gain_bw))):
            gain_bw[i] += local.phase_gain_bw[i]
        for i in range(min(n_ph, len(local.phase_gain_lat))):
            gain_lat[i] += local.phase_gain_lat[i]
    # demoted tenants serve from slow: evict their fast residents so
    # admitted tenants actually get the capacity the shares promise
    for t in sorted(demoted):
        for o in sorted(n for n, owner in member.items() if owner == t):
            if o in reg and reg[o].tier == "fast" and not reg[o].pinned:
                moves.append(MoveOp(o, "slow", 0, 0, size(o),
                                    size(o) / state.machine.copy_bw))
    state.plan = PlacementPlan(
        "bandwidth_partition", placements, moves, max(0.0, predicted), B,
        phase_baseline=[ph.time for ph in graph],
        phase_gain_bw=gain_bw, phase_gain_lat=gain_lat)
    state.tenant_solution = dict(
        shares={t: int(v) for t, v in shares.items()},
        channels={t: list(c) for t, c in channels.items()},
        admission=dict(demoted))
    state.record(
        policy, "solve",
        f"{len(admitted)} tenants admitted, {len(demoted)} demoted; "
        + ";".join(f"{t or '<unowned>'}:{shares.get(t, 0)}B"
                   f"+ch{channels.get(t, [])}"
                   for t in sorted(specs)))


def stage_schedule(state: PipelineState, policy: str = "unimem") -> None:
    """Annotate every move with its copy window, duration and slack — the
    schedule the slack-aware mover releases most-urgent-first.  The
    planner entry points already emit the schedule for the plans they
    build; this stage only fills it in for plans that arrived without one
    (a custom policy's solve stage), so a normal build does not pay for
    the emission twice."""
    if state.plan is None:
        return
    if len(state.plan.schedule) != len(state.plan.moves):
        state.plan.schedule = emit_schedule(
            state.plan.moves, state.graph, state.machine.copy_bw)
    state.record(policy, "schedule",
                 f"{len(state.plan.schedule)} moves annotated")


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------
@runtime_checkable
class PlacementPolicy(Protocol):
    """A placement policy builds a :class:`PlanProgram` from characterized
    state (and optionally re-solves against a standing program)."""

    name: str

    def build(self, state: PipelineState) -> Optional[PlanProgram]: ...


class UnimemPolicy:
    """The paper's planner as a five-stage pipeline (see module docstring).
    Custom policies can subclass and override ``stages``."""

    name = "unimem"
    stages: Tuple[Callable[[PipelineState, str], None], ...] = (
        stage_attribute, stage_partition, stage_coalesce, stage_solve,
        stage_schedule)

    def build(self, state: PipelineState) -> Optional[PlanProgram]:
        for stage in self.stages:
            stage(state, self.name)
        if state.plan is None:
            return None
        return PlanProgram.from_plan(
            state.plan, policy=self.name, provenance=state.provenance,
            profile_epoch=state.profiler.epoch,
            chunk_generation=state.registry.generation,
            capacity_bytes=state.planner.capacity,
            hist_epoch=getattr(state.profiler, "hist_epoch", 0),
            phase_decisions=state.local_decisions,
            global_contribs=state.global_contribs,
            graph_digest=state.graph_digest)


class LruPolicy(UnimemPolicy):
    """Clock/LRU baseline for ablations: the solve stage is replaced by a
    demand-driven recency policy (fetch what the phase touches, evict the
    least-recently-used resident to make room, no benefit model, no
    lookahead triggers), while the characterization stages — attribute,
    partition, coalesce — and the schedule stage are reused unchanged.
    Quantifies how much of Unimem's win comes from the Eq. (1)-(5) solve
    rather than from chunking/attribution alone."""

    name = "lru"
    stages = (stage_attribute, stage_partition, stage_coalesce,
              stage_solve_lru, stage_schedule)


class IntervalPolicy(UnimemPolicy):
    """Olson-style online interval guidance (arxiv 2110.02150) as a
    placement policy: the solve stage ranks objects by exponentially
    decayed per-interval access density and greedily packs fast memory at
    every interval boundary, while the characterization stages —
    attribute, partition, coalesce — and the schedule stage are reused
    unchanged.  The third point on the ablation axis: LRU shows what
    recency alone buys, interval guidance what decayed frequency/density
    profiling buys, and the Unimem solve what the calibrated Eq. (1)-(5)
    benefit model adds on top."""

    name = "interval"
    stages = (stage_attribute, stage_partition, stage_coalesce,
              stage_solve_interval, stage_schedule)


class BandwidthPartitionPolicy(UnimemPolicy):
    """Multi-tenant QoS policy (the stage slot named open since PR 4):
    the solve stage is replaced by admission control + QoS-weighted
    partitioning of fast-tier capacity and copy channels + one isolated
    Unimem local solve per admitted tenant, while the characterization
    stages — attribute, partition, coalesce — and the schedule stage are
    reused unchanged.  The program additionally carries
    ``tenant_shares`` / ``tenant_channels`` / ``tenant_admission``; the
    mover consumes the channel ownership map for its chooser.  With no
    tenants declared the pipeline is bit-identical to ``unimem``.

    Scoped standing-plan reuse is disabled for multi-tenant solves (the
    merged plan records no per-phase decisions to reuse); each rebuild
    re-partitions and re-solves, which is what admission control needs
    anyway — shares must track the live traffic mix."""

    name = "bandwidth_partition"
    stages = (stage_attribute, stage_partition, stage_coalesce,
              stage_solve_bandwidth_partition, stage_schedule)

    def build(self, state: PipelineState) -> Optional[PlanProgram]:
        program = super().build(state)
        if program is not None and state.tenant_solution:
            program.tenant_shares = dict(state.tenant_solution["shares"])
            program.tenant_channels = {
                t: list(c)
                for t, c in state.tenant_solution["channels"].items()}
            program.tenant_admission = dict(
                state.tenant_solution["admission"])
        return program


# ---------------------------------------------------------------------------
# registry (mirrors core.backends)
# ---------------------------------------------------------------------------
PolicyFactory = Callable[..., PlacementPolicy]

_REGISTRY: Dict[str, PolicyFactory] = {}


def register_policy(name: str, factory: PolicyFactory,
                    *, overwrite: bool = False) -> None:
    """Register a placement-policy factory under ``name``."""
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"policy {name!r} is already registered "
                         "(pass overwrite=True to replace it)")
    _REGISTRY[name] = factory


def available_policies() -> List[str]:
    return sorted(_REGISTRY)


def make_policy(name: str, **options: Any) -> PlacementPolicy:
    """Instantiate the policy registered under ``name``."""
    factory = _REGISTRY.get(name)
    if factory is None:
        raise ValueError(f"unknown placement policy {name!r}; registered: "
                         f"{available_policies()}")
    return factory(**options)


register_policy("unimem", lambda **_: UnimemPolicy())
register_policy("lru", lambda **_: LruPolicy())
register_policy("interval", lambda **_: IntervalPolicy())
register_policy("bandwidth_partition",
                lambda **_: BandwidthPartitionPolicy())
