"""Pluggable instrumentation sources (runtime API v2).

The paper gets per-phase access counts from PEBS sampling; this repo has
grown three other ways to learn how a phase touches the registered objects
(explicit driver dicts, the simulator's density physics, XLA cost analysis
on hardware dry-runs).  Each used to hand-roll its own
``phase_end(accesses=..., access_bins=...)`` plumbing; the
:class:`InstrumentationSource` protocol makes them interchangeable
providers that a :class:`~.session.Session` consults at every phase exit:

* :class:`ManualSource` — the Table-2 style: the driver states each phase's
  per-object access counts explicitly (what the old imperative API passed
  to ``phase_end``).
* ``repro.sim.SimSource`` — the discrete-event simulator's density-driven
  physics (stream/chase service times, per-chunk densities), migrated out
  of ``sim/engine.py`` so the engine is just a clock around it.
* :class:`XlaCostAnalysisSource` — the TPU attribution analogue: there is
  no PEBS on TPU, but a compiled XLA program's per-op operand footprints
  can be mapped onto the registered objects' recorded leaf spans, giving
  the same ``accesses``/``access_bins`` stream the simulator produces —
  hardware dry-runs feed the exact profiler pipeline the paper's sampler
  does.

A source returns a :class:`PhaseSample`; fields left ``None`` fall back to
the session's own measurement (wall-clock timing, access-count shares).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Protocol, Sequence, Tuple, Union

import numpy as np

from .histogram import Histogram


@dataclasses.dataclass
class PhaseSample:
    """One phase execution's instrumentation (profiler input, pre-sampling).

    ``access_bins`` values are either legacy fixed-width weight sequences
    (relative weights over equal-width bins) or multi-resolution
    :class:`~.histogram.Histogram`\\ s (variable-width bins, e.g. one bin
    per pytree leaf) — the profiler re-samples either onto its own
    (budgeted, adaptively refined) bin edges.

    ``elapsed`` is the phase's execution time in seconds when the source
    defines virtual time (the simulator) or an analytic estimate; ``None``
    means the session should use the wall-clock time its phase context
    measured."""

    accesses: Dict[str, float] = dataclasses.field(default_factory=dict)
    time_shares: Optional[Dict[str, float]] = None
    access_bins: Optional[Dict[str, Union[Sequence[float], Histogram]]] = None
    elapsed: Optional[float] = None


class InstrumentationSource(Protocol):
    """Provider of per-phase instrumentation, consulted at phase exit."""

    def collect(self, phase_name: str) -> PhaseSample: ...


# ---------------------------------------------------------------------------
class ManualSource:
    """Explicit per-phase instrumentation dicts.

    The driver states (once, or per iteration via :meth:`set`) what each
    phase touches — the information the old imperative API passed to every
    ``phase_end`` call."""

    def __init__(self, phases: Optional[Dict[str, PhaseSample]] = None):
        self._phases: Dict[str, PhaseSample] = dict(phases or {})

    def set(self, phase_name: str, *,
            accesses: Optional[Dict[str, float]] = None,
            time_shares: Optional[Dict[str, float]] = None,
            access_bins: Optional[Dict[str, Sequence[float]]] = None,
            elapsed: Optional[float] = None) -> None:
        self._phases[phase_name] = PhaseSample(
            accesses=dict(accesses or {}), time_shares=time_shares,
            access_bins=access_bins, elapsed=elapsed)

    def collect(self, phase_name: str) -> PhaseSample:
        return self._phases.get(phase_name, PhaseSample())


# ---------------------------------------------------------------------------
# XLA cost-analysis attribution
# ---------------------------------------------------------------------------
#: tensor dtype -> bytes, covering both HLO (f32, s32, pred) and StableHLO
#: MLIR (f32, i32, i1) spellings
_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "i64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4, "i32": 4, "ui32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "i16": 2, "ui16": 2,
    "s8": 1, "u8": 1, "i8": 1, "ui8": 1, "pred": 1, "i1": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8E4M3FN": 1, "f8E5M2": 1,
}


def _program_text(program: Any) -> str:
    if isinstance(program, str):
        return program
    as_text = getattr(program, "as_text", None)
    if as_text is None:
        raise TypeError(f"cannot extract program text from {type(program)!r}")
    return as_text()


def _mlir_param_uses(text: str) -> Optional[Dict[int, int]]:
    """Use counts per ``%argN`` of a StableHLO module (``Lowered.as_text``).

    Only the entry function's region is counted: private helper functions
    (``lax.scan`` bodies lower to ``func.func private @...``) re-declare
    and use their own ``%argN`` names, which must not be charged to the
    entry parameters.  Regions nested inside @main are safe — StableHLO
    prints their block arguments as ``%iterArg...``, never ``%argN``.
    Returns None when the text is not MLIR."""
    if "func.func" not in text:
        return None
    m = re.search(r"func\.func\s+(?:\w+\s+)?@main\b", text)
    if m is not None:
        # @main's region runs until the next function declaration (jax
        # prints one func.func per module-level function, entry first)
        nxt = text.find("func.func", m.end())
        region = text[m.start():nxt if nxt != -1 else len(text)]
    else:
        region = text                   # no @main: single-function module
    uses: Dict[int, int] = {}
    for mm in re.finditer(r"%arg(\d+)\b", region):
        idx = int(mm.group(1))
        uses[idx] = uses.get(idx, 0) + 1
    # one occurrence per parameter is its declaration in the signature
    return {k: max(v - 1, 0) for k, v in uses.items()}


def _hlo_param_uses(text: str) -> Dict[int, int]:
    """Use counts per ``parameter(N)`` of the ENTRY computation of compiled
    HLO text (``Compiled.as_text``)."""
    entry = text
    m = re.search(r"^ENTRY\b.*?\{(.*?)^\}", text, re.S | re.M)
    if m is not None:
        entry = m.group(1)
    names: Dict[int, str] = {}
    for m in re.finditer(
            r"^\s*(%?[\w.\-]+)\s*=\s*[^=\n]*?\bparameter\((\d+)\)",
            entry, re.M):
        names[int(m.group(2))] = m.group(1)
    uses: Dict[int, int] = {}
    for idx, name in names.items():
        # anchor on both sides (optionally %-sigiled) so `param_0` never
        # matches inside `fused_param_0`
        bare = name.lstrip("%")
        pat = r"(?<![\w.\-])%?" + re.escape(bare) + r"(?![\w.\-])"
        hits = len(re.findall(pat, entry))
        uses[idx] = max(hits - 1, 0)        # minus the defining line
    return uses


class XlaCostAnalysisSource:
    """Per-op operand footprints of compiled XLA programs, mapped onto the
    registered objects' recorded leaf byte spans.

    :meth:`bind` associates a phase name with a lowered/compiled program
    and an *operand layout*: the program's flat parameter list described as
    a sequence whose entries are registered object names (each consuming
    that object's recorded leaves, in registration order — pytree-native
    :meth:`Session.register` records them), plain ints (that many
    unregistered parameters, e.g. the token batch), or example pytrees
    (unregistered, leaf count taken from the tree).

    Attribution: every instruction that reads parameter ``p`` contributes
    ``p``'s tensor bytes to its footprint (the per-op operand footprint XLA
    cost analysis charges); a leaf's footprint lands on the bins its byte
    span covers inside the owning object, so objects whose leaves have
    unequal fan-out produce *non-uniform* ``access_bins`` — exactly what
    the skew-aware partitioner needs, with chunk boundaries free to align
    to leaf boundaries.

    ``edges="uniform"`` (default) spreads each leaf's footprint over a
    fixed grid of ``n_bins`` equal-width bins (the legacy representation);
    ``edges="leaf"`` emits a multi-resolution
    :class:`~.histogram.Histogram` with one variable-width bin per
    registered leaf span — the instrumentation-native resolution, exact
    per-leaf attribution with no grid quantization (small hot leaves keep
    their own bins instead of smearing into neighbors).

    Caveat: ``jax.jit`` prunes unused arguments by default; bind programs
    whose listed operands are all used (or pass ``keep_unused=True``)."""

    def __init__(self, session: Any, *, n_bins: int = 64,
                 edges: str = "uniform"):
        if edges not in ("uniform", "leaf"):
            raise ValueError(f"edges must be 'uniform' or 'leaf', "
                             f"got {edges!r}")
        self.registry = session.registry
        self.machine = session.machine
        self.n_bins = int(n_bins)
        self.edges = edges
        self._samples: Dict[str, PhaseSample] = {}

    # -- binding -------------------------------------------------------------
    def _leaf_count(self, entry: Any) -> int:
        if isinstance(entry, int):
            return entry
        import jax
        return len(jax.tree_util.tree_leaves(entry))

    def bind(self, phase_name: str, program: Any,
             operands: Sequence[Any], *,
             elapsed: Optional[float] = None) -> PhaseSample:
        """Attribute ``program``'s operand footprints to the registered
        objects named in ``operands`` and store the resulting sample under
        ``phase_name``."""
        text = _program_text(program)
        uses = _mlir_param_uses(text)
        if uses is None:
            uses = _hlo_param_uses(text)

        # flat parameter index -> (object name, leaf byte span)
        param_spans: Dict[int, Tuple[str, int, int]] = {}
        next_param = 0
        for entry in operands:
            if isinstance(entry, str):
                obj = self.registry[entry]
                spans = obj.leaf_spans or [("", 0, obj.size_bytes)]
                for _, off, nbytes in spans:
                    param_spans[next_param] = (entry, off, nbytes)
                    next_param += 1
            else:
                next_param += self._leaf_count(entry)

        footprint: Dict[str, float] = {}
        bins: Dict[str, np.ndarray] = {}
        leaf_mass: Dict[str, Dict[int, float]] = {}
        for pidx, (name, off, nbytes) in param_spans.items():
            n_uses = uses.get(pidx, 0)
            if n_uses <= 0 or nbytes <= 0:
                continue
            mass = float(nbytes) * n_uses
            footprint[name] = footprint.get(name, 0.0) + mass
            if self.edges == "leaf":
                lm = leaf_mass.setdefault(name, {})
                lm[off] = lm.get(off, 0.0) + mass
                continue
            size = max(self.registry[name].size_bytes, 1)
            hist = bins.setdefault(name, np.zeros(self.n_bins))
            # spread the leaf's footprint over the bins its span covers
            width = size / self.n_bins
            lo_b = off / width
            hi_b = (off + nbytes) / width
            lo_i = int(np.floor(lo_b))
            hi_i = min(int(np.ceil(hi_b)), self.n_bins)
            for b in range(lo_i, max(hi_i, lo_i + 1)):
                if b >= self.n_bins:
                    break
                overlap = min(hi_b, b + 1) - max(lo_b, b)
                if overlap > 0:
                    hist[b] += mass * overlap / max(hi_b - lo_b, 1e-12)

        access_bins: Dict[str, Any] = {
            n: h.tolist() for n, h in bins.items() if float(h.sum()) > 0.0}
        for name, lm in leaf_mass.items():
            h = self._leaf_histogram(name, lm)
            if h is not None:
                access_bins[name] = h

        line = float(getattr(self.machine, "cacheline_bytes", 64))
        sample = PhaseSample(
            accesses={n: fp / line for n, fp in footprint.items()},
            access_bins=access_bins or None,
            elapsed=elapsed)
        self._samples[phase_name] = sample
        return sample

    def _leaf_histogram(self, name: str,
                        leaf_mass: Dict[int, float]) -> Optional[Histogram]:
        """Variable-width histogram with one bin per registered leaf span
        (``edges="leaf"``): each leaf's footprint lands exactly in its own
        bin — instrumentation-native multi-resolution attribution."""
        obj = self.registry[name]
        size = max(obj.size_bytes, 1)
        spans = obj.leaf_spans or [("", 0, obj.size_bytes)]
        edges, counts, pos = [0.0], [], 0
        for _, off, nbytes in spans:
            if nbytes <= 0:
                continue
            counts.append(leaf_mass.get(off, 0.0))
            pos = off + nbytes
            edges.append(min(pos / size, 1.0))
        if not counts or sum(counts) <= 0.0:
            return None
        edges[-1] = 1.0
        return Histogram(edges, counts)

    # -- protocol ------------------------------------------------------------
    def collect(self, phase_name: str) -> PhaseSample:
        return self._samples.get(phase_name, PhaseSample())
