"""Initial data placement (paper §3.2 "Initial data placement").

By default every object starts in the slow tier.  The paper improves on this
with *compiler analysis*: a symbolic count of memory references per object,
available before the main loop, places the most-referenced objects in the
fast tier up front (ignoring caching effects — which in their evaluation
matches the runtime's cross-phase global decision anyway).

Here the "compiler analysis" is the analytic reference-count model that every
workload/model definition exposes (``static_ref_counts``): for an LM step the
counts come from the model graph (each weight is read once per microbatch,
optimizer state read+written once per step, KV blocks read per token, ...).
"""

from __future__ import annotations

from typing import Dict, List, Optional

from .data_objects import ObjectRegistry


def initial_placement(registry: ObjectRegistry,
                      static_ref_counts: Dict[str, float],
                      fast_capacity_bytes: int,
                      *, reserve_bytes: int = 0) -> List[str]:
    """Greedy fill of the fast tier by descending static reference count.

    Mutates ``obj.tier`` for the chosen objects and returns their names.
    Unknown objects (no static estimate) are left in the slow tier.
    """
    budget = fast_capacity_bytes - reserve_bytes
    # tie-break by name so the placement is a pure function of the counts —
    # not of the dict insertion order the driver happened to use (old-API
    # start_loop(static_refs=...) vs v2 per-register static_refs must be
    # bit-identical)
    order = sorted(
        (name for name in static_ref_counts if name in registry),
        key=lambda n: (-static_ref_counts[n], n))
    placed: List[str] = []
    for name in order:
        obj = registry[name]
        if obj.pinned:
            continue
        if obj.size_bytes <= budget and static_ref_counts[name] > 0:
            obj.tier = "fast"
            budget -= obj.size_bytes
            placed.append(name)
    return placed


def static_ref_counts_from_graph(phase_refs: Dict[int, Dict[str, float]]
                                 ) -> Dict[str, float]:
    """Aggregate per-phase analytic reference counts into per-object totals."""
    totals: Dict[str, float] = {}
    for refs in phase_refs.values():
        for obj, cnt in refs.items():
            totals[obj] = totals.get(obj, 0.0) + cnt
    return totals
