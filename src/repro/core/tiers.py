"""Memory tier descriptions for heterogeneous memory systems.

The paper pairs a fast-small tier (DRAM) with a slow-big tier (NVM).  On TPU
the same structure appears twice: HBM vs. host DRAM at the runtime level and
VMEM vs. HBM at the kernel level.  ``TierSpec`` describes one tier;
``MachineProfile`` describes a two-tier machine plus the copy engine between
the tiers (the paper's ``mem_copy_bw``).

Bandwidths are bytes/second, latencies are seconds.  Profiles named after
Table 1 of the paper reproduce its DRAM/STT-RAM/PCRAM/ReRAM numbers;
``TPU_V5E`` is the production target.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

GB = 1024 ** 3
MB = 1024 ** 2
NS = 1e-9


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One memory tier.

    ``memory_kind`` is the JAX memory kind used when arrays are really moved
    (``device`` / ``pinned_host``); ``None`` means simulation-only.
    """

    name: str
    capacity_bytes: int
    read_bw: float          # bytes/s
    write_bw: float         # bytes/s
    read_lat: float         # s
    write_lat: float        # s
    memory_kind: Optional[str] = None

    @property
    def bw(self) -> float:
        """Symmetric effective bandwidth used by Eq. (2)."""
        return min(self.read_bw, self.write_bw)

    @property
    def lat(self) -> float:
        """Symmetric effective latency used by Eq. (3)."""
        return max(self.read_lat, self.write_lat)


@dataclasses.dataclass(frozen=True)
class MachineProfile:
    """A two-tier machine: ``fast`` (paper: DRAM) and ``slow`` (paper: NVM)."""

    name: str
    fast: TierSpec
    slow: TierSpec
    copy_bw: float                  # fast<->slow memory copy bandwidth, bytes/s
    cacheline_bytes: int = 64
    sample_rate_hz: float = 2.4e6   # counter sampling rate (1000 cyc @ 2.4 GHz)
    # Peak *measured* bandwidth of the slow tier (paper: STREAM on NVM).
    # Defaults to the spec sheet number when not separately calibrated.
    slow_bw_peak: Optional[float] = None

    @property
    def bw_peak(self) -> float:
        return self.slow_bw_peak if self.slow_bw_peak is not None else self.slow.bw

    def scaled(self, *, bw_scale: float = 1.0, lat_scale: float = 1.0,
               name: Optional[str] = None) -> "MachineProfile":
        """Derive a profile whose slow tier is scaled relative to the fast
        tier — the paper's ``1/2 DRAM bandwidth`` / ``4x DRAM latency``
        emulation knobs (Figs 2-3)."""
        slow = dataclasses.replace(
            self.slow,
            read_bw=self.fast.read_bw * bw_scale,
            write_bw=self.fast.write_bw * bw_scale,
            read_lat=self.fast.read_lat * lat_scale,
            write_lat=self.fast.write_lat * lat_scale,
        )
        return dataclasses.replace(
            self, name=name or f"{self.name}[bw={bw_scale},lat={lat_scale}]",
            slow=slow, slow_bw_peak=None)


def _dram(capacity=256 * MB) -> TierSpec:
    # Sustained per-socket DRAM characteristics of the paper's Platform A
    # (2x E5-2630); Table 1's random-access numbers are captured by the
    # per-technology profiles below via scaled() knobs.
    return TierSpec("DRAM", capacity, 12e9, 10e9, 90 * NS, 90 * NS,
                    memory_kind="device")


def _nvm(read_bw, write_bw, read_lat, write_lat, capacity=16 * GB) -> TierSpec:
    return TierSpec("NVM", capacity, read_bw, write_bw, read_lat, write_lat,
                    memory_kind="pinned_host")


# --- machine profiles (paper's emulated platforms) --------------------------
# Default NVM: 1/2 DRAM bandwidth, 2x DRAM latency (mid-range PCM-like).
PAPER_DRAM_NVM = MachineProfile(
    name="paper-generic", fast=_dram(),
    slow=_nvm(6e9, 5e9, 180 * NS, 180 * NS),
    copy_bw=10e9)

# Table-1 relative profiles (slow tier scaled from the measured DRAM).
STT_RAM = MachineProfile(
    name="stt-ram", fast=_dram(),
    slow=_nvm(12e9 * 0.8, 10e9 * 0.6, 6 * 90 * NS, 8 * 90 * NS), copy_bw=10e9)

PCRAM = MachineProfile(
    name="pcram", fast=_dram(),
    slow=_nvm(12e9 * 0.5, 10e9 * 0.45, 10 * 90 * NS, 100 * 90 * NS),
    copy_bw=10e9)

RERAM = MachineProfile(
    name="reram", fast=_dram(),
    slow=_nvm(12e9 * 0.06, 10e9 * 0.005, 50 * 90 * NS, 100 * 90 * NS),
    copy_bw=10e9)

# --- TPU v5e production target ---------------------------------------------
# fast = HBM (16 GB, 819 GB/s), slow = host DRAM behind PCIe.  A v5e host
# feeds 4 chips; we budget 32 GB/s/chip optimistic, and the tier model's
# latency reflects PCIe+driver round trip.
TPU_V5E = MachineProfile(
    name="tpu-v5e", fast=TierSpec("HBM", 16 * GB, 819e9, 819e9,
                                  400 * NS, 400 * NS, memory_kind="device"),
    slow=TierSpec("HOST", 64 * GB, 32e9, 32e9, 2000 * NS, 2000 * NS,
                  memory_kind="pinned_host"),
    copy_bw=32e9, cacheline_bytes=512)

# Kernel-level tiers on one v5e core: fast = VMEM, slow = HBM.
TPU_V5E_VMEM = MachineProfile(
    name="tpu-v5e-vmem",
    fast=TierSpec("VMEM", 128 * MB, 20e12, 20e12, 30 * NS, 30 * NS),
    slow=TierSpec("HBM", 16 * GB, 819e9, 819e9, 400 * NS, 400 * NS),
    copy_bw=819e9, cacheline_bytes=512)

PROFILES = {p.name: p for p in
            [PAPER_DRAM_NVM, STT_RAM, PCRAM, RERAM, TPU_V5E, TPU_V5E_VMEM]}

# Roofline hardware constants for TPU v5e (per chip).
V5E_PEAK_FLOPS_BF16 = 197e12
V5E_HBM_BW = 819e9
V5E_ICI_BW = 50e9
