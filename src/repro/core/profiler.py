"""Online phase profiling (paper §3.1.1) with per-chunk attribution.

The paper samples last-level-cache-miss events (PEBS/IBS) during the first
iteration and attributes sampled memory addresses to target data objects.
On TPU there is no PEBS; the *true* per-(phase, object) access counts come
from the compiled phase's cost analysis plus analytic per-object reference
counts (see ``repro.launch.dryrun`` / ``repro.sim.workloads``).  To keep the
downstream pipeline identical to the paper's — including its tolerance to
sampling error, which the CF constants compensate — the profiler converts
true counts into *sampled observations*:

* ``n_samples``        : phase_time x sample_rate
* ``samples_with_hit`` : samples that observed >=1 access to the object
* ``data_access``      : access count estimated from the sampled subset

A deterministic seeded RNG injects the sampling noise.

**Per-chunk attribution** extends the sampling model below object
granularity: when the instrumentation reports how an object's accesses
distribute over its byte range (``PhaseTraceEvent.access_bins`` — the
address histogram a PEBS sample stream would produce), each sample that hit
the object also "records an address", i.e. lands in one of the measured
histogram's bins.  The profiler draws those bin hits from a seeded
multinomial over the true distribution, so the measured histogram carries
realistic sampling noise that shrinks as more samples accumulate.
Downstream, the skew-aware partitioner (``partition.skew_boundaries``) and
the planner's chunk fallback read the measured histogram instead of
assuming uniform density.

**Multi-resolution histograms**: the measured histogram is a
:class:`~.histogram.Histogram` — variable-width bins over the object's
byte range under a total bin budget (``hist_bins``; ``None`` keeps the
instrumentation's native uniform resolution, the legacy fixed-width
behavior, bit-identical plans included).  With ``hist_refine``,
:meth:`PhaseProfiler.refine_histograms` adaptively re-bins between
profiling iterations: hot bins split finer, cold bins coarsen to pay for
it, and the *next* iteration's sampled addresses land in the refined bins
— so resolution concentrates where the mass is without growing the
budget.  Every resolution change bumps the phase's profile version and
the profiler-wide ``hist_epoch``, which join the planner's phase
fingerprints / plan provenance so scoped replanning stays provably equal.

**Accumulation** is a running (weighted) mean: observing the same
(phase, object) across ``profile_iterations > 1`` iterations folds each new
observation into the stored profile instead of overwriting it, so
multi-iteration profiling actually reduces sampling noise.  ``decay``
down-weights the accumulated history, letting fresh observations dominate —
the incremental-replan path uses it so a drifted workload re-profiles
without throwing the old plan away.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from .histogram import Histogram
from .phase import PhaseGraph, PhaseTraceEvent
from .tiers import MachineProfile

#: cap on multinomial draws per (phase, object) observation — beyond this the
#: histogram is effectively converged and more draws only cost time
MAX_BIN_DRAWS = 1 << 16


@dataclasses.dataclass
class ObjectPhaseProfile:
    """Profiler output for one (phase, object) pair — inputs to Eq. (1).

    Values are running means over every folded observation (``weight``
    observations so far, possibly fractional after :meth:`PhaseProfiler.decay`).
    ``bin_counts`` accumulates sampled address->bin hits across observations
    as a (possibly multi-resolution) :class:`~.histogram.Histogram`;
    ``bin_weights`` exposes the same histogram when it carries mass (None
    when the object was never observed with per-chunk attribution)."""

    phase_index: int
    obj: str
    data_access: float          # #data_access (estimated accesses to memory)
    n_samples: float            # #samples
    samples_with_access: float  # #samples_with_data_accesses
    phase_time: float           # seconds
    cacheline_bytes: float = 64.0   # machine.cacheline_bytes at observation
    bin_counts: Optional[Histogram] = None
    weight: float = 1.0         # observations folded into the running means

    @property
    def accessed_bytes(self) -> float:
        """Bytes this object moved through main memory in the phase
        (Eq. (1)-(2) numerator: #data_access x cacheline)."""
        return self.data_access * self.cacheline_bytes

    @property
    def bin_weights(self) -> Optional[Histogram]:
        """Measured access histogram over the object's byte range
        (mass-carrying), or None when no per-chunk attribution was ever
        observed.  Downstream integrates it with ``partition.bin_mass`` /
        :meth:`Histogram.mass_fraction` — the bins may be variable-width."""
        if self.bin_counts is None or self.bin_counts.total <= 0.0:
            return None
        return self.bin_counts


class PhaseProfiler:
    """Builds per-(phase, object) profiles from raw phase trace events."""

    def __init__(self, machine: MachineProfile, *, seed: int = 0,
                 noise: float = 0.05, hist_bins: Optional[int] = None,
                 hist_refine: bool = False):
        self.machine = machine
        self.noise = noise
        #: measured-histogram bin budget: None accumulates at the
        #: instrumentation's native uniform resolution (legacy behavior);
        #: an int projects every observation onto that many bins
        self.hist_bins = hist_bins
        #: whether refine_histograms should adapt bin edges (the session
        #: calls it between profiling iterations when enabled)
        self.hist_refine = hist_refine
        #: profile epoch: bumped whenever accumulated history is decayed or
        #: cleared — plan provenance records which epoch produced a decision
        self.epoch = 0
        #: histogram resolution epoch: bumped whenever any measured
        #: histogram's bin edges change (plan provenance)
        self.hist_epoch = 0
        self._rng = np.random.default_rng(seed)
        # accumulated observations: (phase, obj) -> running-mean profile
        self._acc: Dict[int, Dict[str, ObjectPhaseProfile]] = {}
        # phase -> (running mean time, accumulated weight)
        self._times: Dict[int, List[float]] = {}
        # phase -> observation counter: bumped on every mutation of that
        # phase's accumulated state.  (epoch, phase_version, resolution)
        # identifies a phase's profile state exactly, so the scoped
        # replanner can prove "this phase's solve inputs did not change"
        # without recomputing benefits (see planner.PhaseDecision).
        self._versions: Dict[int, int] = {}
        # phase -> histogram resolution counter: bumped when any of the
        # phase's measured histograms is re-binned
        self._hist_res: Dict[int, int] = {}

    # -- ingestion -----------------------------------------------------------
    def observe(self, ev: PhaseTraceEvent) -> None:
        """Ingest one dynamic phase execution (one loop iteration's phase).

        Repeat observations of the same (phase, object) fold into a running
        mean (weighted by prior accumulation) rather than clobbering the
        stored profile."""
        n_samples = max(ev.time * self.machine.sample_rate_hz, 1.0)
        self._versions[ev.phase_index] = \
            self._versions.get(ev.phase_index, 0) + 1
        prof_map = self._acc.setdefault(ev.phase_index, {})
        tm = self._times.get(ev.phase_index)
        if tm is None:
            self._times[ev.phase_index] = [ev.time, 1.0]
        else:
            tm[1] += 1.0
            tm[0] += (ev.time - tm[0]) / tm[1]
        total_access = sum(ev.accesses.values())
        for obj, true_access in ev.accesses.items():
            if true_access <= 0:
                continue
            # Sampling model: a sample observes this object iff it lands in a
            # window where the object's accesses are in flight, i.e. with
            # probability = the object's share of phase *time* (PEBS
            # semantics).  Falls back to access-count share when the caller
            # cannot attribute time.  Multiplicative noise models PEBS skid
            # and uncounted events (evictions/prefetches), which the paper
            # compensates with CF constants.
            if ev.time_shares is not None and obj in ev.time_shares:
                share = ev.time_shares[obj]
            else:
                share = true_access / max(total_access, 1.0)
            jitter = 1.0 + self.noise * self._rng.standard_normal()
            jitter = float(np.clip(jitter, 0.5, 1.5))
            observed = true_access * jitter
            hit_frac = min(1.0, share * jitter)
            swa = max(hit_frac * n_samples, 1.0)
            prev = prof_map.get(obj)
            counts: Optional[Histogram] = None
            if ev.access_bins is not None and obj in ev.access_bins:
                counts = self._sample_bins(
                    ev.access_bins[obj], swa,
                    prev.bin_counts if prev is not None else None)
            if prev is None:
                prof_map[obj] = ObjectPhaseProfile(
                    phase_index=ev.phase_index, obj=obj,
                    data_access=observed,
                    n_samples=n_samples,
                    samples_with_access=swa,
                    phase_time=ev.time,
                    cacheline_bytes=float(self.machine.cacheline_bytes),
                    bin_counts=counts)
            else:
                w = prev.weight + 1.0
                prev.data_access += (observed - prev.data_access) / w
                prev.n_samples += (n_samples - prev.n_samples) / w
                prev.samples_with_access += (swa - prev.samples_with_access) / w
                prev.phase_time += (ev.time - prev.phase_time) / w
                prev.weight = w
                if counts is not None:
                    if prev.bin_counts is None:
                        prev.bin_counts = counts
                    elif prev.bin_counts.same_edges(counts):
                        prev.bin_counts = prev.bin_counts.add(counts)
                    else:       # instrumentation changed its bin resolution
                        prev.bin_counts = counts
        # An execution where a previously-profiled object had *no* accesses
        # is a real observation of zero — fold it in, so objects that go
        # cold actually fade from the profile (without this, a drifted
        # workload's stale hot set would survive re-profiling forever).
        for obj, prev in prof_map.items():
            if ev.accesses.get(obj, 0.0) > 0:
                continue
            w = prev.weight + 1.0
            prev.data_access += (0.0 - prev.data_access) / w
            prev.n_samples += (n_samples - prev.n_samples) / w
            prev.samples_with_access += (0.0 - prev.samples_with_access) / w
            prev.phase_time += (ev.time - prev.phase_time) / w
            prev.weight = w

    def _native_hist(self, truth) -> Histogram:
        """Empty histogram at the truth's native resolution."""
        if isinstance(truth, Histogram):
            return Histogram(truth.edges, np.zeros(truth.n_bins))
        n = int(np.asarray(truth, dtype=np.float64).size) or 1
        return Histogram.uniform(n)

    def _target_hist(self, truth, prev: Optional[Histogram]) -> Histogram:
        """The edge set this observation's sampled addresses land in: the
        accumulated histogram's (possibly refined) edges when one exists,
        else the bin budget's uniform grid, else the truth's native
        resolution.

        Legacy native mode (no bin budget) with an un-refined (uniform)
        accumulated histogram: a source that changes its native resolution
        mid-run re-targets to the new resolution, which resets the
        accumulation (the pre-multi-res behavior — stale coarse edges must
        not quantize a newly finer truth forever).  Refined histograms
        keep their adapted edges regardless."""
        if prev is not None:
            if self.hist_bins is None and prev.is_uniform:
                native = self._native_hist(truth)
                if not prev.same_edges(native):
                    return native
            return prev
        if self.hist_bins is not None:
            return Histogram.uniform(int(self.hist_bins))
        return self._native_hist(truth)

    def _sample_bins(self, true_weights, swa: float,
                     prev: Optional[Histogram]) -> Optional[Histogram]:
        """Sampled address->bin histogram: each sample that hit the object
        records an address; addresses land in the target histogram's bins
        proportionally to the true access distribution (the PEBS event
        stream, with multinomial noise).  The target edges are the
        accumulated histogram's — refined edges keep receiving samples at
        their own resolution."""
        target = self._target_hist(true_weights, prev)
        p = target.project(true_weights)
        if p is None:
            return None
        draws = int(min(max(swa, 8.0), MAX_BIN_DRAWS))
        counts = self._rng.multinomial(draws, p).astype(np.float64)
        return Histogram(target.edges, counts)

    def observe_iteration(self, events: Iterable[PhaseTraceEvent]) -> None:
        for ev in events:
            self.observe(ev)

    # -- outputs --------------------------------------------------------------
    def profile(self, phase_index: int, obj: str) -> Optional[ObjectPhaseProfile]:
        return self._acc.get(phase_index, {}).get(obj)

    def profiles_for_phase(self, phase_index: int) -> Dict[str, ObjectPhaseProfile]:
        return dict(self._acc.get(phase_index, {}))

    def phase_time(self, phase_index: int) -> float:
        tm = self._times.get(phase_index)
        return float(tm[0]) if tm else 0.0

    def phase_version(self, phase_index: int) -> Tuple[int, int, int]:
        """(epoch, observation counter, histogram resolution counter) —
        identifies this phase's accumulated profile state, including its
        measured histograms' bin edges, exactly (scoped-replan reuse
        key)."""
        return (self.epoch, self._versions.get(phase_index, 0),
                self._hist_res.get(phase_index, 0))

    def object_bins(self, obj: str) -> Dict[int, Histogram]:
        """Measured per-phase access histograms for ``obj`` (phases where the
        object was observed with per-chunk attribution only)."""
        out: Dict[int, Histogram] = {}
        for phase_index, prof_map in self._acc.items():
            p = prof_map.get(obj)
            if p is not None:
                w = p.bin_weights
                if w is not None:
                    out[phase_index] = w
        return out

    def annotate_graph(self, graph: PhaseGraph,
                       phases: Optional[Sequence[int]] = None) -> None:
        """Write measured times + access counts back into the phase graph.

        An object whose folded mean has faded below one access is treated as
        *unreferenced* by the phase (its ref entry is dropped): a lingering
        epsilon ref would still count as a reference and e.g. shield a
        gone-cold object from eviction forever.

        ``phases`` scopes the rewrite to the listed phase indices (a
        serving-tick replan annotates only the drifted phases — an
        unchanged profile version rewrites identical values, so skipping
        it cannot change the graph)."""
        scope = None if phases is None else set(phases)
        for p in graph:
            if scope is not None and p.index not in scope:
                continue
            t = self.phase_time(p.index)
            if t > 0:
                p.time = t
            for obj, prof in self.profiles_for_phase(p.index).items():
                if prof.data_access >= 1.0:
                    p.refs[obj] = prof.data_access
                else:
                    p.refs.pop(obj, None)

    def decay(self, factor: float = 0.25,
              phases: Optional[Union[int, Sequence[int]]] = None) -> None:
        """Down-weight accumulated history so subsequent observations dominate
        the running means (incremental replanning: reuse the old profiles as a
        prior instead of throwing them away).

        ``phases`` restricts the decay to the given phase indices (a bare
        int is accepted as a single phase) — the scoped drift response:
        only the drifted phases' histories are down-weighted and
        re-observed, so every other phase's profile state stays bitwise
        identical and its standing plan decision remains provably reusable.
        A phase that was observed zero times (no accumulated state) is a
        documented **no-op**: there is nothing to decay, nothing raises,
        and no version advances."""
        if not 0.0 <= factor <= 1.0:
            raise ValueError("decay factor must be in [0, 1]")
        if phases is not None and isinstance(phases, int):
            phases = [phases]
        scope = None if phases is None else set(phases)
        if scope is None:
            self.epoch += 1
        for phase_index, prof_map in self._acc.items():
            if scope is not None:
                if phase_index not in scope:
                    continue
                self._versions[phase_index] = \
                    self._versions.get(phase_index, 0) + 1
            for p in prof_map.values():
                p.weight *= factor
                if p.bin_counts is not None:
                    p.bin_counts = p.bin_counts.scaled(factor)
        for phase_index, tm in self._times.items():
            if scope is not None and phase_index not in scope:
                continue
            tm[1] *= factor

    def refine_histograms(self, budget: Optional[int] = None,
                          phases: Optional[Sequence[int]] = None,
                          *, min_width: Optional[float] = None,
                          decay: float = 0.25) -> List[int]:
        """Adaptively re-bin the accumulated measured histograms: hot bins
        split finer, cold regions coarsen, total bins stay within
        ``budget`` (default: the profiler's ``hist_bins``, else 64).  The
        session calls this *between* profiling iterations so the next
        iteration's sampled addresses land in the refined bins.

        A split bin hands each half exactly half its mass — the best
        piecewise-constant guess, but *no information* about the true
        sub-structure — so a re-binned histogram's accumulated counts are
        additionally scaled by ``decay``: the next iteration's sampled
        addresses (drawn at the refined resolution) dominate the running
        histogram instead of being averaged into the flat-prior residue
        (which would bias fine-bin masses toward uniform for ~1/weight
        iterations and mis-rank the hot head's chunks).

        ``phases`` scopes the refinement (the scoped drift response: a
        phase outside the scope keeps its bin edges — and therefore its
        profile version — bitwise intact, so its standing plan decision
        stays reusable).  Phases observed zero times are no-ops.  Returns
        the phase indices whose resolution changed; any change bumps the
        profiler-wide ``hist_epoch`` (plan provenance)."""
        budget = int(budget if budget is not None
                     else (self.hist_bins or 64))
        min_width = (min_width if min_width is not None
                     else 1.0 / (16 * budget))
        scope = None if phases is None else set(phases)
        changed: List[int] = []
        for phase_index in sorted(self._acc):
            if scope is not None and phase_index not in scope:
                continue
            ph_changed = False
            for p in self._acc[phase_index].values():
                h = p.bin_counts
                if h is None or h.total <= 0.0:
                    continue
                h2 = h.refined(budget, min_width=min_width)
                if h2 is not h:     # refined() returns self when unchanged
                    p.bin_counts = h2.scaled(decay)
                    ph_changed = True
            if ph_changed:
                self._hist_res[phase_index] = \
                    self._hist_res.get(phase_index, 0) + 1
                self._versions[phase_index] = \
                    self._versions.get(phase_index, 0) + 1
                changed.append(phase_index)
        if changed:
            self.hist_epoch += 1
        return changed

    def clear(self) -> None:
        self.epoch += 1
        self.hist_epoch += 1
        self._versions.clear()
        self._hist_res.clear()
        self._acc.clear()
        self._times.clear()
