"""Online phase profiling (paper §3.1.1).

The paper samples last-level-cache-miss events (PEBS/IBS) during the first
iteration and attributes sampled memory addresses to target data objects.
On TPU there is no PEBS; the *true* per-(phase, object) access counts come
from the compiled phase's cost analysis plus analytic per-object reference
counts (see ``repro.launch.dryrun`` / ``repro.sim.workloads``).  To keep the
downstream pipeline identical to the paper's — including its tolerance to
sampling error, which the CF constants compensate — the profiler converts
true counts into *sampled observations*:

* ``n_samples``        : phase_time x sample_rate
* ``samples_with_hit`` : samples that observed >=1 access to the object
* ``data_access``      : access count estimated from the sampled subset

A deterministic seeded RNG injects the sampling noise.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional

import numpy as np

from .phase import PhaseGraph, PhaseTraceEvent
from .tiers import MachineProfile


@dataclasses.dataclass
class ObjectPhaseProfile:
    """Profiler output for one (phase, object) pair — inputs to Eq. (1)."""

    phase_index: int
    obj: str
    data_access: float          # #data_access (estimated accesses to memory)
    n_samples: float            # #samples
    samples_with_access: float  # #samples_with_data_accesses
    phase_time: float           # seconds

    @property
    def accessed_bytes(self) -> float:
        raise NotImplementedError  # needs cacheline size; see perfmodel


class PhaseProfiler:
    """Builds per-(phase, object) profiles from raw phase trace events."""

    def __init__(self, machine: MachineProfile, *, seed: int = 0,
                 noise: float = 0.05):
        self.machine = machine
        self.noise = noise
        self._rng = np.random.default_rng(seed)
        # accumulated observations: (phase, obj) -> list of profiles
        self._acc: Dict[int, Dict[str, ObjectPhaseProfile]] = {}
        self._times: Dict[int, List[float]] = {}

    # -- ingestion -----------------------------------------------------------
    def observe(self, ev: PhaseTraceEvent) -> None:
        """Ingest one dynamic phase execution (one loop iteration's phase)."""
        n_samples = max(ev.time * self.machine.sample_rate_hz, 1.0)
        prof_map = self._acc.setdefault(ev.phase_index, {})
        self._times.setdefault(ev.phase_index, []).append(ev.time)
        total_access = sum(ev.accesses.values())
        for obj, true_access in ev.accesses.items():
            if true_access <= 0:
                continue
            # Sampling model: a sample observes this object iff it lands in a
            # window where the object's accesses are in flight, i.e. with
            # probability = the object's share of phase *time* (PEBS
            # semantics).  Falls back to access-count share when the caller
            # cannot attribute time.  Multiplicative noise models PEBS skid
            # and uncounted events (evictions/prefetches), which the paper
            # compensates with CF constants.
            if ev.time_shares is not None and obj in ev.time_shares:
                share = ev.time_shares[obj]
            else:
                share = true_access / max(total_access, 1.0)
            jitter = 1.0 + self.noise * self._rng.standard_normal()
            jitter = float(np.clip(jitter, 0.5, 1.5))
            observed = true_access * jitter
            hit_frac = min(1.0, share * jitter)
            prof_map[obj] = ObjectPhaseProfile(
                phase_index=ev.phase_index, obj=obj,
                data_access=observed,
                n_samples=n_samples,
                samples_with_access=max(hit_frac * n_samples, 1.0),
                phase_time=ev.time)

    def observe_iteration(self, events: Iterable[PhaseTraceEvent]) -> None:
        for ev in events:
            self.observe(ev)

    # -- outputs --------------------------------------------------------------
    def profile(self, phase_index: int, obj: str) -> Optional[ObjectPhaseProfile]:
        return self._acc.get(phase_index, {}).get(obj)

    def profiles_for_phase(self, phase_index: int) -> Dict[str, ObjectPhaseProfile]:
        return dict(self._acc.get(phase_index, {}))

    def phase_time(self, phase_index: int) -> float:
        ts = self._times.get(phase_index)
        return float(np.mean(ts)) if ts else 0.0

    def annotate_graph(self, graph: PhaseGraph) -> None:
        """Write measured times + access counts back into the phase graph."""
        for p in graph:
            t = self.phase_time(p.index)
            if t > 0:
                p.time = t
            for obj, prof in self.profiles_for_phase(p.index).items():
                p.refs[obj] = prof.data_access

    def clear(self) -> None:
        self._acc.clear()
        self._times.clear()
