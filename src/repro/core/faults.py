"""Fault injection and fault-tolerance primitives for the migration stack.

Real tiered hardware breaks the assumptions the copy path was built on:
NVM effective bandwidth collapses by an order of magnitude under
contention (Peng et al., arXiv 2002.06499), device transfers fail
transiently, and a wedged DMA engine can leave a handle that never
completes.  This module gives every layer a shared vocabulary for those
failures:

* **Typed copy errors** — :class:`CopyError` and its refinements
  (:class:`TransientCopyError`, :class:`CopyFailedError`,
  :class:`CopyTimeoutError`) — raised by backends, handled by the movers.
* :class:`FaultSpec` — a *seeded* description of an injected fault
  profile (deterministic: the same spec against the same issue sequence
  produces the same faults — chaos rows are as reproducible as the
  fault-free golden traces).
* :class:`ChaosBackend` — a decorator over any registered
  :class:`~.mover.TierBackend` (sim, channel-sim, jax_async, cpu_pool)
  that injects the spec's faults at the backend boundary, registered as
  ``"chaos"`` in :mod:`.backends`.
* :class:`ChannelHealth` — the per-channel health state machine
  (healthy -> degraded -> quarantined, with probation re-admittance) the
  slack mover feeds from observed faults and consults when choosing
  channels for fetches.
* :class:`DegradedServe` / :class:`EvictionRollback` — the fault events
  the mover emits and the session logs with provenance (iteration,
  phase, reason, channel).

With no :class:`FaultSpec` configured nothing in this module runs on the
hot path: the retry loop executes ``start_move`` exactly once, the
health machine has no faults to record, and every plan/trace stays
bitwise identical to the fault-free pipeline.
"""

from __future__ import annotations

import dataclasses
import random
import zlib
from collections import deque
from typing import Any, Dict, List, Optional, Tuple


# ---------------------------------------------------------------------------
# typed copy errors (the bounded-wait / failure contract of TierBackend)
# ---------------------------------------------------------------------------
class CopyError(RuntimeError):
    """Base class for copy-path failures a mover can handle."""


class TransientCopyError(CopyError):
    """``start_move`` failed but a retry may succeed (driver hiccup,
    momentary channel exhaustion).  The mover retries with exponential
    backoff bounded by the move's slack deadline."""


class CopyFailedError(CopyError):
    """A copy errored at land time: the data never arrived and the
    object's tier did not flip.  Fetches demote to slow-tier service;
    evictions roll back residency."""


class CopyTimeoutError(CopyError, TimeoutError):
    """``wait(handle, timeout=...)`` exceeded its bound before the copy
    landed (the bounded-wait contract: a fence must never hang forever
    on a wedged channel)."""


# ---------------------------------------------------------------------------
# fault events (provenance-carrying, logged by the session)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class DegradedServe:
    """A fetch that exhausted its retries or missed its deadline: the
    consuming phase served the object from the slow tier this iteration
    instead of blocking.  The monitor sees the slowdown as drift and the
    next replan re-prices the move."""

    obj: str
    phase_index: int            # the consuming phase that was demoted
    reason: str                 # retries_exhausted | deadline | late_fail
                                # | admission:cold | admission:over-quota
    channel: int = -1
    slack_s: float = 0.0
    iteration: int = -1         # stamped by the session when logged
    tenant: Optional[str] = None  # owning tenant namespace, if any
    host: Optional[str] = None    # owning cluster host, if any


@dataclasses.dataclass
class EvictionRollback:
    """An eviction copy that failed: the object's residency rolled back
    (it never left the fast tier), so tier accounting stays consistent —
    at the price of capacity the plan thought it had freed.  The session
    audit re-checks the capacity book after any of these."""

    obj: str
    phase_index: int
    reason: str                 # retries_exhausted | late_fail
    channel: int = -1
    iteration: int = -1
    tenant: Optional[str] = None  # owning tenant namespace, if any
    host: Optional[str] = None    # owning cluster host, if any


# ---------------------------------------------------------------------------
# bounded fault log
# ---------------------------------------------------------------------------
class FaultLog:
    """List-like ring buffer for session fault events.

    Long-running chaos/serving loops log a fault event per incident; an
    unbounded list grows without limit.  The ring keeps the most recent
    ``limit`` entries and counts the overwritten rest in :attr:`dropped`
    so provenance *counts* stay exact even after entries age out
    (``len(log) + log.dropped`` == total events ever logged).  A falsy
    limit (0/None) means unbounded."""

    def __init__(self, limit: Optional[int] = None):
        self.limit = int(limit) if limit else 0
        self._entries: deque = deque(maxlen=self.limit or None)
        self.dropped = 0

    def append(self, entry: Any) -> None:
        if self.limit and len(self._entries) >= self.limit:
            self.dropped += 1
        self._entries.append(entry)

    def clear(self) -> None:
        self._entries.clear()
        self.dropped = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __bool__(self) -> bool:
        return bool(self._entries)

    def __iter__(self):
        return iter(self._entries)

    def __getitem__(self, idx):
        if isinstance(idx, slice):
            return list(self._entries)[idx]
        return self._entries[idx]

    def __repr__(self) -> str:
        return (f"FaultLog(len={len(self._entries)}, limit={self.limit}, "
                f"dropped={self.dropped})")


# ---------------------------------------------------------------------------
# channel health state machine
# ---------------------------------------------------------------------------
HEALTHY, DEGRADED, QUARANTINED = "healthy", "degraded", "quarantined"


class ChannelHealth:
    """Healthy -> degraded -> quarantined with probation re-admittance.

    A fault (straggler cancel, late failure, stuck handle) on a channel
    moves it one state down; ``quarantine_after`` consecutive faults
    quarantine it.  Quarantined channels are excluded from the fetch
    channel chooser (:meth:`avoid`) — except that every
    ``probation_interval``-th choose lets one quarantined channel
    through as a probe; a clean landing on a quarantined or degraded
    channel re-admits it one state up.  With no faults recorded the
    machine is empty and :meth:`avoid` returns the empty set, so the
    fault-free chooser is untouched."""

    def __init__(self, quarantine_after: int = 2,
                 probation_interval: int = 8):
        self.quarantine_after = max(1, quarantine_after)
        self.probation_interval = max(1, probation_interval)
        self._state: Dict[int, str] = {}
        self._strikes: Dict[int, int] = {}
        self._chooses = 0           # avoid() calls, drives probation cadence

    def state(self, channel: int) -> str:
        return self._state.get(channel, HEALTHY)

    def record_fault(self, channel: Optional[int]) -> None:
        if channel is None or channel < 0:
            return
        strikes = self._strikes.get(channel, 0) + 1
        self._strikes[channel] = strikes
        if strikes >= self.quarantine_after:
            self._state[channel] = QUARANTINED
        else:
            self._state[channel] = DEGRADED

    def record_success(self, channel: Optional[int]) -> None:
        if channel is None or channel < 0:
            return
        self._strikes[channel] = 0
        state = self._state.get(channel)
        if state == QUARANTINED:
            self._state[channel] = DEGRADED     # probation passed
        elif state == DEGRADED:
            self._state[channel] = HEALTHY

    def avoid(self) -> set:
        """Channels the fetch chooser must skip.  Every
        ``probation_interval``-th call re-admits the lowest-numbered
        quarantined channel for one probe copy."""
        quarantined = sorted(c for c, s in self._state.items()
                             if s == QUARANTINED)
        if not quarantined:
            return set()
        self._chooses += 1
        if self._chooses % self.probation_interval == 0:
            quarantined = quarantined[1:]       # probe the first one
        return set(quarantined)

    def summary(self) -> Dict[int, str]:
        return {c: s for c, s in sorted(self._state.items())
                if s != HEALTHY}


# ---------------------------------------------------------------------------
# fault specification
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Seeded fault profile for :class:`ChaosBackend`.

    All rates are per-``start_move`` probabilities drawn from one
    ``random.Random(seed)`` stream, so a fixed spec against a
    deterministic issue sequence (the virtual-time simulator) reproduces
    the exact same fault pattern run over run.  Multi-host runs give
    each host its own sub-stream (see :func:`host_sub_seed`): a host's
    fault pattern depends only on its own issue sequence, never on how
    the cluster interleaves the hosts.
    """

    seed: int = 0
    #: P(start_move raises TransientCopyError) per attempt — retries
    #: re-roll, so a retry can succeed.
    transient_rate: float = 0.0
    #: P(a copy's handle never completes: ``is_done`` stays false,
    #: completion time goes to +inf, the channel wedges until cancelled).
    stuck_rate: float = 0.0
    #: P(a copy errors at land time: it occupies its channel for the
    #: full duration, then fails — the tier never flips).
    late_fail_rate: float = 0.0
    #: P(a copy opens a straggler window on its channel): bandwidth
    #: collapses by a factor sampled from ``straggler_factor`` for a
    #: duration sampled from ``straggler_duration_s``.
    straggler_rate: float = 0.0
    straggler_factor: Tuple[float, float] = (4.0, 16.0)
    straggler_duration_s: Tuple[float, float] = (0.05, 0.2)
    #: A permanently collapsed channel (the benchmark's "1 straggler
    #: channel" profile): every copy on it runs ``straggler_channel_factor``
    #: times slower.  None = no fixed straggler.
    straggler_channel: Optional[int] = None
    straggler_channel_factor: float = 8.0

    def any_faults(self) -> bool:
        return (self.transient_rate > 0 or self.stuck_rate > 0
                or self.late_fail_rate > 0 or self.straggler_rate > 0
                or self.straggler_channel is not None)


def host_sub_seed(seed: int, host: Optional[str]) -> int:
    """Deterministic per-host sub-seed for a shared cluster fault seed.

    ``None`` (the single-host path) returns ``seed`` unchanged, so
    existing chaos goldens are untouched.  Host ids hash through CRC-32
    (stable across processes and Python versions, unlike ``hash``), so
    two hosts sharing one :class:`FaultSpec` draw from independent
    streams and a host's faults do not depend on scheduling order."""
    if host is None:
        return int(seed)
    return int(seed) ^ zlib.crc32(str(host).encode("utf-8"))


# ---------------------------------------------------------------------------
# chaos backend decorator
# ---------------------------------------------------------------------------
def _obj_name(obj: Any) -> str:
    return getattr(obj, "name", None) or str(obj)


class ChaosBackend:
    """Fault-injecting decorator over any :class:`~.mover.TierBackend`.

    Forwards the full duck-typed backend surface (``start_move`` /
    ``wait`` / ``settle`` / ``complete`` / ``is_done`` / ``cancel`` /
    ``place`` / ``now_fn`` / ...) to the wrapped backend and injects the
    :class:`FaultSpec`'s faults at the boundary:

    * **transient**: ``start_move`` raises :class:`TransientCopyError`
      before touching the inner backend;
    * **stuck**: the issued handle never completes — its completion time
      is stretched to +inf (simulated backends; the channel wedges until
      the mover cancels it) or tagged so ``is_done`` stays false and
      ``wait`` raises :class:`CopyTimeoutError` (real backends);
    * **late failure**: the copy runs to its land time, then errors —
      ``settle`` retires it *without* a tier flip and
      ``complete``/``wait`` raise :class:`CopyFailedError`;
    * **straggler**: the copy's channel bandwidth collapses by a sampled
      factor (timed backends only — completion times are stretched and
      the channel stays busy accordingly).

    Timing faults (stuck/straggler stretching) need the simulated
    backends' ``start``/``done``/``channel`` handle surface; on real
    backends they degrade to the tag-based stuck path.  ``fault_log``
    records every injected fault as ``(kind, obj, channel)``.
    """

    def __init__(self, inner: Any, spec: Optional[FaultSpec] = None,
                 host: Optional[str] = None):
        self.inner = inner
        self.spec = spec or FaultSpec()
        #: owning cluster host (None on the single-host path).  Each
        #: host draws from its own seeded sub-stream, so a multi-host
        #: chaos run is deterministic regardless of host scheduling
        #: order — host A's faults never consume host B's draws.
        self.host = host
        self.rng = random.Random(host_sub_seed(self.spec.seed, host))
        self.fault_log: List[Tuple[str, str, int]] = []
        # open straggler windows: channel -> (start, end, factor)
        self._windows: Dict[int, Tuple[float, float, float]] = {}

    def __getattr__(self, name: str) -> Any:
        # anything not overridden (place, now_fn, machine, copies,
        # busy_seconds, max_concurrency, cancel, shutdown, ...) passes
        # straight through to the wrapped backend
        return getattr(self.inner, name)

    # ------------------------------------------------------------------ issue
    def _straggler_factor_for(self, channel: int, t: float) -> float:
        spec = self.spec
        if (spec.straggler_channel is not None
                and channel == spec.straggler_channel):
            return spec.straggler_channel_factor
        win = self._windows.get(channel)
        if win is not None and win[0] <= t < win[1]:
            return win[2]
        if spec.straggler_rate > 0 and self.rng.random() < spec.straggler_rate:
            f = self.rng.uniform(*spec.straggler_factor)
            d = self.rng.uniform(*spec.straggler_duration_s)
            self._windows[channel] = (t, t + d, f)
            return f
        return 1.0

    def _stretch(self, handle: Any, new_done: float) -> None:
        """Stretch a timed handle's completion and keep the wrapped
        engine's channel bookkeeping consistent (the channel stays busy
        for the stretched duration — a straggler slows its queue too)."""
        ch = getattr(handle, "channel", None)
        free = getattr(self.inner, "_free_at", None)
        if (free is not None and ch is not None
                and free[ch] <= handle.done + 1e-12):
            free[ch] = new_done
        handle.done = new_done

    def start_move(self, obj: Any, dst: str, after: Any = None,
                   avoid: Any = None, prefer: Any = None) -> Any:
        if (self.spec.transient_rate > 0
                and self.rng.random() < self.spec.transient_rate):
            self.fault_log.append(("transient", _obj_name(obj), -1))
            raise TransientCopyError(
                f"injected transient start_move failure: {_obj_name(obj)}"
                f" -> {dst}")
        kwargs = {}
        if after is not None:
            kwargs["after"] = after
        if avoid:
            kwargs["avoid"] = avoid
        if prefer:
            try:
                h = self.inner.start_move(obj, dst, prefer=prefer, **kwargs)
                return self._post_issue(obj, h)
            except TypeError:   # inner without tenant channel preference
                pass
        try:
            h = self.inner.start_move(obj, dst, **kwargs)
        except TypeError:       # inner without chaining / channel choice
            h = self.inner.start_move(obj, dst)
        return self._post_issue(obj, h)

    def _post_issue(self, obj: Any, h: Any) -> Any:
        if h is None:
            return None
        ch = getattr(h, "channel", None)
        start, done = getattr(h, "start", None), getattr(h, "done", None)
        if (self.spec.stuck_rate > 0
                and self.rng.random() < self.spec.stuck_rate):
            h._chaos_stuck = True
            if done is not None:
                self._stretch(h, float("inf"))
            self.fault_log.append(
                ("stuck", _obj_name(obj), ch if ch is not None else -1))
            return h
        if (self.spec.late_fail_rate > 0
                and self.rng.random() < self.spec.late_fail_rate):
            h._chaos_fail = True    # logged when it retires at land time
        if ch is not None and start is not None and done is not None:
            factor = self._straggler_factor_for(ch, start)
            if factor > 1.0:
                self._stretch(h, start + (done - start) * factor)
        return h

    # --------------------------------------------------------------- landing
    def settle(self, now: float = 0.0) -> None:
        """Retire due late-failing copies *without* a tier flip, then let
        the wrapped backend land the rest."""
        open_copies = (getattr(self.inner, "copies", None)
                       or getattr(self.inner, "_open", None) or ())
        for c in list(open_copies):
            if not getattr(c, "_chaos_fail", False) or getattr(c, "landed",
                                                               False):
                continue
            done = getattr(c, "done", None)
            if done is not None:
                due = done <= now
            else:
                probe = getattr(self.inner, "is_done", None)
                due = probe(c) if probe is not None else True
            if due:
                c.landed = True     # retired; tier never flips
                self.fault_log.append(
                    ("late_fail", _obj_name(getattr(c, "obj", "?")),
                     getattr(c, "channel", -1)))
        inner_settle = getattr(self.inner, "settle", None)
        if inner_settle is not None:
            inner_settle(now)

    def _raise_injected(self, handle: Any) -> None:
        if getattr(handle, "_chaos_stuck", False):
            raise CopyTimeoutError(
                f"injected stuck handle: {_obj_name(getattr(handle, 'obj', '?'))}"
                " never completes")
        if getattr(handle, "_chaos_fail", False):
            handle.landed = True    # retired; tier never flips
            self.fault_log.append(
                ("late_fail", _obj_name(getattr(handle, "obj", "?")),
                 getattr(handle, "channel", -1)))
            raise CopyFailedError(
                f"injected copy failure at land time: "
                f"{_obj_name(getattr(handle, 'obj', '?'))}")

    def wait(self, handle: Any, timeout: Optional[float] = None) -> Any:
        if handle is None:
            return 0.0
        self._raise_injected(handle)
        try:
            return self.inner.wait(handle, timeout=timeout)
        except TypeError:           # inner without the bounded-wait surface
            return self.inner.wait(handle)

    def complete(self, handle: Any) -> None:
        if handle is None:
            return
        self._raise_injected(handle)
        complete = getattr(self.inner, "complete", None)
        if complete is not None:
            complete(handle)
        else:
            self.inner.wait(handle)

    def is_done(self, handle: Any) -> bool:
        if handle is None:
            return True
        if getattr(handle, "_chaos_stuck", False):
            return False
        probe = getattr(self.inner, "is_done", None)
        if probe is not None:
            return probe(handle)
        done = getattr(handle, "done", None)
        now_fn = getattr(self.inner, "now_fn", None)
        if done is not None and now_fn is not None:
            return done <= now_fn()
        return True
