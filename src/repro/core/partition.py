"""Large data object partitioning (paper §3.2 "Handling large data objects").

An object larger than the fast tier can never be migrated whole.  The paper
partitions *one-dimensional arrays with regular references* into chunks that
are profiled and placed independently, and notes the trade-off: chunking adds
movement frequency that is rarely hidden (only FT benefits in their suite).

``partition_object`` splits a registered object into equal chunks; payloads
that are single 1-D JAX arrays are physically split, otherwise the chunks are
logical byte-ranges (simulation objects).  The runtime decides *whether* to
chunk via ``should_partition`` — the conservative policy from the paper.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional

import jax.numpy as jnp

from .data_objects import DataObject, ObjectRegistry
from .phase import PhaseGraph


def should_partition(obj: DataObject, fast_capacity: int,
                     *, threshold: float = 1.0) -> bool:
    """Partition only objects that cannot fit (``size > threshold*capacity``)
    and are declared chunkable (regular 1-D references)."""
    return obj.chunkable and obj.size_bytes > threshold * fast_capacity


def partition_object(registry: ObjectRegistry, name: str,
                     chunk_bytes: int) -> List[DataObject]:
    """Split ``name`` into ceil(size/chunk_bytes) chunks, replacing it."""
    obj = registry[name]
    if chunk_bytes <= 0:
        raise ValueError("chunk_bytes must be positive")
    n_chunks = max(1, math.ceil(obj.size_bytes / chunk_bytes))
    if n_chunks == 1:
        return [obj]

    payloads: List[Optional[object]] = [None] * n_chunks
    if obj.payload is not None and hasattr(obj.payload, "ndim") \
            and getattr(obj.payload, "ndim", 0) == 1:
        arr = obj.payload
        per = math.ceil(arr.shape[0] / n_chunks)
        payloads = [arr[i * per:(i + 1) * per] for i in range(n_chunks)]

    chunks = []
    remaining = obj.size_bytes
    for i in range(n_chunks):
        sz = min(chunk_bytes, remaining)
        remaining -= sz
        chunks.append(registry.register(DataObject(
            name=f"{name}#{i}", size_bytes=sz, chunkable=False,
            payload=payloads[i], parent=name, chunk_index=i,
            tier=obj.tier, pinned=obj.pinned)))
    registry.remove(name)
    return chunks


def split_refs_to_chunks(graph: PhaseGraph, name: str, chunks: List[DataObject],
                         per_chunk_refs: Optional[Dict[int, Dict[int, float]]] = None
                         ) -> None:
    """Rewrite phase reference counts of a partitioned object.

    ``per_chunk_refs``: optional {phase_index: {chunk_index: accesses}} from
    chunk-aware profiling; defaults to an even split (regular references)."""
    n = len(chunks)
    for ph in graph:
        if name not in ph.refs:
            continue
        total = ph.refs.pop(name)
        if per_chunk_refs and ph.index in per_chunk_refs:
            dist = per_chunk_refs[ph.index]
            s = sum(dist.values()) or 1.0
            for c in chunks:
                ph.refs[c.name] = total * dist.get(c.chunk_index, 0.0) / s
        else:
            for c in chunks:
                ph.refs[c.name] = total / n


def auto_partition(registry: ObjectRegistry, graph: PhaseGraph,
                   fast_capacity: int, *, chunk_divisor: int = 4) -> List[str]:
    """Apply the conservative policy: chunk each chunkable object that cannot
    fit the fast tier into ``capacity/chunk_divisor``-byte chunks."""
    partitioned = []
    for name in list(registry.names()):
        obj = registry[name]
        if should_partition(obj, fast_capacity):
            chunk_bytes = max(1, fast_capacity // chunk_divisor)
            chunks = partition_object(registry, name, chunk_bytes)
            split_refs_to_chunks(graph, name, chunks)
            partitioned.append(name)
    return partitioned
