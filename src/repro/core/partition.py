"""Large data object partitioning (paper §3.2 "Handling large data objects"),
extended with skew-aware repartitioning.

An object larger than the fast tier can never be migrated whole.  The paper
partitions *one-dimensional arrays with regular references* into equal chunks
that are profiled and placed independently.  Equal chunks are the right
answer only when references really are regular: under skewed access (graph
adjacency with power-law degrees, KV caches with a sliding hot window) an
even split smears the hot subset across every chunk and the knapsack can no
longer pick just the hot head.

**Skew-aware partitioning** uses the profiler's measured per-object access
histograms (``ObjectPhaseProfile.bin_weights``, sampled PEBS-style): the
object's byte range is split by recursive bisection until each chunk's
access density is near-uniform *in every profiled phase* (or a minimum chunk
floor is hit), so chunk boundaries land on the access CDF's knees — small
chunks over the hot head, coarse chunks over the cold tail.  Chunks larger
than the conservative ``capacity/chunk_divisor`` ceiling are always split
further, preserving the paper's policy as the uniform-access limit.

``auto_partition`` decides per object: measured histograms -> skew-aware
bisection; no histograms -> the paper's equal chunking.  ``resplit_refs``
rewrites per-phase reference counts from the same measured histograms (per-
chunk attribution), falling back to size fractions, and is re-run on every
(re)plan so drifted access patterns re-attribute without re-partitioning.

**Leaf alignment** (``auto_partition(..., leaf_aligned=True)``): objects
registered from pytrees carry per-leaf byte spans; snapping chunk cuts to
the nearest leaf boundary (:func:`snap_to_leaf_boundaries`) makes every
chunk moveable as a set of *whole arrays* on real backends — no sub-leaf
copies.

**Coalescing** (:func:`coalesce_chunks`): bisection only ever splits, so
when drift moves the hot window, stale fine chunks linger and the registry
grows monotonically.  The coalescing pass re-merges *adjacent* chunks whose
measured per-phase access densities converged and whose current tiers
agree (never past the conservative ``capacity/chunk_divisor`` ceiling),
capping registry growth across long drift sequences while leaving density
edges — and therefore plan quality — intact.

**Multi-resolution mode** (refined histograms, ``RuntimeConfig.
histogram_refine``): measured histograms are variable-width
:class:`~.histogram.Histogram`\\ s whose hot bins have been adaptively
re-binned finer, so (a) :func:`skew_boundaries` with ``local_floor`` may
cut below the legacy one-bin ceiling — each segment's min-chunk floor is
bounded by the *finest measured bin overlapping it*, with splits
allocated worst-imbalance-first (mass-weighted) under the chunk budget —
and (b) :func:`resplit_hot_chunks` re-splits *existing* chunks whose
refined densities turned imbalanced, which is what lets a previously
coalesced chunk re-split when drift re-heats it.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .data_objects import DataObject, ObjectRegistry
from .histogram import Histogram, uniform_mass
from .phase import PhaseGraph
from .profiler import PhaseProfiler


def should_partition(obj: DataObject, fast_capacity: int,
                     *, threshold: float = 1.0) -> bool:
    """Partition only objects that cannot fit (``size > threshold*capacity``)
    and are declared chunkable (regular 1-D references)."""
    return obj.chunkable and obj.size_bytes > threshold * fast_capacity


# ---------------------------------------------------------------------------
# measured-histogram geometry
# ---------------------------------------------------------------------------
def bin_mass(weights, lo_frac: float, hi_frac: float) -> float:
    """Integral of the piecewise-constant access density described by
    ``weights`` over the fractional byte range [lo_frac, hi_frac).

    ``weights`` is either a legacy fixed-width weight sequence (relative
    weights over equal-width bins spanning [0, 1]) or a multi-resolution
    :class:`~.histogram.Histogram` (variable-width bins); uniform inputs
    take the bit-identical legacy arithmetic path."""
    if isinstance(weights, Histogram):
        return weights.mass_fraction(lo_frac, hi_frac)
    return uniform_mass(weights, lo_frac, hi_frac)


def _finest_width(bins: Sequence, lo_frac: float, hi_frac: float) -> float:
    """Narrowest measured bin (byte fraction) overlapping [lo_frac,
    hi_frac) across all phase histograms — the local measurement
    resolution the partitioner's min-chunk floor is bounded by."""
    finest = 1.0
    for b in bins:
        if isinstance(b, Histogram):
            finest = min(finest, b.finest_width(lo_frac, hi_frac))
        else:
            n = len(b)
            if n:
                finest = min(finest, 1.0 / n)
    return finest


def chunk_spans(registry: ObjectRegistry, parent: str
                ) -> List[Tuple[DataObject, int, int]]:
    """Chunks of ``parent`` in index order with their [lo, hi) byte spans."""
    chunks = sorted((o for o in registry if o.parent == parent),
                    key=lambda o: o.chunk_index or 0)
    out, acc = [], 0
    for c in chunks:
        out.append((c, acc, acc + c.size_bytes))
        acc += c.size_bytes
    return out


def _clean_bins(phase_bins: Sequence) -> List:
    """Drop empty / zero-mass histograms; pass Histograms through and
    coerce legacy sequences to float arrays."""
    out: List = []
    for b in phase_bins:
        if isinstance(b, Histogram):
            if b.n_bins and b.total > 0.0:
                out.append(b)
        else:
            arr = np.asarray(b, dtype=np.float64)
            if arr.size and arr.sum() > 0.0:
                out.append(arr)
    return out


def skew_boundaries(size_bytes: int, phase_bins: Sequence,
                    *, coarse_bytes: int, min_chunk_bytes: int,
                    tol: float = 0.15, max_chunks: int = 64,
                    local_floor: bool = False) -> List[int]:
    """Chunk boundaries from measured access histograms by recursive
    bisection.

    A segment is split while it exceeds ``coarse_bytes`` (the paper's
    conservative ceiling — large chunks throttle the mover regardless of
    skew), or while any profiled phase's access mass is imbalanced across
    its midpoint by more than ``tol`` (relative to the segment's mass) and
    both halves stay above the min-chunk floor.  ``phase_bins`` entries are
    legacy fixed-width weight sequences or multi-resolution
    :class:`~.histogram.Histogram`\\ s.  Returns interior + end boundaries:
    ``[b_1, ..., b_k, size_bytes]``.

    With ``local_floor`` (the multi-resolution mode), the floor of each
    segment is bounded by the *finest measured bin* overlapping it rather
    than a single global constant: where refined histograms carry fine hot
    bins the cuts may go just as fine (down to ``min_chunk_bytes``), while
    coarsely-binned cold spans stop at their own resolution.  Splits are
    then allocated worst-imbalance-first under the ``max_chunks`` budget
    instead of depth-limited, so a sharp hot head can cut far below the
    legacy one-bin ceiling without exploding the chunk count."""
    bins = _clean_bins(phase_bins)

    def imbalance(lo: int, mid: int, hi: int) -> float:
        worst = 0.0
        for b in bins:
            seg = bin_mass(b, lo / size_bytes, hi / size_bytes)
            if seg <= 1e-12:
                continue
            left = bin_mass(b, lo / size_bytes, mid / size_bytes)
            worst = max(worst, abs(2.0 * left - seg) / seg)
        return worst

    if local_floor:
        return _mr_boundaries(size_bytes, bins, imbalance, 0, size_bytes,
                              coarse_bytes=coarse_bytes,
                              min_chunk_bytes=min_chunk_bytes, tol=tol,
                              max_chunks=max_chunks)

    max_depth = max(1, int(math.ceil(math.log2(max(max_chunks, 2)))))
    bounds: List[int] = []

    def rec(lo: int, hi: int, depth: int) -> None:
        size = hi - lo
        mid = lo + size // 2
        must = size > coarse_bytes
        may = (size >= 2 * min_chunk_bytes and depth < max_depth
               and imbalance(lo, mid, hi) > tol)
        if (must or may) and mid > lo and mid < hi:
            rec(lo, mid, depth + 1)
            rec(mid, hi, depth + 1)
        else:
            bounds.append(hi)

    rec(0, size_bytes, 0)
    return bounds


def _mr_boundaries(size_bytes: int, bins: Sequence, imbalance, seg_lo: int,
                   seg_hi: int, *, coarse_bytes: int, min_chunk_bytes: int,
                   tol: float, max_chunks: int) -> List[int]:
    """Worst-imbalance-first bisection of [seg_lo, seg_hi) under a chunk
    budget, with the per-segment min-chunk floor bounded by the finest
    measured bin overlapping the segment (multi-resolution mode)."""
    import heapq

    def floor_of(lo: int, hi: int) -> int:
        fw = _finest_width(bins, lo / size_bytes, hi / size_bytes)
        return max(min_chunk_bytes, int(fw * size_bytes))

    def seg_mass(lo: int, hi: int) -> float:
        return max((bin_mass(b, lo / size_bytes, hi / size_bytes)
                    for b in bins), default=0.0)

    def entry(lo: int, hi: int):
        size = hi - lo
        mid = lo + size // 2
        must = size > coarse_bytes
        imb = imbalance(lo, mid, hi) if mid > lo and mid < hi else 0.0
        may = (mid > lo and mid < hi and imb > tol
               and size >= 2 * floor_of(lo, hi))
        # mandatory splits first (the mover-throttle ceiling holds
        # regardless of the budget), then by mass-weighted imbalance: a
        # badly-cut *hot* segment wins split budget over an equally
        # imbalanced cold one (relative imbalance alone would spend the
        # budget resolving noise in the tail)
        return (0 if must else 1, -imb * seg_mass(lo, hi), lo, hi,
                must or may)

    heap = [entry(seg_lo, seg_hi)]
    done: List[Tuple[int, int]] = []
    while heap:
        rank, _, lo, hi, splittable = heapq.heappop(heap)
        over_budget = len(heap) + len(done) + 1 >= max_chunks
        if not splittable or (over_budget and rank != 0):
            done.append((lo, hi))
            continue
        mid = lo + (hi - lo) // 2
        heapq.heappush(heap, entry(lo, mid))
        heapq.heappush(heap, entry(mid, hi))
    done.sort()
    return [hi for _, hi in done]


def snap_to_leaf_boundaries(bounds: Sequence[int],
                            leaf_spans: Sequence[Tuple[str, int, int]],
                            size_bytes: int) -> List[int]:
    """Snap each interior chunk cut to the nearest registered leaf boundary.

    ``leaf_spans`` is the object's ``(path, offset, nbytes)`` list recorded
    at pytree registration.  Cuts that collapse onto the same leaf edge (or
    onto 0 / ``size_bytes``) are deduplicated, so an object with fewer
    leaves than requested chunks simply degenerates to leaf-granular
    chunks.  The trailing boundary is always ``size_bytes``."""
    edges = sorted({off for _, off, _ in leaf_spans if 0 < off < size_bytes})
    if not edges:
        return [size_bytes]
    snapped = set()
    for b in bounds:
        if b >= size_bytes:
            continue
        e = min(edges, key=lambda x: (abs(x - b), x))
        snapped.add(e)
    return sorted(snapped) + [size_bytes]


# ---------------------------------------------------------------------------
# physical / logical splitting
# ---------------------------------------------------------------------------
def partition_object_spans(registry: ObjectRegistry, name: str,
                           boundaries: Sequence[int]) -> List[DataObject]:
    """Split ``name`` into chunks at the given byte ``boundaries`` (strictly
    increasing, ending at the object's size), replacing it in the registry."""
    obj = registry[name]
    bounds = list(boundaries)
    if not bounds or bounds[-1] != obj.size_bytes:
        raise ValueError("boundaries must end at the object's size")
    if any(b2 <= b1 for b1, b2 in zip([0] + bounds, bounds)):
        raise ValueError("boundaries must be strictly increasing")
    if len(bounds) == 1:
        return [obj]

    n_chunks = len(bounds)
    payloads: List[Optional[object]] = [None] * n_chunks
    if obj.payload is not None and hasattr(obj.payload, "ndim") \
            and getattr(obj.payload, "ndim", 0) == 1:
        arr = obj.payload
        n_el = arr.shape[0]
        cuts = [0] + [round(b * n_el / obj.size_bytes) for b in bounds]
        cuts[-1] = n_el
        payloads = [arr[cuts[i]:cuts[i + 1]] for i in range(n_chunks)]

    chunks = []
    lo = 0
    for i, hi in enumerate(bounds):
        chunks.append(registry.register(DataObject(
            name=f"{name}#{i}", size_bytes=hi - lo, chunkable=False,
            payload=payloads[i], parent=name, chunk_index=i,
            tier=obj.tier, pinned=obj.pinned)))
        lo = hi
    registry.remove(name)
    return chunks


def partition_object(registry: ObjectRegistry, name: str,
                     chunk_bytes: int) -> List[DataObject]:
    """Split ``name`` into ceil(size/chunk_bytes) equal chunks (the paper's
    regular-reference policy), replacing it."""
    obj = registry[name]
    if chunk_bytes <= 0:
        raise ValueError("chunk_bytes must be positive")
    n_chunks = max(1, math.ceil(obj.size_bytes / chunk_bytes))
    if n_chunks == 1:
        return [obj]
    bounds = [min((i + 1) * chunk_bytes, obj.size_bytes)
              for i in range(n_chunks)]
    return partition_object_spans(registry, name, bounds)


# ---------------------------------------------------------------------------
# reference attribution
# ---------------------------------------------------------------------------
def resplit_refs(graph: PhaseGraph, registry: ObjectRegistry,
                 profiler: Optional[PhaseProfiler] = None,
                 phases: Optional[Sequence[int]] = None) -> None:
    """Re-attribute every partitioned parent's per-phase reference counts to
    its chunks, using the profiler's measured histograms when available
    (falling back to size fractions).

    Safe to call on every (re)plan: ``annotate_graph`` re-writes parent-name
    reference counts from the (parent-keyed) profiles, and this pass splits
    them back down to chunk granularity with the freshest attribution.

    ``phases`` scopes the re-attribution to the listed phase indices (the
    serving-tick replan path: an undrifted phase was skipped by the scoped
    ``annotate_graph`` too, so its refs still hold the previous build's
    chunk attribution — recomputing it from the same profile version would
    write identical values).
    """
    scope = None if phases is None else set(phases)
    parents = sorted({o.parent for o in registry if o.parent is not None})
    for parent in parents:
        spans = chunk_spans(registry, parent)
        if not spans:
            continue
        total_bytes = sum(c.size_bytes for c, _, _ in spans) or 1
        for ph in graph:
            if scope is not None and ph.index not in scope:
                continue
            if parent not in ph.refs:
                # A parent that was profiled but faded below annotate_graph's
                # one-access floor has no ref key anymore — its chunks are
                # unreferenced too, so stale attribution from an earlier
                # build must not linger (it would shield the cold chunks
                # from eviction forever).
                if (profiler is not None
                        and profiler.profile(ph.index, parent) is not None):
                    for c, _, _ in spans:
                        ph.refs.pop(c.name, None)
                continue
            total = ph.refs.pop(parent)
            for c, _, _ in spans:           # drop stale chunk attribution
                ph.refs.pop(c.name, None)
            bins = None
            if profiler is not None:
                prof = profiler.profile(ph.index, parent)
                if prof is not None:
                    bins = prof.bin_weights
            if bins is None:
                for c, lo, hi in spans:
                    ph.refs[c.name] = total * c.size_bytes / total_bytes
            else:
                masses = [bin_mass(bins, lo / total_bytes, hi / total_bytes)
                          for _, lo, hi in spans]
                norm = sum(masses) or 1.0
                for (c, _, _), m in zip(spans, masses):
                    r = total * m / norm
                    if r > 0.0:
                        # a zero-access chunk is unreferenced this phase; a
                        # 0.0 entry would still count as a reference (dict
                        # membership) and shield the chunk from eviction
                        ph.refs[c.name] = r


# ---------------------------------------------------------------------------
# chunk coalescing (re-merging)
# ---------------------------------------------------------------------------
def coalesce_chunks(registry: ObjectRegistry, graph: PhaseGraph,
                    profiler: Optional[PhaseProfiler],
                    fast_capacity: int, *, chunk_divisor: int = 4,
                    tol: float = 0.15, cold_floor: float = 0.05
                    ) -> Dict[str, Tuple[int, int]]:
    """Merge adjacent chunks whose measured densities converged.

    For every partitioned parent with measured per-phase histograms, two
    adjacent chunks are merge candidates when, in *every* profiled phase,
    their per-byte access densities agree within ``tol`` (relative to the
    larger) or both sit below ``cold_floor`` x the parent's uniform density
    (converged-cold).  Runs of candidates additionally require agreeing
    current tiers (a merged chunk has one residency), matching payload-free
    chunks (physical slices cannot be re-joined without a copy), and a
    merged size within the conservative ``capacity/chunk_divisor`` mover
    ceiling.  Each run also re-checks convergence against its *first*
    member, so a slowly drifting density cannot chain A~B, B~C into a
    merged A..C with A and C far apart.

    Per-phase chunk references are conserved exactly: a merged chunk's
    count is the sum of its members' (the property tests pin this).
    Returns ``{parent: (chunks_before, chunks_after)}`` for every parent
    that changed."""
    coarse = max(1, fast_capacity // chunk_divisor)
    out: Dict[str, Tuple[int, int]] = {}
    parents = sorted({o.parent for o in registry if o.parent is not None})
    for parent in parents:
        # histogram check first: it is O(profiled phases) while chunk_spans
        # scans the whole registry, and most parents have no measured
        # densities on any given tick
        phase_bins = (profiler.object_bins(parent)
                      if profiler is not None else {})
        if not phase_bins:
            continue        # no measured densities: nothing to judge by
        spans = chunk_spans(registry, parent)
        if len(spans) < 2:
            continue
        if any(c.payload is not None for c, _, _ in spans):
            continue        # physical slices: re-joining would copy
        total = spans[-1][2] or 1
        # per-phase per-byte density of each chunk (mass / byte fraction;
        # the parent's uniform density is 1.0 on this scale)
        dens = {phi: [bin_mass(bins, lo / total, hi / total)
                      / max((hi - lo) / total, 1e-300)
                      for _, lo, hi in spans]
                for phi, bins in sorted(phase_bins.items())}

        def converged(i: int, j: int) -> bool:
            for dd in dens.values():
                a, b = dd[i], dd[j]
                hi_ = max(a, b)
                if hi_ <= cold_floor:
                    continue            # both converged-cold in this phase
                if abs(a - b) > tol * hi_:
                    return False
            return True

        runs: List[List[int]] = []
        cur = [0]
        for k in range(1, len(spans)):
            run_size = spans[k][2] - spans[cur[0]][1]
            if (spans[k][0].tier == spans[cur[0]][0].tier
                    and run_size <= coarse
                    and converged(cur[-1], k) and converged(cur[0], k)):
                cur.append(k)
            else:
                runs.append(cur)
                cur = [k]
        runs.append(cur)
        if all(len(r) == 1 for r in runs):
            continue

        # rebuild the parent's chunking from the merged runs
        merged_refs: List[Dict[int, float]] = []
        specs = []
        for run in runs:
            members = [spans[i][0] for i in run]
            lo, hi = spans[run[0]][1], spans[run[-1]][2]
            specs.append((hi - lo, members[0].tier, members[0].pinned))
            refs: Dict[int, float] = {}
            for ph in graph:
                s = 0.0
                present = False
                for m in members:
                    if m.name in ph.refs:
                        present = True
                        s += ph.refs[m.name]
                if present:
                    refs[ph.index] = s
            merged_refs.append(refs)
        for c, _, _ in spans:
            for ph in graph:
                ph.refs.pop(c.name, None)
            registry.remove(c.name)
        for k, (size, tier, pinned) in enumerate(specs):
            registry.register(DataObject(
                name=f"{parent}#{k}", size_bytes=size, chunkable=False,
                parent=parent, chunk_index=k, tier=tier, pinned=pinned))
            for phi, r in merged_refs[k].items():
                graph[phi].refs[f"{parent}#{k}"] = r
        out[parent] = (len(spans), len(runs))
    return out


# ---------------------------------------------------------------------------
# hot-chunk re-splitting (multi-resolution mode)
# ---------------------------------------------------------------------------
def resplit_hot_chunks(registry: ObjectRegistry, graph: PhaseGraph,
                       profiler: Optional[PhaseProfiler],
                       fast_capacity: int, *, chunk_divisor: int = 4,
                       tol: float = 0.15, max_chunks: int = 64,
                       min_chunk_divisor: int = 64,
                       leaf_aligned: bool = False
                       ) -> Dict[str, Tuple[int, int]]:
    """Re-split existing chunks whose measured densities turned imbalanced.

    Bisection only runs when a parent is first partitioned, and
    :func:`coalesce_chunks` only ever merges — so when drift re-heats a
    merged (or originally coarse) chunk, nothing re-cuts it and its hot
    head stays smeared across the whole chunk.  With multi-resolution
    histograms the refined bin edges *can* resolve sub-chunk structure;
    this pass walks every partitioned parent's chunks and re-splits any
    chunk whose measured per-phase mass is imbalanced beyond ``tol``
    (worst-imbalance-first, min-chunk floor bounded by the finest local
    bin, parent chunk count capped at ``max_chunks``).

    Sub-chunks inherit the split chunk's tier/pinned state, and the split
    chunk's per-phase reference counts are conserved exactly — distributed
    over its sub-chunks by measured histogram mass (size fractions when a
    phase has no histogram).  Returns ``{parent: (before, after)}`` for
    every parent that changed.

    ``leaf_aligned`` makes the pass a **no-op**: leaf-aligned chunks are
    whole-array units by contract, a midpoint bisection would cut inside
    a leaf (exactly the sub-leaf copies the flag forbids), and the
    parent's leaf spans are no longer recorded after partitioning, so
    cuts cannot be re-snapped.  (Recording per-chunk leaf spans to allow
    leaf-granular re-splits is a follow-on.)"""
    if leaf_aligned:
        return {}
    coarse = max(1, fast_capacity // chunk_divisor)
    floor = max(coarse // min_chunk_divisor, 1)
    out: Dict[str, Tuple[int, int]] = {}
    parents = sorted({o.parent for o in registry if o.parent is not None})
    for parent in parents:
        spans = chunk_spans(registry, parent)
        if not spans:
            continue
        if any(c.payload is not None for c, _, _ in spans):
            continue        # physical slices: re-cutting would copy
        phase_bins = (profiler.object_bins(parent)
                      if profiler is not None else {})
        bins = _clean_bins(list(phase_bins.values()))
        if not bins:
            continue        # no measured densities: nothing to judge by
        size = spans[-1][2] or 1

        def imbalance(lo: int, mid: int, hi: int) -> float:
            worst = 0.0
            for b in bins:
                seg = bin_mass(b, lo / size, hi / size)
                if seg <= 1e-12:
                    continue
                left = bin_mass(b, lo / size, mid / size)
                worst = max(worst, abs(2.0 * left - seg) / seg)
            return worst

        budget = max_chunks - len(spans)
        sub_bounds: Dict[str, List[int]] = {}
        # allocate the parent-wide split budget worst-imbalance-first
        # across chunks (span order would let an early, mildly imbalanced
        # chunk starve the re-heated one this pass exists for)
        def chunk_imb(lo: int, hi: int) -> float:
            mid = lo + (hi - lo) // 2
            return imbalance(lo, mid, hi) if mid > lo and mid < hi else 0.0

        for c, lo, hi in sorted(spans,
                                key=lambda s_: (-chunk_imb(s_[1], s_[2]),
                                                s_[1])):
            if budget <= 0:
                break
            cuts = _mr_boundaries(
                size, bins, imbalance, lo, hi, coarse_bytes=coarse,
                min_chunk_bytes=floor, tol=tol,
                max_chunks=min(budget + 1, max_chunks))
            if len(cuts) > 1:
                sub_bounds[c.name] = cuts
                budget -= len(cuts) - 1
        if not sub_bounds:
            continue

        # rebuild the parent's chunking with the re-split chunks expanded
        specs: List[Tuple[int, str, bool]] = []
        merged_refs: List[Dict[int, float]] = []
        for c, lo, hi in spans:
            cuts = sub_bounds.get(c.name, [hi])
            seg_lo = lo
            for cut in cuts:
                specs.append((cut - seg_lo, c.tier, c.pinned))
                refs: Dict[int, float] = {}
                for phi in range(len(graph)):
                    ph = graph[phi]
                    if c.name not in ph.refs:
                        continue
                    total_ref = ph.refs[c.name]
                    b = phase_bins.get(phi)
                    chunk_m = (bin_mass(b, lo / size, hi / size)
                               if b is not None else 0.0)
                    if b is not None and chunk_m > 1e-300:
                        frac = bin_mass(b, seg_lo / size,
                                        cut / size) / chunk_m
                    else:
                        frac = (cut - seg_lo) / max(hi - lo, 1)
                    r = total_ref * frac
                    if r > 0.0:
                        refs[phi] = r
                merged_refs.append(refs)
                seg_lo = cut
        for c, _, _ in spans:
            for ph in graph:
                ph.refs.pop(c.name, None)
            registry.remove(c.name)
        for k, (sz, tier, pinned) in enumerate(specs):
            registry.register(DataObject(
                name=f"{parent}#{k}", size_bytes=sz, chunkable=False,
                parent=parent, chunk_index=k, tier=tier, pinned=pinned))
            for phi, r in merged_refs[k].items():
                graph[phi].refs[f"{parent}#{k}"] = r
        out[parent] = (len(spans), len(specs))
    return out


# ---------------------------------------------------------------------------
# policy
# ---------------------------------------------------------------------------
def auto_partition(registry: ObjectRegistry, graph: PhaseGraph,
                   fast_capacity: int, *, chunk_divisor: int = 4,
                   profiler: Optional[PhaseProfiler] = None,
                   skew_aware: bool = True,
                   max_chunks: int = 64,
                   leaf_aligned: bool = False,
                   multi_res: bool = False) -> List[str]:
    """Chunk each chunkable object that cannot fit the fast tier.

    With measured per-object histograms (``profiler`` given and the object
    observed with per-chunk attribution) and ``skew_aware``, boundaries come
    from :func:`skew_boundaries`; otherwise the paper's conservative equal
    split into ``capacity/chunk_divisor``-byte chunks.  With ``multi_res``
    (refined multi-resolution histograms), the bisection allocates splits
    worst-imbalance-first and its min-chunk floor is bounded by the finest
    *local* measured bin instead of a global constant — hot-head chunks can
    cut below the legacy one-bin ceiling.  With ``leaf_aligned`` and a
    pytree-registered object, cuts snap to the nearest leaf boundary
    (chunks moveable as whole arrays).  Per-phase references are
    re-attributed from the same histograms (:func:`resplit_refs`)."""
    coarse = max(1, fast_capacity // chunk_divisor)
    partitioned = []
    for name in list(registry.names()):
        obj = registry[name]
        if not should_partition(obj, fast_capacity):
            continue
        phase_bins = (list(profiler.object_bins(name).values())
                      if profiler is not None else [])
        if skew_aware and phase_bins:
            min_chunk = (max(coarse // 64, 1) if multi_res
                         else max(coarse // 16, 1))
            bounds = skew_boundaries(
                obj.size_bytes, phase_bins, coarse_bytes=coarse,
                min_chunk_bytes=min_chunk, max_chunks=max_chunks,
                local_floor=multi_res)
        else:
            n_chunks = max(1, math.ceil(obj.size_bytes / coarse))
            bounds = [min((i + 1) * coarse, obj.size_bytes)
                      for i in range(n_chunks)]
        if leaf_aligned and obj.leaf_spans:
            bounds = snap_to_leaf_boundaries(bounds, obj.leaf_spans,
                                             obj.size_bytes)
        chunks = partition_object_spans(registry, name, bounds)
        if len(chunks) > 1:
            partitioned.append(name)
    if partitioned:
        resplit_refs(graph, registry, profiler)
    return partitioned
