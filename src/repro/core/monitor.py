"""Workload variation monitoring (paper §3.2) — doubles as the straggler
watchdog at scale.

Unimem re-activates profiling when a phase's execution time drifts more than
10% from the time the current plan was built on.  In the distributed setting
the same signal flags stragglers: a phase that is suddenly slow on some step
(hardware fault, preemption, contended host) triggers re-profiling and a new
placement plan instead of silently degrading every subsequent step.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass
class DriftEvent:
    phase_index: int
    baseline: float
    observed: float

    @property
    def ratio(self) -> float:
        """Observed-over-baseline slowdown.  A zero/negative baseline means
        the phase was never meaningfully observed — there is no slowdown to
        report, so the ratio is a neutral 1.0 (an infinite ratio here would
        poison any threshold comparison built on it)."""
        return self.observed / self.baseline if self.baseline > 0 else 1.0


class VariationMonitor:
    def __init__(self, threshold: float = 0.10, patience: int = 2):
        """``patience``: consecutive drifting executions before firing (debounce
        so a single straggler step does not thrash the planner)."""
        self.threshold = threshold
        self.patience = patience
        self._baseline: Dict[int, float] = {}
        self._strikes: Dict[int, int] = {}
        self.events: List[DriftEvent] = []

    def set_baseline(self, phase_index: int, time_s: float) -> None:
        self._baseline[phase_index] = time_s
        self._strikes[phase_index] = 0

    def observe(self, phase_index: int, time_s: float,
                faulted: bool = False) -> Optional[DriftEvent]:
        """Returns a DriftEvent when re-profiling should be triggered.

        ``faulted`` marks an execution slowed by a *confirmed* fault (a
        degraded slow-tier serve) rather than by noise: the debounce is
        bypassed, so a threshold-exceeding slowdown fires immediately and
        the next replan re-prices the undeliverable move."""
        base = self._baseline.get(phase_index)
        if base is None or base <= 0:
            self._baseline[phase_index] = time_s
            return None
        drift = abs(time_s - base) / base
        if drift > self.threshold:
            self._strikes[phase_index] = (self._strikes.get(phase_index, 0)
                                          + (self.patience if faulted else 1))
            if self._strikes[phase_index] >= self.patience:
                ev = DriftEvent(phase_index, base, time_s)
                self.events.append(ev)
                self._strikes[phase_index] = 0
                return ev
        else:
            self._strikes[phase_index] = 0
        return None

    def drifted_phases(self) -> List[int]:
        """Phases with a pending (not-yet-consumed) drift event — a
        diagnostic for tests and operators inspecting what triggered a
        replan before ``consume_events`` clears it."""
        return sorted({ev.phase_index for ev in self.events})

    def consume_events(self) -> List[DriftEvent]:
        """Return and clear the pending drift events (called when a replan
        has been enacted, so stale events don't re-trigger it)."""
        out = list(self.events)
        self.events.clear()
        return out
