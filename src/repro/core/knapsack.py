"""0/1 knapsack for data placement (paper §3.1.3).

Items are data objects with value ``w`` (Eq. 5, seconds of predicted benefit)
and weight ``size_bytes``; capacity is the fast-tier budget.  Solved with
dynamic programming over a quantized capacity grid; falls back to
density-greedy when the DP table would be unreasonably large (the paper cites
an empirical O((log n)^2) specialization; DP is exact and fast at our n).

Items with non-positive value are never selected (moving them cannot help).

Three implementations share the algorithm:

* :func:`solve_arrays` — the production path: an array program over
  ``(values, sizes)`` ndarrays (no per-item ``Item`` boxing, which at
  10k-100k candidate chunks costs more than the solve itself).  The DP
  inner loop runs three fused numpy passes per item against a bit-packed
  keep table; with :data:`use_jax` enabled and the problem large enough to
  amortize a compile, the whole table recurrence runs as one jitted
  ``lax.scan`` (float64, shapes bucketed so the kernel cache stays small).
  Every path returns selections bit-identical to the reference.
* :func:`solve` — the :class:`Item`-sequence wrapper around
  :func:`solve_arrays` (the planner's historical entry point).
* :func:`solve_reference` — the pre-optimization implementation, kept as the
  oracle for value-equality property tests and the planner-latency
  benchmark's "before" measurement.

All are exact on the same quantized grid and return identical selections.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Item:
    name: str
    value: float        # w from Eq. (5); may be <= 0
    size_bytes: int


def _quantize(sizes: Sequence[int], capacity: int, max_cells: int) -> Tuple[np.ndarray, int]:
    """Pick a quantum so the DP has at most ``max_cells`` capacity cells.

    Sizes are rounded *up* (conservative: never overfills the fast tier)."""
    if capacity <= 0:
        return np.zeros(len(sizes), dtype=np.int64), 0
    quantum = max(1, int(np.ceil(capacity / max_cells)))
    qsizes = (np.asarray(sizes, dtype=np.int64) + quantum - 1) // quantum
    qcap = capacity // quantum
    return qsizes, qcap


# --------------------------------------------------------------------------
# jitted DP kernel (optional): the whole table recurrence as one lax.scan.
# The per-item update is identical IEEE float64 arithmetic (add, compare,
# select), so the table — and therefore the backtracked selection — is
# bit-identical to the numpy path; a property test pins that.  Item counts
# are padded to power-of-two buckets so the compile cache stays at a
# handful of shapes per (process, capacity).
# --------------------------------------------------------------------------
_JAX_MIN_WORK = 8_000_000       # n * qcap below this: numpy wins w/ no compile
#: opt-in switch for the jitted DP kernel.  On CPU XLA the scan loses to
#: the fused numpy passes (~70ms vs ~53ms at 2k items x 16k cells — the
#: scan can't amortize its dispatch against a memory-bound recurrence), so
#: the default keeps numpy; the kernel stays bit-identical (property-
#: tested) for backends where the jit wins.
use_jax: bool = False
_jax_kernels: dict = {}
_jax_state: Optional[bool] = None    # None = untried, False = unavailable


def _jax_dp(values: np.ndarray, qsizes: np.ndarray, qcap: int
            ) -> Optional[np.ndarray]:
    """Packed keep table from the jitted scan, or None when jax is
    unavailable (the numpy path is the behavioural twin, so callers just
    fall through)."""
    global _jax_state
    if _jax_state is False:
        return None
    try:
        import jax
        import jax.numpy as jnp
        from jax.experimental import enable_x64
        _jax_state = True
    except Exception:       # pragma: no cover - jax is baked into the image
        _jax_state = False
        return None

    n = len(values)
    n_pad = 1 << max(int(np.ceil(np.log2(max(n, 1)))), 0)
    kernel = _jax_kernels.get(qcap)
    if kernel is None:
        row_bytes = (qcap + 8) // 8

        def dp(vals, sizes):
            neg = jnp.full(qcap + 1, -jnp.inf, jnp.float64)
            pad = (-(qcap + 1)) % 8
            weights = jnp.asarray([128, 64, 32, 16, 8, 4, 2, 1],
                                  dtype=jnp.uint8)

            def step(table, sv):
                s, v = sv
                padded = jnp.concatenate([neg, table])
                shifted = jax.lax.dynamic_slice(
                    padded, (qcap + 1 - s,), (qcap + 1,)) + v
                better = shifted > table
                new = jnp.where(better, shifted, table)
                packed = jnp.concatenate(
                    [better, jnp.zeros(pad, bool)]).reshape(
                        row_bytes, 8).astype(jnp.uint8) @ weights
                return new, packed

            _, keep = jax.lax.scan(step, jnp.zeros(qcap + 1, jnp.float64),
                                   (sizes, vals))
            return keep

        kernel = jax.jit(dp)
        _jax_kernels[qcap] = kernel

    vals = np.zeros(n_pad, dtype=np.float64)
    vals[:n] = values
    sizes = np.ones(n_pad, dtype=np.int64)      # v=0 padding is inert
    sizes[:n] = qsizes
    with enable_x64():
        keep = np.asarray(kernel(vals, sizes))
    return keep[:n]


def _numpy_dp(values: np.ndarray, qsizes: np.ndarray, qcap: int) -> np.ndarray:
    """Packed keep table from the in-process DP: three fused passes per
    item (add into a scratch buffer, compare into the keep row, masked
    copy back) and one vectorized pack at the end."""
    n = len(values)
    table = np.zeros(qcap + 1, dtype=np.float64)
    buf = np.empty(qcap + 1, dtype=np.float64)
    rows = np.zeros((n, qcap + 1), dtype=bool)
    for i in range(n):
        s, v = int(qsizes[i]), values[i]
        if s > qcap:
            continue
        m = qcap - s + 1
        cand = np.add(table[:m], v, out=buf[:m])
        better = np.greater(cand, table[s:], out=rows[i, s:])
        np.copyto(table[s:], cand, where=better)
    return np.packbits(rows, axis=1)


def solve_arrays(values: np.ndarray, sizes: np.ndarray, capacity_bytes: int,
                 *, max_cells: int = 1 << 14) -> np.ndarray:
    """Indices (into ``values``/``sizes``) of the selected items.

    The array-program core shared by :func:`solve`: selections are
    bit-identical to :func:`solve_reference` on the same inputs — the same
    quantized grid, the same item order through the DP (tie-breaks
    included), the same density-greedy fallback past the table-size cap."""
    values = np.asarray(values, dtype=np.float64)
    sizes = np.asarray(sizes, dtype=np.int64)
    if capacity_bytes <= 0 or len(values) == 0:
        return np.empty(0, dtype=np.int64)
    pos_idx = np.flatnonzero((values > 0.0) & (sizes <= capacity_bytes))
    if len(pos_idx) == 0:
        return np.empty(0, dtype=np.int64)
    pvals, psizes = values[pos_idx], sizes[pos_idx]
    qsizes, qcap = _quantize(psizes, capacity_bytes, max_cells)
    if qcap <= 0:
        return np.empty(0, dtype=np.int64)
    n = len(pos_idx)
    if n * qcap > 50_000_000:   # DP too big -> density greedy
        return pos_idx[_greedy_arrays(pvals, psizes, capacity_bytes)]

    keep = None
    if use_jax and n * qcap >= _JAX_MIN_WORK:
        keep = _jax_dp(pvals, qsizes, qcap)
    if keep is None:
        keep = _numpy_dp(pvals, qsizes, qcap)
    # backtrack
    chosen: List[int] = []
    c = qcap
    for i in range(n - 1, -1, -1):
        if c >= 0 and (keep[i, c >> 3] >> (7 - (c & 7))) & 1:
            chosen.append(i)
            c -= int(qsizes[i])
    chosen.reverse()
    return pos_idx[np.asarray(chosen, dtype=np.int64)]


def _greedy_arrays(values: np.ndarray, sizes: np.ndarray,
                   capacity_bytes: int) -> np.ndarray:
    """Array-program :func:`_greedy`: a stable density argsort (ties keep
    input order, exactly like ``sorted(..., reverse=True)``), then a scan
    that stops early once nothing in the remaining suffix can fit."""
    density = values / np.maximum(sizes, 1)
    order = np.argsort(-density, kind="stable")
    ssizes = sizes[order]
    # smallest size at-or-after each position: once the remaining budget
    # drops below it, no later item fits and the scan can stop
    suffix_min = np.minimum.accumulate(ssizes[::-1])[::-1]
    out: List[int] = []
    used = 0
    budget = capacity_bytes
    for j in range(len(order)):
        if budget - used < suffix_min[j]:
            break
        s = int(ssizes[j])
        if used + s <= budget:
            out.append(int(order[j]))
            used += s
    return np.asarray(out, dtype=np.int64)


def solve(items: Sequence[Item], capacity_bytes: int,
          *, max_cells: int = 1 << 14) -> List[str]:
    """Return names of selected items maximizing total value under capacity.

    Identical selections to :func:`solve_reference`; thin wrapper over
    :func:`solve_arrays` (array callers should use that directly and skip
    the Item boxing)."""
    if not items:
        return []
    values = np.fromiter((it.value for it in items), dtype=np.float64,
                         count=len(items))
    sizes = np.fromiter((it.size_bytes for it in items), dtype=np.int64,
                        count=len(items))
    idx = solve_arrays(values, sizes, capacity_bytes, max_cells=max_cells)
    return [items[i].name for i in idx]


def solve_reference(items: Sequence[Item], capacity_bytes: int,
                    *, max_cells: int = 1 << 14) -> List[str]:
    """Pre-optimization solver (n x cells bool keep matrix) — the oracle the
    array-program :func:`solve_arrays` is property-tested against, and the
    baseline the planner-latency benchmark measures."""
    pos = [it for it in items if it.value > 0.0 and it.size_bytes <= capacity_bytes]
    if not pos or capacity_bytes <= 0:
        return []
    qsizes, qcap = _quantize([it.size_bytes for it in pos], capacity_bytes, max_cells)
    if qcap <= 0:
        return []
    n = len(pos)
    if n * qcap > 50_000_000:   # DP too big -> density greedy
        return _greedy(pos, capacity_bytes)

    values = np.array([it.value for it in pos], dtype=np.float64)
    table = np.zeros(qcap + 1, dtype=np.float64)
    keep = np.zeros((n, qcap + 1), dtype=bool)
    for i in range(n):
        s, v = int(qsizes[i]), values[i]
        if s > qcap:
            continue
        cand = table[: qcap - s + 1] + v
        better = cand > table[s:]
        table[s:] = np.where(better, cand, table[s:])
        keep[i, s:] = better
    chosen: List[str] = []
    c = qcap
    for i in range(n - 1, -1, -1):
        if c >= 0 and keep[i, c]:
            chosen.append(pos[i].name)
            c -= int(qsizes[i])
    chosen.reverse()
    return chosen


def _greedy(items: Sequence[Item], capacity_bytes: int) -> List[str]:
    """Value-density greedy (each object has distinct value per byte in
    practice, matching the paper's empirical-complexity remark)."""
    order = sorted(items, key=lambda it: it.value / max(it.size_bytes, 1),
                   reverse=True)
    out, used = [], 0
    for it in order:
        if used + it.size_bytes <= capacity_bytes:
            out.append(it.name)
            used += it.size_bytes
    return out


def total_value(items: Sequence[Item], chosen: Sequence[str]) -> float:
    by = {it.name: it for it in items}
    return sum(by[c].value for c in chosen)


def total_size(items: Sequence[Item], chosen: Sequence[str]) -> int:
    by = {it.name: it for it in items}
    return sum(by[c].size_bytes for c in chosen)
