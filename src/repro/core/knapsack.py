"""0/1 knapsack for data placement (paper §3.1.3).

Items are data objects with value ``w`` (Eq. 5, seconds of predicted benefit)
and weight ``size_bytes``; capacity is the fast-tier budget.  Solved with
dynamic programming over a quantized capacity grid; falls back to
density-greedy when the DP table would be unreasonably large (the paper cites
an empirical O((log n)^2) specialization; DP is exact and fast at our n).

Items with non-positive value are never selected (moving them cannot help).

Two solvers share the algorithm:

* :func:`solve` — the production path: the per-item keep table is stored as
  a packed bitset (uint8, one bit per capacity cell) instead of an
  n x (cells+1) bool matrix, cutting the table's footprint 8x and its
  allocation/write traffic with it — at 2,000 candidate chunks and the
  default 16k-cell grid that is 4 MB instead of 32 MB per phase decision.
* :func:`solve_reference` — the pre-optimization implementation, kept as the
  oracle for value-equality property tests and the planner-latency
  benchmark's "before" measurement.

Both are exact on the same quantized grid and return identical selections.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Item:
    name: str
    value: float        # w from Eq. (5); may be <= 0
    size_bytes: int


def _quantize(sizes: Sequence[int], capacity: int, max_cells: int) -> Tuple[np.ndarray, int]:
    """Pick a quantum so the DP has at most ``max_cells`` capacity cells.

    Sizes are rounded *up* (conservative: never overfills the fast tier)."""
    if capacity <= 0:
        return np.zeros(len(sizes), dtype=np.int64), 0
    quantum = max(1, int(np.ceil(capacity / max_cells)))
    qsizes = np.array([(s + quantum - 1) // quantum for s in sizes], dtype=np.int64)
    qcap = capacity // quantum
    return qsizes, qcap


def solve(items: Sequence[Item], capacity_bytes: int,
          *, max_cells: int = 1 << 14) -> List[str]:
    """Return names of selected items maximizing total value under capacity.

    Identical selections to :func:`solve_reference`; the keep table is a
    packed bitset rather than a bool matrix."""
    pos = [it for it in items if it.value > 0.0 and it.size_bytes <= capacity_bytes]
    if not pos or capacity_bytes <= 0:
        return []
    qsizes, qcap = _quantize([it.size_bytes for it in pos], capacity_bytes, max_cells)
    if qcap <= 0:
        return []
    n = len(pos)
    if n * qcap > 50_000_000:   # DP too big -> density greedy
        return _greedy(pos, capacity_bytes)

    # DP over capacity; table[c] = best value using items so far within c.
    # keep is bit-packed: bit c of row i says item i is taken at capacity c.
    values = np.array([it.value for it in pos], dtype=np.float64)
    table = np.zeros(qcap + 1, dtype=np.float64)
    row = np.zeros(qcap + 1, dtype=bool)        # scratch, reused per item
    keep = np.zeros((n, (qcap + 8) // 8), dtype=np.uint8)
    for i in range(n):
        s, v = int(qsizes[i]), values[i]
        if s > qcap:
            continue
        cand = table[: qcap - s + 1] + v
        better = cand > table[s:]
        table[s:] = np.where(better, cand, table[s:])
        row[:s] = False
        row[s:] = better
        keep[i] = np.packbits(row)
    # backtrack
    chosen: List[str] = []
    c = qcap
    for i in range(n - 1, -1, -1):
        if c >= 0 and (keep[i, c >> 3] >> (7 - (c & 7))) & 1:
            chosen.append(pos[i].name)
            c -= int(qsizes[i])
    chosen.reverse()
    return chosen


def solve_reference(items: Sequence[Item], capacity_bytes: int,
                    *, max_cells: int = 1 << 14) -> List[str]:
    """Pre-optimization solver (n x cells bool keep matrix) — the oracle the
    packed-bit :func:`solve` is property-tested against, and the baseline the
    planner-latency benchmark measures."""
    pos = [it for it in items if it.value > 0.0 and it.size_bytes <= capacity_bytes]
    if not pos or capacity_bytes <= 0:
        return []
    qsizes, qcap = _quantize([it.size_bytes for it in pos], capacity_bytes, max_cells)
    if qcap <= 0:
        return []
    n = len(pos)
    if n * qcap > 50_000_000:   # DP too big -> density greedy
        return _greedy(pos, capacity_bytes)

    values = np.array([it.value for it in pos], dtype=np.float64)
    table = np.zeros(qcap + 1, dtype=np.float64)
    keep = np.zeros((n, qcap + 1), dtype=bool)
    for i in range(n):
        s, v = int(qsizes[i]), values[i]
        if s > qcap:
            continue
        cand = table[: qcap - s + 1] + v
        better = cand > table[s:]
        table[s:] = np.where(better, cand, table[s:])
        keep[i, s:] = better
    chosen: List[str] = []
    c = qcap
    for i in range(n - 1, -1, -1):
        if c >= 0 and keep[i, c]:
            chosen.append(pos[i].name)
            c -= int(qsizes[i])
    chosen.reverse()
    return chosen


def _greedy(items: Sequence[Item], capacity_bytes: int) -> List[str]:
    """Value-density greedy (each object has distinct value per byte in
    practice, matching the paper's empirical-complexity remark)."""
    order = sorted(items, key=lambda it: it.value / max(it.size_bytes, 1),
                   reverse=True)
    out, used = [], 0
    for it in order:
        if used + it.size_bytes <= capacity_bytes:
            out.append(it.name)
            used += it.size_bytes
    return out


def total_value(items: Sequence[Item], chosen: Sequence[str]) -> float:
    by = {it.name: it for it in items}
    return sum(by[c].value for c in chosen)


def total_size(items: Sequence[Item], chosen: Sequence[str]) -> int:
    by = {it.name: it for it in items}
    return sum(by[c].size_bytes for c in chosen)
