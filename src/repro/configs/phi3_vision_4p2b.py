"""phi-3-vision-4.2b [vlm]: phi3-mini backbone + CLIP frontend (stub).

32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064
[hf:microsoft/Phi-3-vision-128k-instruct; hf]

The CLIP vision tower is a STUB: ``input_specs`` provides precomputed patch
embeddings (B, frontend_tokens, d_model) prepended to the text sequence.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    frontend="vision",
    frontend_tokens=144,              # one 336px tile of CLIP patches
)
