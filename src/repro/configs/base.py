"""Architecture and shape configuration.

Every assigned architecture is an :class:`ArchConfig`; the four assigned
input shapes are :class:`ShapeConfig`.  ``reduced()`` yields the small
same-family variant used by CPU smoke tests (full configs are exercised only
through the dry-run with ShapeDtypeStructs).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense|moe|hybrid|vlm|audio|ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default: d_model // n_heads
    activation: str = "silu"
    mlp_type: str = "swiglu"                # swiglu|geglu|mlp
    norm: str = "rms"                       # rms|layer
    attn_bias: bool = False
    rope_theta: float = 10000.0
    rotary_fraction: float = 1.0            # chatglm3: 0.5 (2d RoPE)
    tie_embeddings: bool = False
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_shared_experts: int = 0
    moe_capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0                      # mamba2 d_state
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    attn_every: int = 0                     # hybrid: shared attn block period
    slstm_every: int = 0                    # xlstm: sLSTM block period
    block_pattern: str = "attn"             # attn|mamba_shared_attn|xlstm
    # modality frontend stub
    frontend: Optional[str] = None          # None|vision|audio
    frontend_tokens: int = 0
    # shape applicability
    supports_long_context: bool = False     # sub-quadratic -> run long_500k
    max_position: int = 544 * 1024

    # ------------------------------------------------------------- derived
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, hd = self.d_model, self.resolved_head_dim
        p = self.vocab_size * d            # embed
        if not self.tie_embeddings:
            p += d * self.vocab_size       # head
        per_layer = 0
        if self.block_pattern == "mamba_shared_attn":
            d_in = self.ssm_expand * d
            n_h = d_in // self.ssm_head_dim
            per_layer = (d * (2 * d_in + 2 * self.ssm_state) + 3 * n_h
                         + d_in * self.ssm_conv + d_in * d + 2 * d)
            p += per_layer * self.n_layers
            # one shared attention block (+ its mlp) reused across the stack
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            p += q + kv + o + 3 * d * self.d_ff + 2 * d
            return p
        if self.block_pattern == "xlstm":
            d_in = self.ssm_expand * d
            H = self.n_heads
            n_s = self.n_layers // self.slstm_every if self.slstm_every else 0
            n_m = self.n_layers - n_s
            mlstm = (d * (3 * d_in + 2 * H) + d * d_in   # in_proj + o_gate
                     + d_in + d_in * d + d)              # norm + out + ln1
            slstm = (d * 4 * d + 4 * d * d // H          # w_gates + r_gates
                     + d + d * d + d)                    # norm + out + ln1
            p += mlstm * n_m + slstm * n_s
            return p
        q = d * self.n_heads * hd
        kv = 2 * d * self.n_kv_heads * hd
        o = self.n_heads * hd * d
        attn = q + kv + o
        if self.is_moe:
            expert = 3 * d * self.moe_d_ff if self.mlp_type != "mlp" \
                else 2 * d * self.moe_d_ff
            mlp = (self.moe_experts + self.moe_shared_experts) * expert \
                + d * self.moe_experts    # router
        else:
            mlp = 3 * d * self.d_ff if self.mlp_type != "mlp" \
                else 2 * d * self.d_ff
        p += (attn + mlp + 2 * d) * self.n_layers
        return p

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only routed experts count)."""
        if not self.is_moe:
            return self.n_params()
        d = self.d_model
        expert = 3 * d * self.moe_d_ff if self.mlp_type != "mlp" \
            else 2 * d * self.moe_d_ff
        inactive = (self.moe_experts - self.moe_top_k) * expert * self.n_layers
        return self.n_params() - inactive

    def shape_applicable(self, shape: ShapeConfig) -> Tuple[bool, str]:
        if shape.name == "long_500k" and not self.supports_long_context:
            return False, "pure full-attention arch: quadratic at 500k (skip)"
        return True, ""

    def reduced(self) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=max(2, min(4, (self.attn_every or 2) + 1)
                         if self.block_pattern == "mamba_shared_attn" else 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4 // self.n_heads)),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=128,
            moe_experts=4 if self.is_moe else 0,
            moe_top_k=2 if self.is_moe else 0,
            moe_d_ff=32 if self.is_moe else 0,
            moe_shared_experts=min(1, self.moe_shared_experts),
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            attn_every=2 if self.attn_every else 0,
            slstm_every=2 if self.slstm_every else 0,
            frontend_tokens=4 if self.frontend else 0,
            max_position=1024,
        )
