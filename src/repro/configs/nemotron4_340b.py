"""nemotron-4-340b [dense]: GQA, squared-ReLU MLP.

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000
[arXiv:2402.16819; unverified]

The flagship tiering demo: optimizer state (4 TB fp32) cannot fit a single
v5e pod's HBM — the Unimem planner offloads it to the host tier and streams
shard updates (see launch/dryrun.py offload programs).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    activation="squared_relu",
    mlp_type="mlp",
    attn_bias=False,
)
