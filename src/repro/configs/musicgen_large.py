"""musicgen-large [audio]: decoder-only over EnCodec tokens.

48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048
[arXiv:2306.05284; hf]

The EnCodec frontend is a STUB: the backbone consumes codec token ids
directly (vocab 2048); conditioning frame embeddings come precomputed via
``input_specs`` (frontend_tokens slots).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    activation="gelu",
    mlp_type="mlp",
    norm="layer",
    frontend="audio",
    frontend_tokens=64,               # conditioning frames (stubbed)
)
