"""xlstm-350m [ssm]: sLSTM + mLSTM blocks (attention-free).

24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304
[arXiv:2405.04517; unverified]

d_ff=0: xLSTM blocks carry their own up/down projections (expand factor 2);
there is no separate FFN.  sLSTM every 8th layer, mLSTM otherwise (the
paper's sparse-sLSTM placement).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern="xlstm",
    slstm_every=8,
    ssm_expand=2,
    supports_long_context=True,
    tie_embeddings=True,
)
