"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared attention blocks.

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64
[arXiv:2411.15242; hf]
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_every=6,                     # shared attn block every 6 Mamba2 layers
    block_pattern="mamba_shared_attn",
    supports_long_context=True,       # Mamba2 backbone is sub-quadratic
    tie_embeddings=True,
)
