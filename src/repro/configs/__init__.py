"""Architecture config registry: the 10 assigned architectures."""

from typing import Dict, List

from .base import ArchConfig, ShapeConfig, SHAPES
from .zamba2_1p2b import CONFIG as ZAMBA2_1P2B
from .phi3_vision_4p2b import CONFIG as PHI3_VISION_4P2B
from .nemotron4_340b import CONFIG as NEMOTRON4_340B
from .yi_6b import CONFIG as YI_6B
from .gemma_2b import CONFIG as GEMMA_2B
from .chatglm3_6b import CONFIG as CHATGLM3_6B
from .moonshot_v1_16b_a3b import CONFIG as MOONSHOT_V1_16B_A3B
from .dbrx_132b import CONFIG as DBRX_132B
from .musicgen_large import CONFIG as MUSICGEN_LARGE
from .xlstm_350m import CONFIG as XLSTM_350M

ARCHS: Dict[str, ArchConfig] = {c.name: c for c in [
    ZAMBA2_1P2B, PHI3_VISION_4P2B, NEMOTRON4_340B, YI_6B, GEMMA_2B,
    CHATGLM3_6B, MOONSHOT_V1_16B_A3B, DBRX_132B, MUSICGEN_LARGE, XLSTM_350M,
]}

# short aliases for --arch flags
ALIASES = {
    "zamba2-1.2b": "zamba2-1.2b", "zamba2": "zamba2-1.2b",
    "phi-3-vision-4.2b": "phi-3-vision-4.2b", "phi3v": "phi-3-vision-4.2b",
    "nemotron-4-340b": "nemotron-4-340b", "nemotron": "nemotron-4-340b",
    "yi-6b": "yi-6b", "yi": "yi-6b",
    "gemma-2b": "gemma-2b", "gemma": "gemma-2b",
    "chatglm3-6b": "chatglm3-6b", "chatglm3": "chatglm3-6b",
    "moonshot-v1-16b-a3b": "moonshot-v1-16b-a3b",
    "moonshot": "moonshot-v1-16b-a3b",
    "dbrx-132b": "dbrx-132b", "dbrx": "dbrx-132b",
    "musicgen-large": "musicgen-large", "musicgen": "musicgen-large",
    "xlstm-350m": "xlstm-350m", "xlstm": "xlstm-350m",
}


def get_config(name: str) -> ArchConfig:
    key = ALIASES.get(name, name)
    if key not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[key]


def list_archs() -> List[str]:
    return sorted(ARCHS)


__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "ARCHS", "get_config",
           "list_archs"]
