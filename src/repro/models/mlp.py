"""MLP variants: SwiGLU / GeGLU (gated) and plain 2-layer (GELU / squared-ReLU)."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import ACTIVATIONS, dense_init, split_keys


def init_mlp_params(key: jax.Array, cfg: ArchConfig, d_ff: int = 0,
                    dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = split_keys(key, 3)
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], (d, f), dtype),
            "w_up": dense_init(ks[1], (d, f), dtype),
            "w_down": dense_init(ks[2], (f, d), dtype),
        }
    return {
        "w_up": dense_init(ks[0], (d, f), dtype),
        "w_down": dense_init(ks[1], (f, d), dtype),
    }


def mlp_forward(params: Dict[str, jax.Array], x: jax.Array,
                cfg: ArchConfig) -> jax.Array:
    act = ACTIVATIONS[cfg.activation]
    if cfg.mlp_type in ("swiglu", "geglu"):
        return (act(x @ params["w_gate"]) * (x @ params["w_up"])) \
            @ params["w_down"]
    return act(x @ params["w_up"]) @ params["w_down"]
