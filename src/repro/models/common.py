"""Shared model primitives: norms, activations, rotary embeddings, init."""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

DEFAULT_COMPUTE_DTYPE = jnp.bfloat16
DEFAULT_PARAM_DTYPE = jnp.bfloat16

# ---------------------------------------------------------------------------
# Mesh hint: the launch layer registers the active mesh so model code can
# constrain activation shardings (batch over DP axes, hidden over "model")
# without importing the launch layer.  ``None`` (tests, single device) makes
# constraints no-ops.
_MESH_HINT = None


def set_mesh_hint(mesh) -> None:
    global _MESH_HINT
    _MESH_HINT = mesh


def get_mesh_hint():
    return _MESH_HINT


def shard_hint(x: "jax.Array", *axes) -> "jax.Array":
    """Apply a sharding constraint if a mesh hint is active.

    ``axes``: per-dim axis roles; "dp" expands to ("pod", "data")."""
    mesh = _MESH_HINT
    if mesh is None:
        return x
    from ..distributed.sharding import dp_axes, fit  # local: avoid cycle
    resolved = tuple(dp_axes(mesh) if a == "dp" else a for a in axes)
    spec = fit(mesh, x.shape, *resolved)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


# ---------------------------------------------------------------- norms
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mean) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------- activations
def squared_relu(x: jax.Array) -> jax.Array:
    r = jax.nn.relu(x)
    return r * r


ACTIVATIONS: dict = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "squared_relu": squared_relu,
}


# ---------------------------------------------------------------- rotary
def rope_frequencies(head_dim: int, max_pos: int, theta: float = 10000.0,
                     rotary_dim: Optional[int] = None
                     ) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables of shape (max_pos, rotary_dim // 2), float32."""
    rd = rotary_dim or head_dim
    inv = 1.0 / (theta ** (jnp.arange(0, rd, 2, dtype=jnp.float32) / rd))
    pos = jnp.arange(max_pos, dtype=jnp.float32)
    ang = jnp.outer(pos, inv)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array,
               positions: Optional[jax.Array] = None,
               rotary_dim: Optional[int] = None) -> jax.Array:
    """Rotate pairs (interleaved-half convention).  ``x``: (..., S, H, D);
    ``positions``: (..., S) token positions (defaults to arange)."""
    D = x.shape[-1]
    rd = rotary_dim or D
    if positions is None:
        S = x.shape[-3]
        positions = jnp.arange(S)
        c = cos[positions][..., None, :]       # (S, 1, rd/2)
        s = sin[positions][..., None, :]
    else:
        c = cos[positions][..., None, :]       # (..., S, 1, rd/2)
        s = sin[positions][..., None, :]
    xr, xp = x[..., :rd], x[..., rd:]
    x1, x2 = xr[..., : rd // 2], xr[..., rd // 2:]
    out1 = x1 * c - x2 * s
    out2 = x2 * c + x1 * s
    return jnp.concatenate([out1.astype(x.dtype), out2.astype(x.dtype), xp],
                           axis=-1)


# ----------------------------------------------------------- embedding
def embed_lookup(table: jax.Array, tokens: jax.Array,
                 tied: bool = False) -> jax.Array:
    """Embedding gather with a sharding-disciplined backward pass.

    XLA's SPMD partitioner handles neither the vocab-sharded gather nor its
    scatter-add transpose efficiently at 256k-vocab/18k-d scale (it
    replicates full-batch fp32 hidden tensors).  Both directions are
    therefore written with ``shard_map``:

    * untied: table d-sharded over "model" — gather and scatter fully local
      per d-slice, grads psum'd over the DP axes.
    * tied: table vocab-sharded over "model" (the head needs vocab-parallel
      logits) — masked local gather + psum over "model".
    """
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = get_mesh_hint()
    if mesh is None:
        return jnp.take(table, tokens, axis=0)
    from ..distributed.sharding import dp_axes, fit

    dp = dp_axes(mesh)
    shape, dtype = table.shape, table.dtype
    tok_spec = fit(mesh, tokens.shape, *((dp,) + (None,) * (tokens.ndim - 1)))
    x_axes = (dp,) + (None,) * (tokens.ndim - 1)
    vocab_sharded = tied
    if tied:
        table_spec = fit(mesh, shape, "model", None)
        vocab_sharded = table_spec[0] is not None
        x_spec = fit(mesh, tokens.shape + (shape[1],), *x_axes, None)
    else:
        table_spec = fit(mesh, shape, None, "model")
        x_spec = fit(mesh, tokens.shape + (shape[1],), *x_axes, "model")

    dp_used = []
    t0 = tok_spec[0]
    for ax in (dp if isinstance(dp, tuple) else (dp,)):
        if t0 is not None and ax in (t0 if isinstance(t0, tuple) else (t0,)):
            dp_used.append(ax)

    def _fwd_local(tb, tok):
        if vocab_sharded:
            vloc = tb.shape[0]
            start = jax.lax.axis_index("model") * vloc
            rel = jnp.clip(tok - start, 0, vloc - 1)
            x = jnp.take(tb, rel, axis=0)
            ok = ((tok - start) >= 0) & ((tok - start) < vloc)
            x = jnp.where(ok[..., None], x, jnp.zeros((), x.dtype))
            return jax.lax.psum(x, "model")
        return jnp.take(tb, tok, axis=0)

    def _bwd_local(g, tok):
        if vocab_sharded:
            vloc = shape[0] // mesh.shape["model"]
            start = jax.lax.axis_index("model") * vloc
            rel = jnp.clip(tok - start, 0, vloc - 1)
            ok = ((tok - start) >= 0) & ((tok - start) < vloc)
            gm = jnp.where(ok[..., None], g.astype(jnp.float32), 0.0)
            dt = jnp.zeros((vloc, shape[1]), jnp.float32).at[rel].add(gm)
        else:
            dt = jnp.zeros((shape[0], g.shape[-1]), jnp.float32).at[tok].add(
                g.astype(jnp.float32))
        if dp_used:
            dt = jax.lax.psum(dt, tuple(dp_used))
        return dt.astype(dtype)

    fwd_sm = shard_map(_fwd_local, mesh=mesh,
                       in_specs=(table_spec, tok_spec),
                       out_specs=x_spec, check_rep=False)
    bwd_sm = shard_map(_bwd_local, mesh=mesh,
                       in_specs=(x_spec, tok_spec),
                       out_specs=table_spec, check_rep=False)

    @jax.custom_vjp
    def _lookup(t, tok):
        return fwd_sm(t, tok)

    def _vjp_fwd(t, tok):
        return fwd_sm(t, tok), tok

    def _vjp_bwd(tok, g):
        return bwd_sm(g, tok), np.zeros(tok.shape, dtype=jax.dtypes.float0)

    _lookup.defvjp(_vjp_fwd, _vjp_bwd)
    return _lookup(table, tokens)


# ------------------------------------------------------------------ init
def dense_init(key: jax.Array, shape: Tuple[int, ...],
               dtype=DEFAULT_PARAM_DTYPE, scale: Optional[float] = None
               ) -> jax.Array:
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key: jax.Array, shape: Tuple[int, ...],
               dtype=DEFAULT_PARAM_DTYPE, std: float = 0.02) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def split_keys(key: jax.Array, n: int):
    return list(jax.random.split(key, n))


def count_params(tree) -> int:
    return sum(int(l.size) for l in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(l.size) * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(tree))
