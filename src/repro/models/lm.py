"""Language model assembly for all assigned architectures.

Three block patterns share one LM skeleton (embed -> blocks -> norm -> head):

* ``attn``              — dense / MoE / VLM / audio transformers; layers are
                          stacked and scanned (``lax.scan`` keeps HLO small).
* ``mamba_shared_attn`` — zamba2: Mamba2 backbone, one *shared* attention
                          block (own KV per application) every ``attn_every``
                          layers.
* ``xlstm``             — mLSTM stacks with an sLSTM block every
                          ``slstm_every`` layers.

Functional API:
  init_params(cfg, key)                       -> params pytree
  forward(params, cfg, tokens, frontend)      -> logits
  loss_fn(params, cfg, batch)                 -> scalar loss
  init_cache(cfg, batch, max_seq)             -> decode cache pytree
  decode_step(params, cfg, cache, token, pos) -> (logits, new cache)

Weights use remat-friendly ``lax.scan`` over stacked layers; activation
checkpointing policy is chosen by the launch layer.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import attention, mamba2, mlp as mlp_mod, moe as moe_mod, xlstm
from .common import (dense_init, embed_init, embed_lookup, layer_norm,
                     rms_norm, rope_frequencies, shard_hint, split_keys)


# ---------------------------------------------------------------- norms
def _norm(params_block: Dict[str, jax.Array], name: str, x: jax.Array,
          cfg: ArchConfig) -> jax.Array:
    if cfg.norm == "layer":
        return layer_norm(x, params_block[f"{name}_scale"],
                          params_block[f"{name}_bias"])
    return rms_norm(x, params_block[f"{name}_scale"])


def _init_norm(cfg: ArchConfig, name: str, dtype=jnp.bfloat16):
    p = {f"{name}_scale": jnp.zeros((cfg.d_model,), dtype)}
    if cfg.norm == "layer":
        p[f"{name}_scale"] = jnp.ones((cfg.d_model,), dtype)
        p[f"{name}_bias"] = jnp.zeros((cfg.d_model,), dtype)
    return p


# ----------------------------------------------------------- transformer blk
def _init_attn_block(key: jax.Array, cfg: ArchConfig, dtype=jnp.bfloat16):
    ks = split_keys(key, 2)
    p = {"attn": attention.init_attn_params(ks[0], cfg, dtype)}
    p.update(_init_norm(cfg, "ln1", dtype))
    p.update(_init_norm(cfg, "ln2", dtype))
    if cfg.is_moe:
        p["moe"] = moe_mod.init_moe_params(ks[1], cfg, dtype)
    elif cfg.d_ff:
        p["mlp"] = mlp_mod.init_mlp_params(ks[1], cfg, dtype=dtype)
    return p


def _attn_block_fwd(block, x, cos, sin, cfg, q_offset=0):
    aux = jnp.zeros((), jnp.float32)
    h = _norm(block, "ln1", x, cfg)
    x = x + attention.attn_forward(block["attn"], h, cos, sin, cfg,
                                   q_offset=q_offset)
    h = _norm(block, "ln2", x, cfg)
    if cfg.is_moe:
        out, aux = moe_mod.moe_forward(block["moe"], h, cfg)
        x = x + out
    elif cfg.d_ff:
        x = x + mlp_mod.mlp_forward(block["mlp"], h, cfg)
    return x, aux


def _attn_block_decode(block, x, ck, cv, pos, cos, sin, cfg):
    h = _norm(block, "ln1", x, cfg)
    out, ck, cv = attention.attn_decode(block["attn"], h, ck, cv, pos,
                                        cos, sin, cfg)
    x = x + out
    h = _norm(block, "ln2", x, cfg)
    if cfg.is_moe:
        out, _ = moe_mod.moe_forward(block["moe"], h, cfg)
        x = x + out
    elif cfg.d_ff:
        x = x + mlp_mod.mlp_forward(block["mlp"], h, cfg)
    return x, ck, cv


# ----------------------------------------------------------------- init all
def init_params(cfg: ArchConfig, key: jax.Array,
                dtype=jnp.bfloat16) -> Dict[str, Any]:
    ks = split_keys(key, 8)
    params: Dict[str, Any] = {
        "embed": embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype),
    }
    params.update({f"final_{k}": v
                   for k, v in _init_norm(cfg, "ln", dtype).items()})
    if not cfg.tie_embeddings:
        params["head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size),
                                    dtype)

    if cfg.block_pattern == "attn":
        layer_keys = jnp.stack(split_keys(ks[2], cfg.n_layers))
        params["blocks"] = jax.vmap(
            lambda k: _init_attn_block(k, cfg, dtype))(layer_keys)
    elif cfg.block_pattern == "mamba_shared_attn":
        layer_keys = jnp.stack(split_keys(ks[2], cfg.n_layers))
        def mamba_block(k):
            p = mamba2.init_mamba2_params(k, cfg, dtype)
            p.update(_init_norm(cfg, "ln1", dtype))
            return p
        params["mamba_blocks"] = jax.vmap(mamba_block)(layer_keys)
        params["shared_attn"] = _init_attn_block(ks[3], cfg, dtype)
    elif cfg.block_pattern == "xlstm":
        n_s = cfg.n_layers // cfg.slstm_every if cfg.slstm_every else 0
        n_m = cfg.n_layers - n_s
        mkeys = jnp.stack(split_keys(ks[2], n_m))
        def m_block(k):
            p = xlstm.init_mlstm_params(k, cfg, dtype)
            p.update(_init_norm(cfg, "ln1", dtype))
            return p
        params["mlstm_blocks"] = jax.vmap(m_block)(mkeys)
        if n_s:
            skeys = jnp.stack(split_keys(ks[3], n_s))
            def s_block(k):
                p = xlstm.init_slstm_params(k, cfg, dtype)
                p.update(_init_norm(cfg, "ln1", dtype))
                return p
            params["slstm_blocks"] = jax.vmap(s_block)(skeys)
    else:
        raise ValueError(cfg.block_pattern)

    if cfg.frontend == "vision":
        params["frontend_proj"] = dense_init(ks[4], (cfg.d_model, cfg.d_model),
                                             dtype)
    return params


# ----------------------------------------------------------------- forward
def _rope_tables(cfg: ArchConfig, max_pos: int):
    rd = int(cfg.resolved_head_dim * cfg.rotary_fraction)
    return rope_frequencies(cfg.resolved_head_dim, max_pos,
                            theta=cfg.rope_theta, rotary_dim=rd)


def forward(params: Dict[str, Any], cfg: ArchConfig, tokens: jax.Array,
            frontend_embeds: Optional[jax.Array] = None,
            remat: bool = False) -> Tuple[jax.Array, jax.Array]:
    """tokens: (B, S_text).  Returns (logits (B, S_total, V), aux_loss)."""
    x = embed_lookup(params["embed"], tokens, tied=cfg.tie_embeddings)
    if cfg.frontend == "vision" and frontend_embeds is not None:
        fe = frontend_embeds.astype(x.dtype) @ params["frontend_proj"]
        x = jnp.concatenate([fe, x], axis=1)
    elif frontend_embeds is not None:      # audio conditioning frames
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
    B, S, d = x.shape
    x = shard_hint(x, "dp", None, "model")
    cos, sin = _rope_tables(cfg, S)
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.block_pattern == "attn":
        def body(carry, block):
            h, aux = carry
            h, a = _attn_block_fwd(block, h, cos, sin, cfg)
            h = shard_hint(h, "dp", None, "model")
            return (h, aux + a), None
        if remat:
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total),
                                         params["blocks"])
    elif cfg.block_pattern == "mamba_shared_attn":
        x = _hybrid_forward(params, cfg, x, cos, sin, remat=remat)
    else:
        x = _xlstm_forward(params, cfg, x, remat=remat)

    x = (rms_norm(x, params["final_ln_scale"]) if cfg.norm == "rms"
         else layer_norm(x, params["final_ln_scale"], params["final_ln_bias"]))
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = shard_hint(x @ head, "dp", None, "model")
    return logits, aux_total


def _hybrid_forward(params, cfg, x, cos, sin, remat=False):
    """zamba2: shared attn block every ``attn_every`` Mamba2 layers."""
    L, k = cfg.n_layers, cfg.attn_every
    blocks = params["mamba_blocks"]

    def shared(h):
        return _attn_block_fwd(params["shared_attn"], h, cos, sin, cfg)[0]

    def body(h, blk):
        hn = _norm(blk, "ln1", h, cfg)
        h = h + mamba2.mamba2_forward(blk, hn, cfg)
        return shard_hint(h, "dp", None, "model"), None

    if remat:
        shared = jax.checkpoint(shared)
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    done = 0
    while done < L:
        x = shared(x)
        take = min(k, L - done)
        chunk = jax.tree_util.tree_map(lambda w: w[done:done + take], blocks)
        x, _ = jax.lax.scan(body, x, chunk)
        done += take
    return x


def _xlstm_forward(params, cfg, x, remat=False):
    L = cfg.n_layers
    period = cfg.slstm_every or (L + 1)
    n_s = L // period
    m_per_group = period - 1
    mi, si = 0, 0
    mblocks = params["mlstm_blocks"]
    done = 0
    while done < L:
        take = min(m_per_group, L - done - (1 if si < n_s else 0))
        if take > 0:
            chunk = jax.tree_util.tree_map(
                lambda w: w[mi:mi + take], mblocks)
            def body(h, blk):
                hn = _norm(blk, "ln1", h, cfg)
                h = h + xlstm.mlstm_forward(blk, hn, cfg)
                return shard_hint(h, "dp", None, "model"), None
            if remat:
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.nothing_saveable)
            x, _ = jax.lax.scan(body, x, chunk)
            mi += take
            done += take
        if si < n_s and done < L:
            blk = jax.tree_util.tree_map(lambda w: w[si],
                                         params["slstm_blocks"])
            def s_apply(h):
                hn = _norm(blk, "ln1", h, cfg)
                return h + xlstm.slstm_forward(blk, hn, cfg)
            if remat:
                s_apply = jax.checkpoint(s_apply)
            x = s_apply(x)
            si += 1
            done += 1
    return x


# -------------------------------------------------------------------- loss
def loss_fn(params: Dict[str, Any], cfg: ArchConfig,
            batch: Dict[str, jax.Array], remat: bool = False
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = forward(params, cfg, batch["tokens"],
                          batch.get("frontend"), remat=remat)
    # align: frontend tokens carry no loss
    n_front = logits.shape[1] - batch["tokens"].shape[1]
    logits = logits[:, n_front:]
    targets = batch["labels"]
    logits = logits[:, :-1].astype(jnp.float32)
    targets = targets[:, 1:]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold).mean()
    loss = nll + 0.01 * aux
    return loss, {"nll": nll, "aux": aux}


# ------------------------------------------------------------------- decode
def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               kv_dtype=jnp.bfloat16) -> Dict[str, Any]:
    """``kv_dtype=jnp.float8_e4m3fn`` halves KV-cache HBM (keys/values are
    dequantized to fp32 inside attention; per-value fp8 e4m3 is the
    standard low-risk KV compression)."""
    K, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    if cfg.block_pattern == "attn":
        shape = (cfg.n_layers, batch, max_seq, K, Dh)
        return {"k": jnp.zeros(shape, kv_dtype),
                "v": jnp.zeros(shape, kv_dtype)}
    if cfg.block_pattern == "mamba_shared_attn":
        n_apps = -(-cfg.n_layers // cfg.attn_every)
        m = mamba2.init_mamba2_cache(cfg, batch)
        return {
            "mamba": jax.tree_util.tree_map(
                lambda z: jnp.broadcast_to(
                    z[None], (cfg.n_layers,) + z.shape), m),
            "k": jnp.zeros((n_apps, batch, max_seq, K, Dh), kv_dtype),
            "v": jnp.zeros((n_apps, batch, max_seq, K, Dh), kv_dtype),
        }
    # xlstm
    period = cfg.slstm_every or (cfg.n_layers + 1)
    n_s = cfg.n_layers // period
    n_m = cfg.n_layers - n_s
    mc = xlstm.init_mlstm_cache(cfg, batch)
    cache = {"mlstm": jax.tree_util.tree_map(
        lambda z: jnp.broadcast_to(z[None], (n_m,) + z.shape), mc)}
    if n_s:
        sc = xlstm.init_slstm_cache(cfg, batch)
        cache["slstm"] = jax.tree_util.tree_map(
            lambda z: jnp.broadcast_to(z[None], (n_s,) + z.shape), sc)
    return cache


def decode_step(params: Dict[str, Any], cfg: ArchConfig,
                cache: Dict[str, Any], token: jax.Array, pos: jax.Array
                ) -> Tuple[jax.Array, Dict[str, Any]]:
    """token: (B,) int32; pos: scalar int32 (current sequence length).

    Returns (logits (B, V), new cache)."""
    x = embed_lookup(params["embed"], token,
                     tied=cfg.tie_embeddings)[:, None, :]   # (B, 1, d)
    max_pos = cfg.max_position
    cos, sin = _rope_tables(cfg, max_pos)

    if cfg.block_pattern == "attn":
        def body(h, inputs):
            blk, ck, cv = inputs
            h, ck, cv = _attn_block_decode(blk, h, ck, cv, pos, cos, sin, cfg)
            return h, (ck, cv)
        x, (ks, vs) = jax.lax.scan(body, x,
                                   (params["blocks"], cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs}
    elif cfg.block_pattern == "mamba_shared_attn":
        x, new_cache = _hybrid_decode(params, cfg, cache, x, pos, cos, sin)
    else:
        x, new_cache = _xlstm_decode(params, cfg, cache, x)

    x = (rms_norm(x, params["final_ln_scale"]) if cfg.norm == "rms"
         else layer_norm(x, params["final_ln_scale"], params["final_ln_bias"]))
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = shard_hint((x @ head)[:, 0], None, "model")
    return logits, new_cache


def _hybrid_decode(params, cfg, cache, x, pos, cos, sin):
    L, k = cfg.n_layers, cfg.attn_every
    blocks = params["mamba_blocks"]
    new_m = []
    ks, vs = [], []
    done, app = 0, 0
    while done < L:
        x, ck, cv = _attn_block_decode(
            params["shared_attn"], x, cache["k"][app], cache["v"][app],
            pos, cos, sin, cfg)
        ks.append(ck)
        vs.append(cv)
        app += 1
        take = min(k, L - done)
        chunk = jax.tree_util.tree_map(lambda w: w[done:done + take], blocks)
        mcache = jax.tree_util.tree_map(lambda w: w[done:done + take],
                                        cache["mamba"])
        def body(h, inputs):
            blk, mc = inputs
            hn = _norm(blk, "ln1", h, cfg)
            out, mc2 = mamba2.mamba2_decode(blk, hn, mc, cfg)
            return h + out, mc2
        x, mc_new = jax.lax.scan(body, x, (chunk, mcache))
        new_m.append(mc_new)
        done += take
    mamba_cache = jax.tree_util.tree_map(
        lambda *xs: jnp.concatenate(xs, axis=0), *new_m)
    return x, {"mamba": mamba_cache, "k": jnp.stack(ks), "v": jnp.stack(vs)}


def _xlstm_decode(params, cfg, cache, x):
    L = cfg.n_layers
    period = cfg.slstm_every or (L + 1)
    n_s = L // period
    m_per_group = period - 1
    mblocks = params["mlstm_blocks"]
    new_m, new_s = [], []
    mi, si, done = 0, 0, 0
    while done < L:
        take = min(m_per_group, L - done - (1 if si < n_s else 0))
        if take > 0:
            chunk = jax.tree_util.tree_map(lambda w: w[mi:mi + take], mblocks)
            mcache = jax.tree_util.tree_map(lambda w: w[mi:mi + take],
                                            cache["mlstm"])
            def body(h, inputs):
                blk, mc = inputs
                hn = _norm(blk, "ln1", h, cfg)
                out, mc2 = xlstm.mlstm_decode(blk, hn, mc, cfg)
                return h + out, mc2
            x, mc_new = jax.lax.scan(body, x, (chunk, mcache))
            new_m.append(mc_new)
            mi += take
            done += take
        if si < n_s and done < L:
            blk = jax.tree_util.tree_map(lambda w: w[si],
                                         params["slstm_blocks"])
            sc = jax.tree_util.tree_map(lambda w: w[si], cache["slstm"])
            hn = _norm(blk, "ln1", x, cfg)
            out, sc2 = xlstm.slstm_decode(blk, hn, sc, cfg)
            x = x + out
            new_s.append(sc2)
            si += 1
            done += 1
    out_cache = {"mlstm": jax.tree_util.tree_map(
        lambda *xs: jnp.concatenate(xs, axis=0), *new_m)}
    if new_s:
        out_cache["slstm"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs, axis=0), *new_s)
    return x, out_cache
