"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory, sequential) [arXiv:2405.04517].

mLSTM is driven by the shared chunked linear-recurrence engine from
``repro.models.mamba2``:  C_t = f_t C_{t-1} + i_t v_t k_t^T  with the
normalizer n_t = f_t n_{t-1} + i_t k_t computed by appending a ones-column
to v (state width P+1).  Gates use the exponential-gating stabilization of
the paper folded into per-step decays.

sLSTM keeps per-head scalar memories and is inherently sequential: a
``lax.scan`` over time with block-diagonal recurrent weights.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import dense_init, rms_norm, split_keys
from .mamba2 import chunked_linear_scan, linear_scan_step


# ------------------------------------------------------------------ mLSTM
def init_mlstm_params(key: jax.Array, cfg: ArchConfig,
                      dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    H = cfg.n_heads
    ks = split_keys(key, 3)
    return {
        # q, k, v over the up-projected stream + i, f gates per head
        "in_proj": dense_init(ks[0], (d, 3 * d_in + 2 * H), dtype),
        "o_gate": dense_init(ks[1], (d, d_in), dtype),
        "norm": jnp.zeros((d_in,), dtype),
        "out_proj": dense_init(ks[2], (d_in, d), dtype),
    }


def _mlstm_qkv(params, x, cfg):
    B, S, d = x.shape
    d_in = cfg.ssm_expand * d
    H = cfg.n_heads
    P = d_in // H
    proj = x @ params["in_proj"]
    q = proj[..., :d_in].reshape(B, S, H, P)
    k = proj[..., d_in:2 * d_in].reshape(B, S, H, P) / jnp.sqrt(P)
    v = proj[..., 2 * d_in:3 * d_in].reshape(B, S, H, P)
    ig = proj[..., 3 * d_in:3 * d_in + H].astype(jnp.float32)
    fg = proj[..., 3 * d_in + H:].astype(jnp.float32)
    return q, k, v, ig, fg, d_in, H, P


def mlstm_forward(params: Dict[str, jax.Array], x: jax.Array,
                  cfg: ArchConfig, *, chunk: int = 256) -> jax.Array:
    B, S, d = x.shape
    q, k, v, ig, fg, d_in, H, P = _mlstm_qkv(params, x, cfg)
    f = jax.nn.sigmoid(fg)                           # per-step decay (B,S,H)
    i = jnp.exp(ig - jax.nn.softplus(ig))            # stabilized input gate
    # state update: C = f*C + (i*v) k^T ; normalizer via ones column on v
    v_aug = jnp.concatenate(
        [v.astype(jnp.float32) * i[..., None],
         i[..., None] * jnp.ones((B, S, H, 1), jnp.float32)], axis=-1)
    y, _ = chunked_linear_scan(f, k, v_aug, q, chunk=chunk)
    num, den = y[..., :P], y[..., P:]
    h = num / jnp.maximum(jnp.abs(den), 1.0)
    h = h.reshape(B, S, d_in).astype(x.dtype)
    h = rms_norm(h, params["norm"]) * jax.nn.sigmoid(x @ params["o_gate"])
    return h @ params["out_proj"]


def init_mlstm_cache(cfg: ArchConfig, batch: int):
    d_in = cfg.ssm_expand * cfg.d_model
    H = cfg.n_heads
    P = d_in // H
    return {"state": jnp.zeros((batch, H, P, P + 1), jnp.float32)}


def mlstm_decode(params: Dict[str, jax.Array], x: jax.Array, cache: Dict,
                 cfg: ArchConfig) -> Tuple[jax.Array, Dict]:
    B = x.shape[0]
    q, k, v, ig, fg, d_in, H, P = _mlstm_qkv(params, x, cfg)
    f = jax.nn.sigmoid(fg[:, 0])
    i = jnp.exp(ig[:, 0] - jax.nn.softplus(ig[:, 0]))
    v_aug = jnp.concatenate(
        [v[:, 0].astype(jnp.float32) * i[..., None],
         i[..., None] * jnp.ones((B, H, 1), jnp.float32)], axis=-1)
    y, new_state = linear_scan_step(cache["state"], f, k[:, 0], v_aug, q[:, 0])
    num, den = y[..., :P], y[..., P:]
    h = (num / jnp.maximum(jnp.abs(den), 1.0)).reshape(B, 1, d_in)
    h = h.astype(x.dtype)
    h = rms_norm(h, params["norm"]) * jax.nn.sigmoid(x @ params["o_gate"])
    return h @ params["out_proj"], {"state": new_state}


# ------------------------------------------------------------------ sLSTM
def init_slstm_params(key: jax.Array, cfg: ArchConfig,
                      dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    d = cfg.d_model
    H = cfg.n_heads
    P = d // H
    ks = split_keys(key, 3)
    return {
        "w_gates": dense_init(ks[0], (d, 4 * d), dtype),     # z, i, f, o
        "r_gates": dense_init(ks[1], (H, P, 4 * P), dtype),  # block-diag rec
        "norm": jnp.zeros((d,), dtype),
        "out_proj": dense_init(ks[2], (d, d), dtype),
    }


def _slstm_cell(params, carry, gates_t, H, P):
    """One sLSTM step.  gates_t: (B, 4d) pre-activations from the input."""
    h, c, n, m = carry                                  # (B, H, P) each / m: (B,H,P)
    rec = jnp.einsum("bhp,hpq->bhq", h, params["r_gates"].astype(jnp.float32))
    g = gates_t.reshape(gates_t.shape[0], H, 4 * P).astype(jnp.float32) + rec
    z = jnp.tanh(g[..., :P])
    i_t = g[..., P:2 * P]
    f_t = g[..., 2 * P:3 * P]
    o = jax.nn.sigmoid(g[..., 3 * P:])
    # exponential gating with stabilizer state m
    m_new = jnp.maximum(f_t + m, i_t)
    i_e = jnp.exp(i_t - m_new)
    f_e = jnp.exp(f_t + m - m_new)
    c_new = f_e * c + i_e * z
    n_new = f_e * n + i_e
    h_new = o * (c_new / jnp.maximum(jnp.abs(n_new), 1.0))
    return (h_new, c_new, n_new, m_new)


def slstm_forward(params: Dict[str, jax.Array], x: jax.Array,
                  cfg: ArchConfig) -> jax.Array:
    B, S, d = x.shape
    H = cfg.n_heads
    P = d // H
    gates = x @ params["w_gates"]                        # (B, S, 4d)
    zeros = jnp.zeros((B, H, P), jnp.float32)
    carry0 = (zeros, zeros, zeros, zeros)

    def step(carry, g_t):
        new = _slstm_cell(params, carry, g_t, H, P)
        return new, new[0]

    _, hs = jax.lax.scan(step, carry0, jnp.moveaxis(gates, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(x.dtype)
    h = rms_norm(h, params["norm"])
    return h @ params["out_proj"]


def init_slstm_cache(cfg: ArchConfig, batch: int):
    H = cfg.n_heads
    P = cfg.d_model // H
    z = jnp.zeros((batch, H, P), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": z}


def slstm_decode(params: Dict[str, jax.Array], x: jax.Array, cache: Dict,
                 cfg: ArchConfig) -> Tuple[jax.Array, Dict]:
    B = x.shape[0]
    H = cfg.n_heads
    P = cfg.d_model // H
    gates = (x @ params["w_gates"])[:, 0]
    carry = (cache["h"], cache["c"], cache["n"], cache["m"])
    h, c, n, m = _slstm_cell(params, carry, gates, H, P)
    out = h.reshape(B, 1, cfg.d_model).astype(x.dtype)
    out = rms_norm(out, params["norm"]) @ params["out_proj"]
    return out, {"h": h, "c": c, "n": n, "m": m}
