"""Mamba-2 (SSD) block and the shared chunked linear-recurrence engine.

The SSD recurrence  S_t = a_t * S_{t-1} + k_t v_t^T,  y_t = S_t^T q_t  is
computed chunkwise (Mamba-2 paper §6): intra-chunk quadratic term with a
decay mask + inter-chunk state carried by a ``lax.scan``.  The carried state
is (B, H, P, N) — constant in sequence length, which is what makes
``long_500k`` feasible.  The same engine drives the mLSTM in
``repro.models.xlstm`` (state N == P, gate-derived decays).

A Pallas TPU kernel for the intra-chunk term lives in
``repro.kernels.ssd_scan``.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import dense_init, rms_norm, split_keys


# ---------------------------------------------------------------------------
def chunked_linear_scan(a: jax.Array, k: jax.Array, v: jax.Array,
                        q: jax.Array, *, chunk: int = 256,
                        initial_state: jax.Array = None
                        ) -> Tuple[jax.Array, jax.Array]:
    """Chunked scan of S_t = a_t S_{t-1} + k_t v_t^T ;  y_t = S_t^T q_t.

    a: (B, S, H) per-step decay in (0, 1]; k, q: (B, S, H, N);
    v: (B, S, H, P).  Returns y: (B, S, H, P) and final state (B, H, N, P).
    """
    B, S, H, N = k.shape
    P = v.shape[-1]
    Q = min(chunk, S)
    n_chunks = -(-S // Q)
    pad = n_chunks * Q - S
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))

    def to_chunks(x):
        return jnp.moveaxis(
            x.reshape((B, n_chunks, Q) + x.shape[2:]), 1, 0)

    ac, kc, vc, qc = map(to_chunks, (a, k, v, q))    # (n, B, Q, ...)

    if initial_state is None:
        initial_state = jnp.zeros((B, H, N, P), jnp.float32)

    def body(S_prev, inp):
        a_b, k_b, v_b, q_b = inp                      # (B, Q, H, ...)
        la = jnp.log(jnp.maximum(a_b.astype(jnp.float32), 1e-37))
        cum = jnp.cumsum(la, axis=1)                  # (B, Q, H)
        # intra-chunk: mask[i, j] = prod_{j < t <= i} a_t  (i >= j)
        seg = cum[:, :, None, :] - cum[:, None, :, :]     # (B, Q, Q, H)
        iq = jnp.arange(Q)
        causal = (iq[:, None] >= iq[None, :])
        mask = jnp.where(causal[None, :, :, None], jnp.exp(seg), 0.0)
        scores = jnp.einsum("bihn,bjhn->bijh", q_b.astype(jnp.float32),
                            k_b.astype(jnp.float32)) * mask
        y_intra = jnp.einsum("bijh,bjhp->bihp", scores,
                             v_b.astype(jnp.float32))
        # inter-chunk: decay from chunk start to position i (inclusive)
        dec_in = jnp.exp(cum)                          # (B, Q, H)
        y_inter = jnp.einsum("bihn,bhnp->bihp",
                             q_b.astype(jnp.float32) * dec_in[..., None],
                             S_prev)
        # chunk state update: decay each contribution to chunk end
        dec_out = jnp.exp(cum[:, -1:, :] - cum)        # (B, Q, H)
        S_chunk = jnp.einsum("bihn,bihp->bhnp",
                             k_b.astype(jnp.float32) * dec_out[..., None],
                             v_b.astype(jnp.float32))
        S_new = S_prev * jnp.exp(cum[:, -1, :])[:, :, None, None] + S_chunk
        return S_new, y_intra + y_inter

    S_fin, yc = jax.lax.scan(body, initial_state, (ac, kc, vc, qc))
    y = jnp.moveaxis(yc, 0, 1).reshape(B, n_chunks * Q, H, P)[:, :S]
    return y, S_fin


def linear_scan_step(state: jax.Array, a: jax.Array, k: jax.Array,
                     v: jax.Array, q: jax.Array
                     ) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrence step (decode).

    state: (B, H, N, P); a: (B, H); k, q: (B, H, N); v: (B, H, P).
    Returns (y (B, H, P), new_state)."""
    state = state * a[..., None, None].astype(jnp.float32) \
        + jnp.einsum("bhn,bhp->bhnp", k.astype(jnp.float32),
                     v.astype(jnp.float32))
    y = jnp.einsum("bhnp,bhn->bhp", state, q.astype(jnp.float32))
    return y, state


# ---------------------------------------------------------------------------
def init_mamba2_params(key: jax.Array, cfg: ArchConfig,
                       dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    N = cfg.ssm_state
    H = d_in // cfg.ssm_head_dim
    conv_ch = d_in + 2 * N
    ks = split_keys(key, 4)
    return {
        # order: [z (d_in), x (d_in), B (N), C (N), dt (H)]
        "in_proj": dense_init(ks[0], (d, 2 * d_in + 2 * N + H), dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, conv_ch), dtype,
                             scale=0.5),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.zeros((H,), jnp.float32),              # A = -exp(a_log)
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),       # softplus bias
        "d_skip": jnp.ones((H,), jnp.float32),
        "norm": jnp.zeros((d_in,), dtype),
        "out_proj": dense_init(ks[2], (d_in, d), dtype),
    }


def _split_proj(proj: jax.Array, cfg: ArchConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    N = cfg.ssm_state
    H = d_in // cfg.ssm_head_dim
    z = proj[..., :d_in]
    xbc = proj[..., d_in:d_in + d_in + 2 * N]
    dt = proj[..., d_in + d_in + 2 * N:]
    return z, xbc, dt, d_in, N, H


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along S.  xbc: (B, S, C); w: (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def mamba2_forward(params: Dict[str, jax.Array], x: jax.Array,
                   cfg: ArchConfig, *, chunk: int = 256) -> jax.Array:
    """Full-sequence Mamba-2 block.  x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    P = cfg.ssm_head_dim
    proj = x @ params["in_proj"]
    z, xbc, dt, d_in, N, H = _split_proj(proj, cfg)
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xs = xbc[..., :d_in].reshape(B, S, H, P)
    Bmat = xbc[..., d_in:d_in + N]                       # (B, S, N)
    Cmat = xbc[..., d_in + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"])            # (B, S, H)
    A = -jnp.exp(params["a_log"])                        # (H,)
    a = jnp.exp(dt * A)                                  # decay in (0,1]
    k = jnp.broadcast_to(Bmat[:, :, None, :], (B, S, H, N))
    q = jnp.broadcast_to(Cmat[:, :, None, :], (B, S, H, N))
    v = xs * dt[..., None]
    y, _ = chunked_linear_scan(a, k, v, q, chunk=chunk)
    y = y + xs.astype(jnp.float32) * params["d_skip"][None, None, :, None]
    y = y.reshape(B, S, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    return y @ params["out_proj"]


def init_mamba2_cache(cfg: ArchConfig, batch: int):
    d_in = cfg.ssm_expand * cfg.d_model
    N = cfg.ssm_state
    H = d_in // cfg.ssm_head_dim
    P = cfg.ssm_head_dim
    conv_ch = d_in + 2 * N
    return {
        "ssm": jnp.zeros((batch, H, N, P), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), jnp.bfloat16),
    }


def mamba2_decode(params: Dict[str, jax.Array], x: jax.Array, cache: Dict,
                  cfg: ArchConfig) -> Tuple[jax.Array, Dict]:
    """One-token step.  x: (B, 1, d)."""
    B = x.shape[0]
    P = cfg.ssm_head_dim
    proj = x @ params["in_proj"]
    z, xbc, dt, d_in, N, H = _split_proj(proj, cfg)
    # conv over the cached window + current token
    win = jnp.concatenate([cache["conv"], xbc.astype(cache["conv"].dtype)],
                          axis=1)                        # (B, K, C)
    w = params["conv_w"]
    conv = jax.nn.silu((win * w[None]).sum(axis=1, keepdims=True)
                       + params["conv_b"])
    xs = conv[..., :d_in].reshape(B, H, P)
    Bmat = conv[:, 0, d_in:d_in + N]
    Cmat = conv[:, 0, d_in + N:]
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["a_log"])
    a = jnp.exp(dt * A)                                  # (B, H)
    k = jnp.broadcast_to(Bmat[:, None, :], (B, H, N))
    q = jnp.broadcast_to(Cmat[:, None, :], (B, H, N))
    v = xs * dt[..., None]
    y, new_state = linear_scan_step(cache["ssm"], a, k, v, q)
    y = y + xs.astype(jnp.float32) * params["d_skip"][None, :, None]
    y = y.reshape(B, 1, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    new_cache = {"ssm": new_state, "conv": win[:, 1:]}
    return y @ params["out_proj"], new_cache
