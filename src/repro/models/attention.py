"""GQA/MQA/MHA attention with a memory-efficient (flash-style) JAX path and
a KV-cache decode path.

The chunked formulation below is the pure-JAX twin of the Pallas flash
kernel in ``repro.kernels.flash_attention``: it never materializes the full
(S, T) score matrix, which is what lets ``prefill_32k`` compile within HBM.
``repro.kernels.ops`` dispatches to the Pallas kernel on TPU.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import apply_rope, dense_init, split_keys

NEG_INF = -2.0 ** 30


# ----------------------------------------------------------------- params
def init_attn_params(key: jax.Array, cfg: ArchConfig,
                     dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    d, H, K, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H * Dh), dtype),
        "wk": dense_init(ks[1], (d, K * Dh), dtype),
        "wv": dense_init(ks[2], (d, K * Dh), dtype),
        "wo": dense_init(ks[3], (H * Dh, d), dtype),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((H * Dh,), dtype)
        p["bk"] = jnp.zeros((K * Dh,), dtype)
        p["bv"] = jnp.zeros((K * Dh,), dtype)
    return p


# ------------------------------------------------- chunked causal attention
def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      *, causal: bool = True, chunk: int = 1024,
                      q_chunk: int = 512, q_offset: int = 0) -> jax.Array:
    """Flash-style attention blocked in BOTH directions (never materializes
    more than a (bq, bk) score tile per head group).

    q: (B, S, H, D); k/v: (B, T, K, D) with H = G*K.  ``q_offset``: absolute
    position of q[0] (decode / chunked prefill).  Outer scan over q tiles,
    inner scan over KV tiles with the running (max, sum, acc) triple — the
    same loop structure as the Pallas kernel grid.
    """
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(D)

    bq = min(q_chunk, S)
    nq = -(-S // bq)
    pad_q = nq * bq - S
    qf = (q.astype(jnp.float32) * scale).reshape(B, S, K, G, D)
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    qc = jnp.moveaxis(qf.reshape(B, nq, bq, K, G, D), 1, 0)

    bk = min(chunk, T)
    nk = -(-T // bk)
    pad_t = nk * bk - T
    if pad_t:
        k = jnp.pad(k, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
    kc = jnp.moveaxis(k.reshape(B, nk, bk, K, D), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nk, bk, K, D), 1, 0)

    def q_block(_, q_in):
        qb, qi = q_in                              # (B, bq, K, G, D)
        q_pos = q_offset + qi * bq + jnp.arange(bq)

        def body(carry, inputs):
            m, l, acc = carry
            kb, vb, c_idx = inputs
            kv_pos = c_idx * bk + jnp.arange(bk)
            s = jnp.einsum("bskgd,btkd->bskgt", qb, kb.astype(jnp.float32))
            bad = (kv_pos >= T)[None, :]
            if causal:
                bad = bad | (kv_pos[None, :] > q_pos[:, None])
            s = jnp.where(bad[None, :, None, None, :], NEG_INF, s)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bskgt,btkd->bskgd", p, vb.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, bq, K, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, bq, K, G), jnp.float32)
        a0 = jnp.zeros((B, bq, K, G, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                      (kc, vc, jnp.arange(nk)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_block, None, (qc, jnp.arange(nq)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * bq, K, G, D)[:, :S]
    return out.reshape(B, S, H, D)


# ----------------------------------------------------------- full forward
def attn_forward(params: Dict[str, jax.Array], x: jax.Array,
                 cos: jax.Array, sin: jax.Array, cfg: ArchConfig,
                 *, q_offset: int = 0, kv_chunk: int = 1024) -> jax.Array:
    """Causal self-attention over a full sequence (train / prefill)."""
    B, S, d = x.shape
    H, K, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.attn_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, S, H, Dh)
    k = k.reshape(B, S, K, Dh)
    v = v.reshape(B, S, K, Dh)
    rd = int(Dh * cfg.rotary_fraction)
    if rd:
        pos = q_offset + jnp.arange(S)
        q = apply_rope(q, cos, sin, positions=pos, rotary_dim=rd)
        k = apply_rope(k, cos, sin, positions=pos, rotary_dim=rd)
    out = chunked_attention(q, k, v, causal=True, chunk=kv_chunk,
                            q_offset=q_offset)
    return out.reshape(B, S, H * Dh) @ params["wo"]


# ------------------------------------------------------------------ decode
def attn_decode(params: Dict[str, jax.Array], x: jax.Array,
                cache_k: jax.Array, cache_v: jax.Array, pos: jax.Array,
                cos: jax.Array, sin: jax.Array, cfg: ArchConfig,
                *, kv_chunk: int = 8192
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode against a KV cache.

    x: (B, 1, d); cache_k/v: (B, S_max, K, Dh); pos: scalar current length.
    Returns (out (B,1,d), new_cache_k, new_cache_v).
    """
    B, _, d = x.shape
    H, K, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    S_max = cache_k.shape[1]
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.attn_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, 1, H, Dh)
    k = k.reshape(B, 1, K, Dh)
    v = v.reshape(B, 1, K, Dh)
    rd = int(Dh * cfg.rotary_fraction)
    if rd:
        pvec = jnp.full((1,), pos, jnp.int32)
        q = apply_rope(q, cos, sin, positions=pvec, rotary_dim=rd)
        k = apply_rope(k, cos, sin, positions=pvec, rotary_dim=rd)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), pos, axis=1)

    # flash-decoding: stream KV chunks with a running softmax so the score
    # tensor never exceeds (B, K, G, chunk) — bounded at 500k-token caches
    G = H // K
    scale = 1.0 / math.sqrt(Dh)
    qf = (q.astype(jnp.float32) * scale).reshape(B, K, G, Dh)
    bk = min(kv_chunk, S_max)
    while S_max % bk:          # keep chunks aligned without padding copies
        bk //= 2
    nk = S_max // bk

    def body(carry, ci):
        m, l, acc = carry
        # dynamic slices view the cache in place — no transposed copy of a
        # multi-GiB buffer
        kb = jax.lax.dynamic_slice_in_dim(cache_k, ci * bk, bk, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(cache_v, ci * bk, bk, axis=1)
        t_pos = ci * bk + jnp.arange(bk)
        s = jnp.einsum("bkgd,btkd->bkgt", qf, kb.astype(jnp.float32))
        s = jnp.where((t_pos > pos)[None, None, None, :], NEG_INF, s)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgt,btkd->bkgd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G), jnp.float32)
    a0 = jnp.zeros((B, K, G, Dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(nk))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.reshape(B, 1, H * Dh).astype(x.dtype) @ params["wo"]
    return out, cache_k, cache_v
