"""Mixture-of-Experts layer: top-k routing with capacity-based dispatch.

GShard-style dispatch implemented scatter/gather-style (no (T, E, C) one-hot
einsum): assignment positions come from a one-hot cumsum, tokens above
capacity are dropped (capacity_factor controls slack), combine weights are
the renormalized top-k gates.  Shared experts (DeepSeek/Moonlight style) run
densely alongside.

Sharding: expert-stacked weights (E, d, f) shard E over the ``model`` axis
(expert parallelism); the dispatch buffer (E, C, d) shards E over ``model``
and C over ``data`` so XLA lowers the token exchange to all-to-all-like
collectives.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import ACTIVATIONS, dense_init, shard_hint, split_keys


def init_moe_params(key: jax.Array, cfg: ArchConfig,
                    dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    d, E, f = cfg.d_model, cfg.moe_experts, cfg.moe_d_ff
    ks = split_keys(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "w_gate": dense_init(ks[1], (E, d, f), dtype),
        "w_up": dense_init(ks[2], (E, d, f), dtype),
        "w_down": dense_init(ks[3], (E, f, d), dtype),
    }
    if cfg.moe_shared_experts:
        fs = f * cfg.moe_shared_experts
        ks2 = split_keys(ks[4], 3)
        p["shared_gate"] = dense_init(ks2[0], (d, fs), dtype)
        p["shared_up"] = dense_init(ks2[1], (d, fs), dtype)
        p["shared_down"] = dense_init(ks2[2], (fs, d), dtype)
    return p


def _capacity(n_tokens: int, cfg: ArchConfig) -> int:
    c = int(n_tokens * cfg.moe_top_k / cfg.moe_experts
            * cfg.moe_capacity_factor)
    return max(8, -(-c // 8) * 8)


def moe_forward(params: Dict[str, jax.Array], x: jax.Array,
                cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    """Returns (output, aux_loss).  x: (B, S, d).

    Dispatch is *group-local* (GShard): each batch row dispatches its own
    tokens with row-local capacity, so every dispatch buffer keeps a leading
    batch dim — scatters/gathers stay batched (dp-sharded) and the expert
    dim shards over "model" (EP); XLA lowers the (dp x model) resharding of
    the (B, E, C, d) buffer to the expert all-to-all.

    Long sequences run the dispatch *sequentially* over <=4096-token chunks
    (``lax.map``) so the (tokens*k, d) gather/scatter tensors stay bounded
    — chunked-prefill MoE; capacity is per 4k window, standard practice."""
    B0, S0, d = x.shape
    SC = 4096
    if S0 > SC and S0 % SC == 0:
        nc = S0 // SC
        xs = jnp.swapaxes(x.reshape(B0, nc, SC, d), 0, 1)   # (nc, B, SC, d)
        outs, auxs = jax.lax.map(
            lambda xc: _moe_core(params, xc, cfg), xs)
        out = jnp.swapaxes(outs, 0, 1).reshape(B0, S0, d)
        return out, auxs.mean()
    return _moe_core(params, x, cfg)


def _moe_core(params: Dict[str, jax.Array], x: jax.Array,
              cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    B, S, d = x.shape
    E, k = cfg.moe_experts, cfg.moe_top_k
    act = ACTIVATIONS[cfg.activation]

    logits = x.astype(jnp.float32) @ params["router"]           # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                        # (B, S, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): E * mean(frac_tokens * frac_prob)
    me = probs.mean(axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=(0, 1, 2))
    aux = E * jnp.sum(me * ce)

    C = _capacity(S, cfg)                       # row-local capacity
    flat_e = idx.reshape(B, S * k)

    def dispatch_row(xrow, erow):
        """xrow: (S, d); erow: (S*k,) -> (E, C, d), pos, keep.

        Positions within each expert come from an argsort rank (O(S*k)
        memory) instead of a one-hot cumsum (O(S*k*E))."""
        order = jnp.argsort(erow)                   # stable
        rank = jnp.argsort(order)
        counts = jnp.bincount(erow, length=E)
        start = jnp.cumsum(counts) - counts
        pos = rank - start[erow]
        keep = pos < C
        pos_c = jnp.minimum(pos, C - 1)
        contrib = jnp.repeat(xrow, k, axis=0) \
            * keep[:, None].astype(xrow.dtype)
        buf = jnp.zeros((E, C, d), xrow.dtype).at[erow, pos_c].add(contrib)
        return buf, pos_c, keep

    buf, pos_c, keep = jax.vmap(dispatch_row)(x, flat_e)
    buf = shard_hint(buf, "dp", "model", None, None)   # EP all-to-all here
    h = (act(jnp.einsum("becd,edf->becf", buf, params["w_gate"]))
         * jnp.einsum("becd,edf->becf", buf, params["w_up"]))
    h = shard_hint(h, "dp", "model", None, None)
    h = jnp.einsum("becf,efd->becd", h, params["w_down"])
    h = shard_hint(h, "dp", "model", None, None)

    def combine_row(hrow, erow, prow, krow, grow):
        picked = hrow[erow, prow]                              # (S*k, d)
        picked = picked * (grow.reshape(-1, 1)
                           * krow[:, None]).astype(hrow.dtype)
        tok = jnp.arange(S * k) // k
        return jnp.zeros((S, d), hrow.dtype).at[tok].add(picked)

    out = jax.vmap(combine_row)(h, flat_e, pos_c, keep,
                                gates.reshape(B, S * k))
    out = shard_hint(out, "dp", None, "model")

    if cfg.moe_shared_experts:
        out = out + (act(x @ params["shared_gate"])
                     * (x @ params["shared_up"])) @ params["shared_down"]
    return out, aux
