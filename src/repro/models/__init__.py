"""Model zoo: shared layers + LM assembly for the 10 assigned archs."""

from . import attention, common, lm, mamba2, mlp, moe, xlstm

__all__ = ["attention", "common", "lm", "mamba2", "mlp", "moe", "xlstm"]
