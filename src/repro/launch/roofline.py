"""Roofline analysis per (arch x shape) on the single-pod mesh.

Why analytic: XLA's ``cost_analysis()`` counts a ``lax.scan`` body once
(and unrolls length-1 scans), so loop-heavy programs (layer scans, flash
q/kv tile loops, microbatch loops) cannot be totalled from the compiled
artifact alone — L1/L2 probe extrapolation produces negative per-layer
deltas.  The three roofline terms are therefore derived analytically from
the architecture/shape/parallelism (the standard napkin model), while the
compiled dry-run supplies the *validation* side: memory_analysis (fit
proof), the collective op census (which collectives, how many, what shapes)
and the per-body cost sanity checks recorded in EXPERIMENTS.md.

Terms (per chip, per step):
  compute_s    = FLOPs / 197e12          (dense 6ND train / 2ND inference,
                                          N_active for MoE, + exact causal
                                          attention term, x3 for backward,
                                          +1 fwd repeat when remat)
  memory_s     = HBM bytes / 819e9       (weight passes + activation
                                          traffic + optimizer state + KV)
  collective_s = ici bytes / 50e9        (FSDP all-gather + grad
                                          reduce-scatter + TP activation
                                          ARs + EP all-to-all + logits AR;
                                          AR costs 2x its payload)

  PYTHONPATH=src python -m repro.launch.roofline --dir experiments/dryrun
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

from ..configs import ARCHS, SHAPES, get_config
from ..configs.base import ArchConfig, ShapeConfig
from ..core.tiers import V5E_HBM_BW, V5E_ICI_BW, V5E_PEAK_FLOPS_BF16

CHIPS, DP, TP = 256, 16, 16


def attention_flops_fwd(cfg: ArchConfig, B: int, S: int, cache: int = 0
                        ) -> float:
    """Causal attention matmul FLOPs, forward, all layers."""
    H, Dh = cfg.n_heads, cfg.resolved_head_dim
    if cfg.block_pattern == "mamba_shared_attn":
        n_attn = -(-cfg.n_layers // cfg.attn_every)
    elif cfg.block_pattern == "xlstm":
        n_attn = 0
    else:
        n_attn = cfg.n_layers
    if cache:                       # decode: 1 token vs cache
        return n_attn * 4.0 * B * H * Dh * cache
    return n_attn * 2.0 * B * S * S * H * Dh      # causal half of 4BSSHD


def ssm_flops_fwd(cfg: ArchConfig, tokens: float) -> float:
    """Linear-recurrence extra FLOPs (state updates), forward."""
    if cfg.block_pattern == "mamba_shared_attn":
        d_in = cfg.ssm_expand * cfg.d_model
        return cfg.n_layers * 6.0 * tokens * d_in * cfg.ssm_state
    if cfg.block_pattern == "xlstm":
        d_in = cfg.ssm_expand * cfg.d_model
        P = d_in // cfg.n_heads
        return cfg.n_layers * 4.0 * tokens * d_in * P
    return 0.0


def analytic_terms(cfg: ArchConfig, shape: ShapeConfig, r: Dict) -> Dict:
    B, S = shape.global_batch, shape.seq_len
    N = cfg.n_active_params()
    N_total = cfg.n_params()
    mb = r.get("microbatches") or 1
    offload = r.get("mode") == "offload-grads"
    kv_bytes = 1 if "float8" in str(r.get("kv_dtype", "")) else 2

    if shape.kind == "train":
        tokens = B * S
        flops = (6.0 * N * tokens
                 + 3.0 * (attention_flops_fwd(cfg, B, S)
                          + ssm_flops_fwd(cfg, tokens)))
        flops *= 4.0 / 3.0          # remat: one extra forward
        # HBM: weights 3 passes (fwd+bwd read, write) in bf16 + optimizer
        # r/w fp32 (unless offloaded) + activation boundary traffic x2
        w_traffic = 3 * 2 * N_total
        opt_traffic = 0 if offload else 2 * 12 * N_total
        act = 2 * 2 * tokens * cfg.d_model * cfg.n_layers / TP
        hbm = w_traffic / CHIPS + opt_traffic / CHIPS + act / DP
        # ICI: FSDP all-gather weights (fwd+bwd) over dp of the tp-shard +
        # grad reduce-scatter + 2 TP ARs per layer on activations (x2 for AR)
        ag = 2 * mb * 2 * N_total / TP
        rs = 2 * N_total / TP
        tp_ar = 2 * 2 * 2 * (tokens / DP) * cfg.d_model * cfg.n_layers
        a2a = (2 * 2 * tokens * cfg.moe_top_k * cfg.d_model / CHIPS
               if cfg.is_moe else 0.0)
        ici = ag + rs + tp_ar / 1e0 + a2a
        coll = {"all-gather": ag, "reduce-scatter": rs,
                "all-reduce(x2)": tp_ar, "all-to-all": a2a}
    elif shape.kind == "prefill":
        tokens = B * S
        flops = (2.0 * N * tokens + attention_flops_fwd(cfg, B, S)
                 + ssm_flops_fwd(cfg, tokens))
        hbm = (2 * N_total / CHIPS
               + 2 * tokens * cfg.d_model * cfg.n_layers / DP / TP)
        ag = 2 * N_total / TP
        tp_ar = 2 * 2 * (tokens / DP) * cfg.d_model * cfg.n_layers
        a2a = (2 * tokens * cfg.moe_top_k * cfg.d_model / CHIPS
               if cfg.is_moe else 0.0)
        ici = ag + tp_ar + a2a
        coll = {"all-gather": ag, "all-reduce(x2)": tp_ar, "all-to-all": a2a}
    else:                            # decode: one token, cache of length S
        tokens = B
        flops = (2.0 * N * tokens + attention_flops_fwd(cfg, B, S, cache=S)
                 + ssm_flops_fwd(cfg, tokens))
        cache_gib = r["memory"]["argument_bytes"] - 2 * N_total / CHIPS
        K, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
        if cfg.block_pattern == "attn":
            cache_bytes = 2 * cfg.n_layers * B * S * K * Dh * kv_bytes
        elif cfg.block_pattern == "mamba_shared_attn":
            n_apps = -(-cfg.n_layers // cfg.attn_every)
            d_in = cfg.ssm_expand * cfg.d_model
            cache_bytes = (2 * n_apps * B * S * K * Dh * kv_bytes
                           + cfg.n_layers * B * (d_in // cfg.ssm_head_dim)
                           * cfg.ssm_state * cfg.ssm_head_dim * 4)
        else:
            d_in = cfg.ssm_expand * cfg.d_model
            P = d_in // cfg.n_heads
            cache_bytes = cfg.n_layers * B * cfg.n_heads * P * (P + 1) * 4
        hbm = (2 * N_total + cache_bytes) / CHIPS
        tp_ar = 2 * 2 * (tokens / max(1, min(DP, B))) * cfg.d_model \
            * cfg.n_layers
        ici = tp_ar
        coll = {"all-reduce(x2)": tp_ar}

    return {
        "flops_per_chip": flops / CHIPS,
        "hbm_bytes_per_chip": hbm,
        "ici_bytes_per_chip": ici,
        "collectives": coll,
        "compute_s": flops / CHIPS / V5E_PEAK_FLOPS_BF16,
        "memory_s": hbm / V5E_HBM_BW,
        "collective_s": ici / V5E_ICI_BW,
    }


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    n = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch


def analyze(r: Dict) -> Dict:
    arch, shape_name, _ = r["cell"].split("|")
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    t = analytic_terms(cfg, shape, r)
    terms = {"compute": t["compute_s"], "memory": t["memory_s"],
             "collective": t["collective_s"]}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(cfg, shape) / CHIPS
    # roofline fraction: useful-work time of the *ideal* program (max of
    # pure-compute and minimal-traffic bounds) over this program's bound
    ideal_mem = ((2 * cfg.n_params() / CHIPS) / V5E_HBM_BW
                 if shape.kind == "decode" else 0.0)
    ideal = max(mf / V5E_PEAK_FLOPS_BF16,
                ideal_mem if shape.kind == "decode" else 0.0,
                t["memory_s"] if shape.kind == "decode" else 0.0)
    frac = ideal / bound if bound else 0.0
    return {
        "cell": r["cell"], "arch": arch, "shape": shape_name,
        "mode": r.get("mode"), "microbatches": r.get("microbatches"),
        "compute_s": terms["compute"], "memory_s": terms["memory"],
        "collective_s": terms["collective"], "dominant": dominant,
        "model_flops_per_chip": mf,
        "hlo_flops_ratio": mf / t["flops_per_chip"],
        "roofline_fraction": frac,
        "step_bound_s": bound,
        "peak_gib": r["memory"]["peak_bytes"] / 2 ** 30,
        "fits": r.get("fits_hbm"),
        "hlo_collectives": {k: v["count"]
                            for k, v in r.get("collectives_raw", {}).items()
                            if v["count"]},
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--csv", default="experiments/roofline.csv")
    args = ap.parse_args()

    rows: List[Dict] = []
    for fn in sorted(glob.glob(os.path.join(args.dir, "*16x16.json"))):
        r = json.load(open(fn))
        if r.get("status") != "ok":
            rows.append({"cell": r["cell"],
                         "skip": r.get("reason", r.get("error"))})
            continue
        rows.append(analyze(r))

    cols = ["cell", "mode", "microbatches", "compute_s", "memory_s",
            "collective_s", "dominant", "model_flops_per_chip",
            "hlo_flops_ratio", "roofline_fraction", "step_bound_s",
            "peak_gib", "fits"]
    os.makedirs(os.path.dirname(args.csv), exist_ok=True)
    with open(args.csv, "w") as f:
        f.write(",".join(cols) + "\n")
        for row in rows:
            if "skip" in row:
                f.write(f"{row['cell']},SKIPPED\n")
                continue
            f.write(",".join(
                f"{row[c]:.6g}" if isinstance(row[c], float) else str(row[c])
                for c in cols) + "\n")
    print(f"wrote {args.csv}")
    for row in rows:
        if "skip" in row:
            print(f"{row['cell']:52s} SKIP ({row['skip'][:48]})")
            continue
        print(f"{row['cell']:52s} dom={row['dominant']:10s} "
              f"C={row['compute_s'] * 1e3:9.2f}ms "
              f"M={row['memory_s'] * 1e3:8.2f}ms "
              f"X={row['collective_s'] * 1e3:8.2f}ms "
              f"frac={row['roofline_fraction'] * 100:5.1f}% "
              f"peak={row['peak_gib']:5.2f}GiB "
              f"hlo_colls={row['hlo_collectives']}")


if __name__ == "__main__":
    main()
