"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches JAX device state — the dry-run must set
``xla_force_host_platform_device_count`` before any device query.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 (one v5e pod, 256 chips) or 2x16x16 (two pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(*, data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many devices this host exposes (tests)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    devs = jax.devices()[: data * model]
    import numpy as np
    return Mesh(np.array(devs).reshape(data, model), ("data", "model"))
