import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the step function (train_step for ``train_*``, prefill_step for
     ``prefill_*``, serve/decode_step for ``decode_*`` / ``long_*``),
  2. lowers it with ShapeDtypeStruct inputs (no allocation) under explicit
     in/out shardings on the production mesh,
  3. compiles, prints ``memory_analysis()`` (fit proof) and
     ``cost_analysis()`` (roofline inputs),
  4. extracts per-collective byte counts from the compiled HLO, and
  5. re-lowers two reduced-layer probes to extrapolate loop-body costs to
     the full layer count (XLA's cost analysis counts a ``lax.scan`` body
     once — verified experimentally).

HBM-infeasible cells (nemotron-4-340b train on one pod) run in *offload
mode*: the fused step is split into a grads program plus per-slice optimizer
programs whose fp32 state the Unimem runtime keeps on the host tier and
streams through HBM (the paper's technique making the infeasible feasible).

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod both --out experiments/
"""

import argparse
import dataclasses
import functools
import json
import math
import re
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, SHAPES, get_config
from ..configs.base import ArchConfig, ShapeConfig
from ..distributed import sharding as shd
from ..models import lm
from ..optim import AdamWConfig, init_opt_state
from ..serve.engine import build_decode_step
from ..train.step import auto_microbatches, build_grads_step, build_train_step
from .mesh import make_production_mesh

HBM_PER_CHIP = 16 * 1024 ** 3
DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "c64": 8,
               "f8e4m3fn": 1, "f8e5m2": 1, "s16": 2, "u16": 2}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


# ---------------------------------------------------------------------------
def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        out = {"tokens": sds((B, S), jnp.int32),
               "labels": sds((B, S), jnp.int32)}
        if cfg.frontend:
            out["frontend"] = sds((B, cfg.frontend_tokens, cfg.d_model),
                                  jnp.bfloat16)
        return out
    return {"token": sds((B,), jnp.int32), "pos": sds((), jnp.int32)}


def _tree_sds(tree):
    return jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


def _params_shapes(cfg: ArchConfig):
    return _tree_sds(jax.eval_shape(
        functools.partial(lm.init_params, cfg), jax.random.PRNGKey(0)))


def _bytes_of(tree) -> int:
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(tree))


# ---------------------------------------------------------------------------
def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum result-tensor bytes per collective kind (per-device program)."""
    stats = {c: {"count": 0, "bytes": 0.0} for c in COLLECTIVES}
    pat = re.compile(
        r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^)]*?\)?\s+"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
        r"collective-permute)(?:-start)?\(")
    for m in pat.finditer(hlo_text):
        dt, dims, kind = m.groups()
        size = DTYPE_BYTES.get(dt, 4)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        stats[kind]["count"] += 1
        stats[kind]["bytes"] += n * size
    return stats


def emulation_shadow_bytes(hlo_text: str) -> int:
    """Lower-bound the CPU backend's dtype-emulation overhead.

    The CPU backend computes bf16/fp8 in fp32/fp16, and loop-invariant code
    motion hoists the converted copies out of layer loops — so the compiled
    module holds an f32 twin of bf16 weight stacks and an f16 twin of fp8
    caches that a bf16/fp8-native TPU would never materialize.  Detected as
    same-dims tensors present in both the wide and the narrow dtype; the
    wide copy is counted once."""
    dims_by_dtype: Dict[str, set] = {}
    for m in re.finditer(r"([a-z0-9]+)\[([0-9,]+)\]", hlo_text):
        dims_by_dtype.setdefault(m.group(1), set()).add(m.group(2))

    def nbytes(dims: str, size: int) -> int:
        n = 1
        for d in dims.split(","):
            n *= int(d)
        return n * size

    shadow = 0
    for dims in dims_by_dtype.get("f32", set()) \
            & dims_by_dtype.get("bf16", set()):
        b = nbytes(dims, 4)
        if b > 64 * 1024 ** 2:
            shadow += b
    for dims in dims_by_dtype.get("f16", set()) \
            & dims_by_dtype.get("f8e4m3fn", set()):
        b = nbytes(dims, 2)
        if b > 64 * 1024 ** 2:
            shadow += b
    return shadow


def _reduced_layer_counts(cfg: ArchConfig) -> Tuple[int, int]:
    if cfg.block_pattern == "mamba_shared_attn":
        g = cfg.attn_every
        return g, 2 * g
    if cfg.block_pattern == "xlstm":
        g = cfg.slstm_every or 2
        return g, 2 * g
    return 1, 2


def _cost(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    ca = ca or {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


# ---------------------------------------------------------------------------
def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh,
               *, microbatches: Optional[int] = None,
               offload: bool = False, remat: bool = True,
               opt_cfg: Optional[AdamWConfig] = None,
               kv_dtype=jnp.bfloat16, flat_dp: bool = False):
    """Returns (jitted_fn, example_args) ready to .lower(*args)."""
    from ..models.common import set_mesh_hint
    set_mesh_hint(mesh)
    shd.set_flat_dp(flat_dp)
    dp = shd.mesh_axis_size(mesh, shd.dp_axes(mesh))
    tp = shd.mesh_axis_size(mesh, "model")
    pshapes = _params_shapes(cfg)
    pspecs = shd.param_specs(mesh, pshapes)
    psh = shd.shardings(mesh, pspecs)
    ins = input_specs(cfg, shape)

    if shape.kind == "train":
        opt_cfg = opt_cfg or AdamWConfig()
        mb = microbatches or auto_microbatches(
            cfg, shape.global_batch, shape.seq_len, dp, tp)
        bspecs = shd.batch_specs(mesh, cfg, shape)
        bsh = {k: NamedSharding(mesh, bspecs[k]) for k in ins}
        if offload:
            step = build_grads_step(cfg, microbatches=mb, remat=remat)
            jitted = jax.jit(step, in_shardings=(psh, bsh),
                             out_shardings=(psh, None))
            return jitted, (pshapes, ins), {"microbatches": mb,
                                            "mode": "offload-grads"}
        oshapes = _tree_sds(jax.eval_shape(
            functools.partial(init_opt_state, cfg=opt_cfg), pshapes))
        ospecs = shd.opt_specs(mesh, oshapes, pshapes, pspecs)
        osh = shd.shardings(mesh, ospecs)
        step = build_train_step(cfg, opt_cfg, microbatches=mb, remat=remat)
        jitted = jax.jit(step, in_shardings=(psh, osh, bsh),
                         out_shardings=(psh, osh, None),
                         donate_argnums=(0, 1))
        return jitted, (pshapes, oshapes, ins), {"microbatches": mb,
                                                 "mode": "fused"}

    if shape.kind == "prefill":
        bspecs = shd.batch_specs(mesh, cfg, shape)
        bsh = {k: NamedSharding(mesh, bspecs[k]) for k in ins}

        def prefill_step(params, batch):
            logits, _ = lm.forward(params, cfg, batch["tokens"],
                                   batch.get("frontend"), remat=False)
            return logits

        logit_sh = NamedSharding(mesh, shd.fit(
            mesh, (shape.global_batch, shape.seq_len, cfg.vocab_size),
            shd.dp_axes(mesh), None, "model"))
        jitted = jax.jit(prefill_step, in_shardings=(psh, bsh),
                         out_shardings=logit_sh)
        return jitted, (pshapes, ins), {"mode": "prefill"}

    # decode: one new token against a seq_len cache
    cache_shapes = _tree_sds(jax.eval_shape(
        lambda _: lm.init_cache(cfg, shape.global_batch, shape.seq_len,
                                kv_dtype=kv_dtype),
        0))
    cspecs = shd.cache_specs(mesh, cfg, cache_shapes, shape.global_batch)
    csh = shd.shardings(mesh, cspecs)
    batch_ok = shape.global_batch % dp == 0
    tok_spec = shd.fit(mesh, (shape.global_batch,),
                       shd.dp_axes(mesh) if batch_ok else None)
    tok_sh = NamedSharding(mesh, tok_spec)
    logits_sh = NamedSharding(mesh, shd.fit(
        mesh, (shape.global_batch, cfg.vocab_size),
        shd.dp_axes(mesh) if batch_ok else None, "model"))
    step = build_decode_step(cfg)
    jitted = jax.jit(step,
                     in_shardings=(psh, csh, tok_sh, NamedSharding(mesh, P())),
                     out_shardings=(tok_sh, logits_sh, csh),
                     donate_argnums=(1,))
    args = (pshapes, cache_shapes, ins["token"], ins["pos"])
    return jitted, args, {"mode": "decode",
                          "kv_dtype": str(jnp.dtype(kv_dtype))}


# ---------------------------------------------------------------------------
#: operand layout per cell mode: which positional args of the jitted step are
#: registered Unimem objects (name) vs unregistered inputs (None -> leaf
#: count taken from the example tree)
_ATTRIBUTION_OPERANDS = {
    "fused": ("params", "opt_state", None),
    "offload-grads": ("params", None),
    "prefill": ("params", None),
    "decode": ("params", "kv_cache", None, None),
}


def unimem_attribution(compiled, args, mode: str,
                       n_bins: int = 64) -> Dict[str, Any]:
    """Map the compiled cell's per-op operand footprints onto Unimem data
    objects (the TPU attribution analogue: no PEBS on TPU, so per-chunk
    ``access_bins`` come from XLA cost analysis instead — and feed the
    exact same profiler pipeline the simulator drives).

    Registers each managed arg tree pytree-natively (recording leaf byte
    spans), binds the compiled program through
    :class:`~repro.core.instrumentation.XlaCostAnalysisSource`, and returns
    a JSON-able summary of the measured per-object access histograms."""
    from ..core.instrumentation import XlaCostAnalysisSource
    from ..core.session import Session
    from ..core.tiers import TPU_V5E

    sess = Session(TPU_V5E)
    operands = []
    for name, tree in zip(_ATTRIBUTION_OPERANDS[mode], args):
        if name is None:
            operands.append(tree)
        else:
            sess.register(name, tree, chunkable=(name != "params"))
            operands.append(name)
    src = XlaCostAnalysisSource(sess, n_bins=n_bins)
    sample = src.bind("step", compiled, operands)
    out: Dict[str, Any] = {}
    for obj, acc in sorted(sample.accesses.items()):
        bins = np.asarray((sample.access_bins or {}).get(obj, []))
        entry: Dict[str, Any] = {"accesses": float(acc)}
        if bins.size and bins.sum() > 0:
            w = bins / bins.sum()
            entry["n_bins"] = int(bins.size)
            entry["nonzero_bins"] = int((bins > 0).sum())
            entry["peak_over_mean"] = float(w.max() * bins.size)
            entry["bins"] = [round(float(x), 6) for x in w]
        out[obj] = entry
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             probes: bool = True, verbose: bool = True,
             flat_dp: bool = False,
             attribution: bool = False) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cfg.shape_applicable(shape)
    cell_id = f"{cfg.name}|{shape_name}|{'2x16x16' if multi_pod else '16x16'}"
    if not ok:
        return {"cell": cell_id, "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = math.prod(mesh.devices.shape)

    # offload mode when fused optimizer state leaves too little headroom
    # (the Unimem planner's host-tier placement of fp32 master/moments)
    opt_cfg = AdamWConfig()
    state_bytes = cfg.n_params() * (2 + 12)          # bf16 + fp32 master/m/v
    offload = (shape.kind == "train"
               and state_bytes / n_chips > 0.35 * HBM_PER_CHIP)

    t0 = time.time()
    microbatches = None
    kv_dtype = jnp.bfloat16
    for attempt in range(4):
        jitted, args, info = build_cell(cfg, shape, mesh, offload=offload,
                                        opt_cfg=opt_cfg,
                                        microbatches=microbatches,
                                        kv_dtype=kv_dtype, flat_dp=flat_dp)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "generated_code_bytes": int(ma.generated_code_size_in_bytes),
        }
        mem["peak_bytes"] = (mem["argument_bytes"] + mem["output_bytes"]
                             + mem["temp_bytes"] - mem["alias_bytes"])
        if mem["peak_bytes"] <= 0.95 * HBM_PER_CHIP:
            break
        if shape.kind == "train" \
                and info.get("microbatches", 1) < shape.global_batch:
            # fit loop: double the microbatch count and recompile
            microbatches = info.get("microbatches", 1) * 2
        elif shape.kind == "decode" and kv_dtype == jnp.bfloat16:
            # fit loop: fp8 KV cache (halves cache HBM)
            kv_dtype = jnp.float8_e4m3fn
        else:
            break
    compile_s = time.time() - t0
    cost_full = _cost(compiled)
    hlo_text = compiled.as_text()
    coll = parse_collectives(hlo_text)
    # distinct tensors can share a dims-string, so cap the shadow estimate
    # at 80% of temp (the shadows are always temps)
    shadow = min(emulation_shadow_bytes(hlo_text),
                 int(0.8 * mem["temp_bytes"]))
    mem["emulation_shadow_bytes"] = shadow
    mem["peak_tpu_estimate_bytes"] = mem["peak_bytes"] - shadow

    result: Dict[str, Any] = {
        "cell": cell_id, "status": "ok", "mode": info["mode"],
        "n_chips": n_chips, "compile_s": round(compile_s, 2),
        "microbatches": info.get("microbatches"),
        "memory": mem, "cost_raw": cost_full, "collectives_raw": coll,
        "fits_hbm": mem["peak_bytes"] <= HBM_PER_CHIP,
        "fits_hbm_tpu_estimate":
            mem["peak_tpu_estimate_bytes"] <= HBM_PER_CHIP,
    }

    if attribution:
        # hardware-path instrumentation: per-object access_bins from the
        # compiled program's operand footprints (ROADMAP "TPU attribution
        # analogue") — the same sample stream the simulator's SimSource
        # produces, so it flows through the identical profiler pipeline
        result["unimem_attribution"] = unimem_attribution(
            compiled, args, info["mode"])

    if offload:
        result["offload"] = offload_programs(cfg, shape, mesh, opt_cfg)
        # device residency proof = grads program peak + streamed slice
        result["fits_hbm"] = (mem["peak_bytes"]
                              + result["offload"]["slice_peak_bytes"]
                              <= HBM_PER_CHIP)

    if probes:
        result["roofline_inputs"] = cost_probes(cfg, shape, mesh,
                                                offload=offload)

    if verbose:
        print(f"[{cell_id}] {result['mode']} compile={compile_s:.1f}s "
              f"peak={mem['peak_bytes']/2**30:.2f}GiB "
              f"fits={result['fits_hbm']}")
        print("  memory_analysis:", {k: f"{v/2**30:.3f}GiB"
                                     for k, v in mem.items()
                                     if k != 'generated_code_bytes'})
        print("  cost_analysis(raw):", cost_full)
    return result


def cost_probes(cfg: ArchConfig, shape: ShapeConfig, mesh,
                *, offload: bool) -> Dict[str, Any]:
    """Two reduced-layer lowers -> per-layer deltas -> full-model totals."""
    L1, L2 = _reduced_layer_counts(cfg)
    out = {}
    for L in (L1, L2):
        c = dataclasses.replace(cfg, n_layers=L)
        jitted, args, _ = build_cell(c, shape, mesh, microbatches=1,
                                     offload=offload, remat=True)
        compiled = jitted.lower(*args).compile()
        cost = _cost(compiled)
        coll = parse_collectives(compiled.as_text())
        out[f"L{L}"] = {"cost": cost, "collectives": coll}
    L = cfg.n_layers
    c1, c2 = out[f"L{L1}"], out[f"L{L2}"]

    def extrap(a, b):
        per_layer = (b - a) / (L2 - L1)
        return b + per_layer * (L - L2)

    flops = extrap(c1["cost"]["flops"], c2["cost"]["flops"])
    hbytes = extrap(c1["cost"]["bytes"], c2["cost"]["bytes"])
    coll_bytes = {}
    for kind in COLLECTIVES:
        coll_bytes[kind] = extrap(c1["collectives"][kind]["bytes"],
                                  c2["collectives"][kind]["bytes"])
    return {"probe_layers": [L1, L2], "flops_per_device": flops,
            "bytes_per_device": hbytes, "collective_bytes": coll_bytes,
            "probes": out}


# ---------------------------------------------------------------------------
def offload_programs(cfg: ArchConfig, shape: ShapeConfig, mesh,
                     opt_cfg: AdamWConfig,
                     n_slices: int = 12) -> Dict[str, Any]:
    """Per-slice optimizer-update program (host-tier state streamed through
    HBM by the Unimem mover).  Compiles one representative slice."""
    from ..optim.adamw import adamw_update

    L_slice = max(1, cfg.n_layers // n_slices)
    c = dataclasses.replace(cfg, n_layers=L_slice)
    pshapes = _params_shapes(c)
    # drop embed/head (they get their own slice; blocks dominate)
    blocks = {k: v for k, v in pshapes.items() if "blocks" in k}
    pspecs = shd.param_specs(mesh, blocks)
    psh = shd.shardings(mesh, pspecs)
    oshapes = _tree_sds(jax.eval_shape(
        functools.partial(init_opt_state, cfg=opt_cfg), blocks))
    ospecs = shd.opt_specs(mesh, oshapes, blocks, pspecs)
    osh = shd.shardings(mesh, ospecs)
    gsh = jax.tree_util.tree_map(
        lambda s: s, psh)   # grads shard like params

    def upd(params, opt_state, grads):
        new_p, new_o, _ = adamw_update(grads, params, opt_state, opt_cfg,
                                       jnp.float32(1e-4))
        return new_p, new_o

    jitted = jax.jit(upd, in_shardings=(psh, osh, gsh),
                     out_shardings=(psh, osh), donate_argnums=(0, 1))
    compiled = jitted.lower(blocks, oshapes, blocks).compile()
    ma = compiled.memory_analysis()
    peak = int(ma.argument_size_in_bytes + ma.output_size_in_bytes
               + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    n_chips = math.prod(mesh.devices.shape)
    slice_state = _bytes_of(oshapes) / n_chips
    return {
        "n_slices": n_slices, "layers_per_slice": L_slice,
        "slice_peak_bytes": peak,
        "slice_state_bytes_per_chip": int(slice_state),
        "host_resident_bytes_per_chip": int(
            cfg.n_params() * 12 / n_chips),
        "note": "fp32 master+moments live on host tier; the Unimem mover "
                "streams slices through HBM overlapped with backward "
                "(paper Fig 5/6 trigger-point schedule)",
    }


# ---------------------------------------------------------------------------
def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"],
                    default="off")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--attribution", action="store_true",
                    help="emit per-object Unimem access_bins from XLA "
                         "cost-analysis operand footprints")
    ap.add_argument("--flat-dp", action="store_true",
                    help="fold the model axis into DP (small-model profile)")
    ap.add_argument("--out", default=None, help="directory for JSON results")
    args = ap.parse_args()

    cells = []
    archs = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    pods = {"off": [False], "on": [True], "both": [False, True]}[args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in pods:
                cells.append((a, s, mp))

    results = []
    for a, s, mp in cells:
        try:
            r = run_cell(a, s, multi_pod=mp, probes=not args.no_probes,
                         flat_dp=args.flat_dp, attribution=args.attribution)
        except Exception as e:  # noqa: BLE001 — report and continue
            r = {"cell": f"{a}|{s}|{'2x16x16' if mp else '16x16'}",
                 "status": "error", "error": f"{type(e).__name__}: {e}"}
            print(f"[{r['cell']}] ERROR {r['error']}")
        results.append(r)
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            fn = r["cell"].replace("|", "_").replace("/", "_") + ".json"
            with open(os.path.join(args.out, fn), "w") as f:
                json.dump(r, f, indent=2)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\n== dry-run summary: {n_ok} ok, {n_skip} skipped "
          f"(documented), {n_err} errors ==")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
