"""Training launcher.

CPU-scale smoke runs use reduced configs; the production path is the same
code under a real TPU mesh.

  python -m repro.launch.train --arch yi-6b --reduced --steps 50
"""

from __future__ import annotations

import argparse

from ..configs import get_config
from ..optim import AdamWConfig
from ..train.loop import TrainConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--moments", choices=["float32", "bfloat16", "int8"],
                    default="float32")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tcfg = TrainConfig(steps=args.steps, global_batch=args.batch,
                       seq_len=args.seq_len, lr=args.lr,
                       microbatches=args.microbatches,
                       checkpoint_dir=args.checkpoint_dir)
    opt = AdamWConfig(lr=args.lr, moments_dtype=args.moments)
    result = train(cfg, tcfg, opt)
    print(f"final loss: {result.losses[-1]:.4f} "
          f"(first: {result.losses[0]:.4f}); "
          f"mean step {1e3 * sum(result.step_times[1:]) / max(1, len(result.step_times) - 1):.0f} ms")
    print("unimem:", result.runtime_stats)


if __name__ == "__main__":
    main()
