"""Serving launcher: batched greedy generation on a reduced config.

  python -m repro.launch.serve --arch gemma --reduced --batch 4 --new 32
"""

from __future__ import annotations

import argparse
import time

import jax

from ..configs import get_config
from ..models import lm
from ..serve.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=256)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_seq=args.max_seq,
                         batch=args.batch)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.perf_counter()
    out = engine.generate(prompts, args.new)
    dt = time.perf_counter() - t0
    total = engine.stats.prefill_tokens + engine.stats.decode_tokens
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({total / dt:.0f} tok/s incl. prefill)")
    print("sample:", out[0, :24].tolist())


if __name__ == "__main__":
    main()
