"""Deterministic synthetic token pipeline.

Production shape without external data: an infinite, seekable stream of
token batches derived from a counter-based PRNG (threefry), so every
(step, dp_shard) batch is reproducible — which is what checkpoint/restart
and elastic reshape need: after resuming at step N on a *different* mesh,
every shard still sees exactly the stream it would have seen.

The synthetic distribution is a Zipf-ish unigram mix with Markov bigram
structure so losses move (not uniform noise).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    zipf_alpha: float = 1.1


class SyntheticTokenPipeline:
    """Seekable synthetic LM data."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # fixed unigram distribution (Zipf) + a random permutation so token
        # frequency is not aligned with token id
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = 1.0 / ranks ** cfg.zipf_alpha
        probs /= probs.sum()
        rng = np.random.default_rng(cfg.seed)
        self._perm = rng.permutation(cfg.vocab_size)
        self._probs = jnp.asarray(probs, jnp.float32)
        self._perm_j = jnp.asarray(self._perm, jnp.int32)

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        """Batch for a global step — pure function of (seed, step)."""
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        draws = jax.random.categorical(
            key, jnp.log(self._probs)[None, None, :],
            shape=(cfg.global_batch, cfg.seq_len))
        tokens = self._perm_j[draws]
        # Markov structure: every other token depends on its predecessor
        shifted = jnp.roll(tokens, 1, axis=1)
        mix = (shifted * 31 + 7) % cfg.vocab_size
        parity = (jnp.arange(cfg.seq_len) % 2).astype(bool)
        tokens = jnp.where(parity[None, :], mix, tokens)
        return {"tokens": tokens.astype(jnp.int32),
                "labels": tokens.astype(jnp.int32)}

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
