"""Training step builder: loss -> grads (microbatched) -> AdamW update.

``microbatches > 1`` accumulates gradients over a ``lax.scan`` of
microbatches (the activation-memory knob that, together with per-layer
remat, bounds live activations to one microbatch x one layer).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import lm
from ..optim import AdamWConfig, adamw_update


def build_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig, *,
                     microbatches: int = 1, remat: bool = True,
                     lr: float = 3e-4) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""

    def loss_wrap(params, mb):
        loss, metrics = lm.loss_fn(params, cfg, mb, remat=remat)
        return loss, metrics

    grad_fn = jax.grad(loss_wrap, has_aux=True)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            grads, metrics = grad_fn(params, batch)
        else:
            resh = jax.tree_util.tree_map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def body(acc, mb):
                g, m = grad_fn(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), acc, g)
                return acc, m

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, ms = jax.lax.scan(body, zeros, resh)
            grads = jax.tree_util.tree_map(
                lambda g: g / microbatches, grads)
            metrics = jax.tree_util.tree_map(lambda m: m.mean(), ms)

        new_params, new_opt, opt_metrics = adamw_update(
            grads, params, opt_state, opt_cfg, jnp.float32(lr))
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = metrics.pop("nll")
        return new_params, new_opt, metrics

    return train_step


def build_grads_step(cfg: ArchConfig, *, microbatches: int = 1,
                     remat: bool = True) -> Callable:
    """Forward+backward only — the device-resident phase of offload mode.

    The optimizer update runs as separate per-shard phase programs whose
    state the Unimem runtime keeps on the host tier (see
    ``launch.dryrun.offload_programs``)."""

    def loss_wrap(params, mb):
        return lm.loss_fn(params, cfg, mb, remat=remat)

    grad_fn = jax.grad(loss_wrap, has_aux=True)

    def grads_step(params, batch):
        if microbatches == 1:
            return grad_fn(params, batch)
        resh = jax.tree_util.tree_map(
            lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                + x.shape[1:]), batch)

        def body(acc, mb):
            g, m = grad_fn(params, mb)
            return jax.tree_util.tree_map(
                lambda a, b: a + b.astype(a.dtype), acc, g), m

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
        grads, ms = jax.lax.scan(body, zeros, resh)
        grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
        return grads, jax.tree_util.tree_map(lambda m: m.mean(), ms)

    return grads_step


def auto_microbatches(cfg: ArchConfig, global_batch: int, seq_len: int,
                      dp: int, tp: int,
                      *, act_budget_bytes: float = 2e9) -> int:
    """Pick the microbatch count that bounds per-device live activations.

    With per-layer remat the live set is ~ one boundary activation per layer
    per microbatch: L x (tokens/dp) x d_model x 2 bytes / tp."""
    tokens_per_dp = global_batch * seq_len / dp
    per_layer = tokens_per_dp * cfg.d_model * 2 / tp
    if cfg.is_moe:
        # dispatch buffers / expert activations saved for backward
        per_layer *= 4
    total = per_layer * cfg.n_layers
    mb = 1
    while total / mb > act_budget_bytes and mb < global_batch:
        mb *= 2
    while global_batch % mb:
        mb *= 2
    return min(mb, global_batch)
