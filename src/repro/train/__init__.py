from .step import (auto_microbatches, build_grads_step, build_train_step)

__all__ = ["auto_microbatches", "build_grads_step", "build_train_step"]
