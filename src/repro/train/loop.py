"""Training loop with the Unimem runtime in charge of tier placement.

Per-step phases (the paper's MPI-delimited phases, here jit/collective
boundaries): data fetch -> train_step -> (periodically) checkpoint.  The
Unimem runtime profiles the first iteration(s), plans placement for the
registered data objects (optimizer-state groups, checkpoint staging
buffers), and proactively moves them between HBM and host; the drift
monitor doubles as the straggler detector and triggers re-planning.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs.base import ArchConfig
from ..core import ManualSource, RuntimeConfig, UnimemRuntime
from ..core.tiers import TPU_V5E, MachineProfile
from ..data import DataConfig, SyntheticTokenPipeline
from ..models import lm
from ..models.common import tree_bytes
from ..optim import AdamWConfig, init_opt_state
from .step import build_train_step


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 128
    lr: float = 3e-4
    microbatches: int = 1
    remat: bool = True
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 50
    log_every: int = 10
    seed: int = 0
    machine: MachineProfile = dataclasses.field(default_factory=lambda: TPU_V5E)
    use_unimem: bool = True


@dataclasses.dataclass
class TrainResult:
    losses: list
    step_times: list
    final_step: int
    runtime_stats: Dict[str, Any]


def train(cfg: ArchConfig, tcfg: TrainConfig,
          opt_cfg: Optional[AdamWConfig] = None) -> TrainResult:
    opt_cfg = opt_cfg or AdamWConfig()
    key = jax.random.PRNGKey(tcfg.seed)
    params = lm.init_params(cfg, key)
    opt_state = init_opt_state(params, opt_cfg)
    data = SyntheticTokenPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=tcfg.seq_len,
        global_batch=tcfg.global_batch, seed=tcfg.seed))
    step_fn = jax.jit(build_train_step(
        cfg, opt_cfg, microbatches=tcfg.microbatches, remat=tcfg.remat,
        lr=tcfg.lr), donate_argnums=(0, 1))

    ckpt = (CheckpointManager(tcfg.checkpoint_dir)
            if tcfg.checkpoint_dir else None)
    start_step = 0
    if ckpt is not None and ckpt.latest_step() is not None:
        start_step, state = ckpt.restore()
        params, opt_state = state["params"], state["opt"]

    # ---- Unimem runtime: optimizer-state groups are the tierable objects.
    # Pytree-native registration records per-leaf byte spans (chunk
    # boundaries can align to them); the state is donated through step_fn,
    # so tiers are tracked logically (manage_payload=False).  The "step"
    # phase's per-object access counts are static for a fixed step function,
    # so a ManualSource states them once instead of every phase_end.
    rt: Optional[UnimemRuntime] = None
    if tcfg.use_unimem:
        rt = UnimemRuntime(tcfg.machine, RuntimeConfig(
            fast_capacity_bytes=tcfg.machine.fast.capacity_bytes))
        rt.register("opt_state", opt_state, chunkable=True,
                    manage_payload=False)
        rt.register("params", params, pinned=True, manage_payload=False)
        src = ManualSource()
        src.set("step", accesses={"opt_state": tree_bytes(opt_state) / 512,
                                  "params": tree_bytes(params) / 512})
        rt.attach_source(src)

    losses, times = [], []
    for step in range(start_step, tcfg.steps):
        t0 = time.perf_counter()
        with rt.iteration() if rt else contextlib.nullcontext():
            with rt.phase("data") if rt else contextlib.nullcontext():
                batch = data.batch_at(step)
            with rt.phase("step") if rt else contextlib.nullcontext():
                params, opt_state, metrics = step_fn(params, opt_state, batch)
                loss = float(metrics["loss"])
            with rt.phase("ckpt") if rt else contextlib.nullcontext():
                if ckpt is not None \
                        and (step + 1) % tcfg.checkpoint_every == 0:
                    ckpt.save(step + 1, {"params": params, "opt": opt_state})
        losses.append(loss)
        times.append(time.perf_counter() - t0)
        if (step + 1) % tcfg.log_every == 0:
            print(f"step {step + 1}: loss={loss:.4f} "
                  f"({times[-1] * 1e3:.0f} ms)")
        if not np.isfinite(loss):
            raise FloatingPointError(f"loss diverged at step {step}")
    if ckpt is not None:
        ckpt.save(tcfg.steps, {"params": params, "opt": opt_state},
                  blocking=True)
    return TrainResult(losses, times, tcfg.steps,
                       rt.stats() if rt else {})
