from .adamw import (AdamWConfig, init_opt_state, adamw_update,
                    opt_state_bytes)
from .schedule import cosine_schedule

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update",
           "opt_state_bytes", "cosine_schedule"]
