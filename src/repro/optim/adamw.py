"""AdamW with a fp32 master copy and optional 8-bit quantized moments.

The optimizer state is the canonical Unimem offload victim (touched once per
step, 12-16 bytes/param in fp32): the runtime places it on the host tier for
HBM-constrained architectures.  The 8-bit moment option (block-wise scaled,
error preserved in the scale) is the in-HBM alternative the perf loop
compares against — a beyond-paper optimization.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    master_fp32: bool = True
    moments_dtype: str = "float32"     # "float32" | "bfloat16" | "int8"
    quant_block: int = 256


# ------------------------------------------------------------- int8 moments
def _quant(x: jax.Array, block: int) -> Tuple[jax.Array, jax.Array]:
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequant(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


# ----------------------------------------------------------------- opt state
def init_opt_state(params: Any, cfg: AdamWConfig) -> Dict[str, Any]:
    def zeros_like_moment(p):
        if cfg.moments_dtype == "int8":
            q, s = _quant(jnp.zeros(p.shape, jnp.float32), cfg.quant_block)
            return {"q": q, "s": s}
        dt = jnp.bfloat16 if cfg.moments_dtype == "bfloat16" else jnp.float32
        return jnp.zeros(p.shape, dt)

    state = {
        "mu": jax.tree_util.tree_map(zeros_like_moment, params),
        "nu": jax.tree_util.tree_map(zeros_like_moment, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.master_fp32:
        state["master"] = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.float32), params)
    return state


def _read_moment(m, shape, cfg: AdamWConfig) -> jax.Array:
    if isinstance(m, dict):
        return _dequant(m["q"], m["s"], shape)
    return m.astype(jnp.float32)


def _write_moment(val: jax.Array, cfg: AdamWConfig):
    if cfg.moments_dtype == "int8":
        q, s = _quant(val, cfg.quant_block)
        return {"q": q, "s": s}
    dt = jnp.bfloat16 if cfg.moments_dtype == "bfloat16" else jnp.float32
    return val.astype(dt)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(grads: Any, params: Any, state: Dict[str, Any],
                 cfg: AdamWConfig, lr: jax.Array
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    masters = state.get("master", params)

    def upd(g, p, mu, nu, master):
        g = g.astype(jnp.float32) * clip
        m = _read_moment(mu, g.shape, cfg)
        v = _read_moment(nu, g.shape, cfg)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / bc1
        vh = v / bc2
        base = master.astype(jnp.float32)
        new_master = base - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                                  + cfg.weight_decay * base)
        return new_master, _write_moment(m, cfg), _write_moment(v, cfg)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    flat_ma = treedef.flatten_up_to(masters)

    out = [upd(g, p, mu, nu, ma) for g, p, mu, nu, ma in
           zip(flat_g, flat_p, flat_mu, flat_nu, flat_ma)]
    new_masters = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    new_params = jax.tree_util.tree_map(
        lambda m, p: m.astype(p.dtype), new_masters, params)

    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    if cfg.master_fp32:
        new_state["master"] = new_masters
    return new_params, new_state, {"grad_norm": gnorm,
                                   "step": step.astype(jnp.float32)}


def opt_state_bytes(params: Any, cfg: AdamWConfig) -> int:
    n = sum(int(l.size) for l in jax.tree_util.tree_leaves(params))
    per = 0
    per += 4 if cfg.master_fp32 else 0
    if cfg.moments_dtype == "int8":
        per += 2 * (1 + 4 / cfg.quant_block)
    elif cfg.moments_dtype == "bfloat16":
        per += 4
    else:
        per += 8
    return int(n * per)
