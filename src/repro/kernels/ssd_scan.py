"""Mamba-2 SSD chunked scan — Pallas TPU kernel.

One grid step processes one (batch, head, chunk) cell:  the intra-chunk
quadratic term (decay-masked scores) runs on the MXU while the inter-chunk
state (N, P) lives in VMEM scratch and carries across the chunk axis (grid
is sequential over its last dimension on TPU).  This is the zamba2 /
long-context hot spot: state size is constant in sequence length.

Inputs are laid out (B, H, S, ·) so the chunk axis tiles the
second-to-last dimension.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(la_ref, k_ref, v_ref, q_ref, o_ref, state_scr, *, Q: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    la = la_ref[0, 0, 0].astype(jnp.float32)        # (1, Q) log-decays
    k = k_ref[0, 0].astype(jnp.float32)             # (Q, N)
    v = v_ref[0, 0].astype(jnp.float32)             # (Q, P)
    q = q_ref[0, 0].astype(jnp.float32)             # (Q, N)

    cum = jnp.cumsum(la, axis=1)                    # (1, Q) inclusive
    cum_t = cum.reshape(Q, 1)
    # intra-chunk decay mask: exp(cum_i - cum_j) for i >= j else 0
    seg = cum_t - cum                               # (Q, Q): [i, j]
    rows = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    mask = jnp.where(rows >= cols, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * mask
    y = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, P)
    # inter-chunk: y += (q * exp(cum)) @ S_prev
    y += jax.lax.dot_general(q * jnp.exp(cum_t), state_scr[...],
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    # state update: S = exp(cum[-1]) * S + (k * exp(cum[-1] - cum))^T @ v
    total = cum[0, Q - 1]
    dec_out = jnp.exp(total - cum_t)                # (Q, 1)
    state_scr[...] = jnp.exp(total) * state_scr[...] + jax.lax.dot_general(
        k * dec_out, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[0, 0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(a: jax.Array, k: jax.Array, v: jax.Array, q: jax.Array, *,
             chunk: int = 256, interpret: bool = False) -> jax.Array:
    """SSD scan  S_t = a_t S_{t-1} + k_t v_t^T ;  y_t = S_t^T q_t.

    a: (B, H, S) decays in (0,1]; k, q: (B, H, S, N); v: (B, H, S, P).
    S must be a multiple of ``chunk`` (ops.py pads).  Returns (B, H, S, P).
    """
    B, H, S = a.shape
    N = k.shape[-1]
    P = v.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    la = jnp.log(jnp.maximum(a.astype(jnp.float32), 1e-37))
    la = la.reshape(B, H, nc, 1, chunk)
    kernel = functools.partial(_ssd_kernel, Q=chunk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, 1, chunk), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, h, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, P), v.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(la, k, v, q)
