"""Tiered (streamed) matmul — the paper's technique at the VMEM/HBM level.

``y = x @ W`` for weights too large for VMEM: the grid pipeline streams
(bk, bn) weight tiles HBM->VMEM while the MXU consumes the previous tile —
Mosaic double-buffers input BlockSpecs automatically, which *is* Unimem's
proactive helper-thread mover one memory level down:

=====================  ====================================================
paper concept          kernel realization
=====================  ====================================================
data object            one (bk, bn) weight tile
phase                  one grid step
placement plan         BlockSpec index_map (which tile is VMEM-resident)
helper thread + FIFO   Mosaic grid pipeline (double-buffered async DMA)
DRAM capacity          VMEM budget = block sizes chosen below
=====================  ====================================================

The x tile is reused across the N axis (grid ordered so x stays resident),
and a float32 VMEM scratch accumulates across the K axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(x_ref, w_ref, o_ref, acc_scr, *, nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def tiered_matmul(x: jax.Array, w: jax.Array, *, bm: int = 256,
                  bn: int = 256, bk: int = 512,
                  interpret: bool = False) -> jax.Array:
    """x: (M, K); w: (K, N) -> (M, N).  Dims must divide the block sizes
    (ops.py pads).  VMEM working set ~= bm*bk + bk*bn + 2*bm*bn floats."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and M % bm == 0 and N % bn == 0 and K % bk == 0
    grid = (M // bm, N // bn, K // bk)
    kernel = functools.partial(_mm_kernel, nk=K // bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
