"""Single-token (decode) attention over a long KV cache — Pallas TPU kernel.

The decode-shape hot spot: one query token per sequence attends to a KV
cache of up to 512k positions.  Compute is negligible; the kernel is a
bandwidth machine — performance is HBM-stream speed of K and V.  Grid
(B, K, nk): the (G, D) query tile stays in VMEM while (bk, D) cache tiles
stream through, with the same running-softmax scratch recurrence as the
prefill kernel and masking past ``length``.

Unimem note: tiles beyond ``length`` are skipped entirely (@pl.when), the
kernel-level analogue of not migrating objects that a phase never
references.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, bk: int, n_kv: int, scale: float):
    ki = pl.program_id(2)
    length = len_ref[0]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(ki * bk < length)        # skip tiles entirely past the length
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale     # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)             # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, bk)
        pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos >= length, NEG_INF, s)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     length: jax.Array, *, bk: int = 512,
                     interpret: bool = False) -> jax.Array:
    """q: (B, K, G, D); k, v: (B, K, T, D); length: () int32 — number of
    valid cache positions.  Returns (B, K, G, D)."""
    B, K, G, D = q.shape
    T = k.shape[2]
    assert T % bk == 0, (T, bk)
    nk = T // bk
    scale = 1.0 / math.sqrt(D)
    kernel = functools.partial(_decode_kernel, bk=bk, n_kv=nk, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, K, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j, L: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j, L: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j, L: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j, L: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        interpret=interpret,
    )(jnp.asarray(length, jnp.int32).reshape(1), q, k, v)
