"""Causal GQA flash attention — Pallas TPU kernel.

Grid (B, K, nq, nk): one VMEM-resident (G*bq, D) query tile attends to
streamed (bk, D) key/value tiles with the running-softmax (m, l, acc)
recurrence; accumulators live in VMEM scratch and persist across the nk
axis (sequentially innermost on TPU).

Unimem mapping: the BlockSpec index maps are the *placement plan* (which
HBM tile sits in VMEM at each grid step) and Mosaic's double-buffered grid
pipeline is the *proactive mover* — the next KV tile streams HBM->VMEM while
the current tile is being consumed, exactly the paper's helper-thread
overlap, one memory level down.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, bq: int, bk: int, n_kv: int, causal: bool, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def compute():
        G = q_ref.shape[2]
        D = q_ref.shape[4]
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (G, bq, D)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q.reshape(G * bq, D), k,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)          # (G*bq, bk)
        if causal:
            rows = jax.lax.broadcasted_iota(jnp.int32, (G * bq, bk), 0) % bq
            cols = jax.lax.broadcasted_iota(jnp.int32, (G * bq, bk), 1)
            qpos = qi * bq + rows
            kpos = ki * bk + cols
            s = jnp.where(kpos > qpos, NEG_INF, s)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal:
        # skip fully-masked tiles (block-sparsity of the causal mask)
        @pl.when(ki * bk <= qi * bq + bq - 1)
        def _():
            compute()
    else:
        compute()

    @pl.when(ki == n_kv - 1)
    def _finish():
        G = o_ref.shape[2]
        D = o_ref.shape[4]
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = out.reshape(G, bq, D).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = 128, bk: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, K, G, S, D); k, v: (B, K, T, D).  Returns (B, K, G, S, D).

    S must be a multiple of bq and T of bk (ops.py pads)."""
    B, K, G, S, D = q.shape
    T = k.shape[2]
    assert S % bq == 0 and T % bk == 0, (S, T, bq, bk)
    nq, nk = S // bq, T // bk
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, n_kv=nk,
                               causal=causal, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(B, K, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, bq, D), lambda b, h, i, j: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, bq, D),
                               lambda b, h, i, j: (b, h, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G * bq, 1), jnp.float32),
            pltpu.VMEM((G * bq, 1), jnp.float32),
            pltpu.VMEM((G * bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
