"""Public jit'd wrappers over the Pallas kernels.

Dispatch policy: Pallas kernels on TPU, pure-jnp oracles elsewhere
(CPU/interpret is for tests only — ``interpret=True`` executes the kernel
body in Python).  Wrappers handle padding to block multiples so callers can
pass arbitrary shapes.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .decode_attention import decode_attention as _decode_pallas
from .flash_attention import flash_attention as _flash_pallas
from .ssd_scan import ssd_scan as _ssd_pallas
from .tiered_matmul import tiered_matmul as _mm_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = 128, bk: int = 128,
                    force_pallas: Optional[bool] = None,
                    interpret: bool = False) -> jax.Array:
    """q: (B, K, G, S, D); k, v: (B, K, T, D)."""
    use_pallas = force_pallas if force_pallas is not None else on_tpu()
    if not use_pallas:
        return ref.flash_attention_ref(q, k, v, causal=causal)
    S, T = q.shape[3], k.shape[2]
    qp = _pad_to(q, 3, bq)
    kp = _pad_to(k, 2, bk)
    vp = _pad_to(v, 2, bk)
    # padded KV columns must not win the softmax: causal masking handles the
    # tail since padded q rows are discarded and kpos > qpos there.
    out = _flash_pallas(qp, kp, vp, causal=causal, bq=bq, bk=bk,
                        interpret=interpret)
    return out[:, :, :, :S]


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     length, *, bk: int = 512,
                     force_pallas: Optional[bool] = None,
                     interpret: bool = False) -> jax.Array:
    """q: (B, K, G, D); k, v: (B, K, T, D); length: valid cache positions."""
    use_pallas = force_pallas if force_pallas is not None else on_tpu()
    if not use_pallas:
        return ref.decode_attention_ref(q, k, v, length)
    kp = _pad_to(k, 2, bk)
    vp = _pad_to(v, 2, bk)
    return _decode_pallas(q, kp, vp, length, bk=bk, interpret=interpret)


def tiered_matmul(x: jax.Array, w: jax.Array, *, bm: int = 256,
                  bn: int = 256, bk: int = 512,
                  force_pallas: Optional[bool] = None,
                  interpret: bool = False) -> jax.Array:
    M, N = x.shape[0], w.shape[1]
    use_pallas = force_pallas if force_pallas is not None else on_tpu()
    if not use_pallas:
        return ref.tiered_matmul_ref(x, w)
    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w, 0, bk), 1, bn)
    return _mm_pallas(xp, wp, bm=bm, bn=bn, bk=bk,
                      interpret=interpret)[:M, :N]


def ssd_scan(a: jax.Array, k: jax.Array, v: jax.Array, q: jax.Array, *,
             chunk: int = 256, force_pallas: Optional[bool] = None,
             interpret: bool = False) -> jax.Array:
    use_pallas = force_pallas if force_pallas is not None else on_tpu()
    if not use_pallas:
        return ref.ssd_scan_ref(a, k, v, q)
    S = a.shape[2]
    pad = (-S) % chunk
    if pad:
        a = jnp.pad(a, ((0, 0), (0, 0), (0, pad)), constant_values=1.0)
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    out = _ssd_pallas(a, k, v, q, chunk=chunk, interpret=interpret)
    return out[:, :, :S]
