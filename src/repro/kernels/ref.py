"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

Deliberately naive: full score matrices, step-by-step recurrences, fp32
everywhere.  Tests sweep shapes/dtypes and assert the kernels (interpret
mode on CPU) match these within tolerance.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True) -> jax.Array:
    """q: (B, K, G, S, D); k, v: (B, K, T, D) -> (B, K, G, S, D)."""
    B, K, G, S, D = q.shape
    T = k.shape[2]
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bkgsd,bktd->bkgst", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    if causal:
        mask = jnp.arange(T)[None, :] > jnp.arange(S)[:, None]
        s = jnp.where(mask[None, None, None], NEG_INF, s)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         length: int) -> jax.Array:
    """q: (B, K, G, D); k, v: (B, K, T, D) -> (B, K, G, D)."""
    D = q.shape[-1]
    T = k.shape[2]
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bkgd,bktd->bkgt", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    s = jnp.where((jnp.arange(T) >= length)[None, None, None], NEG_INF, s)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgt,bktd->bkgd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def tiered_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(x.astype(jnp.float32),
                   w.astype(jnp.float32)).astype(x.dtype)


def ssd_scan_ref(a: jax.Array, k: jax.Array, v: jax.Array, q: jax.Array
                 ) -> jax.Array:
    """Step-by-step SSD recurrence.  a: (B,H,S); k,q: (B,H,S,N); v: (B,H,S,P)."""
    B, H, S = a.shape
    N, P = k.shape[-1], v.shape[-1]

    def step(state, inp):
        a_t, k_t, v_t, q_t = inp
        state = state * a_t[..., None, None] + jnp.einsum(
            "bhn,bhp->bhnp", k_t, v_t)
        y = jnp.einsum("bhnp,bhn->bhp", state, q_t)
        return state, y

    init = jnp.zeros((B, H, N, P), jnp.float32)
    xs = (jnp.moveaxis(a, 2, 0).astype(jnp.float32),
          jnp.moveaxis(k, 2, 0).astype(jnp.float32),
          jnp.moveaxis(v, 2, 0).astype(jnp.float32),
          jnp.moveaxis(q, 2, 0).astype(jnp.float32))
    _, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 2).astype(v.dtype)
