"""Pallas TPU kernels (+ pure-jnp oracles in ref.py, wrappers in ops.py).

flash_attention — causal GQA flash attention (train / prefill hot spot)
decode_attention — one-token attention over long KV caches (decode shapes)
tiered_matmul   — HBM->VMEM streamed matmul (the paper's proactive-mover
                  pattern at the kernel memory level)
ssd_scan        — Mamba-2 SSD chunked scan (zamba2 / long-context hot spot)
"""

from . import ops, ref
from .decode_attention import decode_attention
from .flash_attention import flash_attention
from .ssd_scan import ssd_scan
from .tiered_matmul import tiered_matmul

__all__ = ["ops", "ref", "decode_attention", "flash_attention", "ssd_scan",
           "tiered_matmul"]
