"""Benchmark suite — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; ``derived`` carries the
figure-specific quantity (normalized slowdowns, overlap fractions, ...).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only fig9
"""

from __future__ import annotations

import argparse
import pathlib
import sys
import time

from repro.core import PAPER_DRAM_NVM, calibrate
from repro.sim import (NPB_WORKLOADS, SCENARIO_WORKLOADS,
                       SKEWED_SCENARIO_WORKLOADS, lm_train_workload)
from repro.sim.workloads import graph_chase_skewed, kv_serving_skewed
from repro.core.tiers import TPU_V5E

from .common import (DEFAULT_DRAM, MB, run_static, run_unimem, run_xmen)

ROWS = []
RESULTS_DIR = pathlib.Path(__file__).resolve().parent / "results"
SAVE_RESULTS = False            # set by --save: refresh the committed CSVs
SCENARIO_FILTER = None          # set by --scenario: substring workload filter
CHAOS_SEED = 42                 # fixed seed: the committed chaos rows are
                                # a deterministic fault replay, not a sample


def emit(name: str, us: float, derived: str) -> None:
    row = f"{name},{us:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def _scenario_selected(wl_name: str) -> bool:
    return SCENARIO_FILTER is None or SCENARIO_FILTER in wl_name


def write_rows(filename: str, prefix: str, must_contain: str = None,
               exclude: str = None) -> None:
    """With ``--save``, commit this run's rows matching ``prefix`` to
    results/<filename> (the nightly-regression baselines); default runs
    only print, so a casual local run never rewrites the committed CSVs.
    ``must_contain``/``exclude`` split row families sharing a prefix
    (``scenario_*_chaos`` goes to chaos.csv, everything else to
    scenarios.csv)."""
    if not SAVE_RESULTS:
        return
    if SCENARIO_FILTER is not None:
        print(f"# --scenario filter active: not rewriting {filename}",
              flush=True)
        return
    rows = [r for r in ROWS if r.startswith(prefix)
            and (must_contain is None or must_contain in r.split(",", 1)[0])
            and (exclude is None or exclude not in r.split(",", 1)[0])]
    if not rows:
        return
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    path = RESULTS_DIR / filename
    path.write_text("name,us_per_call,derived\n" + "\n".join(rows) + "\n")
    print(f"# wrote {len(rows)} rows -> {path}", flush=True)


# ---------------------------------------------------------------- Figs 2-3
def bench_tier_sweep() -> None:
    """NVM-only slowdown vs bandwidth (Fig 2) and latency (Fig 3)."""
    for knob, scales in (("bw", [1.0, 0.5, 0.25, 0.125]),
                         ("lat", [1.0, 2.0, 4.0, 8.0])):
        for wl_name, make in NPB_WORKLOADS.items():
            wl = make()
            for s in scales:
                m = (PAPER_DRAM_NVM.scaled(bw_scale=s) if knob == "bw"
                     else PAPER_DRAM_NVM.scaled(lat_scale=s))
                t0 = time.perf_counter()
                dram = run_static(m, wl, "fast", iters=6)
                nvm = run_static(m, wl, "slow", iters=6)
                us = (time.perf_counter() - t0) * 1e6
                ratio = nvm.steady_iteration_time / dram.steady_iteration_time
                emit(f"fig{2 if knob == 'bw' else 3}_{wl_name}_{knob}{s}",
                     us, f"nvm_over_dram={ratio:.3f}")


# ------------------------------------------------------------------- Fig 4
def bench_object_placement() -> None:
    """Per-object placement impact on SP (Fig 4): which objects are
    bandwidth- vs latency-sensitive."""
    from repro.core.data_objects import ObjectRegistry
    from repro.sim import SimulationEngine

    wl = NPB_WORKLOADS["sp"]()
    for nvm_cfg, mach in (("halfbw", PAPER_DRAM_NVM.scaled(bw_scale=0.5)),
                          ("4xlat", PAPER_DRAM_NVM.scaled(lat_scale=4.0))):
        dram = run_static(mach, wl, "fast", iters=6)
        nvm = run_static(mach, wl, "slow", iters=6)
        for target in (["in_buffer", "out_buffer"], ["lhs"], ["rhs"]):
            reg = ObjectRegistry()
            for n, s in wl.objects.items():
                reg.alloc(n, s, tier="fast" if n in target else "slow")
            t0 = time.perf_counter()
            res = SimulationEngine(mach, wl, registry=reg).run(6)
            us = (time.perf_counter() - t0) * 1e6
            emit(f"fig4_sp_{nvm_cfg}_{'+'.join(target)}", us,
                 f"norm={res.steady_iteration_time / dram.steady_iteration_time:.3f};"
                 f"nvm_only={nvm.steady_iteration_time / dram.steady_iteration_time:.3f}")


# ---------------------------------------------------------------- Figs 9-10
def bench_unimem_gap() -> None:
    """DRAM-only vs NVM-only vs X-Men vs Unimem (Figs 9-10)."""
    for fig, mach in (("fig9", PAPER_DRAM_NVM.scaled(bw_scale=0.5)),
                      ("fig10", PAPER_DRAM_NVM.scaled(lat_scale=4.0))):
        gaps = []
        for wl_name, make in NPB_WORKLOADS.items():
            wl = make()
            t0 = time.perf_counter()
            dram = run_static(mach, wl, "fast")
            nvm = run_static(mach, wl, "slow")
            xmen = run_xmen(mach, wl)
            uni, rt = run_unimem(mach, wl)
            us = (time.perf_counter() - t0) * 1e6
            d = dram.steady_iteration_time
            gaps.append(uni.steady_iteration_time / d - 1)
            emit(f"{fig}_{wl_name}", us,
                 f"nvm={nvm.steady_iteration_time / d:.3f};"
                 f"xmen={xmen.steady_iteration_time / d:.3f};"
                 f"unimem={uni.steady_iteration_time / d:.3f};"
                 f"strategy={rt.plan.strategy if rt.plan else 'none'}")
        emit(f"{fig}_average", 0.0,
             f"unimem_avg_gap={sum(gaps) / len(gaps) * 100:.1f}%"
             f";paper_claim={'3%' if fig == 'fig9' else '7%'}")


# ------------------------------------------------------------------ Fig 11
def bench_ablation() -> None:
    """Contribution of the four techniques (Fig 11): apply cumulatively
    (1) global search, (2) +local search, (3) +partitioning, (4) +initial
    placement."""
    from repro.core import RuntimeConfig

    mach = PAPER_DRAM_NVM.scaled(bw_scale=0.5)
    stages = [
        ("global", dict(enable_local_search=False, enable_partitioning=False,
                        enable_initial_placement=False)),
        ("+local", dict(enable_partitioning=False,
                        enable_initial_placement=False)),
        ("+partition", dict(enable_initial_placement=False)),
        ("+initial", dict()),
    ]
    for wl_name, make in NPB_WORKLOADS.items():
        wl = make()
        dram = run_static(mach, wl, "fast")
        nvm = run_static(mach, wl, "slow")
        base = nvm.steady_iteration_time
        derived = [f"nvm={base / dram.steady_iteration_time:.3f}"]
        t0 = time.perf_counter()
        for name, kw in stages:
            cfgr = RuntimeConfig(fast_capacity_bytes=DEFAULT_DRAM, **kw)
            res, _ = run_unimem(mach, wl, config=cfgr)
            derived.append(
                f"{name}="
                f"{res.steady_iteration_time / dram.steady_iteration_time:.3f}")
        us = (time.perf_counter() - t0) * 1e6
        emit(f"fig11_{wl_name}", us, ";".join(derived))


# ----------------------------------------------------------------- Table 4
def bench_migration_stats() -> None:
    mach = PAPER_DRAM_NVM.scaled(bw_scale=0.5)
    for wl_name, make in NPB_WORKLOADS.items():
        wl = make()
        t0 = time.perf_counter()
        res, rt = run_unimem(mach, wl)
        us = (time.perf_counter() - t0) * 1e6
        s = rt.stats()
        emit(f"table4_{wl_name}", us,
             f"migrations={s['n_moves']};"
             f"moved_mb={s['moved_bytes'] / MB:.0f};"
             f"overlap={100 * (s['overlap_fraction'] or 0):.0f}%;"
             f"strategy={s['strategy']}")


# ------------------------------------------------------------------ Fig 12
def bench_scaling() -> None:
    """Strong scaling (Fig 12): per-rank problem shrinks as ranks grow."""
    mach = PAPER_DRAM_NVM.scaled(bw_scale=0.6, lat_scale=1.89)  # Edison emu
    for ranks in (4, 8, 16, 32, 64):
        wl = NPB_WORKLOADS["cg"](scale=4.0 / ranks)
        t0 = time.perf_counter()
        dram = run_static(mach, wl, "fast")
        uni, rt = run_unimem(mach, wl)
        us = (time.perf_counter() - t0) * 1e6
        emit(f"fig12_cg_ranks{ranks}", us,
             f"unimem={uni.steady_iteration_time / dram.steady_iteration_time:.3f}")


# ------------------------------------------------------------------ Fig 13
def bench_dram_size() -> None:
    mach = PAPER_DRAM_NVM.scaled(bw_scale=0.5)
    for size_mb in (128, 256, 512):
        for wl_name in ("cg", "ft", "mg", "sp"):
            wl = NPB_WORKLOADS[wl_name]()
            t0 = time.perf_counter()
            dram = run_static(mach, wl, "fast")
            uni, _ = run_unimem(mach, wl, dram_bytes=size_mb * MB)
            us = (time.perf_counter() - t0) * 1e6
            emit(f"fig13_{wl_name}_dram{size_mb}mb", us,
                 f"unimem={uni.steady_iteration_time / dram.steady_iteration_time:.3f}")


# ------------------------------------------- beyond-paper: LM tiering (v5e)
def bench_lm_tiering() -> None:
    """Optimizer-state offload on the TPU tier model: nemotron-340b-like
    per-chip slice (the flagship dry-run cell, simulated end to end)."""
    GB = 1024 ** 3
    for name, layer_b, opt_b, act_b, layers in (
            ("nemotron340b_chip", 28 * MB, 166 * MB, 18 * MB, 96),
            ("dbrx132b_chip", 11 * MB, 64 * MB, 6 * MB, 40)):
        wl = lm_train_workload(n_layers=layers, layer_bytes=layer_b,
                               opt_bytes=opt_b, act_bytes=act_b,
                               name=name, compute_per_group_s=0.012)
        t0 = time.perf_counter()
        hbm_unlimited = run_static(TPU_V5E, wl, "fast", iters=6)
        host_all = run_static(TPU_V5E, wl, "slow", iters=6)
        uni, rt = run_unimem(TPU_V5E, wl,
                             dram_bytes=int(10 * GB), iters=8)
        us = (time.perf_counter() - t0) * 1e6
        d = hbm_unlimited.steady_iteration_time
        emit(f"lm_tiering_{name}", us,
             f"host_all={host_all.steady_iteration_time / d:.3f};"
             f"unimem={uni.steady_iteration_time / d:.3f};"
             f"overlap={100 * (rt.stats()['overlap_fraction'] or 0):.0f}%")


# ------------------------------------- scenario matrix: slack vs FIFO mover
def bench_scenarios() -> None:
    """Slack-aware async scheduler vs the FIFO phase-boundary mover on the
    steady-state-churn scenario matrix (KV-cache serving, MoE expert churn,
    pointer-chasing graph).  Reports per scenario: steady iteration time
    normalized to DRAM-only for each policy, absolute steady-state fence
    stall per iteration, and the slack engine's overlap fractions
    (move-count based and copy-time based).

    ``drift_threshold`` is pinned high so both movers execute the *same*
    plan — the comparison isolates the migration engine."""
    mach = PAPER_DRAM_NVM.scaled(bw_scale=0.5, lat_scale=2.0)
    for wl_name, make in SCENARIO_WORKLOADS.items():
        if not _scenario_selected(wl_name):
            continue
        wl = make()
        t0 = time.perf_counter()
        dram = run_static(mach, wl, "fast")
        nvm = run_static(mach, wl, "slow")
        results = {}
        for mover in ("fifo", "slack"):
            res, rt = run_unimem(mach, wl, mover=mover, drift_threshold=10.0)
            tail = res.phase_trace[len(res.phase_trace) // 2:]
            stall = (sum(p.stall_s for p in tail)
                     / (len(tail) / len(wl.phases)))
            results[mover] = (res, rt, stall)
        us = (time.perf_counter() - t0) * 1e6
        d = dram.steady_iteration_time
        (fifo, _, fifo_stall) = results["fifo"]
        (slack, srt, slack_stall) = results["slack"]
        s = srt.stats()
        emit(f"scenario_{wl_name}", us,
             f"nvm={nvm.steady_iteration_time / d:.3f};"
             f"fifo={fifo.steady_iteration_time / d:.3f};"
             f"slack={slack.steady_iteration_time / d:.3f};"
             f"speedup={fifo.steady_iteration_time / slack.steady_iteration_time:.3f};"
             f"fifo_stall_s={fifo_stall:.4f};"
             f"slack_stall_s={slack_stall:.4f};"
             f"overlap={s['overlap_fraction']:.2f};"
             f"overlap_time={(s['overlap_time_fraction'] or 0):.2f};"
             f"strategy={s['strategy']}")

    # skewed variants: hot-chunk pipeline (per-chunk attribution + skew-aware
    # partitioning, chunk_aware=True) vs PR 1's uniform-attribution slack
    # engine (chunk_aware=False) — both on the slack mover, same machine.
    for wl_name, make in SKEWED_SCENARIO_WORKLOADS.items():
        if not _scenario_selected(wl_name):
            continue
        wl = make()
        t0 = time.perf_counter()
        dram = run_static(mach, wl, "fast")
        nvm = run_static(mach, wl, "slow")
        uni, _ = run_unimem(mach, wl, drift_threshold=10.0, chunk_aware=False)
        hot, hrt = run_unimem(mach, wl, drift_threshold=10.0, chunk_aware=True)
        us = (time.perf_counter() - t0) * 1e6
        d = dram.steady_iteration_time
        s = hrt.stats()
        n_chunks = sum(1 for o in hrt.registry if o.parent is not None)
        emit(f"scenario_{wl_name}", us,
             f"nvm={nvm.steady_iteration_time / d:.3f};"
             f"uniform={uni.steady_iteration_time / d:.3f};"
             f"hotchunk={hot.steady_iteration_time / d:.3f};"
             f"speedup={uni.steady_iteration_time / hot.steady_iteration_time:.3f};"
             f"overlap={s['overlap_fraction']:.2f};"
             f"n_chunks={n_chunks};"
             f"strategy={s['strategy']}")

    # multi-resolution refinement (PR 5): the full multi-res mode
    # (adaptive re-binning plus its enactment-consistent solve — fine
    # chunks need the churn-guarded pricing, so the mode ships as one
    # switch) vs the legacy fixed-width pipeline at the SAME total bin
    # budget (64), on skewed workloads whose true densities carry
    # structure finer than one uniform bin.  Global search runs at its
    # default (on): since PR 6 prices global moves through the same
    # schedule-aware estimate as local ones, the best-of-two chooser no
    # longer hands global a free-movement advantage, so the rows need no
    # pin.  The committed gates enforce equal-or-better steady slack
    # (mr_gain >= 1) with hot-head chunks finer than one legacy bin
    # (hot_chunk_frac < 1).
    from repro.core.partition import chunk_spans

    mr_scenarios = (
        ("graph_chase_skew", lambda: graph_chase_skewed(density_bins=256)),
        ("kv_serving_skew",
         lambda: kv_serving_skewed(sub=16, window=4, taper=0.4)),
    )
    for wl_name, make in mr_scenarios:
        if not _scenario_selected(wl_name):
            continue
        wl = make()
        t0 = time.perf_counter()
        dram = run_static(mach, wl, "fast")
        common = dict(drift_threshold=10.0, chunk_aware=True,
                      histogram_bins=64, profile_iterations=3)
        uni, _ = run_unimem(mach, wl, **common)
        ref, rrt = run_unimem(mach, wl, histogram_refine=True, **common)
        us = (time.perf_counter() - t0) * 1e6
        d = dram.steady_iteration_time
        # finest fast-resident hot-head chunk vs one legacy (1/64) bin —
        # uncapped, so a regression past 1.0 is visible to the nightly
        # ceiling gate
        frac = float("inf")
        parents = sorted({o.parent for o in rrt.registry
                          if o.parent is not None})
        n_chunks = 0
        for par in parents:
            spans = chunk_spans(rrt.registry, par)
            n_chunks += len(spans)
            size = spans[-1][2]
            fast = [c.size_bytes for c, _, _ in spans if c.tier == "fast"]
            if fast:
                frac = min(frac, min(fast) / (size / 64))
        if frac == float("inf"):
            frac = 64.0         # nothing fast-resident: fail the ceiling
        emit(f"scenario_{wl_name}_mr", us,
             f"nvm={run_static(mach, wl, 'slow').steady_iteration_time / d:.3f};"
             f"uniform64={uni.steady_iteration_time / d:.3f};"
             f"refined={ref.steady_iteration_time / d:.3f};"
             f"mr_gain={uni.steady_iteration_time / ref.steady_iteration_time:.3f};"
             f"hot_chunk_frac={frac:.3f};"
             f"n_chunks={n_chunks}")

    # policy ablation (PR 5 + PR 6): the registry's clock/LRU baseline
    # and the calibrated planner (calibrate_feedback=True, PR 6's online
    # per-class CF folds) against the uncalibrated benefit-model planner,
    # one row per scenario.  LRU wins some rotations against the
    # *uncalibrated* model (fsdp_buckets books latency gains ~14x
    # optimistic and plans essentially no moves); the calibrated arm
    # closes that gap (``cal_parity`` = lru/unimem_cal, floor-gated at
    # 1.0 on fsdp_buckets) and ``pred_err`` records how honest the kept
    # model's prediction is (ceiling-gated where folds are kept; a
    # reverted epoch keeps the uncalibrated prediction, err ~1.0).
    for wl_name, make in {**SCENARIO_WORKLOADS,
                          **SKEWED_SCENARIO_WORKLOADS}.items():
        if not _scenario_selected(wl_name):
            continue
        wl = make()
        t0 = time.perf_counter()
        dram = run_static(mach, wl, "fast")
        uni, _ = run_unimem(mach, wl, drift_threshold=10.0)
        lru, _ = run_unimem(mach, wl, drift_threshold=10.0, policy="lru")
        cal, crt = run_unimem(mach, wl, drift_threshold=10.0,
                              calibrate_feedback=True)
        us = (time.perf_counter() - t0) * 1e6
        d = dram.steady_iteration_time
        cs = crt.stats()
        emit(f"scenario_{wl_name}_ablation", us,
             f"unimem={uni.steady_iteration_time / d:.3f};"
             f"lru={lru.steady_iteration_time / d:.3f};"
             f"unimem_cal={cal.steady_iteration_time / d:.3f};"
             f"lru_over_unimem="
             f"{lru.steady_iteration_time / uni.steady_iteration_time:.3f};"
             f"cal_parity="
             f"{lru.steady_iteration_time / cal.steady_iteration_time:.3f};"
             f"pred_err={(cs['pred_err'] if cs['pred_err'] is not None else -1):.3f};"
             f"n_folds={cs['n_recalibrations']}")

    # interval-guidance ablation (PR 6): Olson-style decayed interval
    # profiling (arxiv 2110.02150) as the third policy arm — recency
    # (lru) vs decayed frequency/density (interval) vs the calibrated
    # benefit model.  ``vs_nvm`` floors the rows: the guidance must keep
    # a real speedup over NVM-only or the gate fails loudly.
    for wl_name, make in {**SCENARIO_WORKLOADS,
                          **SKEWED_SCENARIO_WORKLOADS}.items():
        if not _scenario_selected(wl_name):
            continue
        wl = make()
        t0 = time.perf_counter()
        dram = run_static(mach, wl, "fast")
        nvm = run_static(mach, wl, "slow")
        uni, _ = run_unimem(mach, wl, drift_threshold=10.0)
        itv, irt = run_unimem(mach, wl, drift_threshold=10.0,
                              policy="interval")
        us = (time.perf_counter() - t0) * 1e6
        d = dram.steady_iteration_time
        emit(f"scenario_{wl_name}_interval", us,
             f"interval={itv.steady_iteration_time / d:.3f};"
             f"interval_over_unimem="
             f"{itv.steady_iteration_time / uni.steady_iteration_time:.3f};"
             f"vs_nvm="
             f"{nvm.steady_iteration_time / itv.steady_iteration_time:.3f};"
             f"moves={len(irt.plan.moves) if irt.plan else 0}")
    write_rows("scenarios.csv", "scenario_", exclude="_chaos")


# --------------------------- chaos: the scenario matrix under fault injection
def bench_chaos() -> None:
    """The full scenario matrix re-run under the gated chaos profile (5%
    transient start failures + one 8x straggler channel, fixed seed — a
    deterministic fault replay, not a sample).  Each row reports the
    degraded-mode slack engine against its own fault-free run
    (``vs_faultfree``, nightly floor 0.85): retries, degraded serves,
    rollbacks and straggler reissues absorb the faults, the channel
    health machine quarantines the straggler channel, and the post-run
    tier audit must stay violation-free (``audit_violations`` counts
    in-run audit violations plus any final-state divergence; the nightly
    ceiling pins it to zero)."""
    from repro.sim.workloads import chaos_gated_spec

    mach = PAPER_DRAM_NVM.scaled(bw_scale=0.5, lat_scale=2.0)
    for wl_name, make in {**SCENARIO_WORKLOADS,
                          **SKEWED_SCENARIO_WORKLOADS}.items():
        if not _scenario_selected(wl_name):
            continue
        wl = make()
        t0 = time.perf_counter()
        base, _ = run_unimem(mach, wl, mover="slack", drift_threshold=10.0)
        chaos, rt = run_unimem(mach, wl, mover="slack", drift_threshold=10.0,
                               fault_spec=chaos_gated_spec(seed=CHAOS_SEED))
        us = (time.perf_counter() - t0) * 1e6
        s = rt.stats()
        audit = rt.audit_tiers(heal=False)     # final-state reconciliation
        health = s["channel_health"]
        emit(f"scenario_{wl_name}_chaos", us,
             f"vs_faultfree={base.steady_iteration_time / chaos.steady_iteration_time:.3f};"
             f"audit_violations={s['n_audit_violations'] + len(audit.violations)};"
             f"retries={s['n_retries']};"
             f"degraded={s['n_degraded_serves']};"
             f"rollbacks={s['n_eviction_rollbacks']};"
             f"reissues={s['n_straggler_reissues']};"
             f"quarantined="
             f"{sum(1 for v in health.values() if v == 'quarantined')}")
    write_rows("chaos.csv", "scenario_", must_contain="_chaos")


# --------------------------- multi-tenant serving: QoS partition vs aggregate
def bench_tenants() -> None:
    """The tenancy layer's gated row: ``tenant_serving`` (one whale, three
    mid tenants, one cold archive) under the aggregate unimem solve vs the
    ``bandwidth_partition`` policy, against a DRAM-only reference.

    Per tenant, ``slack = dram_p99 / arm_p99`` (p99 of the per-iteration
    time summed over the tenant's phases, steady tail).  The gated
    quantities: ``tail_gain`` — the worst admitted non-whale tenant's
    slack ratio partition/unimem (nightly floor 1.15: partitioning must
    buy the long tail real p99 headroom) — and ``whale_ratio`` — the
    whale's same ratio (floor 0.95: without starving the whale).  The
    cold tenant is admission-demoted to serve-from-slow and excluded from
    the tail by the demotion record itself."""
    from repro.core.tenancy import per_tenant_p99
    from repro.sim.workloads import TENANT_SERVING_QOS, tenant_serving

    from .common import run_unimem_tenants

    mach = PAPER_DRAM_NVM.scaled(bw_scale=0.5, lat_scale=2.0)
    wl = tenant_serving()
    qos = TENANT_SERVING_QOS
    names = [ph.name for ph in wl.phases]
    iters = 20
    kw = dict(dram_bytes=192 * MB, iters=iters, copy_channels=7,
              drift_threshold=10.0)
    t0 = time.perf_counter()
    dram = run_static(mach, wl, "fast", iters=iters)
    uni, _ = run_unimem_tenants(mach, wl, qos, **kw)
    part, prt = run_unimem_tenants(mach, wl, qos,
                                   policy="bandwidth_partition", **kw)
    us = (time.perf_counter() - t0) * 1e6
    p_dram = per_tenant_p99(dram.phase_trace, names, qos)
    p_uni = per_tenant_p99(uni.phase_trace, names, qos)
    p_bp = per_tenant_p99(part.phase_trace, names, qos)
    slack_uni = {t: p_dram[t] / p_uni[t] for t in p_dram}
    slack_bp = {t: p_dram[t] / p_bp[t] for t in p_dram}
    admission = dict(getattr(prt.plan, "tenant_admission", None) or {})
    tail = [t for t in sorted(qos) if t != "whale" and t not in admission]
    tail_gain = min(slack_bp[t] / slack_uni[t] for t in tail)
    whale_ratio = slack_bp["whale"] / slack_uni["whale"]
    shares = dict(getattr(prt.plan, "tenant_shares", None) or {})
    channels = dict(getattr(prt.plan, "tenant_channels", None) or {})
    derived = [f"tail_gain={tail_gain:.3f}", f"whale_ratio={whale_ratio:.3f}"]
    for t in sorted(qos):
        derived.append(f"{t}_slack_uni={slack_uni[t]:.3f}")
        derived.append(f"{t}_slack_bp={slack_bp[t]:.3f}")
    derived.append(f"demoted={'+'.join(sorted(admission)) or 'none'}")
    derived.append(f"whale_share_mb={shares.get('whale', 0) / MB:.0f}")
    derived.append(f"whale_channels={len(channels.get('whale', []))}")
    emit("scenario_tenant_serving", us, ";".join(derived))
    write_rows("tenants.csv", "scenario_tenant")


# ------------------------------------- multi-host cluster coordination
def bench_multihost() -> None:
    """Multi-host tier management's gated row: ``moe_churn_multihost``
    (4 virtual hosts, one host's expert shard hot past DRAM capacity
    after router churn, peers idle with spare capacity).

    Host-local-only management leaves the hot host serving surplus
    experts from NVM; the cluster coordinator re-homes them to peers
    over the modeled interconnect (cross_host backend).  Gated
    quantities: ``hot_gain`` — the hot host's steady iteration time,
    local-only over coordinated (nightly floor 1.10) — and
    ``cluster_gain`` — the same ratio on the slowest host (the cluster's
    effective iteration time).  ``migration_ms`` records the one-time
    virtual-time cost of the pulls over the apportioned link pairs."""
    from repro.sim import ClusterSimulation, moe_churn_multihost

    machine, wl, links, knobs = moe_churn_multihost()
    sim = ClusterSimulation(machine, wl, links=links, **knobs)
    t0 = time.perf_counter()
    local = sim.run_local_only(12)
    coord = sim.run_coordinated(12)
    us = (time.perf_counter() - t0) * 1e6
    hot = "h0"
    hot_gain = local.steady_time(hot) / coord.steady_time(hot)
    cluster_gain = local.cluster_steady_time / coord.cluster_steady_time
    pulls = [m for m in coord.migrations if m.mode == "cross_host"]
    derived = [f"hot_gain={hot_gain:.3f}",
               f"cluster_gain={cluster_gain:.3f}",
               f"n_migrations={len(pulls)}",
               f"migrated_mb={sum(m.size_bytes for m in pulls) / MB:.0f}",
               f"migration_ms={coord.migration_s * 1e3:.2f}"]
    for h in wl.hosts():
        derived.append(f"{h}_local_ms={local.steady_time(h) * 1e3:.2f}")
        derived.append(f"{h}_coord_ms={coord.steady_time(h) * 1e3:.2f}")
    emit("multihost_moe_churn", us, ";".join(derived))
    write_rows("multihost.csv", "multihost_")


# ------------------------------ planner latency: vectorized vs pre-PR path
def bench_planner() -> None:
    """Plan-construction latency vs registry size.

    Builds a registry of N chunks (10 partitioned parents, parent-level
    profiles so every candidate exercises the chunk-attribution fallback —
    the planner's hot path), then times ``Planner.plan`` in both modes:
    ``legacy`` is the pre-optimization per-candidate scalar path with the
    bool-matrix knapsack, ``vectorized`` the batched numpy path with the
    packed-bitset knapsack.  Both produce identical plans."""
    import random

    from repro.core import (CalibrationConstants, PhaseProfiler, Planner,
                            build_phase_graph)
    from repro.core.data_objects import DataObject, ObjectRegistry
    from repro.core.partition import resplit_refs
    from repro.core.phase import PhaseTraceEvent

    mach = PAPER_DRAM_NVM.scaled(bw_scale=0.5)

    def build(n_objs: int, n_phases: int = 12, seed: int = 0):
        rng = random.Random(seed)
        reg = ObjectRegistry()
        n_parents = 10
        per = n_objs // n_parents
        for p in range(n_parents):
            for k in range(per):
                reg.register(DataObject(
                    name=f"par{p}#{k}", size_bytes=rng.randint(1, 4) * MB,
                    parent=f"par{p}", chunk_index=k))
        refs, times = [], []
        for _ in range(n_phases):
            r = {f"par{p}": rng.uniform(1e5, 1e7) for p in range(10)
                 if rng.random() < 0.7}
            refs.append(r)
            times.append(rng.uniform(0.01, 0.2))
        graph = build_phase_graph(
            [(f"ph{i}", rr) for i, rr in enumerate(refs)], times=times)
        prof = PhaseProfiler(mach, seed=seed)
        for i, rr in enumerate(refs):
            prof.observe(PhaseTraceEvent(i, times[i], dict(rr)))
        prof.annotate_graph(graph)
        resplit_refs(graph, reg)    # parent refs -> size-fraction chunk refs
        return reg, graph, prof, refs, times

    def timed(fn, repeats):
        """Run ``fn`` ``repeats`` times; return (last result, best µs,
        median µs).  Best-of-k is what the gates compare (least noisy);
        the median rides along so a single lucky run is visible."""
        ts, out = [], None
        for _ in range(repeats):
            t0 = time.perf_counter()
            out = fn()
            ts.append(time.perf_counter() - t0)
        ts.sort()
        return out, ts[0] * 1e6, ts[len(ts) // 2] * 1e6

    for n in (100, 500, 2000):
        reg, graph, prof, _, _ = build(n)
        plans, best, med = {}, {}, {}
        for mode, vec in (("vectorized", True), ("legacy", False)):
            def cold_plan(vec=vec):
                # fresh planner per repeat: this row times the *cold*
                # build (cross-tick caches are the replan rows' job)
                return Planner(mach, reg, CalibrationConstants(),
                               DEFAULT_DRAM, vectorized=vec).plan(graph, prof)
            plans[mode], best[mode], med[mode] = timed(
                cold_plan, 3 if n <= 500 else 2)
        equal = (plans["vectorized"].moves == plans["legacy"].moves
                 and plans["vectorized"].predicted_iteration_time
                 == plans["legacy"].predicted_iteration_time)
        if not equal:   # the oracle guarantee must hold at benchmark scale
            raise RuntimeError(
                f"vectorized plan diverged from the scalar oracle at n={n}")
        emit(f"planner_n{n}", best["vectorized"],
             f"legacy_us={best['legacy']:.0f};"
             f"vectorized_us={best['vectorized']:.0f};"
             f"median_us={med['vectorized']:.0f};"
             f"speedup={best['legacy'] / best['vectorized']:.1f};"
             f"seed=0;plans_equal={equal}")

    # vectorized-only cold build at 20k chunks (the scalar path takes
    # minutes at this scale, so no legacy comparison / speedup key)
    n = 20000
    reg, graph, prof, _, _ = build(n)
    plan20k, b, m = timed(lambda: Planner(
        mach, reg, CalibrationConstants(), DEFAULT_DRAM).plan(graph, prof), 2)
    emit(f"planner_n{n}", b,
         f"vectorized_us={b:.0f};median_us={m:.0f};seed=0;"
         f"legacy=skipped_at_scale;strategy={plan20k.strategy}")

    # ---- scoped replan vs full rebuild, single-phase intensity drift ----
    # The fixture mirrors a layered training loop (32 phases — modest next
    # to lm_train_workload's 72 at 96 layers / 4 per group).  The drift is
    # a single phase's access *intensity* shifting (same reference set,
    # counts scaled, time held) — the localized-drift case the scoped
    # response targets.  The scoped replan must (a) produce exactly the
    # plan a from-scratch rebuild produces and (b) stay far under the
    # serving-tick budget (nightly: scoped_us ceiling at 20k chunks,
    # scoped_speedup floor at 2k, greuse_frac floor at 20k).
    def replan_row(n, full_repeats, scoped_repeats, n_phases=32):
        reg, graph, prof, refs, times_ = build(n, n_phases=n_phases)
        rng = random.Random(1)
        planner = Planner(mach, reg, CalibrationConstants(), DEFAULT_DRAM)
        local = planner.plan_local(graph, prof)
        glob = planner.plan_global(graph, prof)
        drift = n_phases - 1
        prof.decay(0.25, phases=[drift])
        drifted_refs = {k: v * rng.uniform(0.5, 2.0)
                        for k, v in refs[drift].items()}
        prof.observe(PhaseTraceEvent(drift, times_[drift], drifted_refs))
        prof.annotate_graph(graph)
        resplit_refs(graph, reg)

        def full_rebuild():
            # fresh planner: the cost of replanning with no standing
            # state at all (cold caches, every phase solved)
            return Planner(mach, reg, CalibrationConstants(),
                           DEFAULT_DRAM).plan(graph, prof)

        def scoped_replan():
            # production ticks each see *new* drift, so drop the
            # whole-decision memo between repeats: every repeat pays
            # the row-reuse + drifted-phase solve path, never a
            # memoized whole-plan lookup
            planner._global_memo = None
            return planner.plan(graph, prof,
                                standing=local.phase_decisions,
                                standing_global=glob.global_contribs,
                                standing_digest=local.graph_digest)

        full, best_full, _ = timed(full_rebuild, full_repeats)
        scoped, best_scoped, med_scoped = timed(scoped_replan, scoped_repeats)
        equal = (full.moves == scoped.moves
                 and full.residents == scoped.residents
                 and full.predicted_iteration_time
                 == scoped.predicted_iteration_time
                 and full.strategy == scoped.strategy)
        if not equal:   # scoped replans are bit-identical, or the run dies
            raise RuntimeError(
                f"scoped replan diverged from the full rebuild at n={n}")
        sl = planner.plan_local(graph, prof, standing=local.phase_decisions,
                                standing_digest=local.graph_digest)
        reused = sum(1 for d in sl.phase_decisions if d.reused)
        emit(f"planner_replan_n{n}", best_scoped,
             f"full_us={best_full:.0f};"
             f"scoped_us={best_scoped:.0f};"
             f"median_scoped_us={med_scoped:.0f};"
             f"scoped_speedup={best_full / best_scoped:.1f};"
             f"reused={reused}/{n_phases};"
             f"greuse_frac={scoped.global_rows_reused / n_phases:.3f};"
             f"global_mode={scoped.global_mode};"
             f"seed=0;plans_equal={equal}")

    replan_row(2000, full_repeats=3, scoped_repeats=5)
    replan_row(20000, full_repeats=2, scoped_repeats=5)
    replan_row(100000, full_repeats=1, scoped_repeats=3)    # smoke scale
    write_rows("planner_latency.csv", "planner_")


# ---------------------------------------------------------------- kernels
def bench_kernels() -> None:
    """Interpret-mode sanity timing + analytic v5e roofline per kernel."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.core.tiers import V5E_PEAK_FLOPS_BF16, V5E_HBM_BW

    key = jax.random.PRNGKey(0)
    B, K, G, S, D = 1, 2, 2, 256, 128
    q = jax.random.normal(key, (B, K, G, S, D), jnp.float32)
    kv = jax.random.normal(key, (B, K, S, D), jnp.float32)
    t0 = time.perf_counter()
    ops.flash_attention(q, kv, kv, force_pallas=True,
                        interpret=True).block_until_ready()
    us = (time.perf_counter() - t0) * 1e6
    flops = 4 * B * K * G * S * S * D
    bytes_ = 2 * (q.size + 2 * kv.size + q.size)
    emit("kernel_flash_attention", us,
         f"tpu_roofline_us="
         f"{max(flops / V5E_PEAK_FLOPS_BF16, bytes_ / V5E_HBM_BW) * 1e6:.2f}")

    x = jax.random.normal(key, (512, 1024), jnp.float32)
    w = jax.random.normal(key, (1024, 512), jnp.float32)
    t0 = time.perf_counter()
    ops.tiered_matmul(x, w, force_pallas=True,
                      interpret=True).block_until_ready()
    us = (time.perf_counter() - t0) * 1e6
    flops = 2 * 512 * 1024 * 512
    bytes_ = 2 * (x.size + w.size + 512 * 512)
    emit("kernel_tiered_matmul", us,
         f"tpu_roofline_us="
         f"{max(flops / V5E_PEAK_FLOPS_BF16, bytes_ / V5E_HBM_BW) * 1e6:.2f}")


BENCHES = {
    "fig2_3": bench_tier_sweep,
    "fig4": bench_object_placement,
    "fig9_10": bench_unimem_gap,
    "fig11": bench_ablation,
    "table4": bench_migration_stats,
    "fig12": bench_scaling,
    "fig13": bench_dram_size,
    "lm_tiering": bench_lm_tiering,
    "scenarios": bench_scenarios,
    "chaos": bench_chaos,
    "tenants": bench_tenants,
    "multihost": bench_multihost,
    "planner": bench_planner,
    "kernels": bench_kernels,
}


def main() -> None:
    global SAVE_RESULTS, SCENARIO_FILTER
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--scenario", default=None,
                    help="substring filter on scenario workload names "
                         "(scenarios/chaos benches); filtered runs never "
                         "rewrite the committed CSVs")
    ap.add_argument("--save", action="store_true",
                    help="rewrite the committed baseline CSVs under "
                         "benchmarks/results/ with this run")
    args = ap.parse_args()
    SAVE_RESULTS = args.save
    SCENARIO_FILTER = args.scenario
    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        if args.only and args.only not in name:
            continue
        fn()


if __name__ == "__main__":
    main()
