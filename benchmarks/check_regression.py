"""Benchmark regression gate (nightly CI).

Re-runs are compared row-by-row against the committed CSVs under
``benchmarks/results/``; the job fails when a watched metric regresses
beyond its tolerance.

* ``scenarios.csv`` — steady-state iteration times (virtual-time, hence
  deterministic) normalized to DRAM-only: ``fifo``/``slack`` on the base
  matrix, ``uniform``/``hotchunk`` on the skewed variants,
  ``uniform64``/``refined`` on the multi-resolution rows and ``unimem``
  on the lru-ablation rows.  Higher is worse; >5% regression fails.
  The ``_mr`` rows additionally carry absolute gates: refinement must
  keep equal-or-better slack than the uniform histogram at the same bin
  budget (``mr_gain`` floor 1.0) with fast-resident hot-head chunks
  finer than one legacy bin (``hot_chunk_frac`` ceiling 1.0).
* ``planner_latency.csv`` — the legacy/vectorized ``speedup`` ratio (wall
  clock, so machine-noisy: the ratio is compared at 50% tolerance) plus
  absolute gates: the 2,000-chunk build must stay >= 10x over the frozen
  pre-optimization reference, the 20,000-chunk scoped replan must finish
  under the 15 ms serving-tick ceiling while reusing >= 90% of the
  standing global rows (``greuse_frac``), and the 2,000-chunk scoped
  replan must stay >= 5x faster than a cold full rebuild.
* ``chaos.csv`` — the scenario matrix under the gated fault profile (5%
  transient failures + one 8x straggler channel, fixed seed).  Each
  ``scenario_*_chaos`` row must keep ``vs_faultfree`` (degraded steady
  slack over the fault-free run) at or above the 0.85 floor, and the
  tier audit must stay violation-free: ``audit_violations`` is
  ceiling-gated strictly below 1 — i.e. exactly zero.
* ``tenants.csv`` — the multi-tenant QoS row (``bench_tenants``): the
  ``bandwidth_partition`` policy against the aggregate unimem solve on
  ``tenant_serving``, per-tenant p99 slack vs DRAM-only.  ``tail_gain``
  (the worst admitted non-whale tenant's slack ratio partition/unimem)
  is floor-gated at 1.15 — partitioning must keep buying the long tail
  real p99 headroom — and ``whale_ratio`` (the whale's same ratio) at
  0.95 — without starving the whale (observed 1.27 / 0.97).
* ``multihost.csv`` — the cluster-coordination row (``bench_multihost``):
  ``moe_churn_multihost`` (4 virtual hosts, one expert shard hot past
  its host's DRAM after router churn).  ``hot_gain`` — the hot host's
  steady iteration time under host-local-only management over the
  coordinator's rebalance (surplus hot experts pulled to peers over the
  ``cross_host`` backend) — is floor-gated at 1.10, and ``cluster_gain``
  (the same ratio on the slowest host) at 1.10 (observed ~3.6 / ~3.6).

Usage::

    python -m benchmarks.check_regression --fresh fresh_scenarios.csv \
        --baseline benchmarks/results/scenarios.csv
"""

from __future__ import annotations

import argparse
import pathlib
import sys
from typing import Dict, Tuple

# watched metrics: prefix -> (keys, higher_is_worse, rel tolerance)
WATCHES = {
    "scenario_": (("fifo", "slack", "uniform", "hotchunk", "uniform64",
                   "refined", "unimem", "unimem_cal", "interval"),
                  True, 0.05),
    "planner_": (("speedup", "scoped_speedup"), False, 0.50),
}
# absolute floors: (row, key) -> minimum acceptable value
FLOORS = {
    ("planner_n2000", "speedup"): 10.0,
    # scoped replan on single-phase drift at 2k chunks must stay >=5x
    # faster than a full replan (the scoped-replan latency gate)
    ("planner_replan_n2000", "scoped_speedup"): 5.0,
    # serving-tick scoped replan at 20k chunks must keep reusing the
    # standing global rows: 31/32 phases undrifted -> 0.969 observed; a
    # drop below 0.9 means the incremental global search stopped
    # recognizing unchanged rows and is re-deriving them every tick
    ("planner_replan_n20000", "greuse_frac"): 0.9,
    # multi-resolution refinement must reach equal-or-better steady slack
    # than the uniform histogram at the same total bin budget
    ("scenario_graph_chase_skew_mr", "mr_gain"): 1.0,
    ("scenario_kv_serving_skew_mr", "mr_gain"): 1.0,
    # PR 6 acceptance: with calibration feedback on, unimem must hold
    # at-least-LRU parity on fsdp_buckets (cal_parity = lru/unimem_cal;
    # the uncalibrated model loses this row 1.406 vs 1.209)
    ("scenario_fsdp_buckets_ablation", "cal_parity"): 1.0,
    # the interval-guidance rows must keep a real speedup over NVM-only
    # (observed 1.57-1.93; 1.3 flags a broken heat ranking loudly)
    ("scenario_kv_serving_interval", "vs_nvm"): 1.3,
    ("scenario_moe_churn_interval", "vs_nvm"): 1.3,
    ("scenario_graph_chase_interval", "vs_nvm"): 1.3,
    ("scenario_fsdp_buckets_interval", "vs_nvm"): 1.3,
    ("scenario_graph_chase_skew_interval", "vs_nvm"): 1.3,
    ("scenario_kv_serving_skew_interval", "vs_nvm"): 1.3,
    ("scenario_paged_serving_interval", "vs_nvm"): 1.3,
    # chaos acceptance: under the gated fault profile every scenario must
    # hold at least 85% of its fault-free steady slack (observed
    # 0.905-1.000 at the committed seed)
    # multi-tenant QoS acceptance: bandwidth partitioning must lift the
    # worst admitted tail tenant's p99 slack >= 1.15x over the aggregate
    # solve while holding >= 95% of the whale's (observed 1.27 / 0.97)
    ("scenario_tenant_serving", "tail_gain"): 1.15,
    ("scenario_tenant_serving", "whale_ratio"): 0.95,
    # multi-host acceptance: coordinator rebalance must beat host-local-
    # only management by >= 1.10x steady time on the hot host, and on the
    # cluster's slowest host (observed ~3.6x for both at the committed
    # scenario)
    ("multihost_moe_churn", "hot_gain"): 1.10,
    ("multihost_moe_churn", "cluster_gain"): 1.10,
    ("scenario_kv_serving_chaos", "vs_faultfree"): 0.85,
    ("scenario_moe_churn_chaos", "vs_faultfree"): 0.85,
    ("scenario_graph_chase_chaos", "vs_faultfree"): 0.85,
    ("scenario_fsdp_buckets_chaos", "vs_faultfree"): 0.85,
    ("scenario_graph_chase_skew_chaos", "vs_faultfree"): 0.85,
    ("scenario_kv_serving_skew_chaos", "vs_faultfree"): 0.85,
    ("scenario_paged_serving_chaos", "vs_faultfree"): 0.85,
}
# absolute ceilings: (row, key) -> maximum acceptable value
CEILINGS = {
    # hard serving-tick latency budget: the scoped replan at 20k chunks
    # (single-phase intensity drift, 32 phases) must land strictly under
    # 15 ms on the nightly runner (observed ~7 ms best-of-5)
    ("planner_replan_n20000", "scoped_us"): 15000.0,
    # the refined hot-head chunks must stay finer than one legacy
    # (1/64-wide) histogram bin on the skew scenarios
    ("scenario_graph_chase_skew_mr", "hot_chunk_frac"): 1.0,
    ("scenario_kv_serving_skew_mr", "hot_chunk_frac"): 1.0,
    # calibrated-prediction honesty on the rows whose epochs *keep*
    # folds (a reverted epoch keeps the uncalibrated prediction and its
    # err, by design — those rows are guarded by the steady-time watch
    # and cal_parity instead).  Observed: kv 0.009, moe 0.065, fsdp
    # 0.049; the ceiling flags a model drifting back toward the
    # pre-calibration ~0.4-1.0 errors.
    ("scenario_kv_serving_ablation", "pred_err"): 0.1,
    ("scenario_moe_churn_ablation", "pred_err"): 0.25,
    ("scenario_fsdp_buckets_ablation", "pred_err"): 0.25,
    # hard zero-audit-violation gate: the ceiling check is strict
    # (value >= ceiling fails), so 1.0 admits only exactly zero
    ("scenario_kv_serving_chaos", "audit_violations"): 1.0,
    ("scenario_moe_churn_chaos", "audit_violations"): 1.0,
    ("scenario_graph_chase_chaos", "audit_violations"): 1.0,
    ("scenario_fsdp_buckets_chaos", "audit_violations"): 1.0,
    ("scenario_graph_chase_skew_chaos", "audit_violations"): 1.0,
    ("scenario_kv_serving_skew_chaos", "audit_violations"): 1.0,
    ("scenario_paged_serving_chaos", "audit_violations"): 1.0,
}


def parse(path: pathlib.Path) -> Dict[str, Dict[str, float]]:
    rows: Dict[str, Dict[str, float]] = {}
    for line in path.read_text().splitlines():
        if not line or line.startswith(("name,", "#")):
            continue
        name, _, derived = line.split(",", 2)
        metrics: Dict[str, float] = {}
        for kv in derived.split(";"):
            if "=" not in kv:
                continue
            k, v = kv.split("=", 1)
            try:
                metrics[k] = float(v.rstrip("%x"))
            except ValueError:
                pass
        rows[name] = metrics
    return rows


def check(fresh: pathlib.Path, baseline: pathlib.Path) -> int:
    fresh_rows, base_rows = parse(fresh), parse(baseline)
    failures = []
    for name, base in sorted(base_rows.items()):
        got = fresh_rows.get(name)
        if got is None:
            failures.append(f"{name}: row missing from fresh run")
            continue
        for prefix, (keys, higher_is_worse, tol) in WATCHES.items():
            if not name.startswith(prefix):
                continue
            for k in keys:
                if k not in base:
                    continue
                if k not in got:
                    failures.append(f"{name}: metric {k} missing")
                    continue
                b, f = base[k], got[k]
                if higher_is_worse and f > b * (1 + tol):
                    failures.append(
                        f"{name}: {k} regressed {b:.4f} -> {f:.4f} "
                        f"(> {tol:.0%} tolerance)")
                elif not higher_is_worse and f < b * (1 - tol):
                    failures.append(
                        f"{name}: {k} regressed {b:.4f} -> {f:.4f} "
                        f"(> {tol:.0%} tolerance)")
        for (row, k), floor in FLOORS.items():
            if name != row:
                continue
            if k not in got:    # a gated metric must not vanish silently
                failures.append(f"{name}: gated metric {k} missing")
            elif got[k] < floor:
                failures.append(
                    f"{name}: {k}={got[k]:.2f} below absolute floor {floor}")
        for (row, k), ceil in CEILINGS.items():
            if name != row:
                continue
            if k not in got:    # a gated metric must not vanish silently
                failures.append(f"{name}: gated metric {k} missing")
            # strict: reaching the ceiling already fails (hot_chunk_frac
            # == 1.0 means no chunk finer than one legacy bin)
            elif got[k] >= ceil:
                failures.append(
                    f"{name}: {k}={got[k]:.2f} at/above absolute "
                    f"ceiling {ceil}")
    for msg in failures:
        print(f"REGRESSION {msg}")
    if not failures:
        print(f"ok: {len(base_rows)} rows within tolerance "
              f"({fresh.name} vs {baseline.name})")
    return 1 if failures else 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True, type=pathlib.Path)
    ap.add_argument("--baseline", required=True, type=pathlib.Path)
    args = ap.parse_args()
    sys.exit(check(args.fresh, args.baseline))


if __name__ == "__main__":
    main()
