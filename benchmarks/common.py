"""Shared benchmark harness: DRAM-only / NVM-only / X-Men / Unimem runs."""

from __future__ import annotations

import time
from typing import Dict, Optional

from repro.core import (CalibrationConstants, RuntimeConfig, UnimemRuntime,
                        calibrate)
from repro.core.data_objects import ObjectRegistry
from repro.core.knapsack import Item, solve as knapsack_solve
from repro.core.tiers import MachineProfile
from repro.sim import SimulationEngine, SimWorkload

MB = 1024 ** 2
DEFAULT_DRAM = 256 * MB
ITERS = 12


def run_static(machine: MachineProfile, wl: SimWorkload, tier: str,
               iters: int = ITERS):
    reg = ObjectRegistry()
    for n, s in wl.objects.items():
        reg.alloc(n, s, tier=tier)
    return SimulationEngine(machine, wl, registry=reg).run(iters)


def run_unimem(machine: MachineProfile, wl: SimWorkload,
               dram_bytes: int = DEFAULT_DRAM, iters: int = ITERS,
               config: Optional[RuntimeConfig] = None,
               cf: Optional[CalibrationConstants] = None,
               mover: str = "slack", **config_kw):
    if config is not None and (mover != "slack" or config_kw):
        raise ValueError("pass mover/config knobs either via config= or as "
                         "keyword arguments, not both")
    cf = cf or calibrate(machine)
    rt = UnimemRuntime(
        machine,
        config or RuntimeConfig(fast_capacity_bytes=dram_bytes, mover=mover,
                                **config_kw), cf=cf)
    statics = wl.static_ref_counts()
    for n, s in wl.objects.items():
        rt.register(n, s, chunkable=wl.chunkable.get(n, False),
                    static_refs=statics.get(n))
    # v2 session API: no start_loop — the loop auto-starts on the first
    # iteration and phases auto-register as the engine enters them
    eng = SimulationEngine(machine, wl, runtime=rt)
    res = eng.run(iters)
    return res, rt


def run_unimem_tenants(machine: MachineProfile, wl: SimWorkload,
                       qos: Dict[str, tuple],
                       dram_bytes: int = DEFAULT_DRAM, iters: int = ITERS,
                       cf: Optional[CalibrationConstants] = None,
                       **config_kw):
    """Like :func:`run_unimem`, but declares each QoS entry as a tenant and
    registers the workload's ``tenant/``-prefixed objects through the tenant
    handles (``qos`` maps tenant -> (priority, slo))."""
    cf = cf or calibrate(machine)
    rt = UnimemRuntime(
        machine, RuntimeConfig(fast_capacity_bytes=dram_bytes, mover="slack",
                               **config_kw), cf=cf)
    handles = {t: rt.tenant(t, priority=p, slo=s)
               for t, (p, s) in qos.items()}
    statics = wl.static_ref_counts()
    for n, s in wl.objects.items():
        tenant, sep, rest = n.partition("/")
        owner = handles.get(tenant) if sep else None
        target, reg_name = (owner, rest) if owner is not None else (rt, n)
        target.register(reg_name, s, chunkable=wl.chunkable.get(n, False),
                        static_refs=statics.get(n))
    eng = SimulationEngine(machine, wl, runtime=rt)
    res = eng.run(iters)
    return res, rt


def run_xmen(machine: MachineProfile, wl: SimWorkload,
             dram_bytes: int = DEFAULT_DRAM, iters: int = ITERS):
    """X-Men baseline (Dulloor et al., EuroSys'16): offline profiling,
    static hottest-first placement; no movement-cost model, no phase
    adaptivity, homogeneous pattern per object."""
    totals = wl.static_ref_counts()
    items = [Item(n, totals.get(n, 0.0), sz) for n, sz in wl.objects.items()]
    chosen = set(knapsack_solve(items, dram_bytes))
    reg = ObjectRegistry()
    for n, s in wl.objects.items():
        reg.alloc(n, s, tier="fast" if n in chosen else "slow")
    return SimulationEngine(machine, wl, registry=reg).run(iters)


def timed(fn, *args, repeat: int = 3, **kw):
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) / repeat * 1e6   # us
