"""Per-kernel correctness: interpret-mode Pallas vs pure-jnp oracles,
swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(42)


def rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,K,G,S,T,D", [
    (1, 1, 1, 128, 128, 128),
    (2, 2, 2, 256, 256, 128),
    (1, 2, 4, 128, 384, 128),     # GQA, T > S
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(B, K, G, S, T, D, dtype, causal):
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (B, K, G, S, D), dtype)
    k = rand(ks[1], (B, K, T, D), dtype)
    v = rand(ks[2], (B, K, T, D), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, force_pallas=True,
                              interpret=True)
    gold = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gold, np.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,length", [(1024, 700), (512, 512), (2048, 1)])
def test_decode_attention(T, length, dtype):
    B, K, G, D = 2, 2, 4, 128
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (B, K, G, D), dtype)
    k = rand(ks[1], (B, K, T, D), dtype)
    v = rand(ks[2], (B, K, T, D), dtype)
    out = ops.decode_attention(q, k, v, length, force_pallas=True,
                               interpret=True)
    gold = ref.decode_attention_ref(q, k, v, length)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gold, np.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("M,Kd,N", [(256, 512, 256), (300, 700, 500),
                                    (128, 128, 128)])
def test_tiered_matmul(M, Kd, N, dtype):
    ks = jax.random.split(KEY, 2)
    x = rand(ks[0], (M, Kd), dtype) * 0.1
    w = rand(ks[1], (Kd, N), dtype) * 0.1
    out = ops.tiered_matmul(x, w, force_pallas=True, interpret=True)
    gold = ref.tiered_matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gold, np.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("S,N,P,chunk", [(512, 64, 64, 256),
                                         (300, 32, 64, 128),
                                         (256, 16, 16, 256)])
def test_ssd_scan(S, N, P, chunk, dtype):
    B, H = 2, 3
    ks = jax.random.split(KEY, 4)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, H, S)))
    k = rand(ks[1], (B, H, S, N), dtype) * 0.3
    v = rand(ks[2], (B, H, S, P), dtype) * 0.3
    q = rand(ks[3], (B, H, S, N), dtype) * 0.3
    out = ops.ssd_scan(a, k, v, q, chunk=chunk, force_pallas=True,
                       interpret=True)
    gold = ref.ssd_scan_ref(a, k, v, q)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gold, np.float32),
                               rtol=1e-4, atol=1e-4)


def test_chunked_attention_matches_ref():
    """The model's jnp flash path equals the naive oracle too."""
    from repro.models.attention import chunked_attention
    B, S, H, D, K = 2, 256, 8, 64, 4
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (B, S, H, D), jnp.float32)
    k = rand(ks[1], (B, S, K, D), jnp.float32)
    v = rand(ks[2], (B, S, K, D), jnp.float32)
    out = chunked_attention(q, k, v, causal=True, chunk=64, q_chunk=128)
    qr = q.reshape(B, S, K, H // K, D).transpose(0, 2, 3, 1, 4)
    kr = k.transpose(0, 2, 1, 3)                  # (B, K, T, D)
    vr = v.transpose(0, 2, 1, 3)
    gold = ref.flash_attention_ref(qr, kr, vr, causal=True)
    gold = gold.transpose(0, 3, 1, 2, 4).reshape(B, S, H, D)
    np.testing.assert_allclose(out, gold, rtol=2e-5, atol=2e-5)
