"""Seeded property-check fallback for environments without ``hypothesis``.

Provides ``given`` / ``settings`` decorators and an ``st`` strategy
namespace that are call-compatible with the subset of the hypothesis API
the test-suite uses (``integers``, ``floats``, ``lists``, ``tuples``).
Cases are generated from a fixed-seed RNG, with boundary values injected
first, so runs are deterministic and edge cases are always exercised.

Usage in a test module::

    try:
        import hypothesis.strategies as st
        from hypothesis import given, settings
    except ImportError:                      # fallback shim
        from _propcheck import st, given, settings
"""

from __future__ import annotations

import functools
import inspect
import random
import types
from typing import Any, Callable, List, Optional

_SEED = 0x5EEDED


class Strategy:
    """A value generator: ``example(rng, i)`` draws case ``i``."""

    def __init__(self, draw: Callable[[random.Random], Any],
                 boundaries: Optional[List[Any]] = None):
        self._draw = draw
        self.boundaries = boundaries or []

    def example(self, rng: random.Random, i: int) -> Any:
        if i < len(self.boundaries):
            return self.boundaries[i]
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> Strategy:
    return Strategy(lambda rng: rng.randint(min_value, max_value),
                    boundaries=[min_value, max_value])


def floats(min_value: float, max_value: float) -> Strategy:
    bounds = [min_value, max_value]
    if min_value <= 0.0 <= max_value:
        bounds.append(0.0)
    return Strategy(lambda rng: rng.uniform(min_value, max_value),
                    boundaries=bounds)


def lists(elements: Strategy, min_size: int = 0,
          max_size: int = 10) -> Strategy:
    def draw(rng: random.Random):
        n = rng.randint(min_size, max_size)
        return [elements.example(rng, len(elements.boundaries))
                for _ in range(n)]
    bounds: List[Any] = []
    if min_size == 0:
        bounds.append([])
    bounds.append([elements.example(random.Random(_SEED), i)
                   for i in range(min(max(min_size, 1), 3))])
    return Strategy(draw, boundaries=bounds)


def tuples(*element_strategies: Strategy) -> Strategy:
    def draw(rng: random.Random):
        return tuple(s.example(rng, len(s.boundaries))
                     for s in element_strategies)
    return Strategy(draw)


st = types.SimpleNamespace(integers=integers, floats=floats, lists=lists,
                           tuples=tuples)


def settings(max_examples: int = 100, deadline: Any = None,
             **_ignored: Any) -> Callable:
    """Records ``max_examples`` on the test function for ``given``."""
    def deco(fn: Callable) -> Callable:
        fn._propcheck_max_examples = max_examples
        return fn
    return deco


def given(**strategies: Strategy) -> Callable:
    """Runs the test once per generated case (boundary cases first)."""
    def deco(fn: Callable) -> Callable:
        n_examples = getattr(fn, "_propcheck_max_examples", 100)

        @functools.wraps(fn)
        def wrapper(*args: Any, **kw: Any) -> None:
            rng = random.Random(_SEED)
            for i in range(n_examples):
                drawn = {name: s.example(rng, i)
                         for name, s in strategies.items()}
                try:
                    fn(*args, **drawn, **kw)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (case {i}): {drawn!r}") from e

        # hide the generated parameters from pytest's fixture resolution
        params = [p for name, p in inspect.signature(fn).parameters.items()
                  if name not in strategies]
        wrapper.__signature__ = inspect.Signature(params)
        return wrapper
    return deco
