"""Multi-resolution histogram invariants (PR 5).

Covers the ISSUE-5 test checklist:

* mass conservation under refine / coarsen / decay round-trips;
* legacy-uniform parity: uniform histograms integrate bit-identically to
  the fixed-width ``bin_mass`` arithmetic, and with refinement off the
  end-to-end plans are bit-identical to the PR 4 pipeline (cross-PR
  golden digests captured from the pre-multi-res code);
* re-split-after-coalesce regression: a merged chunk re-splits below the
  old coarse ceiling when drift re-heats it;
* scoped-vs-full replan equality with a histogram-resolution drift inside
  the scope;
* the multi-res payoff: refined runs reach equal-or-better steady slack
  with hot-head chunks finer than one legacy bin at the same bin budget;
* ``profiler.decay(phases=...)`` on never-observed phases is a documented
  no-op.
"""

import hashlib
import json

import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                      # pragma: no cover - fallback shim
    from _propcheck import st, given, settings

from repro.core import (PAPER_DRAM_NVM, Histogram, PhaseProfiler,
                        RuntimeConfig, UnimemRuntime, build_phase_graph,
                        calibrate, uniform_mass)
from repro.core.data_objects import DataObject, ObjectRegistry
from repro.core.partition import (auto_partition, bin_mass, chunk_spans,
                                  coalesce_chunks, resplit_hot_chunks,
                                  resplit_refs, skew_boundaries)
from repro.core.phase import PhaseTraceEvent
from repro.sim import SimulationEngine
from repro.sim.workloads import (graph_chase_skewed, kv_serving_skewed,
                                 power_law_density)

MB = 1024 ** 2
M = PAPER_DRAM_NVM


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# mass conservation: refine / coarsen / decay round-trips
# ---------------------------------------------------------------------------
@given(seed=st.integers(0, 200))
@settings(max_examples=40, deadline=None)
def test_refine_coarsen_decay_conserve_mass(seed):
    rng = _rng(seed)
    n = int(rng.integers(4, 257))
    h = Histogram.uniform(n, rng.random(n) ** 3 * 100.0)
    total = h.total
    budget = int(rng.integers(2, 129))
    for _ in range(4):                          # repeated refinement rounds
        h = h.refined(budget, min_width=1.0 / 4096)
        assert h.n_bins <= max(budget, 1) or h.n_bins <= n
        assert h.total == pytest.approx(total, rel=1e-9)
        assert h.edges[0] == 0.0 and h.edges[-1] == 1.0
        assert np.all(np.diff(h.edges) > 0.0)
    factor = float(rng.uniform(0.0, 1.0))
    h2 = h.scaled(factor)
    assert h2.total == pytest.approx(total * factor, rel=1e-9)
    assert h2.same_edges(h)                     # decay never moves edges


@given(seed=st.integers(0, 100))
@settings(max_examples=30, deadline=None)
def test_rebinned_conserves_mass_and_partition_sums_to_one(seed):
    rng = _rng(seed)
    n = int(rng.integers(2, 65))
    h = Histogram.uniform(n, rng.random(n) * 10.0)
    cuts = np.sort(rng.random(int(rng.integers(1, 12))))
    edges = np.concatenate([[0.0], np.unique(cuts), [1.0]])
    h2 = h.rebinned(edges)
    assert h2.total == pytest.approx(h.total, rel=1e-9)
    # any partition of [0, 1] integrates to the full mass
    masses = [h.mass_fraction(lo, hi) for lo, hi in zip(edges[:-1], edges[1:])]
    assert sum(masses) == pytest.approx(1.0, rel=1e-9)


def test_refined_budget_and_fixed_point():
    w = np.array(power_law_density(256, 1.5))
    h = Histogram.uniform(256, w * 1e4)
    r = h.refined(32)
    assert r.n_bins <= 32
    # hot head resolved finer than the cold tail
    assert r.widths[0] < r.widths[-1]
    # refinement converges: a fixed point is reached, not endless churn
    prev = r
    for _ in range(10):
        nxt = prev.refined(32)
        if nxt is prev:
            break
        prev = nxt
    assert prev.refined(32) is prev


def test_refined_empty_and_degenerate():
    h = Histogram.uniform(8)
    assert h.refined(4) is h                    # no mass: nothing to adapt
    h2 = Histogram.uniform(1, [5.0])
    assert h2.refined(0) is h2


# ---------------------------------------------------------------------------
# legacy-uniform parity
# ---------------------------------------------------------------------------
@given(seed=st.integers(0, 300))
@settings(max_examples=60, deadline=None)
def test_uniform_histogram_mass_bitwise_matches_legacy(seed):
    rng = _rng(seed)
    n = int(rng.integers(1, 65))
    counts = rng.random(n) * 50.0
    h = Histogram.uniform(n, counts)
    lo, hi = sorted(rng.uniform(-0.1, 1.1, size=2))
    # the legacy flow normalized the counts (old bin_weights) before
    # integrating — bitwise equality, not approx
    t = float(counts.sum())
    legacy = uniform_mass(counts / t, lo, hi)
    assert h.mass_fraction(lo, hi) == legacy
    assert bin_mass(h, lo, hi) == legacy


@given(seed=st.integers(0, 100))
@settings(max_examples=30, deadline=None)
def test_project_native_uniform_is_legacy_probability_vector(seed):
    rng = _rng(seed)
    n = int(rng.integers(1, 65))
    w = rng.random(n)
    target = Histogram.uniform(n)
    p = target.project(list(w))
    wc = np.clip(np.asarray(w, dtype=np.float64), 0.0, None)
    assert np.array_equal(p, wc / wc.sum())


def test_variable_width_mass_fraction_manual():
    h = Histogram([0.0, 0.25, 0.5, 1.0], [1.0, 1.0, 2.0])
    assert h.mass_fraction(0.0, 1.0) == pytest.approx(1.0)
    assert h.mass_fraction(0.0, 0.25) == pytest.approx(0.25)
    assert h.mass_fraction(0.5, 1.0) == pytest.approx(0.5)
    assert h.mass_fraction(0.5, 0.75) == pytest.approx(0.25)   # half of bin 3
    assert h.mass_fraction(0.125, 0.375) == pytest.approx(0.25)
    assert h.finest_width(0.0, 1.0) == 0.25
    assert h.finest_width(0.6, 1.0) == 0.5


# the PR 4 pipeline's plans, captured from the pre-multi-res code: a
# canonical digest over (strategy, residents, moves, predicted/baseline
# times, schedule), the steady virtual-time iteration time, and the final
# chunk count — refinement off must reproduce all three bit-identically
PR4_GOLDENS = {
    "graph_chase_skew": ("25061f969737e506", 1.5490051191497485, 93),
    "kv_serving_skew": ("72a7b192d1f10eda", 0.9166160486399996, 40),
}


def _plan_digest(plan):
    d = dict(strategy=plan.strategy,
             residents=[sorted(r) for r in plan.residents],
             moves=[(m.obj, m.dst, m.trigger_phase, m.needed_by, m.size_bytes,
                     m.est_unhidden_cost, m.est_benefit) for m in plan.moves],
             predicted=plan.predicted_iteration_time,
             baseline=plan.baseline_iteration_time,
             schedule=[(s.op.obj, s.window_s, s.duration_s, s.slack_s)
                       for s in plan.schedule])
    return hashlib.sha256(json.dumps(d, sort_keys=True).encode()) \
        .hexdigest()[:16]


@pytest.mark.parametrize("name,make", [
    ("graph_chase_skew", graph_chase_skewed),
    ("kv_serving_skew", kv_serving_skewed),
])
def test_refinement_off_is_bit_identical_to_pr4(name, make):
    mach = PAPER_DRAM_NVM.scaled(bw_scale=0.5, lat_scale=2.0)
    wl = make()
    rt = UnimemRuntime(mach, RuntimeConfig(fast_capacity_bytes=256 * MB,
                                           drift_threshold=10.0),
                       cf=calibrate(mach))
    statics = wl.static_ref_counts()
    for n, s in wl.objects.items():
        rt.register(n, s, chunkable=wl.chunkable.get(n, False),
                    static_refs=statics.get(n))
    res = SimulationEngine(mach, wl, runtime=rt).run(8)
    digest, steady, n_chunks = PR4_GOLDENS[name]
    assert _plan_digest(rt.plan) == digest
    assert res.steady_iteration_time == steady
    assert sum(1 for o in rt.registry if o.parent is not None) == n_chunks


# ---------------------------------------------------------------------------
# profiler integration: budgets, refinement epochs, scoped refinement
# ---------------------------------------------------------------------------
def test_profiler_budget_projects_native_bins():
    prof = PhaseProfiler(M, seed=3, hist_bins=16)
    truth = power_law_density(64, 1.4)          # native finer than budget
    for _ in range(6):
        prof.observe(PhaseTraceEvent(0, 0.4, {"a": 1e6},
                                     access_bins={"a": truth}))
    h = prof.profile(0, "a").bin_weights
    assert h is not None and h.n_bins == 16
    # projected masses still track the true distribution
    t = Histogram.from_weights(truth)
    for i in range(16):
        assert h.mass_fraction(i / 16, (i + 1) / 16) == pytest.approx(
            t.mass_fraction(i / 16, (i + 1) / 16), abs=0.05)


def test_refine_histograms_bumps_versions_and_scopes():
    prof = PhaseProfiler(M, seed=5, hist_bins=16, hist_refine=True)
    truth = power_law_density(256, 1.6)
    for ph in (0, 1):
        prof.observe(PhaseTraceEvent(ph, 0.4, {"a": 1e7},
                                     access_bins={"a": truth}))
    v0, v1 = prof.phase_version(0), prof.phase_version(1)
    epoch0 = prof.hist_epoch
    other = prof.profile(1, "a").bin_counts
    changed = prof.refine_histograms(16, phases=[0])
    assert changed == [0]
    assert prof.phase_version(0) != v0           # resolution joins the key
    assert prof.phase_version(1) == v1           # out of scope: untouched
    assert prof.profile(1, "a").bin_counts is other
    assert prof.hist_epoch == epoch0 + 1
    # next observation accumulates at the refined resolution
    prof.observe(PhaseTraceEvent(0, 0.4, {"a": 1e7},
                                 access_bins={"a": truth}))
    h = prof.profile(0, "a").bin_counts
    assert not h.is_uniform and h.n_bins <= 16


def test_decay_on_unobserved_phase_is_noop():
    """Regression (ISSUE 5 satellite): decaying a phase observed zero
    times must be a silent no-op — nothing raises, nothing changes."""
    prof = PhaseProfiler(M, seed=0)
    prof.observe(PhaseTraceEvent(0, 0.1, {"a": 500.0}))
    before = prof.profile(0, "a").weight
    v = prof.phase_version(0)
    prof.decay(0.25, phases=[7])                 # never observed
    prof.decay(0.25, phases=7)                   # bare int accepted
    prof.decay(0.25, phases=[])                  # empty scope
    assert prof.profile(0, "a").weight == before
    assert prof.phase_version(0) == v
    empty = PhaseProfiler(M, seed=0)
    empty.decay(0.5, phases=[0, 1, 2])           # nothing accumulated at all
    assert empty.epoch == 0                      # scoped decay: no new epoch


# ---------------------------------------------------------------------------
# partitioning: local floors, re-split after coalesce
# ---------------------------------------------------------------------------
def test_skew_boundaries_local_floor_cuts_below_legacy_bin():
    size = 640 * MB
    w = np.zeros(256)
    w[40] = 100.0                                # one sharp 2.5 MB hot spot
    w += 0.1
    refined = Histogram.from_weights(w).refined(64)
    coarse = 64 * MB
    legacy = skew_boundaries(size, [Histogram.from_weights(w).rebinned(
        np.arange(65) / 64)], coarse_bytes=coarse,
        min_chunk_bytes=max(coarse // 16, 1))
    mr = skew_boundaries(size, [refined], coarse_bytes=coarse,
                         min_chunk_bytes=max(coarse // 64, 1),
                         local_floor=True)
    legacy_widths = np.diff([0] + legacy)
    mr_widths = np.diff([0] + mr)
    legacy_bin = size / 64
    assert legacy_widths.min() >= legacy_bin     # the old one-bin ceiling
    assert mr_widths.min() < legacy_bin          # multi-res cuts below it
    assert sum(mr_widths) == size


def _observe_density(prof, phase, obj, weights, n=4, access=1e7):
    for _ in range(n):
        prof.observe(PhaseTraceEvent(phase, 0.3, {obj: access},
                                     access_bins={obj: list(weights)}))


def test_merged_chunk_resplits_when_drift_reheats_it():
    """ISSUE 5 regression: coalesce merges converged-cold chunks; when
    drift re-heats a region inside the merged chunk, the refined
    histograms + re-split pass cut it back apart — below the old coarse
    ceiling — which the pre-multi-res pipeline could never do."""
    size = 320 * MB
    cap = 128 * MB
    reg = ObjectRegistry()
    reg.alloc("big", size, chunkable=True)
    graph = build_phase_graph([("p0", {"big": 1e7})], times=[0.3])
    prof = PhaseProfiler(M, seed=11, hist_bins=64, hist_refine=True)

    # phase 1 of life: hot head, cold tail -> skew partition + coalesce
    w = np.ones(256) * 0.05
    w[:32] = 8.0
    _observe_density(prof, 0, "big", w)
    prof.annotate_graph(graph)
    auto_partition(reg, graph, cap, profiler=prof, multi_res=True)
    coalesce_chunks(reg, graph, prof, cap)
    spans = chunk_spans(reg, "big")
    assert len(spans) >= 2
    tail = spans[-1]
    tail_width = tail[2] - tail[1]
    assert tail_width > cap // 8                 # cold tail merged coarse

    # drift: a sharp hot spot re-heats the middle of the merged tail
    prof.decay(0.05)
    prof.refine_histograms(64)
    w2 = np.ones(256) * 0.05
    mid_bin = int((tail[1] + tail_width // 2) / size * 256)
    w2[mid_bin] = 50.0
    _observe_density(prof, 0, "big", w2, n=3)
    prof.refine_histograms(64)
    _observe_density(prof, 0, "big", w2, n=3)
    prof.annotate_graph(graph)
    resplit_refs(graph, reg, prof)
    total_refs = sum(graph[0].refs.get(c.name, 0.0)
                     for c, _, _ in chunk_spans(reg, "big"))

    # leaf-aligned mode: re-splitting would cut inside leaves — no-op
    assert resplit_hot_chunks(reg, graph, prof, cap, leaf_aligned=True) == {}
    changed = resplit_hot_chunks(reg, graph, prof, cap)
    assert "big" in changed
    before, after = changed["big"]
    assert after > before
    spans2 = chunk_spans(reg, "big")
    # the re-heated region is now isolated finer than the merged tail —
    # and below the legacy one-bin ceiling of the original partition
    hot_lo = mid_bin / 256 * size
    hot = [c for c, lo, hi in spans2 if lo <= hot_lo < hi]
    assert hot and hot[0].size_bytes < tail_width
    assert min(hi - lo for _, lo, hi in spans2) < size / 64
    # per-phase references conserved exactly across the re-split
    total2 = sum(graph[0].refs.get(c.name, 0.0) for c, _, _ in spans2)
    assert total2 == pytest.approx(total_refs, rel=1e-9)
    # chunk bytes and indices stay a partition of the parent
    assert sum(c.size_bytes for c, _, _ in spans2) == size
    assert [c.chunk_index for c, _, _ in spans2] == list(range(len(spans2)))


# ---------------------------------------------------------------------------
# scoped-vs-full replan equality with resolution drift in scope
# ---------------------------------------------------------------------------
def test_scoped_replan_equals_full_under_resolution_drift():
    from repro.core import CalibrationConstants, Planner

    mach = PAPER_DRAM_NVM.scaled(bw_scale=0.5)
    reg = ObjectRegistry()
    n_parents, per = 4, 8
    for p in range(n_parents):
        for k in range(per):
            reg.register(DataObject(name=f"par{p}#{k}", size_bytes=4 * MB,
                                    parent=f"par{p}", chunk_index=k))
    refs = [{f"par{p}": 1e6 * (p + 1) for p in range(n_parents)
             if (p + i) % 2 == 0} for i in range(6)]
    times = [0.05 + 0.01 * i for i in range(6)]
    graph = build_phase_graph([(f"ph{i}", r) for i, r in enumerate(refs)],
                              times=times)
    prof = PhaseProfiler(mach, seed=2, hist_bins=32, hist_refine=True)
    truth = power_law_density(128, 1.3, seed=5)
    for i, r in enumerate(refs):
        prof.observe(PhaseTraceEvent(i, times[i], dict(r),
                                     access_bins={o: truth for o in r}))
    prof.annotate_graph(graph)
    resplit_refs(graph, reg, prof)
    planner = Planner(mach, reg, CalibrationConstants(), 64 * MB,
                      enact_consistent=True)
    local = planner.plan_local(graph, prof)
    glob = planner.plan_global(graph, prof)

    # drift scoped to phase 3 INCLUDING a histogram resolution change
    prof.decay(0.25, phases=[3])
    prof.refine_histograms(32, phases=[3])
    prof.observe(PhaseTraceEvent(3, times[3],
                                 {o: v * 1.7 for o, v in refs[3].items()},
                                 access_bins={o: truth for o in refs[3]}))
    prof.annotate_graph(graph)
    resplit_refs(graph, reg, prof)

    full = planner.plan(graph, prof)
    scoped = planner.plan(graph, prof, standing=local.phase_decisions,
                          standing_global=glob.global_contribs,
                          standing_digest=local.graph_digest)
    assert full.moves == scoped.moves
    assert full.residents == scoped.residents
    assert full.predicted_iteration_time == scoped.predicted_iteration_time
    assert full.strategy == scoped.strategy
    # the resolution change joined the fingerprint: phase 3 re-solved
    sl = planner.plan_local(graph, prof, standing=local.phase_decisions,
                            standing_digest=local.graph_digest)
    assert not sl.phase_decisions[3].reused


# ---------------------------------------------------------------------------
# the multi-res payoff, end to end
# ---------------------------------------------------------------------------
def _run_mr(wl, refine):
    mach = PAPER_DRAM_NVM.scaled(bw_scale=0.5, lat_scale=2.0)
    rt = UnimemRuntime(mach, RuntimeConfig(
        fast_capacity_bytes=256 * MB, drift_threshold=10.0,
        chunk_aware=True, histogram_bins=64, profile_iterations=3,
        histogram_refine=refine, enable_global_search=False),
        cf=calibrate(mach))
    statics = wl.static_ref_counts()
    for n, s in wl.objects.items():
        rt.register(n, s, chunkable=wl.chunkable.get(n, False),
                    static_refs=statics.get(n))
    res = SimulationEngine(mach, wl, runtime=rt).run(12)
    return res, rt


def test_refined_hot_head_chunks_below_one_legacy_bin_at_equal_slack():
    wl = graph_chase_skewed(density_bins=256)
    uni, _ = _run_mr(wl, refine=False)
    ref, rrt = _run_mr(wl, refine=True)
    # equal-or-better steady slack at the same total bin budget
    assert ref.steady_iteration_time <= uni.steady_iteration_time * 1.001
    # hot-head chunks finer than one legacy (1/64) bin, fast-resident
    for par in ("adjA", "adjB"):
        spans = chunk_spans(rrt.registry, par)
        size = spans[-1][2]
        fast = [c.size_bytes for c, _, _ in spans if c.tier == "fast"]
        assert fast and min(fast) < size / 64


def test_native_mode_resolution_change_resets_accumulation():
    """Legacy native mode: a source that raises its attribution
    resolution mid-run must reset accumulation at the new resolution
    (the pre-multi-res behavior) — not have the finer truth forever
    projected onto the stale coarse edges."""
    prof = PhaseProfiler(M, seed=9)               # hist_bins=None: native
    coarse = [1.0] * 8
    for _ in range(3):
        prof.observe(PhaseTraceEvent(0, 0.2, {"a": 1e6},
                                     access_bins={"a": coarse}))
    assert prof.profile(0, "a").bin_counts.n_bins == 8
    fine = power_law_density(64, 1.5)
    prof.observe(PhaseTraceEvent(0, 0.2, {"a": 1e6},
                                 access_bins={"a": fine}))
    h = prof.profile(0, "a").bin_counts
    assert h.n_bins == 64                         # reset to the new native
    # refined (non-uniform) histograms keep their adapted edges instead
    prof2 = PhaseProfiler(M, seed=9, hist_bins=16, hist_refine=True)
    for _ in range(2):
        prof2.observe(PhaseTraceEvent(0, 0.2, {"a": 1e7},
                                      access_bins={"a": fine}))
    prof2.refine_histograms(16)
    edges = prof2.profile(0, "a").bin_counts.edges
    prof2.observe(PhaseTraceEvent(0, 0.2, {"a": 1e7},
                                  access_bins={"a": fine}))
    assert np.array_equal(prof2.profile(0, "a").bin_counts.edges, edges)
