"""Placement-policy pipeline: registry, PlanProgram IR round-trip,
per-stage properties (coalesce conservation, leaf alignment), scoped
replanning (plan equality + reuse), and the old-vs-new parity goldens."""

import dataclasses
import random

import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                      # no hypothesis: seeded shim
    from _propcheck import st, given, settings

from repro.core import (CalibrationConstants, PAPER_DRAM_NVM, PhaseProfiler,
                        Planner, PlanProgram, RuntimeConfig, UnimemPolicy,
                        UnimemRuntime, available_policies, build_phase_graph,
                        calibrate, make_policy, register_policy)
from repro.core import partition as partition_mod
from repro.core.data_objects import DataObject, ObjectRegistry
from repro.core.partition import (auto_partition, chunk_spans,
                                  coalesce_chunks, resplit_refs,
                                  snap_to_leaf_boundaries)
from repro.core.phase import PhaseTraceEvent
from repro.core.planner import _WindowIndex, graph_digest
from repro.core.policy import STAGE_NAMES, solve_best
from repro.sim import (SCENARIO_WORKLOADS, SKEWED_SCENARIO_WORKLOADS,
                       SimulationEngine, power_law_density)
from repro.sim.engine import SimPhaseSpec, SimSource
from repro.sim.workloads import SimWorkload

MB = 1024 ** 2
M = PAPER_DRAM_NVM.scaled(bw_scale=0.5, lat_scale=2.0)
CF = calibrate(M)

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


# ---------------------------------------------------------------------------
# harness
# ---------------------------------------------------------------------------
def run_scenario(wl, *, config=None, iters=8, runtime_cls=UnimemRuntime):
    rt = runtime_cls(
        M, config or RuntimeConfig(fast_capacity_bytes=256 * MB,
                                   mover="slack", drift_threshold=10.0),
        cf=CF)
    statics = wl.static_ref_counts()
    for n, s in wl.objects.items():
        rt.register(n, s, chunkable=wl.chunkable.get(n, False),
                    static_refs=statics.get(n))
    res = SimulationEngine(M, wl, runtime=rt).run(iters)
    return res, rt


def build_chunk_fixture(n_objs, n_phases=12, seed=0):
    """The planner-latency fixture: N chunks over 10 partitioned parents
    with parent-level profiles (the chunk-attribution hot path)."""
    rng = random.Random(seed)
    reg = ObjectRegistry()
    per = n_objs // 10
    for p in range(10):
        for k in range(per):
            reg.register(DataObject(
                name=f"par{p}#{k}", size_bytes=rng.randint(1, 4) * MB,
                parent=f"par{p}", chunk_index=k))
    refs, times = [], []
    for _ in range(n_phases):
        r = {f"par{p}": rng.uniform(1e5, 1e7) for p in range(10)
             if rng.random() < 0.7}
        refs.append(r)
        times.append(rng.uniform(0.01, 0.2))
    graph = build_phase_graph(
        [(f"ph{i}", rr) for i, rr in enumerate(refs)], times=times)
    prof = PhaseProfiler(M, seed=seed)
    for i, rr in enumerate(refs):
        prof.observe(PhaseTraceEvent(i, times[i], dict(rr)))
    prof.annotate_graph(graph)
    resplit_refs(graph, reg)
    return reg, graph, prof, refs, times


def plans_equal(a, b) -> bool:
    return (a.moves == b.moves and a.residents == b.residents
            and a.predicted_iteration_time == b.predicted_iteration_time
            and a.strategy == b.strategy)


# ---------------------------------------------------------------------------
# policy registry
# ---------------------------------------------------------------------------
def test_policy_registry_contents():
    assert "unimem" in available_policies()
    assert isinstance(make_policy("unimem"), UnimemPolicy)


def test_unknown_policy_raises_with_listing():
    with pytest.raises(ValueError, match="unimem"):
        make_policy("no_such_policy")


def test_policy_reregistration_guard():
    with pytest.raises(ValueError, match="already registered"):
        register_policy("unimem", lambda **_: UnimemPolicy())


def test_custom_policy_through_config():
    """A registered custom policy is selected by RuntimeConfig.policy and
    drives the session end to end (here: the pipeline minus coalescing,
    with a reordered stage tuple)."""
    from repro.core.policy import (stage_attribute, stage_partition,
                                   stage_schedule, stage_solve)

    class NoCoalescePolicy(UnimemPolicy):
        name = "test_no_coalesce"
        stages = (stage_attribute, stage_partition, stage_solve,
                  stage_schedule)

    register_policy("test_no_coalesce", lambda **_: NoCoalescePolicy(),
                    overwrite=True)
    wl = SCENARIO_WORKLOADS["kv_serving"]()
    res, rt = run_scenario(wl, config=RuntimeConfig(
        fast_capacity_bytes=256 * MB, mover="slack", drift_threshold=10.0,
        policy="test_no_coalesce"))
    assert rt.plan is not None
    assert rt.plan.policy == "test_no_coalesce"
    assert [p.stage for p in rt.plan.provenance] == [
        "attribute", "partition", "solve", "schedule"]


def test_unimem_pipeline_records_five_stages():
    wl = SKEWED_SCENARIO_WORKLOADS["kv_serving_skew"]()
    _, rt = run_scenario(wl)
    assert isinstance(rt.plan, PlanProgram)
    assert tuple(p.stage for p in rt.plan.provenance) == STAGE_NAMES
    # provenance pins what produced the decisions
    assert rt.plan.profile_epoch == rt.profiler.epoch
    assert rt.plan.chunk_generation == rt.registry.generation
    assert rt.plan.capacity_bytes == 256 * MB


# ---------------------------------------------------------------------------
# PlanProgram IR: serialization round-trip
# ---------------------------------------------------------------------------
def test_plan_program_json_round_trip():
    wl = SKEWED_SCENARIO_WORKLOADS["kv_serving_skew"]()
    _, rt = run_scenario(wl)
    prog = rt.plan
    back = PlanProgram.from_json(prog.to_json())
    assert back.strategy == prog.strategy
    assert back.moves == prog.moves
    assert back.residents == prog.residents
    assert back.schedule == prog.schedule
    assert back.predicted_iteration_time == prog.predicted_iteration_time
    assert back.policy == prog.policy
    assert back.provenance == prog.provenance
    assert back.capacity_bytes == prog.capacity_bytes
    assert back.graph_digest == prog.graph_digest
    assert len(back.phase_decisions) == len(prog.phase_decisions)
    for a, b in zip(back.phase_decisions, prog.phase_decisions):
        assert a == b                     # entry/exit/fingerprint/moves
        assert a.benefits == b.benefits
    assert len(back.global_contribs) == len(prog.global_contribs)
    for a, b in zip(back.global_contribs, prog.global_contribs):
        assert a.version == b.version and a.objs == b.objs
        assert np.array_equal(a.row, b.row)


def test_deserialized_program_drives_scoped_replan():
    """The IR is the standing state: a program that went through JSON can
    be re-solved against with full reuse and a bit-identical result."""
    reg, graph, prof, _, _ = build_chunk_fixture(200)
    planner = Planner(M, reg, CalibrationConstants(), 256 * MB)
    local = planner.plan_local(graph, prof)
    glob = planner.plan_global(graph, prof)
    prog = PlanProgram.from_plan(
        local, policy="unimem", provenance=[], profile_epoch=prof.epoch,
        chunk_generation=reg.generation, capacity_bytes=256 * MB,
        phase_decisions=local.phase_decisions,
        global_contribs=glob.global_contribs,
        graph_digest=local.graph_digest)
    back = PlanProgram.from_json(prog.to_json())
    replan = planner.plan_local(graph, prof,
                                standing=back.phase_decisions,
                                standing_digest=back.graph_digest)
    assert plans_equal(replan, local)
    assert all(d.reused for d in replan.phase_decisions)


# ---------------------------------------------------------------------------
# coalesce stage: conservation + acceptance
# ---------------------------------------------------------------------------
@given(seed=st.integers(0, 120))
@settings(max_examples=20, deadline=None)
def test_coalesce_conserves_refs_and_bytes(seed):
    """Property: coalescing preserves the parent's total size, keeps every
    merged chunk within the coarse ceiling, never increases the chunk
    count, and conserves per-phase attributed references exactly."""
    rng = random.Random(seed)
    reg = ObjectRegistry()
    size = rng.randint(280, 600) * MB       # always exceeds the 256 MB tier
    reg.alloc("big", size, chunkable=True)
    n_phases = rng.randint(1, 4)
    graph = build_phase_graph(
        [(f"p{i}", {"big": rng.uniform(1e5, 1e7)}) for i in range(n_phases)],
        times=[0.1] * n_phases)
    prof = PhaseProfiler(M, seed=seed)
    for i in range(n_phases):
        # piecewise density: some adjacent-equal regions -> mergeable runs
        w = []
        while len(w) < 64:
            w.extend([rng.choice([0.0, 0.1, 1.0, 4.0])] * rng.randint(2, 10))
        prof.observe(PhaseTraceEvent(i, 0.1, {"big": graph[i].refs["big"]},
                                     access_bins={"big": w[:64]}))
    prof.annotate_graph(graph)
    cap = 256 * MB
    auto_partition(reg, graph, cap, profiler=prof)
    before = chunk_spans(reg, "big")
    refs_before = [sum(graph[i].refs.get(c.name, 0.0) for c, _, _ in before)
                   for i in range(n_phases)]
    merged = coalesce_chunks(reg, graph, prof, cap)
    after = chunk_spans(reg, "big")
    assert sum(c.size_bytes for c, _, _ in after) == size
    assert len(after) <= len(before)
    assert max(c.size_bytes for c, _, _ in after) <= cap // 4
    for i in range(n_phases):
        got = sum(graph[i].refs.get(c.name, 0.0) for c, _, _ in after)
        assert got == pytest.approx(refs_before[i], rel=1e-9)
    if merged:
        b, a = merged["big"]
        assert (b, a) == (len(before), len(after)) and a < b


def test_coalesce_requires_tier_agreement():
    """Chunks in different tiers never merge (a merged chunk has exactly
    one residency)."""
    reg = ObjectRegistry()
    graph = build_phase_graph([("p0", {})], times=[0.1])
    prof = PhaseProfiler(M, seed=0)
    prof.observe(PhaseTraceEvent(0, 0.1, {"big": 1e6},
                                 access_bins={"big": [1.0] * 8}))
    for k in range(4):
        reg.register(DataObject(name=f"big#{k}", size_bytes=10 * MB,
                                parent="big", chunk_index=k,
                                tier="fast" if k < 2 else "slow"))
    merged = coalesce_chunks(reg, graph, prof, 256 * MB)
    spans = chunk_spans(reg, "big")
    assert len(spans) == 2                      # fast pair + slow pair
    assert [c.tier for c, _, _ in spans] == ["fast", "slow"]
    assert merged == {"big": (4, 2)}


def test_coalesce_keeps_density_edges():
    """Hot and cold regions with distinct measured densities stay
    separate chunks."""
    reg = ObjectRegistry()
    graph = build_phase_graph([("p0", {})], times=[0.1])
    prof = PhaseProfiler(M, seed=0)
    bins = [4.0] * 4 + [0.0] * 4
    prof.observe(PhaseTraceEvent(0, 0.1, {"big": 1e6},
                                 access_bins={"big": bins}))
    for k in range(8):
        reg.register(DataObject(name=f"big#{k}", size_bytes=8 * MB,
                                parent="big", chunk_index=k))
    coalesce_chunks(reg, graph, prof, 256 * MB)
    spans = chunk_spans(reg, "big")
    assert len(spans) == 2
    assert spans[0][0].size_bytes == 32 * MB    # hot head merged
    assert spans[1][0].size_bytes == 32 * MB    # cold tail merged


def test_coalesce_caps_kv_serving_skew_chunks():
    """Acceptance: coalescing reduces the steady-state chunk count on
    kv_serving_skew (from 64) with no steady-state slack regression
    beyond 5%."""
    wl = SKEWED_SCENARIO_WORKLOADS["kv_serving_skew"]()
    cfg = lambda co: RuntimeConfig(fast_capacity_bytes=256 * MB,
                                   mover="slack", drift_threshold=10.0,
                                   coalesce=co)
    off, rt_off = run_scenario(wl, config=cfg(False), iters=10)
    wl = SKEWED_SCENARIO_WORKLOADS["kv_serving_skew"]()
    on, rt_on = run_scenario(wl, config=cfg(True), iters=10)
    n_off = sum(1 for o in rt_off.registry if o.parent is not None)
    n_on = sum(1 for o in rt_on.registry if o.parent is not None)
    assert n_off == 64                  # the ROADMAP's lingering registry
    assert n_on < n_off
    assert (on.steady_iteration_time
            <= off.steady_iteration_time * 1.05)


# ---------------------------------------------------------------------------
# leaf-aligned partitioning
# ---------------------------------------------------------------------------
def test_snap_to_leaf_boundaries_unit():
    spans = [("a", 0, 100), ("b", 100, 60), ("c", 160, 140)]
    size = 300
    snapped = snap_to_leaf_boundaries([90, 170, 300], spans, size)
    assert snapped == [100, 160, 300]
    # duplicate snaps collapse; trailing boundary always the size
    assert snap_to_leaf_boundaries([95, 105, 300], spans, size) == [100, 300]
    # no interior leaf edges: degenerate single chunk
    assert snap_to_leaf_boundaries([50, 100], [("a", 0, 100)], 100) == [100]


def test_leaf_aligned_partition_cuts_on_leaf_edges():
    """With RuntimeConfig.leaf_aligned, every chunk boundary of a
    pytree-registered object lands on a leaf boundary, so chunks are
    moveable as whole arrays."""
    import jax

    rt = UnimemRuntime(
        M, RuntimeConfig(fast_capacity_bytes=64 * MB, mover="fifo",
                         leaf_aligned=True, enable_initial_placement=False),
        cf=CF)
    n_leaves = 10
    tree = {f"l{i:02d}": jax.ShapeDtypeStruct((24, 1024, 1024), "float32")
            for i in range(n_leaves)}    # 96 MB per leaf, 960 MB total
    obj = rt.register("big", tree, chunkable=True)
    leaf_edges = {off for _, off, _ in obj.leaf_spans} | {obj.size_bytes}
    for _ in range(2):
        with rt.iteration():
            with rt.phase("p0", accesses={"big": 1e7}, elapsed=0.1):
                pass
    spans = chunk_spans(rt.registry, "big")
    assert len(spans) >= 2
    for _, lo, hi in spans:
        assert lo in leaf_edges | {0}
        assert hi in leaf_edges
    assert sum(hi - lo for _, lo, hi in spans) == obj.size_bytes


# ---------------------------------------------------------------------------
# scoped replanning: equality properties
# ---------------------------------------------------------------------------
def test_window_index_matches_graph_trigger_points():
    for seed in range(30):
        _, graph, _, refs, _ = build_chunk_fixture(100, seed=seed)
        widx = _WindowIndex(graph)
        for ph in graph:
            for o in ph.refs:
                assert widx.trigger(o, ph.index) == \
                    graph.trigger_point(o, ph.index)


@given(seed=st.integers(0, 150), n_drift=st.integers(1, 3))
@settings(max_examples=25, deadline=None)
def test_scoped_replan_equals_full_replan(seed, n_drift):
    """Property: after perturbing any subset of phases' profiles, a
    scoped replan against the standing decisions produces exactly the
    plan a full replan produces."""
    rng = random.Random(seed)
    n_phases = rng.randint(2, 8)
    reg, graph, prof, refs, times = build_chunk_fixture(
        rng.choice([100, 200]), n_phases=n_phases, seed=seed)
    planner = Planner(M, reg, CalibrationConstants(), 256 * MB)
    local = planner.plan_local(graph, prof)
    glob = planner.plan_global(graph, prof)

    for p in rng.sample(range(n_phases), min(n_drift, n_phases)):
        prof.decay(0.25, phases=[p])
        factor = rng.uniform(0.3, 3.0)
        t = times[p] * (1.0 if rng.random() < 0.5 else rng.uniform(0.5, 2.0))
        prof.observe(PhaseTraceEvent(
            p, t, {k: v * factor for k, v in refs[p].items()}))
    prof.annotate_graph(graph)
    resplit_refs(graph, reg)

    full = planner.plan(graph, prof)
    scoped = planner.plan(graph, prof,
                          standing=local.phase_decisions,
                          standing_global=glob.global_contribs,
                          standing_digest=local.graph_digest)
    assert plans_equal(full, scoped)


def test_scoped_replan_reuses_unaffected_phases():
    """Single-phase drift with unchanged phase time: every other phase's
    decision is reused verbatim (the fast path), and the plan still
    equals a full replan."""
    reg, graph, prof, refs, times = build_chunk_fixture(500, n_phases=12)
    planner = Planner(M, reg, CalibrationConstants(), 256 * MB)
    local = planner.plan_local(graph, prof)
    glob = planner.plan_global(graph, prof)
    drift = 11
    prof.decay(0.25, phases=[drift])
    prof.observe(PhaseTraceEvent(
        drift, times[drift], {k: v * 1.35 for k, v in refs[drift].items()}))
    prof.annotate_graph(graph)
    resplit_refs(graph, reg)

    full = planner.plan_local(graph, prof)
    scoped = planner.plan_local(graph, prof,
                                standing=local.phase_decisions,
                                standing_digest=local.graph_digest)
    assert plans_equal(full, scoped)
    reused = [d.reused for d in scoped.phase_decisions]
    assert sum(reused) == 11 and not reused[drift]


def _drift_variant(wl, phase_idx, factor=3.0):
    """One phase's access counts scale by ``factor`` — a localized drift."""
    phases = list(wl.phases)
    ph = phases[phase_idx]
    touches = {o: dataclasses.replace(a, accesses=a.accesses * factor)
               for o, a in ph.touches.items()}
    phases[phase_idx] = SimPhaseSpec(ph.name, ph.compute_s, touches)
    return SimWorkload(wl.name, phases, wl.objects, wl.chunkable)


class _AuditingPolicy(UnimemPolicy):
    """Runs a full (standing-free) solve next to every scoped build,
    *before* the session enacts any moves, and records equality."""

    def __init__(self):
        self.audits = []

    def build(self, state):
        program = super().build(state)
        if program is not None and state.standing is not None:
            full, _, _, _ = solve_best(state.planner, state.graph,
                                       state.profiler, state.config)
            self.audits.append((plans_equal(program, full),
                                program.reused_phases))
        return program


@pytest.mark.parametrize("wl_name", sorted(SCENARIO_WORKLOADS))
def test_scoped_replan_equality_on_scenario_drift(wl_name):
    """Acceptance: on every scenario-matrix drift case, the session's
    scoped replan produces a plan equal to a full replan of the same
    characterized state."""
    wl = SCENARIO_WORKLOADS[wl_name]()
    rt = UnimemRuntime(
        M, RuntimeConfig(fast_capacity_bytes=256 * MB, mover="slack"),
        cf=CF)
    rt.policy = _AuditingPolicy()
    statics = wl.static_ref_counts()
    for n, s in wl.objects.items():
        rt.register(n, s, chunkable=wl.chunkable.get(n, False),
                    static_refs=statics.get(n))
    eng = SimulationEngine(M, wl, runtime=rt)
    eng.run(6)
    wl2 = _drift_variant(wl, len(wl.phases) // 2)
    eng.workload = wl2
    eng.source = SimSource(M, wl2, rt.registry)
    rt.attach_source(eng.source)
    eng.run(10)
    assert rt.n_replans >= 1
    assert rt.policy.audits, "drift never triggered a replan"
    assert all(eq for eq, _ in rt.policy.audits)


def test_scoped_replan_reuses_in_session_flow():
    """The scoped drift response actually pays off end to end: a localized
    kv_serving drift replans with most phase solves reused."""
    wl = SCENARIO_WORKLOADS["kv_serving"]()
    rt = UnimemRuntime(
        M, RuntimeConfig(fast_capacity_bytes=256 * MB, mover="slack"),
        cf=CF)
    rt.policy = _AuditingPolicy()
    statics = wl.static_ref_counts()
    for n, s in wl.objects.items():
        rt.register(n, s, static_refs=statics.get(n))
    eng = SimulationEngine(M, wl, runtime=rt)
    eng.run(6)
    wl2 = _drift_variant(wl, 5)
    eng.workload = wl2
    eng.source = SimSource(M, wl2, rt.registry)
    rt.attach_source(eng.source)
    eng.run(10)
    assert any(reused > 0 for _, reused in rt.policy.audits)
    assert all(eq for eq, _ in rt.policy.audits)


def test_scoped_replan_off_still_plans():
    """scoped_replan=False always re-solves every phase (no reuse), with
    the same resulting plan."""
    reg, graph, prof, refs, times = build_chunk_fixture(100)
    planner = Planner(M, reg, CalibrationConstants(), 256 * MB)
    local = planner.plan_local(graph, prof)
    again = planner.plan_local(graph, prof,
                               standing=local.phase_decisions,
                               standing_digest=local.graph_digest)
    assert all(d.reused for d in again.phase_decisions)
    bare = planner.plan_local(graph, prof)
    assert plans_equal(bare, again)
    assert not any(d.reused for d in bare.phase_decisions)


def test_load_plan_drops_orphaned_inflight_handles():
    """A rebuild whose coalesce stage retires chunk names and re-registers
    merged chunks under the same names must not leave the mover's
    in-flight table aliasing the orphaned objects — a stale handle would
    match the new chunk's first move as 'already in flight' and swallow
    it (regression for the coalesce-under-live-copies hazard)."""
    from repro.core import ProactiveMover, SlackAwareMover
    from repro.core.planner import MoveOp, PlacementPlan, ScheduledMove

    for mover_cls in (SlackAwareMover, ProactiveMover):
        reg = ObjectRegistry()
        clock = {"t": 0.0}
        from repro.core.mover import ChannelSimBackend
        backend = ChannelSimBackend(M, lambda: clock["t"], channels=2)
        old = reg.register(DataObject(name="big#0", size_bytes=8 * MB,
                                      parent="big", chunk_index=0))
        mover = mover_cls(reg, backend)
        h = backend.start_move(old, "fast")
        mover._inflight["big#0"] = h
        # the rebuild retires big#0 and re-registers a merged chunk under
        # the same name
        reg.remove("big#0")
        merged = reg.register(DataObject(name="big#0", size_bytes=16 * MB,
                                         parent="big", chunk_index=0))
        plan = PlacementPlan(
            "local", [set()], [MoveOp("big#0", "fast", 0, 0, 16 * MB)],
            0.0, 0.0,
            [ScheduledMove(MoveOp("big#0", "fast", 0, 0, 16 * MB),
                           1.0, 0.5, 0.5)])
        mover.load_plan(plan, None)
        assert "big#0" not in mover._inflight
        if mover_cls is SlackAwareMover:
            # the new chunk's move actually issues (and is fenced at this
            # phase, landing it) instead of aliasing the stale handle
            mover.on_phase_start(plan, 0, 1)
            assert mover.stats.n_moves == 1
            assert merged.tier == "fast"
            assert not h.landed or h.obj is not merged


# ---------------------------------------------------------------------------
# parity goldens: the pipeline is bit-identical to the old build path
# ---------------------------------------------------------------------------
class OldPathSession(UnimemRuntime):
    """The pre-pipeline ``_build_plan`` (PR 3), verbatim: annotate ->
    partition/resplit -> best-of-two — the oracle the policy pipeline
    must reproduce bit-for-bit when coalescing is off."""

    def _build_plan(self):
        assert self.graph is not None
        self.profiler.annotate_graph(self.graph)
        if self.config.enable_partitioning:
            newly = partition_mod.auto_partition(
                self.registry, self.graph, self.capacity,
                profiler=self.profiler,
                skew_aware=self.config.chunk_aware)
            if not newly:
                partition_mod.resplit_refs(self.graph, self.registry,
                                           self.profiler)
        plans = []
        if self.config.enable_local_search:
            plans.append(self.planner.plan_local(self.graph, self.profiler))
        if self.config.enable_global_search:
            plans.append(self.planner.plan_global(self.graph, self.profiler))
        self._drift_scope = None
        if not plans:
            self.plan = None
            return
        self.plan = min(plans, key=lambda p: p.predicted_iteration_time)
        self._plan_n_phases = len(self._phase_names)
        self._baseline_pending = True
        self.monitor.consume_events()
        if self.mover is not None:
            if hasattr(self.mover, "load_plan"):
                self.mover.load_plan(self.plan, self.graph)
            self.mover.on_phase_start(self.plan, 0, self._plan_n_phases)


PARITY = {
    "kv_serving": SCENARIO_WORKLOADS["kv_serving"],
    "graph_chase": SCENARIO_WORKLOADS["graph_chase"],
    "fsdp_buckets": SCENARIO_WORKLOADS["fsdp_buckets"],
    "kv_serving_skew": SKEWED_SCENARIO_WORKLOADS["kv_serving_skew"],
    "paged_serving": SKEWED_SCENARIO_WORKLOADS["paged_serving"],
}


@pytest.mark.parametrize("mover", ["slack", "fifo"])
@pytest.mark.parametrize("wl_name", sorted(PARITY))
def test_pipeline_parity_with_old_build_path(wl_name, mover):
    """Acceptance: with coalescing disabled, the policy pipeline produces
    bit-identical plans and identical virtual-time traces to the
    pre-pipeline build path, across the scenario matrix and both movers."""
    cfg = lambda: RuntimeConfig(fast_capacity_bytes=256 * MB, mover=mover,
                                drift_threshold=10.0, coalesce=False)
    old_res, old_rt = run_scenario(PARITY[wl_name](), config=cfg(),
                                   runtime_cls=OldPathSession)
    new_res, new_rt = run_scenario(PARITY[wl_name](), config=cfg())
    assert old_rt.plan is not None and new_rt.plan is not None
    assert isinstance(new_rt.plan, PlanProgram)
    assert not isinstance(old_rt.plan, PlanProgram)
    assert old_rt.plan.moves == new_rt.plan.moves
    assert old_rt.plan.residents == new_rt.plan.residents
    assert (old_rt.plan.predicted_iteration_time
            == new_rt.plan.predicted_iteration_time)
    assert old_rt.plan.strategy == new_rt.plan.strategy
    assert old_res.iteration_times == new_res.iteration_times
    assert {o.name: o.tier for o in old_rt.registry} \
        == {o.name: o.tier for o in new_rt.registry}


# ---------------------------------------------------------------------------
# lru baseline policy plugin (ISSUE 5 satellite)
# ---------------------------------------------------------------------------
def test_lru_policy_registered_and_builds_program():
    from repro.core.policy import LruPolicy

    assert "lru" in available_policies()
    assert isinstance(make_policy("lru"), LruPolicy)

    wl = SCENARIO_WORKLOADS["kv_serving"]()
    cfg = RuntimeConfig(fast_capacity_bytes=256 * MB, drift_threshold=10.0,
                        policy="lru")
    res, rt = run_scenario(wl, config=cfg)
    assert isinstance(rt.plan, PlanProgram)
    assert rt.plan.policy == "lru"
    assert rt.plan.strategy == "lru"
    # solve-stage-only plugin: the characterization stages are unimem's
    stages = [p.stage for p in rt.plan.provenance]
    assert stages == ["attribute", "partition", "coalesce", "solve",
                      "schedule"]
    # demand-driven: every move fires at the phase that needs it (no
    # lookahead triggers — the ablation's defining property)
    assert rt.plan.moves
    assert all(m.trigger_phase == m.needed_by for m in rt.plan.moves)
    assert res.total_time > 0


def test_lru_respects_capacity_and_evicts_least_recent():
    from repro.core import policy as policy_mod
    from repro.core.tiers import MachineProfile

    reg = ObjectRegistry()
    for n, sz in (("a", 40 * MB), ("b", 40 * MB), ("c", 40 * MB)):
        reg.alloc(n, sz)
    graph = build_phase_graph(
        [("p0", {"a": 100.0}), ("p1", {"b": 100.0}), ("p2", {"c": 100.0})],
        times=[0.1, 0.1, 0.1])
    prof = PhaseProfiler(M, seed=0)
    state = policy_mod.PipelineState(
        machine=M, registry=reg, graph=graph, profiler=prof,
        planner=Planner(M, reg, CF, 64 * MB), capacity=64 * MB,
        config=RuntimeConfig(fast_capacity_bytes=64 * MB))
    policy_mod.stage_solve_lru(state)
    plan = state.plan
    # one object fits at a time: each phase holds exactly its referenced
    # object, and the previous phase's (least recent) object was evicted
    assert plan.residents == [{"a"}, {"b"}, {"c"}]
    evs = [m.obj for m in plan.moves if m.dst == "slow"]
    assert evs == ["a", "b"]


def test_lru_ablation_comparable_and_unimem_wins_with_lookahead():
    """The ablation row: on the pointer-chasing scenario — where the
    planner's dependency-safe lookahead triggers actually overlap the
    shard swap — the benefit-model plan beats demand-driven recency.
    (On other scenarios LRU is competitive; the committed scenarios.csv
    ablation rows record the honest per-scenario picture.)"""
    wl = SCENARIO_WORKLOADS["graph_chase"]()
    uni_res, _ = run_scenario(wl, iters=10)
    lru_res, _ = run_scenario(wl, iters=10, config=RuntimeConfig(
        fast_capacity_bytes=256 * MB, drift_threshold=10.0, policy="lru"))
    assert (uni_res.steady_iteration_time
            < lru_res.steady_iteration_time)
