"""Property tests for the 0/1 knapsack placement solver."""

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                      # no hypothesis: seeded shim
    from _propcheck import st, given, settings

from repro.core.knapsack import (Item, solve, solve_reference, total_size,
                                 total_value)

items_strategy = st.lists(
    st.tuples(st.floats(-5.0, 50.0), st.integers(1, 200 * 1024 * 1024)),
    min_size=0, max_size=20)


@given(items=items_strategy, cap=st.integers(0, 1024 * 1024 * 1024))
@settings(max_examples=200, deadline=None)
def test_capacity_respected_and_values_positive(items, cap):
    its = [Item(f"o{i}", v, s) for i, (v, s) in enumerate(items)]
    chosen = solve(its, cap)
    assert total_size(its, chosen) <= cap
    by = {i.name: i for i in its}
    assert all(by[c].value > 0 for c in chosen)
    assert len(set(chosen)) == len(chosen)


@given(items=items_strategy, cap=st.integers(1, 1024 * 1024 * 1024))
@settings(max_examples=100, deadline=None)
def test_no_profitable_leftover_fits(items, cap):
    """No meaningfully-positive item that still fits was left out (local
    optimality; values below fp64 addition precision may be dropped)."""
    its = [Item(f"o{i}", v, s) for i, (v, s) in enumerate(items)]
    chosen = set(solve(its, cap))
    used = total_size(its, list(chosen))
    vmax = max((abs(i.value) for i in its), default=0.0)
    for it in its:
        if it.name not in chosen and it.value > 1e-9 * max(vmax, 1.0):
            # quantization rounds sizes up by at most one quantum
            quantum = max(1, -(-cap // (1 << 14)))
            assert it.size_bytes + quantum > cap - used


def test_exact_small_instance():
    its = [Item("a", 10.0, 6), Item("b", 9.0, 5), Item("c", 8.0, 5)]
    # capacity 10: optimal is b+c (17) not a (10)
    assert set(solve(its, 10)) == {"b", "c"}


def test_negative_never_chosen():
    its = [Item("a", -1.0, 1), Item("b", 2.0, 1)]
    assert solve(its, 10) == ["b"]


@given(items=items_strategy, cap=st.integers(0, 1024 * 1024 * 1024))
@settings(max_examples=200, deadline=None)
def test_packed_bitset_solver_matches_reference(items, cap):
    """The packed-bitset DP must return selections value-equal (in fact
    identical) to the pre-optimization bool-matrix DP on randomized
    instances."""
    its = [Item(f"o{i}", v, s) for i, (v, s) in enumerate(items)]
    fast = solve(its, cap)
    ref = solve_reference(its, cap)
    assert fast == ref
    assert total_value(its, fast) == total_value(its, ref)


def test_packed_bitset_matches_reference_dense():
    """Many similar items exercising deep backtracks across byte borders."""
    import random
    rng = random.Random(0)
    its = [Item(f"o{i}", rng.uniform(0.1, 1.0), rng.randint(1, 1 << 16))
           for i in range(300)]
    cap = 1 << 20
    assert solve(its, cap) == solve_reference(its, cap)
