"""Slack-aware async migration scheduler: invariants + golden traces.

Property tests check the scheduler's safety invariants on randomized
workloads; golden tests pin the virtual-time behaviour (steady iteration
time, fence stall, overlap fraction) of each scenario-matrix workload and
assert the slack engine beats the FIFO phase-boundary mover on all of them.
"""

import math

import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                      # no hypothesis: seeded shim
    from _propcheck import st, given, settings

from repro.core import (PAPER_DRAM_NVM, ChannelSimBackend, RuntimeConfig,
                        UnimemRuntime, calibrate)
from repro.core.data_objects import ObjectRegistry
from repro.sim import SCENARIO_WORKLOADS, SimulationEngine
from repro.sim.engine import SimObjectAccess, SimPhaseSpec
from repro.sim.workloads import SimWorkload

MB = 1024 ** 2
MACHINE = PAPER_DRAM_NVM.scaled(bw_scale=0.5, lat_scale=2.0)
CF = calibrate(MACHINE)
CHANNELS = 2


# ---------------------------------------------------------------------------
# harness
# ---------------------------------------------------------------------------
def run_workload(wl: SimWorkload, mover: str, iters: int = 8,
                 capacity: int = 256 * MB):
    rt = UnimemRuntime(
        MACHINE,
        RuntimeConfig(fast_capacity_bytes=capacity, mover=mover,
                      copy_channels=CHANNELS, drift_threshold=10.0),
        cf=CF)
    for n, s in wl.objects.items():
        rt.alloc(n, size_bytes=s, chunkable=wl.chunkable.get(n, False))
    rt.start_loop([p.name for p in wl.phases],
                  static_refs=wl.static_ref_counts())
    res = SimulationEngine(MACHINE, wl, runtime=rt).run(iters)
    return res, rt


def random_workload(rng_seed: int) -> tuple:
    import random
    rng = random.Random(rng_seed)
    n_obj = rng.randint(2, 8)
    objects = {}
    chunkable = {}
    for i in range(n_obj):
        name = f"o{i}"
        objects[name] = rng.randint(8, 90) * MB
        if rng.random() < 0.25:
            objects[name] = rng.randint(200, 400) * MB
            chunkable[name] = True
    n_phases = rng.randint(2, 6)
    phases = []
    for p in range(n_phases):
        touches = {}
        for name, size in objects.items():
            if rng.random() < 0.55:
                touches[name] = SimObjectAccess(
                    accesses=rng.uniform(0.3, 4.0) * size / 64,
                    stream_fraction=rng.choice([1.0, 0.9, 0.5, 0.0]))
        if not touches:
            name = rng.choice(list(objects))
            touches[name] = SimObjectAccess(accesses=size / 64)
        phases.append(SimPhaseSpec(f"p{p}", rng.uniform(0.002, 0.03),
                                   touches))
    capacity = rng.randint(100, 300) * MB
    return SimWorkload(f"rand{rng_seed}", phases, objects, chunkable), capacity


# ---------------------------------------------------------------------------
# safety invariants on randomized workloads
# ---------------------------------------------------------------------------
@given(seed=st.integers(0, 200))
@settings(max_examples=25, deadline=None)
def test_scheduler_invariants_random(seed):
    wl, capacity = random_workload(seed)
    res, rt = run_workload(wl, "slack", iters=6, capacity=capacity)
    backend = rt.backend
    assert isinstance(backend, ChannelSimBackend)
    trace = rt.mover.trace
    n = len(wl.phases)

    # (1) channel concurrency never exceeds the configured channel count
    assert backend.max_concurrency() <= CHANNELS

    # (2) no move starts before its data is planned: the plan exists only
    # after the profiling iteration, so no copy may begin before it ends
    t_planned = res.iteration_times[0]
    for c in backend.copies:
        assert c.start >= t_planned - 1e-9

    # (3) every issued move comes from the plan, and is released at a phase
    # boundary matching its trigger phase (modulo the iteration)
    plan_keys = {(m.obj, m.dst) for m in rt.plan.moves} if rt.plan else set()
    boundary_starts = {(p.phase_index, round(p.start, 12))
                      for p in res.phase_trace}
    boundaries_by_phase = {}
    for p in res.phase_trace:
        boundaries_by_phase.setdefault(p.phase_index, []).append(p.start)
    for rec in trace:
        assert (rec.obj, rec.dst) in plan_keys
        starts = boundaries_by_phase.get(rec.trigger_phase % n, [])
        assert any(abs(rec.issued_at - s) < 1e-9 for s in starts)

    # (4) no phase consumes an object mid-flight: every fenced fetch landed
    # by the time its (possibly chunk-staggered) consume point had passed
    for rec in trace:
        if rec.dst != "fast" or rec.superseded or math.isnan(rec.fenced_at):
            continue
        assert rec.done <= rec.fenced_at + rec.fence_stall_s + 1e-9

    # (5) copies never start before they are issued
    for rec in trace:
        assert rec.start >= rec.issued_at - 1e-9


# ---------------------------------------------------------------------------
# multi-channel copy-engine semantics
# ---------------------------------------------------------------------------
def test_channel_backend_lone_copy_full_bandwidth():
    clock = {"t": 0.0}
    b = ChannelSimBackend(MACHINE, lambda: clock["t"], channels=4)
    reg = ObjectRegistry()
    obj = reg.alloc("a", int(MACHINE.copy_bw))          # 1 s at full rate
    h = b.start_move(obj, "fast")
    assert h.done == pytest.approx(1.0)
    assert obj.tier == "slow"                           # not landed yet
    b.settle(0.5)
    assert obj.tier == "slow"                           # still in flight
    b.settle(1.0)
    assert obj.tier == "fast"                           # landed


def test_channel_backend_concurrent_copies_share_bandwidth():
    clock = {"t": 0.0}
    b = ChannelSimBackend(MACHINE, lambda: clock["t"], channels=2)
    reg = ObjectRegistry()
    o1 = reg.alloc("a", int(MACHINE.copy_bw))
    o2 = reg.alloc("b", int(MACHINE.copy_bw))
    h1 = b.start_move(o1, "fast")
    assert h1.done == pytest.approx(1.0)                # alone: full rate
    h2 = b.start_move(o2, "fast")
    # both active copies share the link; aggregate never exceeds copy_bw
    assert h1.done == pytest.approx(2.0)                # re-rated to bw/2
    assert h2.done == pytest.approx(2.0)
    assert b.max_concurrency() == 2
    total_bytes = o1.size_bytes + o2.size_bytes
    makespan = max(h1.done, h2.done)
    assert total_bytes / makespan <= MACHINE.copy_bw * (1 + 1e-9)


def test_channel_backend_queues_beyond_channel_count():
    clock = {"t": 0.0}
    b = ChannelSimBackend(MACHINE, lambda: clock["t"], channels=2)
    reg = ObjectRegistry()
    handles = [b.start_move(reg.alloc(f"o{i}", int(MACHINE.copy_bw)), "fast")
               for i in range(5)]
    assert b.max_concurrency() <= 2
    # all five copies eventually complete
    assert all(h.done > 0 for h in handles)


def test_channel_backend_superseded_copy_never_reverts_tier():
    """A force-completed re-fetch retires the in-flight eviction it was
    chained after; a later settle must not apply the stale flip."""
    clock = {"t": 0.0}
    b = ChannelSimBackend(MACHINE, lambda: clock["t"], channels=2)
    reg = ObjectRegistry()
    x = reg.alloc("x", int(MACHINE.copy_bw), tier="fast")
    ev = b.start_move(x, "slow")
    fetch = b.start_move(x, "fast", after=ev)
    b.complete(fetch)                       # fence absorbed the stall
    assert x.tier == "fast"
    clock["t"] = fetch.done + 10.0
    b.settle(clock["t"])
    assert x.tier == "fast"                 # stale eviction stayed retired


def test_channel_backend_dependency_chaining():
    clock = {"t": 0.0}
    b = ChannelSimBackend(MACHINE, lambda: clock["t"], channels=2)
    reg = ObjectRegistry()
    ev = b.start_move(reg.alloc("victim", int(MACHINE.copy_bw),
                                tier="fast"), "slow")
    fetch = b.start_move(reg.alloc("incoming", int(MACHINE.copy_bw)),
                         "fast", after=ev)
    assert fetch.start >= ev.done                       # space frees first


# ---------------------------------------------------------------------------
# golden virtual-time traces for the scenario matrix
# ---------------------------------------------------------------------------
# values measured on the seed machine (iters=8, 256 MB fast tier, 2
# channels, drift replan pinned off); tolerances absorb float noise only.
GOLDEN = {
    "kv_serving": dict(fifo_steady=1.2516, slack_steady=1.0704,
                       slack_stall=0.1057, overlap=0.44, overlap_time=0.52),
    "moe_churn": dict(fifo_steady=3.5176, slack_steady=3.4338,
                      slack_stall=0.0503, overlap=0.43, overlap_time=0.60),
    "graph_chase": dict(fifo_steady=1.2596, slack_steady=0.9769,
                        slack_stall=0.0, overlap=0.93, overlap_time=0.98),
    "fsdp_buckets": dict(fifo_steady=1.2875, slack_steady=1.2525,
                         slack_stall=0.0258, overlap=0.54,
                         overlap_time=0.48),
}


def steady_stall_per_iter(res, n_phases: int) -> float:
    tail = res.phase_trace[len(res.phase_trace) // 2:]
    return sum(p.stall_s for p in tail) / (len(tail) / n_phases)


@pytest.mark.parametrize("wl_name", sorted(SCENARIO_WORKLOADS))
def test_scenario_golden_trace(wl_name):
    wl = SCENARIO_WORKLOADS[wl_name]()
    golden = GOLDEN[wl_name]
    fifo, _ = run_workload(wl, "fifo")
    slack, rt = run_workload(wl, "slack")
    s = rt.stats()

    # slack-aware scheduling strictly beats the FIFO phase-boundary mover
    assert slack.steady_iteration_time < fifo.steady_iteration_time

    assert fifo.steady_iteration_time == pytest.approx(
        golden["fifo_steady"], rel=0.05)
    assert slack.steady_iteration_time == pytest.approx(
        golden["slack_steady"], rel=0.05)
    assert steady_stall_per_iter(slack, len(wl.phases)) == pytest.approx(
        golden["slack_stall"], rel=0.10, abs=2e-3)
    assert s["overlap_fraction"] == pytest.approx(
        golden["overlap"], abs=0.05)
    assert s["overlap_time_fraction"] == pytest.approx(
        golden["overlap_time"], abs=0.05)


def test_scenario_overlap_exceeds_half_somewhere():
    """At least one scenario must overlap more than half of its migrations
    (the tentpole's headline claim)."""
    best = 0.0
    for make in SCENARIO_WORKLOADS.values():
        _, rt = run_workload(make(), "slack")
        best = max(best, rt.stats()["overlap_fraction"])
    assert best > 0.5


# ---------------------------------------------------------------------------
# chunk-granular double buffering
# ---------------------------------------------------------------------------
def test_chunked_fetch_stalls_less_than_whole_object():
    """A chunkable object consumed through the slack mover stalls less than
    the same bytes fenced as one rigid object (double buffering)."""
    def make(chunkable: bool) -> SimWorkload:
        objects = {"big": 320 * MB, "hot": 120 * MB, "small": 16 * MB}
        phases = [
            SimPhaseSpec("scan", 0.020, {
                "big": SimObjectAccess(accesses=3.0 * objects["big"] / 64,
                                       stream_fraction=0.9),
                "small": SimObjectAccess(accesses=objects["small"] / 64),
            }),
            SimPhaseSpec("other", 0.010, {
                "hot": SimObjectAccess(accesses=4.0 * objects["hot"] / 64),
                "small": SimObjectAccess(accesses=objects["small"] / 64),
            }),
        ]
        return SimWorkload("chunk_t", phases, objects,
                           chunkable={"big": chunkable})

    res_chunk, rt_chunk = run_workload(make(True), "slack")
    res_rigid, rt_rigid = run_workload(make(False), "slack")
    # the rigid 320 MB object cannot even fit the 256 MB tier; the chunked
    # variant streams chunks through and must run at least as fast
    assert (res_chunk.steady_iteration_time
            <= res_rigid.steady_iteration_time + 1e-9)


def test_slack_priority_orders_release():
    """At one release point, tighter-slack moves are issued first."""
    from repro.core.planner import MoveOp, PlacementPlan, ScheduledMove
    from repro.core.mover import SlackAwareMover

    reg = ObjectRegistry()
    reg.alloc("urgent", 40 * MB)
    reg.alloc("bulk", 80 * MB)
    clock = {"t": 0.0}
    backend = ChannelSimBackend(MACHINE, lambda: clock["t"], channels=1)
    mover = SlackAwareMover(reg, backend)
    moves = [
        MoveOp("bulk", "fast", 0, 3, 80 * MB, est_benefit=0.1),
        MoveOp("urgent", "fast", 0, 1, 40 * MB, est_benefit=0.1),
    ]
    schedule = [
        ScheduledMove(moves[0], window_s=0.5, duration_s=0.008,
                      slack_s=0.492),
        ScheduledMove(moves[1], window_s=0.004, duration_s=0.004,
                      slack_s=0.0),
    ]
    plan = PlacementPlan("local", [set(), set(), set(), set()], moves,
                         0.0, 0.0, schedule)
    mover.on_phase_start(plan, 0, 4)
    assert [r.obj for r in mover.trace] == ["urgent", "bulk"]
    # on one channel the urgent copy runs first in time as well
    assert mover.trace[0].start < mover.trace[1].start


# ---------------------------------------------------------------------------
# prioritized copy channels (ISSUE 5 satellite)
# ---------------------------------------------------------------------------
def test_priority_channels_default_is_bitwise_unprioritized():
    """priorities=None and all-equal priorities reproduce the legacy
    engine exactly (same channels, same start/done times)."""
    reg1, reg2, reg3 = ObjectRegistry(), ObjectRegistry(), ObjectRegistry()
    clock = {"t": 0.0}
    engines = [
        ChannelSimBackend(MACHINE, lambda: clock["t"], channels=2),
        ChannelSimBackend(MACHINE, lambda: clock["t"], channels=2,
                          priorities=[0, 0]),
        ChannelSimBackend(MACHINE, lambda: clock["t"], channels=2,
                          priorities=[3, 3]),
    ]
    traces = []
    for b, reg in zip(engines, (reg1, reg2, reg3)):
        hs = []
        for i in range(5):
            dst = "slow" if i % 2 else "fast"
            hs.append(b.start_move(reg.alloc(f"o{i}", 32 * MB), dst))
        traces.append([(h.channel, h.start, h.done) for h in hs])
    assert traces[0] == traces[1] == traces[2]


def test_priority_channels_evictions_confined_to_bulk():
    """Demotion evictions only queue on the minimum-priority channels."""
    clock = {"t": 0.0}
    b = ChannelSimBackend(MACHINE, lambda: clock["t"], channels=3,
                          priorities=[0, 0, 1])
    reg = ObjectRegistry()
    hs = [b.start_move(reg.alloc(f"e{i}", 32 * MB, tier="fast"), "slow")
          for i in range(6)]
    assert all(h.channel in (0, 1) for h in hs)


def test_priority_channels_keep_fetch_off_eviction_queue():
    """A burst of evictions must not head-of-line-block an urgent fetch:
    with a reserved high-priority channel the fetch starts immediately;
    without priorities it queues behind the eviction backlog."""
    for priorities, expect_immediate in ((None, False), ([0, 1], True)):
        clock = {"t": 0.0}
        b = ChannelSimBackend(MACHINE, lambda: clock["t"], channels=2,
                              priorities=priorities)
        reg = ObjectRegistry()
        for i in range(4):      # eviction backlog saturating the engine
            b.start_move(reg.alloc(f"e{i}", int(MACHINE.copy_bw), tier="fast"),
                         "slow")
        fetch = b.start_move(reg.alloc("hot", 8 * MB), "fast")
        if expect_immediate:
            assert fetch.start == pytest.approx(0.0)
            assert fetch.channel == 1           # the reserved channel
        else:
            assert fetch.start > 0.0            # queued behind evictions


def test_priority_channels_resolve_through_registry_and_config():
    """RuntimeConfig.copy_channel_priorities reaches the simulated engine
    through the backend registry — no driver changes (satellite claim)."""
    from repro.core import make_backend

    b = make_backend("sim", MACHINE, now_fn=lambda: 0.0, mover="slack",
                     channels=2, priorities=[0, 5])
    assert isinstance(b, ChannelSimBackend)
    assert b._bulk_channels == [0]

    wl = SCENARIO_WORKLOADS["kv_serving"]()
    rt = UnimemRuntime(
        MACHINE,
        RuntimeConfig(fast_capacity_bytes=256 * MB, drift_threshold=10.0,
                      copy_channels=2, copy_channel_priorities=[0, 1]),
        cf=CF)
    for n, s in wl.objects.items():
        rt.register(n, s, chunkable=wl.chunkable.get(n, False))
    eng = SimulationEngine(MACHINE, wl, runtime=rt)
    res = eng.run(6)
    assert rt.backend._bulk_channels == [0]
    # every demotion the run issued stayed on the bulk channel
    evictions = [c for c in rt.backend.copies if c.dst == "slow"]
    assert evictions and all(c.channel == 0 for c in evictions)
    assert res.total_time > 0
