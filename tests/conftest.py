import os
import sys

# Tests run on the default single-device CPU backend (the 512-device flag is
# set ONLY inside launch/dryrun.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
