"""Multi-host tier management: per-host managers, cluster coordinator,
cross-host migration backend, and the single-host fallthrough guarantee.

Covers the PR's contract surface end to end:

* link pricing (``LinkSpec`` / ``InterconnectModel`` /
  ``cross_host_cost``) and the ``"cross_host"`` backend's send/recv
  channel-pair semantics (queueing, ``after=`` chaining, land-time tier
  flip + re-homing callback);
* coordinator rebalance on the gated ``moe_churn_multihost`` scenario —
  must beat host-local-only management by >= 1.10x steady time on the
  hot host (the nightly floor, pinned here at the same threshold);
* the promotion-vs-pull chooser picking local promotion when local spare
  suffices;
* one-host cluster fallthrough: bit-identical plans and virtual-time
  traces to the unclustered PR 8 path (golden-digest pinned, both
  movers);
* per-host chaos RNG sub-streams: two hosts under one FaultSpec draw
  decorrelated fault sequences, deterministically, independent of host
  scheduling order;
* host provenance in ``PlanProgram`` (stage records, host sections,
  migrations) surviving serialization round-trips, and in ``stats()`` /
  ``fault_log``.
"""

import dataclasses
import hashlib
import json

import pytest

from repro.core import (PAPER_DRAM_NVM, CrossHostBackend, FaultSpec,
                        InterconnectModel, LinkSpec, RuntimeConfig,
                        UnimemRuntime, calibrate, cross_host_cost,
                        host_sub_seed, link_transfer_time, make_backend)
from repro.core.data_objects import DataObject
from repro.core.policy import PlanProgram, StageProvenance
from repro.distributed import ClusterCoordinator, HostTierManager
from repro.sim import (ClusterSimulation, ShardPhaseSpec, ShardedWorkload,
                       SimObjectAccess, SimulationEngine, kv_serving,
                       moe_churn_multihost)

MB = 1024 ** 2
MACHINE = PAPER_DRAM_NVM
CF = calibrate(MACHINE)


# ---------------------------------------------------------------------------
# link model
# ---------------------------------------------------------------------------
def test_link_spec_validates():
    with pytest.raises(ValueError):
        LinkSpec("l", bandwidth=0.0)
    with pytest.raises(ValueError):
        LinkSpec("l", bandwidth=1e9, latency=-1.0)
    with pytest.raises(ValueError):
        LinkSpec("l", bandwidth=1e9, channel_pairs=0)


def test_link_transfer_and_cost():
    link = LinkSpec("icl", bandwidth=2e9, latency=1e-3)
    assert link_transfer_time(2e9, link) == pytest.approx(1.0 + 1e-3)
    assert cross_host_cost(2e9, link, overlap_window=0.5) \
        == pytest.approx(0.501)
    # fully hidden behind the overlap window
    assert cross_host_cost(1e6, link, overlap_window=10.0) == 0.0


def test_interconnect_lookup_direction_and_default():
    fast = LinkSpec("fast", bandwidth=8e9)
    dflt = LinkSpec("slow", bandwidth=1e9)
    m = InterconnectModel({("h0", "h1"): fast}, default=dflt)
    assert m.link("h0", "h1") is fast
    assert m.link("h1", "h0") is fast          # symmetric fallback
    assert m.link("h0", "h2") is dflt
    with pytest.raises(KeyError):
        InterconnectModel({("h0", "h1"): fast}).link("h0", "h2")


# ---------------------------------------------------------------------------
# cross_host backend: send/recv pair semantics
# ---------------------------------------------------------------------------
def _xhost_backend(pairs=2, bw=1e9, lat=0.0, now=0.0, on_land=None):
    clock = [now]
    links = InterconnectModel(
        default=LinkSpec("icl", bandwidth=bw, latency=lat,
                         channel_pairs=pairs))
    b = make_backend("cross_host", MACHINE, links=links,
                     now_fn=lambda: clock[0], on_land=on_land)
    assert isinstance(b, CrossHostBackend)
    return b, clock


def test_cross_host_pairs_queue_beyond_budget():
    b, _ = _xhost_backend(pairs=2, bw=1e9)
    objs = [DataObject(f"o{i}", int(1e9)) for i in range(3)]
    h = [b.start_move(o, "fast", src_host="h0", dst_host="h1")
         for o in objs]
    # two pairs run concurrently; the third queues on the earliest-free
    assert h[0].start == 0.0 and h[1].start == 0.0
    assert h[2].start == pytest.approx(1.0)
    assert h[2].done == pytest.approx(2.0)
    assert b.busy_seconds() == pytest.approx(3.0)


def test_cross_host_after_chains_and_settle_flips_tier():
    landed = []
    b, clock = _xhost_backend(pairs=4, bw=1e9, on_land=landed.append)
    a = b.start_move(DataObject("a", int(1e9)), "fast",
                     src_host="h0", dst_host="h1")
    c = b.start_move(DataObject("c", int(1e9)), "fast",
                     src_host="h0", dst_host="h1", after=a)
    assert c.start == pytest.approx(a.done)
    clock[0] = 1.5
    b.settle(clock[0])
    assert a.landed and a.obj.tier == "fast"
    assert not c.landed and c.obj.tier == "slow"
    assert [cp.obj.name for cp in landed] == ["a"]
    b.settle(10.0)
    assert c.landed and len(landed) == 2


def test_cross_host_rejects_same_host():
    b, _ = _xhost_backend()
    with pytest.raises(ValueError):
        b.start_move(DataObject("x", 1), "fast",
                     src_host="h0", dst_host="h0")


def test_cross_host_links_per_pair_are_independent():
    b, _ = _xhost_backend(pairs=1, bw=1e9)
    x = b.start_move(DataObject("x", int(1e9)), "fast",
                     src_host="h0", dst_host="h1")
    y = b.start_move(DataObject("y", int(1e9)), "fast",
                     src_host="h0", dst_host="h2")
    z = b.start_move(DataObject("z", int(1e9)), "fast",
                     src_host="h0", dst_host="h1")
    # distinct host pairs don't contend; the same pair queues
    assert x.start == 0.0 and y.start == 0.0
    assert z.start == pytest.approx(x.done)


# ---------------------------------------------------------------------------
# gated scenario: coordinator rebalance must beat host-local-only
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def churn_runs():
    machine, wl, links, knobs = moe_churn_multihost()
    sim = ClusterSimulation(machine, wl, links=links, **knobs)
    return wl, sim.run_local_only(12), sim.run_coordinated(12)


def test_moe_churn_multihost_coordinator_beats_local(churn_runs):
    _, local, coord = churn_runs
    hot_gain = local.steady_time("h0") / coord.steady_time("h0")
    assert hot_gain >= 1.10          # the nightly regression floor
    assert local.cluster_steady_time / coord.cluster_steady_time >= 1.10


def test_moe_churn_migrations_pull_from_hot_host(churn_runs):
    wl, _, coord = churn_runs
    assert coord.migrations, "rebalance found nothing to move"
    for mig in coord.migrations:
        assert mig.mode == "cross_host"
        assert mig.src_host == "h0" and mig.dst_host != "h0"
        assert mig.obj not in wl.shared      # replicas never re-home
        assert mig.est_cost_s > 0.0 and mig.est_benefit_s > 0.0
        assert coord.assignment[mig.obj] == mig.dst_host
    assert coord.migration_s > 0.0
    # pulls spread across distinct peers (the apportioned link shares)
    assert len({m.dst_host for m in coord.migrations}) \
        == len(coord.migrations)


def test_moe_churn_global_program_aggregates_hosts(churn_runs):
    wl, _, coord = churn_runs
    prog = coord.program
    assert prog.strategy == "cluster" and prog.policy == "cluster"
    assert sorted(prog.host_sections) == wl.hosts()
    for h, sec in prog.host_sections.items():
        assert sec["capacity_bytes"] > 0
        assert sec["n_objects"] > 0
    # cluster time = slowest host, not the sum
    assert prog.predicted_iteration_time == pytest.approx(max(
        sec["predicted_iteration_time"]
        for sec in prog.host_sections.values()))
    hosts_seen = {p.host for p in prog.provenance}
    assert hosts_seen == set(wl.hosts())
    assert [m["obj"] for m in prog.migrations] \
        == [m.obj for m in coord.migrations]
    # and the whole thing serializes
    rt = PlanProgram.from_dict(json.loads(prog.to_json()))
    assert rt.host_sections == prog.host_sections
    assert rt.migrations == prog.migrations


def test_coordinator_prefers_local_promotion_when_spare_suffices():
    # one oversubscribed host with plenty of local spare for its surplus
    # shard: the chooser must keep it home (movement_cost beats the link)
    machine, wl, links, knobs = moe_churn_multihost(experts_per_host=2)
    knobs = dict(knobs, fast_capacity_bytes=200 * MB)
    sim = ClusterSimulation(machine, wl, links=links, **knobs)
    coord, engines = sim._build(wl.assignment)
    sim.run_hosts(engines, 4)
    migs = sim_migs = coord.plan_rebalance()
    assert all(m.mode == "local_promote" for m in migs)
    assert all(m.src_host == m.dst_host == "h0" for m in sim_migs)


def test_one_host_cluster_plans_no_migrations():
    machine, wl, links, knobs = moe_churn_multihost(n_hosts=1)
    sim = ClusterSimulation(machine, wl, links=links, **knobs)
    coord, engines = sim._build(wl.assignment)
    sim.run_hosts(engines, 4)
    assert coord.plan_rebalance() == []


# ---------------------------------------------------------------------------
# single-host fallthrough: bit-identical to the unclustered PR 8 path
# ---------------------------------------------------------------------------
# Golden digests of the unclustered kv_serving run (256 MB, 8 iters) per
# mover — (plan digest, steady time, trace digest).  The one-host cluster
# must reproduce them bit-for-bit; so must the plain path (these pin the
# PR 8 pipeline itself against accidental drift from the host plumbing).
ONE_HOST_GOLDEN = {
    "slack": ("62b4841234212db2", 1.0603286323200083, "200ad44ae9375c36"),
    "fifo": ("62b4841234212db2", 1.2390059827200217, "ffeaba43a494eefd"),
}


def _plan_digest(plan):
    d = dict(strategy=plan.strategy,
             residents=[sorted(r) for r in plan.residents],
             moves=[(m.obj, m.dst, m.trigger_phase, m.needed_by,
                     m.size_bytes, m.est_unhidden_cost, m.est_benefit)
                    for m in plan.moves],
             predicted=plan.predicted_iteration_time,
             baseline=plan.baseline_iteration_time,
             schedule=[(s.op.obj, s.window_s, s.duration_s, s.slack_s)
                       for s in plan.schedule])
    return hashlib.sha256(json.dumps(d, sort_keys=True).encode()) \
        .hexdigest()[:16]


def _trace_digest(trace):
    d = [(p.iteration, p.phase_index, p.start, p.stall_s, p.duration_s)
         for p in trace]
    return hashlib.sha256(json.dumps(d).encode()).hexdigest()[:16]


def _as_sharded(wl, host="h0"):
    return ShardedWorkload(
        wl.name,
        [ShardPhaseSpec(p.name, p.compute_s, p.touches) for p in wl.phases],
        dict(wl.objects), shared={},
        assignment={o: host for o in wl.objects},
        chunkable=dict(wl.chunkable))


def _run_plain(wl, mover, iters=8, cap=256 * MB):
    rt = UnimemRuntime(MACHINE, RuntimeConfig(fast_capacity_bytes=cap,
                                              mover=mover), cf=CF)
    statics = wl.static_ref_counts()
    for n, s in wl.objects.items():
        rt.register(n, s, chunkable=wl.chunkable.get(n, False),
                    static_refs=statics.get(n))
    res = SimulationEngine(MACHINE, wl, runtime=rt).run(iters)
    return res, rt


@pytest.mark.parametrize("mover", ["slack", "fifo"])
def test_one_host_cluster_is_bit_identical_to_unclustered(mover):
    wl = kv_serving()
    res, rt = _run_plain(wl, mover)
    plain = (_plan_digest(rt.plan), res.steady_iteration_time,
             _trace_digest(res.phase_trace))
    assert plain == ONE_HOST_GOLDEN[mover]

    sim = ClusterSimulation(MACHINE, _as_sharded(wl), cf=CF,
                            fast_capacity_bytes=256 * MB, mover=mover)
    cres = sim.run_local_only(8)
    coord, engines = sim._build(sim.workload.assignment)
    cres2 = sim.run_hosts(engines, 8)["h0"]
    cluster = (_plan_digest(engines["h0"].runtime.plan),
               cres.steady_time("h0"), _trace_digest(cres2.phase_trace))
    assert cluster == plain
    assert cres2.iteration_times == cres.host_results["h0"].iteration_times
    # the host tag rides along without perturbing the plan
    assert engines["h0"].runtime.plan.host == "h0"
    prog = coord.aggregate_program()
    assert list(prog.host_sections) == ["h0"]
    assert prog.predicted_iteration_time == pytest.approx(
        engines["h0"].runtime.plan.predicted_iteration_time)


# ---------------------------------------------------------------------------
# per-host chaos RNG sub-streams
# ---------------------------------------------------------------------------
def test_host_sub_seed_is_stable_and_decorrelated():
    assert host_sub_seed(42, None) == 42        # PR 8 path untouched
    assert host_sub_seed(42, "h0") == host_sub_seed(42, "h0")
    assert host_sub_seed(42, "h0") != host_sub_seed(42, "h1")
    assert host_sub_seed(42, "h0") != 42


def _symmetric_churn_cluster(fault_spec):
    """Two hosts with *identical* local workloads (rotating hot expert
    pair over capacity), so only the chaos sub-seed can distinguish
    their fault sequences."""
    ex, passes = 40 * MB, 2.0
    objects, assignment, phases = {}, {}, []
    for h in ("h0", "h1"):
        for k in range(3):
            objects[f"{h}/e{k}"] = ex
            assignment[f"{h}/e{k}"] = h
    for p in range(2):
        touches = {}
        for h in ("h0", "h1"):
            touches[f"{h}/e{p}"] = SimObjectAccess(passes * ex / 64, 0.9)
            touches[f"{h}/e{p + 1}"] = SimObjectAccess(passes * ex / 64, 0.9)
        phases.append(ShardPhaseSpec(f"p{p}", 0.002, touches))
    wl = ShardedWorkload("sym_churn", phases, objects, {}, assignment)
    return ClusterSimulation(MACHINE, wl, fast_capacity_bytes=80 * MB,
                             fault_spec=fault_spec)


def _fault_patterns(engines):
    """Per-host fault sequences with object names elided (the two hosts'
    objects are name-prefixed; the *pattern* is what sub-seeding
    decorrelates)."""
    return {h: [(kind, ch) for kind, _obj, ch in
                engines[h].runtime.backend.fault_log]
            for h in engines}


def test_two_host_chaos_is_deterministic_and_decorrelated():
    spec = FaultSpec(seed=7, transient_rate=0.3)
    runs = []
    for _ in range(2):
        sim = _symmetric_churn_cluster(spec)
        _, engines = sim._build(sim.workload.assignment)
        results = sim.run_hosts(engines, 8)
        runs.append(({h: r.iteration_times for h, r in results.items()},
                     _fault_patterns(engines)))
    # determinism: bit-identical across repeat runs
    assert runs[0] == runs[1]
    times, patterns = runs[0]
    # decorrelation: identical workloads, same spec — different streams
    assert patterns["h0"] != patterns["h1"]
    assert patterns["h0"] and patterns["h1"]


def test_two_host_chaos_is_scheduling_order_independent():
    spec = FaultSpec(seed=7, transient_rate=0.3)
    seq = _symmetric_churn_cluster(spec).run_local_only(8)
    inter = _symmetric_churn_cluster(spec).run_local_only(8, interleave=True)
    for h in ("h0", "h1"):
        assert seq.host_results[h].iteration_times \
            == inter.host_results[h].iteration_times
        assert seq.host_results[h].phase_trace \
            == inter.host_results[h].phase_trace


def test_fault_events_carry_host_provenance():
    spec = FaultSpec(seed=3, late_fail_rate=0.9)
    sim = _symmetric_churn_cluster(spec)
    _, engines = sim._build(sim.workload.assignment)
    sim.run_hosts(engines, 6)
    for h, eng in engines.items():
        assert eng.runtime.stats()["host"] == h
        for ev in eng.runtime.fault_log:
            assert ev.host == h


# ---------------------------------------------------------------------------
# provenance plumbing
# ---------------------------------------------------------------------------
def test_stage_provenance_host_roundtrip_and_backcompat():
    p = StageProvenance(stage="attribute", policy="unimem",
                        profile_epoch=1, chunk_generation=2, host="h3")
    d = dataclasses.asdict(p)
    assert StageProvenance(**d) == p
    legacy = {k: v for k, v in d.items() if k != "host"}
    assert StageProvenance(**legacy).host == ""   # pre-PR 9 dicts load


def test_plan_program_host_fields_default_empty_on_legacy_json():
    prog = PlanProgram(strategy="global", residents=[], moves=[],
                       predicted_iteration_time=1.0,
                       baseline_iteration_time=2.0)
    d = prog.to_dict()
    for key in ("host", "host_sections", "migrations"):
        d.pop(key)
    back = PlanProgram.from_dict(d)
    assert back.host is None
    assert back.host_sections == {} and back.migrations == []


def test_host_tier_manager_rejects_mistagged_session():
    rt = UnimemRuntime(MACHINE, RuntimeConfig(host="h1"), cf=CF)
    with pytest.raises(ValueError):
        HostTierManager("h0", MACHINE, session=rt)


def test_cluster_rejects_duplicate_hosts():
    mk = lambda h: HostTierManager(h, MACHINE)
    with pytest.raises(ValueError):
        ClusterCoordinator([mk("h0"), mk("h0")])
    with pytest.raises(ValueError):
        ClusterCoordinator([])
