"""Substrate tests: data pipeline, checkpointing, optimizer, compression,
partitioning, mover."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PAPER_DRAM_NVM
from repro.core.data_objects import ObjectRegistry
from repro.core.mover import SimTierBackend
from repro.core.partition import auto_partition, partition_object
from repro.core.phase import build_phase_graph
from repro.data import DataConfig, SyntheticTokenPipeline

MB = 1024 ** 2


# ----------------------------------------------------------------- data
def test_pipeline_deterministic_and_seekable():
    cfg = DataConfig(vocab_size=512, seq_len=64, global_batch=4)
    p1 = SyntheticTokenPipeline(cfg)
    p2 = SyntheticTokenPipeline(cfg)
    b1 = p1.batch_at(7)
    b2 = p2.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = p1.batch_at(8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert int(b1["tokens"].max()) < 512


# ----------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_gc(tmp_path):
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "opt": {"step": jnp.int32(3)}}
    for s in (10, 20, 30):
        mgr.save(s, state, blocking=True)
    assert mgr.list_steps() == [20, 30]      # GC keeps last 2
    step, restored = mgr.restore()
    assert step == 30
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.arange(6.0).reshape(2, 3))
    assert int(restored["opt"]["step"]) == 3


def test_checkpoint_atomic_no_partial(tmp_path):
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"x": jnp.ones((4,))}, blocking=True)
    # a stale tmp dir must never be listed
    os.makedirs(tmp_path / "step_99.tmp", exist_ok=True)
    assert 99 not in mgr.list_steps()


# -------------------------------------------------------------- optimizer
@pytest.mark.parametrize("moments", ["float32", "bfloat16", "int8"])
def test_adamw_converges_quadratic(moments):
    from repro.optim import AdamWConfig, adamw_update, init_opt_state
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, moments_dtype=moments)
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_opt_state(params, cfg)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(150):
        grads = jax.grad(loss)(params)
        params, state, _ = adamw_update(grads, params, state, cfg,
                                        jnp.float32(0.1))
    assert float(loss(params)) < 1e-2


def test_grad_clip_applied():
    from repro.optim import AdamWConfig, adamw_update, init_opt_state
    cfg = AdamWConfig(lr=1e-3, grad_clip=1.0)
    params = {"w": jnp.zeros((4,))}
    state = init_opt_state(params, cfg)
    huge = {"w": jnp.full((4,), 1e6)}
    _, _, metrics = adamw_update(huge, params, state, cfg, jnp.float32(1e-3))
    assert float(metrics["grad_norm"]) > 1.0   # reported pre-clip


# ------------------------------------------------------------ compression
def test_int8_error_feedback_unbiased():
    from repro.distributed.grad_compression import (dequantize_int8,
                                                    quantize_int8)
    x = jax.random.normal(jax.random.PRNGKey(0), (1024,)) * 3.0
    q, s = quantize_int8(x)
    x2 = dequantize_int8(q, s, x.shape)
    # block-wise int8 keeps ~1% relative error on normal data
    assert float(jnp.abs(x - x2).max()) < 0.05
    # error feedback: residual + sent == original
    resid = x - x2
    np.testing.assert_allclose(np.asarray(x2 + resid), np.asarray(x),
                               rtol=1e-6)


# ----------------------------------------------------------- partitioning
def test_partition_object_splits_sizes_and_payload():
    reg = ObjectRegistry()
    arr = jnp.arange(1000, dtype=jnp.float32)
    reg.alloc("big", 4000, chunkable=True, payload=arr)
    chunks = partition_object(reg, "big", 1024)
    assert "big" not in reg
    assert sum(c.size_bytes for c in chunks) == 4000
    total = jnp.concatenate([c.payload for c in chunks])
    np.testing.assert_array_equal(np.asarray(total), np.arange(1000))


def test_auto_partition_only_chunkable_oversize():
    reg = ObjectRegistry()
    reg.alloc("big_chunkable", 100 * MB, chunkable=True)
    reg.alloc("big_rigid", 100 * MB, chunkable=False)
    reg.alloc("small", 1 * MB, chunkable=True)
    graph = build_phase_graph([("p0", {"big_chunkable": 1e6,
                                       "big_rigid": 1e6, "small": 1e6})],
                              times=[0.1])
    done = auto_partition(reg, graph, 10 * MB)
    assert done == ["big_chunkable"]
    assert "big_rigid" in reg and "small" in reg
    # refs rewritten to chunks
    assert not graph[0].references("big_chunkable")
    assert any(o.startswith("big_chunkable#") for o in graph[0].refs)


# ----------------------------------------------------------------- mover
def test_sim_mover_overlap_semantics():
    clock = {"t": 0.0}
    backend = SimTierBackend(PAPER_DRAM_NVM, lambda: clock["t"])
    reg = ObjectRegistry()
    obj = reg.alloc("a", int(PAPER_DRAM_NVM.copy_bw))  # 1 second copy
    h = backend.start_move(obj, "fast")
    assert obj.tier == "fast"
    clock["t"] = 0.5
    assert backend.wait(h) == pytest.approx(0.5)   # half the copy remains
    clock["t"] = 2.0
    assert backend.wait(h) == 0.0                  # fully overlapped
