"""End-to-end Unimem runtime behaviour on simulated workloads — validates
the paper's headline claims on our reproduction."""

import pytest

pytestmark = pytest.mark.slow      # full NPB sweep: nightly tier

from repro.core import PAPER_DRAM_NVM, RuntimeConfig, UnimemRuntime, calibrate
from repro.core.data_objects import ObjectRegistry
from repro.sim import NPB_WORKLOADS, SimulationEngine

MB = 1024 ** 2


def run_three(machine, wl, dram=256 * MB, iters=12):
    reg = ObjectRegistry()
    for n, s in wl.objects.items():
        reg.alloc(n, s, tier="fast")
    dram_only = SimulationEngine(machine, wl, registry=reg).run(iters)
    reg2 = ObjectRegistry()
    for n, s in wl.objects.items():
        reg2.alloc(n, s, tier="slow")
    nvm_only = SimulationEngine(machine, wl, registry=reg2).run(iters)
    rt = UnimemRuntime(machine, RuntimeConfig(fast_capacity_bytes=dram),
                       cf=calibrate(machine))
    for n, s in wl.objects.items():
        rt.alloc(n, size_bytes=s, chunkable=wl.chunkable.get(n, False))
    rt.start_loop([p.name for p in wl.phases],
                  static_refs=wl.static_ref_counts())
    uni = SimulationEngine(machine, wl, runtime=rt).run(iters)
    return dram_only, nvm_only, uni, rt


@pytest.mark.parametrize("wl_name", sorted(NPB_WORKLOADS))
@pytest.mark.parametrize("knob", ["bw", "lat"])
def test_unimem_narrows_gap(wl_name, knob):
    """Unimem must recover most of the NVM gap on every workload
    (paper: <=10% worst case; we assert it beats NVM-only and lands within
    25% of DRAM-only even for the hardest cases)."""
    machine = (PAPER_DRAM_NVM.scaled(bw_scale=0.5) if knob == "bw"
               else PAPER_DRAM_NVM.scaled(lat_scale=4.0))
    wl = NPB_WORKLOADS[wl_name]()
    dram, nvm, uni, _ = run_three(machine, wl)
    d = dram.steady_iteration_time
    assert nvm.steady_iteration_time >= d * 0.999
    assert uni.steady_iteration_time <= nvm.steady_iteration_time * 1.001
    assert uni.steady_iteration_time <= d * 1.25


def test_average_gap_close_to_paper():
    """Average Unimem gap across the suite stays single-digit-ish percent
    (paper: 3% at 1/2 bw, 7% at 4x lat; we allow <=10% avg)."""
    for machine in (PAPER_DRAM_NVM.scaled(bw_scale=0.5),
                    PAPER_DRAM_NVM.scaled(lat_scale=4.0)):
        gaps = []
        for name, make in NPB_WORKLOADS.items():
            dram, _, uni, _ = run_three(machine, make())
            gaps.append(uni.steady_iteration_time
                        / dram.steady_iteration_time - 1)
        assert sum(gaps) / len(gaps) <= 0.10


def test_runtime_overhead_small():
    """Pure runtime cost (planning, no movement) <3% (paper Table 4)."""
    import time
    machine = PAPER_DRAM_NVM.scaled(bw_scale=0.5)
    wl = NPB_WORKLOADS["cg"]()
    rt = UnimemRuntime(machine,
                       RuntimeConfig(fast_capacity_bytes=256 * MB))
    for n, s in wl.objects.items():
        rt.alloc(n, size_bytes=s)
    rt.start_loop([p.name for p in wl.phases],
                  static_refs=wl.static_ref_counts())
    t0 = time.perf_counter()
    SimulationEngine(machine, wl, runtime=rt).run(10)
    wall = time.perf_counter() - t0
    # wall time here is pure runtime bookkeeping (simulated phases are free)
    assert wall < 2.0


def test_variation_triggers_replan():
    """>10% phase-time drift re-activates profiling (paper §3.2)."""
    from repro.core.monitor import VariationMonitor
    mon = VariationMonitor(threshold=0.10, patience=2)
    mon.set_baseline(0, 1.0)
    assert mon.observe(0, 1.05) is None          # within 10%
    assert mon.observe(0, 1.2) is None           # strike 1
    assert mon.observe(0, 1.2) is not None       # strike 2 -> replan


def test_migration_stats_overlap():
    """Migrated data is mostly overlapped (paper Table 4: 60-100%)."""
    machine = PAPER_DRAM_NVM.scaled(bw_scale=0.5)
    wl = NPB_WORKLOADS["nek5000"]()
    _, _, uni, rt = run_three(machine, wl)
    s = rt.stats()
    if s["n_moves"]:
        assert s["overlap_fraction"] >= 0.5
