"""Planner invariants: capacity, dependency-safe triggers, best-of-two."""

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                      # no hypothesis: seeded shim
    from _propcheck import st, given, settings

from repro.core import (CalibrationConstants, PAPER_DRAM_NVM, PhaseProfiler,
                        Planner, build_phase_graph)
from repro.core.data_objects import ObjectRegistry
from repro.core.phase import PhaseTraceEvent

MB = 1024 ** 2
M = PAPER_DRAM_NVM.scaled(bw_scale=0.5)


def build_problem(obj_sizes, phase_refs, times):
    reg = ObjectRegistry()
    for name, size in obj_sizes.items():
        reg.alloc(name, size)
    graph = build_phase_graph([(f"p{i}", refs)
                               for i, refs in enumerate(phase_refs)],
                              times=times)
    profiler = PhaseProfiler(M, seed=0)
    for i, refs in enumerate(phase_refs):
        profiler.observe(PhaseTraceEvent(i, times[i], dict(refs)))
    profiler.annotate_graph(graph)
    return reg, graph, profiler


@given(seed=st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_plans_respect_capacity(seed):
    import random
    rng = random.Random(seed)
    n_obj = rng.randint(1, 8)
    sizes = {f"o{i}": rng.randint(1, 100) * MB for i in range(n_obj)}
    n_ph = rng.randint(1, 6)
    refs = []
    for _ in range(n_ph):
        r = {}
        for o in sizes:
            if rng.random() < 0.5:
                r[o] = rng.uniform(1e4, 1e6)
        refs.append(r)
    times = [rng.uniform(0.01, 0.2) for _ in range(n_ph)]
    cap = rng.randint(50, 200) * MB

    reg, graph, prof = build_problem(sizes, refs, times)
    planner = Planner(M, reg, CalibrationConstants(), cap)
    for plan in (planner.plan_local(graph, prof),
                 planner.plan_global(graph, prof)):
        for residents in plan.residents:
            assert sum(reg[o].size_bytes for o in residents) <= cap
        # moves reference known objects; triggers precede needs
        for m in plan.moves:
            assert m.obj in reg
            assert m.trigger_phase <= m.needed_by


def test_trigger_points_respect_dependencies():
    sizes = {"a": 10 * MB, "b": 10 * MB}
    #       p0 uses a      p1 uses b        p2 uses a
    refs = [{"a": 1e6}, {"b": 1e6}, {"a": 1e6}]
    times = [0.1, 0.1, 0.1]
    reg, graph, prof = build_problem(sizes, refs, times)
    # a needed at p2; last prior use at p0 -> earliest trigger p1
    assert graph.trigger_point("a", 2) == 1
    # overlap window = time of p1
    assert abs(graph.overlap_window("a", 2) - 0.1) < 1e-12


def test_best_of_two_picks_lower_predicted():
    sizes = {"a": 10 * MB, "b": 10 * MB}
    refs = [{"a": 1e7}, {"b": 1e7}]
    times = [0.2, 0.2]
    reg, graph, prof = build_problem(sizes, refs, times)
    planner = Planner(M, reg, CalibrationConstants(), 12 * MB)
    best = planner.plan(graph, prof)
    lo = planner.plan_local(graph, prof)
    gl = planner.plan_global(graph, prof)
    assert best.predicted_iteration_time == min(
        lo.predicted_iteration_time, gl.predicted_iteration_time)


def test_pinned_objects_never_move():
    reg = ObjectRegistry()
    reg.alloc("pinned", 10 * MB, pinned=True)
    reg.alloc("free", 10 * MB)
    graph = build_phase_graph(
        [("p0", {"pinned": 1e7, "free": 1e7})], times=[0.1])
    prof = PhaseProfiler(M, seed=0)
    prof.observe(PhaseTraceEvent(0, 0.1, {"pinned": 1e7, "free": 1e7}))
    prof.annotate_graph(graph)
    planner = Planner(M, reg, CalibrationConstants(), 15 * MB)
    for plan in (planner.plan_local(graph, prof),
                 planner.plan_global(graph, prof)):
        assert all(m.obj != "pinned" for m in plan.moves)
