"""Integration: full training loop with checkpoint/restart and the serving
engine, on reduced configs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.optim import AdamWConfig
from repro.serve.engine import ServeEngine
from repro.train.loop import TrainConfig, train

pytestmark = pytest.mark.slow      # jax-heavy train/serve loop: nightly tier


def test_train_loop_loss_decreases(tmp_path):
    cfg = get_config("yi-6b").reduced()
    tcfg = TrainConfig(steps=30, global_batch=4, seq_len=64, lr=3e-3,
                       checkpoint_dir=str(tmp_path), checkpoint_every=10,
                       log_every=100)
    res = train(cfg, tcfg)
    assert res.losses[-1] < res.losses[0]
    assert np.isfinite(res.losses).all()


def test_checkpoint_restart_resumes(tmp_path):
    """Fault tolerance: kill after N steps, restart, continue to the same
    final state as an uninterrupted run (deterministic data pipeline)."""
    cfg = get_config("gemma-2b").reduced()
    common = dict(global_batch=4, seq_len=32, lr=1e-3, log_every=1000,
                  use_unimem=False)
    # uninterrupted 20 steps
    ref = train(cfg, TrainConfig(steps=20, **common))
    # interrupted at 10 + resume
    t1 = TrainConfig(steps=10, checkpoint_dir=str(tmp_path),
                     checkpoint_every=10, **common)
    train(cfg, t1)
    t2 = TrainConfig(steps=20, checkpoint_dir=str(tmp_path),
                     checkpoint_every=10, **common)
    resumed = train(cfg, t2)
    assert resumed.losses[-1] == pytest.approx(ref.losses[-1], rel=2e-2)


def test_microbatched_equals_full_batch():
    """Gradient accumulation must match the unsplit step (same data)."""
    from repro.optim import init_opt_state
    from repro.train.step import build_train_step
    cfg = get_config("yi-6b").reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = init_opt_state(params, opt_cfg)
    toks = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    p1, _, m1 = jax.jit(build_train_step(cfg, opt_cfg))(params, opt, batch)
    p2, _, m2 = jax.jit(build_train_step(cfg, opt_cfg, microbatches=2))(
        params, opt, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=2e-2)
    l1 = jax.tree_util.tree_leaves(p1)
    l2 = jax.tree_util.tree_leaves(p2)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=3e-2, atol=3e-2)


def test_serve_engine_generates():
    cfg = get_config("xlstm-350m").reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_seq=64, batch=2)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    out = engine.generate(prompts, 8)
    assert out.shape == (2, 16)
    assert int(out.max()) < cfg.vocab_size


def test_elastic_restore_resharding(tmp_path):
    """Checkpoints restore onto a different device layout (elastic)."""
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, state, blocking=True)
    # "new mesh": single device with explicit sharding
    sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    step, restored = mgr.restore(shardings={"w": sh})
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))
