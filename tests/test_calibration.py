"""Calibration feedback: per-class online CF folds, the best-of-measured
plan revert, priced global moves, and the interval-guidance policy.

The behavioral contract under test (PR 6):

* with ``calibrate_feedback`` off (the default) nothing changes — plans are
  bit-identical to the PR 5 pipeline (pinned separately in
  ``test_histogram.py``'s PR4 goldens) and the constants never mutate;
* with feedback on, a kept fold must have *measured* better, and a fold
  trajectory that measures worse is reverted to the epoch's best-measured
  plan — so feedback-on can never end meaningfully worse than feedback-off
  on any scenario (the chooser-honesty property);
* ``plan_global`` emits priced moves (no free global migrations);
* the interval policy is a registered third ablation arm.
"""

import dataclasses

import pytest

from repro.core import (PAPER_DRAM_NVM, RuntimeConfig, UnimemRuntime,
                        calibrate)
from repro.core import perfmodel
from repro.core.data_objects import ObjectRegistry
from repro.core.monitor import DriftEvent
from repro.core.perfmodel import CalibrationConstants
from repro.core.phase import PhaseTraceEvent, build_phase_graph
from repro.core.planner import Planner
from repro.core.policy import available_policies
from repro.core.profiler import PhaseProfiler
from repro.sim import (SCENARIO_WORKLOADS, SKEWED_SCENARIO_WORKLOADS,
                       SimulationEngine)

MB = 1024 ** 2
MACHINE = PAPER_DRAM_NVM.scaled(bw_scale=0.5, lat_scale=2.0)
CF = calibrate(MACHINE)

ALL_SCENARIOS = dict(SCENARIO_WORKLOADS)
ALL_SCENARIOS.update(SKEWED_SCENARIO_WORKLOADS)


def _run(wl, *, iters: int = 12, **cfg_kw):
    rt = UnimemRuntime(
        MACHINE, RuntimeConfig(fast_capacity_bytes=256 * MB,
                               drift_threshold=10.0, **cfg_kw), cf=CF)
    statics = wl.static_ref_counts()
    for n, s in wl.objects.items():
        rt.register(n, s, chunkable=wl.chunkable.get(n, False),
                    static_refs=statics.get(n))
    res = SimulationEngine(MACHINE, wl, runtime=rt).run(iters)
    return res, rt


# ---------------------------------------------------------------------------
# solve_gain_folds: the per-class least-squares identification
# ---------------------------------------------------------------------------
def test_solve_gain_folds_recovers_per_class_multipliers():
    a_true, b_true = 0.9, 0.3     # lat over-credits 3x, bw nearly honest
    rows = [(g_bw, g_lat, a_true * g_bw + b_true * g_lat)
            for g_bw, g_lat in [(0.2, 0.05), (0.1, 0.2), (0.0, 0.15),
                                (0.3, 0.0), (0.12, 0.12)]]
    a, b = perfmodel.solve_gain_folds(rows)
    # ridge pulls toward 1.0, so allow a visible but bounded bias
    assert abs(a - a_true) < 0.1
    assert abs(b - b_true) < 0.1


def test_solve_gain_folds_single_class_pins_only_that_class():
    rows = [(g, 0.0, 0.5 * g) for g in (0.1, 0.2, 0.3)]
    a, b = perfmodel.solve_gain_folds(rows)
    assert abs(a - 0.5) < 0.1
    assert abs(b - 1.0) < 1e-9    # nobody booked lat: the prior holds it


def test_solve_gain_folds_degenerate_is_neutral():
    assert perfmodel.solve_gain_folds([]) == (1.0, 1.0)
    assert perfmodel.solve_gain_folds([(0.0, 0.0, 0.4)]) == (1.0, 1.0)


def test_solve_gain_folds_clips_to_bounds():
    rows = [(0.001, 0.0, 10.0)]   # implies a ~10000x multiplier
    a, _ = perfmodel.solve_gain_folds(rows)
    assert a <= 20.0
    rows = [(10.0, 0.0, -100.0)]  # implies a negative multiplier
    a, _ = perfmodel.solve_gain_folds(rows)
    assert a >= 0.05


# ---------------------------------------------------------------------------
# fold_online: multiplicative, bitwise-neutral at 1.0, audited
# ---------------------------------------------------------------------------
def test_fold_online_neutral_is_the_same_object():
    cf = CalibrationConstants(cf_bw=1.3, cf_lat=0.7, cf_move=0.9)
    assert perfmodel.fold_online(cf) is cf
    assert perfmodel.fold_online(cf, gain_bw=1.0, gain_lat=1.0,
                                 move=1.0) is cf


def test_fold_online_blend_and_provenance():
    cf = CalibrationConstants()
    out = perfmodel.fold_online(cf, gain_lat=0.5, blend=0.5, note="iter3")
    assert out.cf_lat == pytest.approx(0.75)   # halfway toward 0.5
    assert out.cf_bw == 1.0 and out.cf_move == 1.0
    assert len(out.provenance) == 1
    assert out.provenance[0].startswith("online(")
    assert "iter3" in out.provenance[0]


def test_fold_online_clips_cumulative_move_price():
    cf = CalibrationConstants(cf_move=0.1)
    out = perfmodel.fold_online(cf, move=0.01)
    assert out.cf_move == 0.05                 # cumulative floor


# ---------------------------------------------------------------------------
# satellite fixes: drift ratio on zero baseline, audited calibrate fallback
# ---------------------------------------------------------------------------
def test_drift_ratio_neutral_on_zero_baseline():
    assert DriftEvent(0, 0.0, 5.0).ratio == 1.0
    assert DriftEvent(0, -1.0, 5.0).ratio == 1.0
    assert DriftEvent(0, 2.0, 5.0).ratio == pytest.approx(2.5)


def test_cf_ratio_degenerate_denominator_warns_and_audits():
    with pytest.warns(RuntimeWarning, match="degenerate predicted"):
        cf, prov = perfmodel._cf_ratio(1.0, 0.0, "cf_bw")
    assert cf == 1.0
    assert prov.startswith("cf_bw:fallback")


def test_calibrate_provenance_is_measured_on_a_real_machine():
    assert all(p.endswith(":measured") for p in CF.provenance)


# ---------------------------------------------------------------------------
# plan_global emits priced moves
# ---------------------------------------------------------------------------
def test_global_plan_moves_are_priced():
    reg = ObjectRegistry()
    sizes = {f"o{i}": 48 * MB for i in range(6)}
    for n, s in sizes.items():
        reg.alloc(n, s)
    refs = [{f"o{i}": 4e7 for i in range(6)} for _ in range(3)]
    times = [0.004, 0.004, 0.004]    # tiny windows: copies cannot hide
    graph = build_phase_graph(
        [(f"p{i}", r) for i, r in enumerate(refs)], times=times)
    prof = PhaseProfiler(MACHINE, seed=0)
    for i, r in enumerate(refs):
        prof.observe(PhaseTraceEvent(i, times[i], dict(r)))
    prof.annotate_graph(graph)
    planner = Planner(MACHINE, reg, CalibrationConstants(), 100 * MB)
    plan = planner.plan_global(graph, prof)
    assert plan.moves, "expected the global search to migrate something"
    assert any(m.est_unhidden_cost > 0.0 for m in plan.moves)
    # and the chooser sees that cost: predicted is not benefit-only
    benefit_only = plan.baseline_iteration_time - sum(
        m.est_benefit for m in plan.moves)
    assert plan.predicted_iteration_time >= benefit_only - 1e-12


def test_cf_move_scales_movement_price():
    reg = ObjectRegistry()
    reg.alloc("a", 64 * MB)
    cheap = Planner(MACHINE, reg, CalibrationConstants(cf_move=0.5),
                    256 * MB)
    dear = Planner(MACHINE, reg, CalibrationConstants(cf_move=2.0),
                   256 * MB)
    assert dear.price_eviction(64 * MB) == pytest.approx(
        4.0 * cheap.price_eviction(64 * MB))


# ---------------------------------------------------------------------------
# the chooser-honesty property across the scenario matrix
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(ALL_SCENARIOS))
def test_feedback_never_ends_worse(name):
    """Calibration feedback is measurement-guarded: every kept fold
    measured better, every worsening trajectory is reverted to the
    epoch's best-measured plan — so feedback-on steady time can never be
    meaningfully worse than feedback-off, on any scenario."""
    wl = ALL_SCENARIOS[name]
    off, _ = _run(wl())
    on, rt = _run(wl(), calibrate_feedback=True)
    assert (on.steady_iteration_time
            <= off.steady_iteration_time * 1.01), (
        f"{name}: feedback-on {on.steady_iteration_time:.4f} worse than "
        f"feedback-off {off.steady_iteration_time:.4f}")
    # a kept recalibration must leave an audited trail
    if rt.cf is not CF:
        assert any(p.startswith("online") for p in rt.cf.provenance)


def test_feedback_off_never_touches_the_constants():
    _, rt = _run(SCENARIO_WORKLOADS["fsdp_buckets"]())
    assert rt.cf is CF
    assert rt.stats()["n_recalibrations"] == 0


def test_fsdp_feedback_closes_the_lru_gap():
    """The PR's acceptance row: with calibration feedback on, unimem's
    fsdp_buckets steady time is at least LRU-ablation parity (the
    uncalibrated model books latency-class benefits ~14x optimistic and
    movement ~2.4x pessimistic, so it plans essentially no moves)."""
    wl = SCENARIO_WORKLOADS["fsdp_buckets"]
    on, rt = _run(wl(), calibrate_feedback=True)
    lru, _ = _run(wl(), policy="lru")
    assert on.steady_iteration_time <= lru.steady_iteration_time
    assert rt.stats()["n_recalibrations"] >= 1
    # and the kept model is honest about it
    assert rt.last_pred_err is not None and rt.last_pred_err <= 0.2


def test_worsening_fold_is_reverted_to_best_measured_plan():
    """paged_serving's uncalibrated plan predicts ~0 (over-credited) but
    *runs* near-optimal; the feedback's fold makes it measurably worse,
    so the epoch must revert — restoring the best-measured plan, not
    re-solving (a re-solve from the excursion's mutated tier state is a
    placement-lock-in lottery)."""
    wl = SKEWED_SCENARIO_WORKLOADS["paged_serving"]
    off, _ = _run(wl())
    on, rt = _run(wl(), calibrate_feedback=True)
    assert any("online:revert" in p for p in rt.cf.provenance)
    assert on.steady_iteration_time <= off.steady_iteration_time * 1.005
    # tail iterations are bit-identical to the uncalibrated plan's steady
    assert on.iteration_times[-1] == pytest.approx(
        off.iteration_times[-1], rel=1e-6)


# ---------------------------------------------------------------------------
# interval-guidance policy (third ablation arm)
# ---------------------------------------------------------------------------
def test_interval_policy_is_registered():
    assert {"unimem", "lru", "interval"} <= set(available_policies())


@pytest.mark.parametrize("name", ["moe_churn", "kv_serving_skew"])
def test_interval_policy_builds_capacity_safe_priced_plans(name):
    res, rt = _run(ALL_SCENARIOS[name](), policy="interval")
    plan = rt.plan
    assert plan is not None and plan.strategy == "interval"
    for residents in plan.residents:
        assert sum(rt.registry[o].size_bytes
                   for o in residents) <= 256 * MB
    # demand moves are priced at their full boundary copy cost
    assert plan.moves
    for m in plan.moves:
        assert m.est_unhidden_cost == pytest.approx(
            m.size_bytes / MACHINE.copy_bw)
    assert res.steady_iteration_time > 0


def test_interval_decay_knob_changes_the_ranking():
    wl = SCENARIO_WORKLOADS["moe_churn"]
    _, short_mem = _run(wl(), policy="interval", interval_decay=0.05)
    _, long_mem = _run(wl(), policy="interval", interval_decay=0.95)
    p_short, p_long = short_mem.plan, long_mem.plan
    assert (p_short.residents != p_long.residents
            or len(p_short.moves) != len(p_long.moves))
